"""Speculative decoding via n-gram self-speculation: each round drafts 4
tokens from the slot's own history, verifies all of them in one chunked-
prefill pass, and commits the accepted prefix on device — composing with
the multi-step window (sync_every) so one host dispatch covers up to
sync_every * (draft_len + 1) tokens.  Greedy output is byte-identical to
plain decode; the printed stats show the draft acceptance rate.

    PYTHONPATH=src python examples/spec_decode.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main([
        "--arch", "qwen2_1_5b",
        "--reduced",
        "--requests", "12",
        "--slots", "4",
        "--max-new", "24",
        "--prompt-len", "6",
        "--sync-every", "4",
        "--spec-decode", "ngram",
        "--draft-len", "4",
    ])
