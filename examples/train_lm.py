"""End-to-end training driver: train a reduced qwen2-family LM for a few
hundred steps on CPU with checkpointing + injected-failure recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys
import tempfile

from repro.launch.train import main as train_main


def run(steps: int = 200):
    with tempfile.TemporaryDirectory() as d:
        result = train_main([
            "--arch", "qwen2_1_5b",
            "--reduced",
            "--steps", str(steps),
            "--batch", "8",
            "--seq", "64",
            "--lr", "3e-3",
            "--ckpt-dir", d,
            "--ckpt-interval", "50",
            "--failure-prob", "0.005",  # exercise the recovery path
            "--log-every", "20",
        ])
    losses_ok = float(result["last_metrics"]["loss"]) < 6.0
    print("loss decreased from ~ln(V)≈5.5:", "✓" if losses_ok else "✗")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()
    run(args.steps)
