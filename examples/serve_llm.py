"""Serve a small model with batched requests through the continuous-batching
engine (per-slot positions, slot recycling).

    PYTHONPATH=src python examples/serve_llm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main([
        "--arch", "qwen2_1_5b",
        "--reduced",
        "--requests", "12",
        "--slots", "4",
        "--max-new", "12",
        "--prompt-len", "6",
    ])
