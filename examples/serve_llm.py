"""Serve a small model with batched requests through the continuous-batching
engine (per-slot positions, slot recycling), with the device-resident
multi-step decode loop running 8 decode ticks per host dispatch.

    PYTHONPATH=src python examples/serve_llm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main([
        "--arch", "qwen2_1_5b",
        "--reduced",
        "--requests", "12",
        "--slots", "4",
        "--max-new", "12",
        "--prompt-len", "6",
        "--sync-every", "8",
    ])
