"""Quickstart: write a tile-DSL kernel, compile it, run it, inspect the
schedule the compiler derived.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import Schedule, compile as tl_compile
from repro.core import lang as T

# ---------------------------------------------------------------------------
# 1. Dataflow only: a tiled matmul (paper Fig. 16).  No thread binding, no
#    layouts, no pipelining code — those are the compiler's job.
# ---------------------------------------------------------------------------
M = N = K = 512
bM = bN = bK = 128


@T.prim_func
def Matmul(
    A: T.Tensor((M, K), "float32"),
    B: T.Tensor((K, N), "float32"),
    C: T.Tensor((M, N), "float32"),
):
    with T.Kernel(T.ceildiv(N, bN), T.ceildiv(M, bM), threads=128) as (bx, by):
        A_shared = T.alloc_shared((bM, bK), "float32")
        B_shared = T.alloc_shared((bK, bN), "float32")
        C_local = T.alloc_fragment((bM, bN), "float32")
        T.clear(C_local)
        for k in T.Pipelined(T.ceildiv(K, bK), num_stages=2):
            T.copy(A[by * bM, k * bK], A_shared)
            T.copy(B[k * bK, bx * bN], B_shared)
            T.gemm(A_shared, B_shared, C_local)
        T.copy(C_local, C[by * bM, bx * bN])


# ---------------------------------------------------------------------------
# 2. Compile.  interpret=True runs the Pallas kernel body on CPU; on a TPU
#    host the same program compiles to a Mosaic kernel.
# ---------------------------------------------------------------------------
kernel = tl_compile(Matmul, Schedule(interpret=True))

print("grid:", kernel.info.grid)
print("dimension semantics:", kernel.info.dimension_semantics)
print(kernel.info.vmem.summary())
print(kernel.info.inference.summary())
print(
    f"cost model: {kernel.info.cost.flops:.3g} FLOPs, "
    f"{kernel.info.cost.hbm_bytes:.3g} HBM bytes, "
    f"AI = {kernel.info.cost.arithmetic_intensity:.1f} FLOP/B"
)

# ---------------------------------------------------------------------------
# 3. Run and check.
# ---------------------------------------------------------------------------
rng = np.random.default_rng(0)
a = rng.standard_normal((M, K), dtype=np.float32)
b = rng.standard_normal((K, N), dtype=np.float32)
c = np.asarray(kernel(a, b))
assert np.allclose(c, a @ b, atol=1e-3)
print("matmul matches numpy ✓")
