"""Advanced tile-DSL usage: a fused dequantize-GEMM with a custom layout
annotation, a tile-library escape hatch, grid swizzling, and the cost-model
autotuner — the paper's §4 machinery end to end.

    PYTHONPATH=src python examples/custom_kernel.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import Schedule, autotune, compile as tl_compile, grid_configs
from repro.core import lang as T
from repro.kernels import ref

M, N, K = 128, 256, 512


def fused_dequant_gelu_matmul(block_M, block_N, block_K, num_stages=2):
    """C = gelu(A @ dequant(B)^T): weight-only int4 + fused activation."""

    @T.prim_func
    def Fused(
        A: T.Tensor((M, K), "float32"),
        B: T.Tensor((N, K // 2), "int8"),
        C: T.Tensor((N, M), "float32"),
    ):
        with T.Kernel(T.ceildiv(N, block_N), T.ceildiv(M, block_M)) as (bx, by):
            A_s = T.alloc_shared((block_M, block_K), "float32")
            B_s = T.alloc_shared((block_N, block_K // 2), "int8")
            B_q = T.alloc_fragment((block_N, block_K), "float32")
            acc = T.alloc_fragment((block_N, block_M), "float32")
            T.use_swizzle(2)  # rasterize the parallel grid for HBM reuse
            T.clear(acc)
            for k in T.Pipelined(T.ceildiv(K, block_K), num_stages=num_stages):
                T.copy(A[by * block_M, k * block_K], A_s)
                T.copy(B[bx * block_N, k * (block_K // 2)], B_s)
                # vectorized int4 unpack on the VPU (the PTX-conversion analogue)
                for i, j in T.Parallel(block_N, block_K):
                    v = (B_s[i, j // 2] >> ((j % 2) * 4)) & 15
                    B_q[i, j] = T.cast(T.if_then_else(v >= 8, v - 16, v), "float32")
                T.gemm(B_q, A_s, acc, transpose_B=True)
            # tile-library escape hatch: fuse the activation with jnp
            act = T.alloc_fragment((block_N, block_M), "float32")
            T.call_tile_lib(lambda x: 0.5 * x * (1 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3))),
                            act, acc, name="gelu")
            T.copy(act, C[bx * block_N, by * block_M])

    return Fused


# --- autotune over block shapes with the static cost model ------------------
kernel, winner = autotune(
    fused_dequant_gelu_matmul,
    grid_configs(block_M=[64, 128], block_N=[64, 128], block_K=[128, 256]),
    schedule=Schedule(interpret=True),
)
print(f"autotuner picked {winner.config}  (predicted {winner.score*1e6:.1f} us, "
      f"mxu={winner.mxu_util:.0%})")

rng = np.random.default_rng(0)
a = rng.standard_normal((M, K), dtype=np.float32)
bp = rng.integers(-128, 128, size=(N, K // 2)).astype(np.int8)
out = np.asarray(kernel(a, bp))


def gelu(x):
    return 0.5 * x * (1 + np.tanh(0.7978845608 * (x + 0.044715 * x**3)))


expect = gelu(np.asarray(ref.dequant_matmul(a, bp, "int4")).T)
assert np.allclose(out, expect, atol=2e-2), np.abs(out - expect).max()
print("fused dequant+gelu matmul matches oracle ✓")
