"""Regression tests for the top-k / top-p edge cases in serving.sampling.

top_k > V used to wrap the negative sort index (``sorted[:, -top_k]``)
around to a *high* logit, silently truncating the distribution; top_p >=
1.0 pushed the cumulative cutoff index to V and leaned on gather's silent
index clamping.  Both are now clamped explicitly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import sampling


def _logits(rng, b=4, v=8):
    return jnp.asarray(rng.normal(size=(b, v)).astype(np.float32))


class TestTopKClamp:
    @pytest.mark.parametrize("top_k", [8, 9, 100])  # V and > V
    def test_top_k_at_or_above_vocab_keeps_full_distribution(self, rng, top_k):
        logits = _logits(rng, v=8)
        key = jax.random.PRNGKey(0)
        got = sampling.sample(logits, key, temperature=1.0, top_k=top_k)
        want = sampling.sample(logits, key, temperature=1.0, top_k=None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_top_k_one_is_greedy(self, rng):
        logits = _logits(rng)
        key = jax.random.PRNGKey(1)
        got = sampling.sample(logits, key, temperature=1.0, top_k=1)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(jnp.argmax(logits, axis=-1))
        )

    def test_top_k_above_vocab_no_wraparound_truncation(self, rng):
        # Pre-fix, top_k = V + 1 indexed sorted[:, -V-1] == sorted[:, -1]
        # (the max), masking everything below the argmax: categorical then
        # always returned the argmax.  With a flat-ish distribution and
        # many draws, a correct sampler must produce non-argmax tokens.
        logits = jnp.zeros((64, 8), jnp.float32)
        key = jax.random.PRNGKey(2)
        got = np.asarray(sampling.sample(logits, key, temperature=1.0, top_k=9))
        assert len(np.unique(got)) > 1


class TestTopPClamp:
    def test_top_p_one_keeps_full_distribution(self, rng):
        logits = _logits(rng)
        key = jax.random.PRNGKey(3)
        got = sampling.sample(logits, key, temperature=1.0, top_p=1.0)
        want = sampling.sample(logits, key, temperature=1.0, top_p=None)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_top_p_one_cutoff_is_min_logit(self, rng):
        # At top_p = 1.0 the clamped cutoff index is V - 1: the cutoff is
        # the smallest logit and nothing is masked.  Verify via the fused
        # step too (jit'd path used by the engine).
        logits = _logits(rng)
        key = jax.random.PRNGKey(4)
        step = jax.jit(
            lambda lg, k: sampling.sample_step(lg, k, temperature=0.7, top_p=1.0)
        )
        tok, new_key = step(logits, key)
        assert tok.shape == (logits.shape[0],)
        assert not np.array_equal(np.asarray(new_key), np.asarray(key))

    def test_top_p_small_masks_tail(self, rng):
        # A tiny top_p keeps only the argmax head.
        logits = jnp.asarray(
            np.array([[10.0, 0.0, 0.0, 0.0]], np.float32).repeat(16, axis=0)
        )
        key = jax.random.PRNGKey(5)
        got = np.asarray(sampling.sample(logits, key, temperature=1.0, top_p=0.1))
        np.testing.assert_array_equal(got, np.zeros(16, np.int32))


class TestPoisonedRowGuard:
    """An all--inf or all-NaN logits row (fully masked distribution, or
    numerical corruption upstream) must never yield a garbage token id:
    the guard falls back to argmax semantics where NaN counts as -inf, so
    a fully poisoned row deterministically emits id 0 — always a valid
    vocab index — and the serving engine separately fails the request."""

    @pytest.mark.parametrize("fill", [-np.inf, np.nan])
    @pytest.mark.parametrize("temperature", [0.0, 1.0])
    def test_fully_poisoned_row_emits_id_zero(self, fill, temperature):
        logits = jnp.full((2, 8), fill, dtype=jnp.float32)
        got = np.asarray(sampling.sample(logits, jax.random.PRNGKey(0),
                                         temperature=temperature))
        assert got.dtype == np.int32
        np.testing.assert_array_equal(got, [0, 0])

    @pytest.mark.parametrize("temperature", [0.0, 1.0])
    def test_mixed_batch_leaves_healthy_rows_alone(self, rng, temperature):
        healthy = _logits(rng, b=3, v=8)
        poisoned = healthy.at[1].set(jnp.nan)
        key = jax.random.PRNGKey(2)
        got = np.asarray(sampling.sample(poisoned, key,
                                         temperature=temperature))
        want = np.asarray(sampling.sample(healthy, key,
                                          temperature=temperature))
        assert got[1] == 0  # NaN row guarded
        assert (0 <= got).all() and (got < 8).all()
        if temperature == 0.0:  # greedy: rows are independent
            np.testing.assert_array_equal(got[[0, 2]], want[[0, 2]])

    def test_guard_survives_top_k_top_p_masking(self, rng):
        # top_p/top_k can mask a row down to nothing only via poisoned
        # input; either way categorical's softmax sees all -inf -> NaN
        logits = _logits(rng, b=2, v=8).at[0].set(-jnp.inf)
        got = np.asarray(sampling.sample(
            logits, jax.random.PRNGKey(3), temperature=0.7, top_k=4,
            top_p=0.9))
        assert got[0] == 0
        assert 0 <= got[1] < 8

    def test_sample_step_greedy_guards_too(self):
        logits = jnp.full((1, 8), jnp.nan, dtype=jnp.float32)
        tok, key = sampling.sample_step(logits, jax.random.PRNGKey(4))
        assert int(tok[0]) == 0
