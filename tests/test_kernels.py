"""Per-kernel validation: Pallas lowering (interpret mode) vs ref.py oracle,
swept over shapes and dtypes; plus reference-backend cross-checks."""
import numpy as np
import pytest

from repro.core import Schedule, compile as tl_compile
from repro.kernels import (
    chunk_scan_program,
    chunk_state_program,
    dequant_matmul_program,
    flash_attention_program,
    matmul_program,
    mla_program,
    ops,
    ref,
)

ATOL = {"float32": 2e-3, "bfloat16": 8e-2, "float16": 2e-2}


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape, dtype=np.float32)
    return np.asarray(x, dtype=np.dtype(dtype) if dtype != "bfloat16" else np.float32)


# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


class TestMatmul:
    @pytest.mark.parametrize(
        "M,N,K,bm,bn,bk",
        [
            (128, 128, 128, 64, 64, 64),
            (256, 128, 64, 64, 32, 32),
            (64, 256, 128, 32, 128, 64),
            (128, 128, 512, 128, 128, 128),
        ],
    )
    def test_shapes_f32(self, rng, M, N, K, bm, bn, bk):
        prog = matmul_program(M, N, K, block_M=bm, block_N=bn, block_K=bk)
        kern = tl_compile(prog, Schedule(interpret=True))
        a = rng.standard_normal((M, K), dtype=np.float32)
        b = rng.standard_normal((K, N), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(kern(a, b)), a @ b, atol=2e-3)

    @pytest.mark.parametrize("dtype", ["bfloat16", "float32"])
    def test_dtypes(self, rng, dtype):
        import jax.numpy as jnp

        M = N = K = 128
        prog = matmul_program(M, N, K, in_dtype=dtype, out_dtype="float32",
                              block_M=64, block_N=64, block_K=64)
        kern = tl_compile(prog, Schedule(interpret=True))
        a = jnp.asarray(rng.standard_normal((M, K), dtype=np.float32), jnp.dtype(dtype))
        b = jnp.asarray(rng.standard_normal((K, N), dtype=np.float32), jnp.dtype(dtype))
        expect = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
        np.testing.assert_allclose(np.asarray(kern(a, b)), expect, atol=ATOL[dtype] * K / 64)

    def test_pallas_matches_reference_backend(self, rng):
        prog = matmul_program(128, 128, 128, block_M=64, block_N=64, block_K=64)
        pk = tl_compile(prog, Schedule(interpret=True))
        rk = tl_compile(prog, backend="reference")
        a = rng.standard_normal((128, 128), dtype=np.float32)
        b = rng.standard_normal((128, 128), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(pk(a, b)), np.asarray(rk(a, b)), atol=1e-4)

    def test_ops_wrapper_xla_vs_pallas(self, rng):
        a = rng.standard_normal((128, 64), dtype=np.float32)
        b = rng.standard_normal((64, 128), dtype=np.float32)
        x = ops.matmul(a, b, backend="xla")
        p = ops.matmul(a, b, backend="pallas")
        np.testing.assert_allclose(np.asarray(x), np.asarray(p), atol=2e-3)


# ---------------------------------------------------------------------------
# FlashAttention
# ---------------------------------------------------------------------------


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [False, True])
    @pytest.mark.parametrize(
        "B,Hq,Hkv,Sq,Sk,D,bm,bn",
        [
            (1, 2, 2, 64, 64, 32, 32, 32),   # MHA
            (2, 4, 2, 64, 128, 32, 32, 64),  # GQA 2:1
            (1, 8, 1, 32, 96, 64, 32, 32),   # MQA
        ],
    )
    def test_against_oracle(self, rng, causal, B, Hq, Hkv, Sq, Sk, D, bm, bn):
        prog = flash_attention_program(B, Hq, Hkv, Sq, Sk, D, causal, bm, bn)
        kern = tl_compile(prog, Schedule(interpret=True))
        q = rng.standard_normal((B, Hq, Sq, D), dtype=np.float32)
        k = rng.standard_normal((B, Hkv, Sk, D), dtype=np.float32)
        v = rng.standard_normal((B, Hkv, Sk, D), dtype=np.float32)
        out = np.asarray(kern(q, k, v))
        expect = np.asarray(ref.attention(q, k, v, causal=causal))
        np.testing.assert_allclose(out, expect, atol=2e-3)
        assert not np.any(np.isnan(out))

    def test_single_kv_block(self, rng):
        prog = flash_attention_program(1, 1, 1, 32, 32, 32, False, 32, 32)
        kern = tl_compile(prog, Schedule(interpret=True))
        q = rng.standard_normal((1, 1, 32, 32), dtype=np.float32)
        k = rng.standard_normal((1, 1, 32, 32), dtype=np.float32)
        v = rng.standard_normal((1, 1, 32, 32), dtype=np.float32)
        np.testing.assert_allclose(
            np.asarray(kern(q, k, v)),
            np.asarray(ref.attention(q, k, v)),
            atol=2e-3,
        )


# ---------------------------------------------------------------------------
# MLA (paper Fig. 18)
# ---------------------------------------------------------------------------


class TestMLA:
    @pytest.mark.parametrize(
        "B,H,Hkv,S,D,Pe,bn,bh",
        [
            (1, 16, 1, 128, 64, 16, 32, 16),
            (2, 8, 1, 64, 32, 8, 32, 8),
            (1, 32, 2, 128, 64, 32, 64, 16),
        ],
    )
    def test_against_oracle(self, rng, B, H, Hkv, S, D, Pe, bn, bh):
        prog = mla_program(B, H, Hkv, S, D, Pe, bn, bh)
        kern = tl_compile(prog, Schedule(interpret=True))
        q = rng.standard_normal((B, H, D), dtype=np.float32)
        qpe = rng.standard_normal((B, H, Pe), dtype=np.float32)
        kv = rng.standard_normal((B, S, Hkv, D), dtype=np.float32)
        kpe = rng.standard_normal((B, S, Hkv, Pe), dtype=np.float32)
        out = np.asarray(kern(q, qpe, kv, kpe))
        expect = np.asarray(ref.mla(q, qpe, kv, kpe))
        np.testing.assert_allclose(out, expect, atol=2e-3)

    def test_loc_budget(self):
        """Paper headline: MLA in ~70 lines of Python."""
        prog = mla_program(1, 16, 1, 128, 64, 16, 32, 16)
        assert prog.source_lines <= 80


# ---------------------------------------------------------------------------
# Dequant GEMM
# ---------------------------------------------------------------------------


class TestDequantMatmul:
    @pytest.mark.parametrize("fmt", ["int4", "int2", "nf4", "int8"])
    def test_formats(self, rng, fmt):
        M, N, K = 32, 64, 128
        pack = {"int4": 2, "int2": 4, "nf4": 2, "int8": 1}[fmt]
        prog = dequant_matmul_program(
            M, N, K, fmt, block_M=16, block_N=16, block_K=32
        )
        kern = tl_compile(prog, Schedule(interpret=True))
        a = rng.standard_normal((M, K), dtype=np.float32)
        bp = rng.integers(-128, 128, size=(N, K // pack)).astype(np.int8)
        out = np.asarray(kern(a, bp))  # (N, M) transposed layout
        expect = np.asarray(ref.dequant_matmul(a, bp, fmt)).T
        np.testing.assert_allclose(out, expect, atol=2e-2)

    def test_with_scales(self, rng):
        M, N, K, bk = 32, 32, 128, 32
        prog = dequant_matmul_program(
            M, N, K, "int4", block_M=16, block_N=16, block_K=bk, with_scales=True
        )
        kern = tl_compile(prog, Schedule(interpret=True))
        a = rng.standard_normal((M, K), dtype=np.float32)
        bp = rng.integers(-128, 128, size=(N, K // 2)).astype(np.int8)
        sc = (rng.standard_normal((N, K // bk), dtype=np.float32) * 0.1).astype(np.float32)
        out = np.asarray(kern(a, bp, sc))
        expect = np.asarray(ref.dequant_matmul(a, bp, "int4", sc, bk)).T
        np.testing.assert_allclose(out, expect, atol=2e-3)

    def test_odd_k_blocks_accepted(self, rng):
        # K=48, block_K=16, pack=2: three K-blocks.  The old guard rejected
        # K % (block_K * pack) != 0 even though block_K already divides K.
        M, N, K = 16, 16, 48
        prog = dequant_matmul_program(
            M, N, K, "int4", block_M=16, block_N=16, block_K=16
        )
        kern = tl_compile(prog, Schedule(interpret=True))
        a = rng.standard_normal((M, K), dtype=np.float32)
        bp = rng.integers(-128, 128, size=(N, K // 2)).astype(np.int8)
        out = np.asarray(kern(a, bp))
        expect = np.asarray(ref.dequant_matmul(a, bp, "int4")).T
        np.testing.assert_allclose(out, expect, atol=2e-2)

    def test_block_k_must_cover_pack(self):
        # The real packing constraint: a block must hold whole packed bytes.
        with pytest.raises(ValueError, match="pack factor"):
            dequant_matmul_program(16, 16, 32, "int2", block_M=16, block_N=16,
                                   block_K=2)

    def test_k_must_divide_blocks(self):
        with pytest.raises(ValueError, match="divide problem shape"):
            dequant_matmul_program(16, 16, 40, "int4", block_M=16, block_N=16,
                                   block_K=16)


# ---------------------------------------------------------------------------
# Quantized KV cache (dequant KV source): ops-level pallas vs xla, which
# pins both the DequantStage kernels against the ref oracles and the
# in-out page/scale ordering of the prefill writes.
# ---------------------------------------------------------------------------


class TestQuantKV:
    @pytest.mark.parametrize("fmt", ["int8", "int4"])
    def test_paged_decode(self, rng, fmt):
        from repro.kernels.ref import KV_PACK

        slots, heads, hkv, d, ps, mp, np_ = 3, 4, 2, 16, 16, 2, 8
        pack = KV_PACK[fmt]
        tables = rng.permutation(np_)[: slots * mp].reshape(slots, mp).astype(np.int32)
        lens = rng.integers(1, mp * ps + 1, size=slots).astype(np.int32)
        q = rng.standard_normal((slots, heads, d), dtype=np.float32)
        kf = rng.standard_normal((hkv, np_, ps, d), dtype=np.float32)
        vf = rng.standard_normal((hkv, np_, ps, d), dtype=np.float32)
        kp, ks = ref.quantize_rows(kf, fmt)
        vp, vs = ref.quantize_rows(vf, fmt)
        x = ops.paged_attention_quant(q, kp, vp, ks, vs, tables, lens,
                                      fmt=fmt, backend="xla")
        p = ops.paged_attention_quant(q, kp, vp, ks, vs, tables, lens,
                                      fmt=fmt, backend="pallas")
        np.testing.assert_allclose(np.asarray(p), np.asarray(x), atol=2e-3)
        # and the quantized cache stays close to the fp attention
        full = np.asarray(
            ref.paged_attention(q, kf, vf, tables, lens)
        )
        atol = 0.05 if fmt == "int8" else 0.35
        np.testing.assert_allclose(np.asarray(x), full, atol=atol)

    @staticmethod
    def _live_rows(pool, tables, starts, lens, page_size):
        """Pool rows at live token positions (page axis at ndim-3).

        Dead-tail rows of a partially-live page and the reserved garbage
        page 0 legitimately differ between the kernel path (writes whole
        pages) and the XLA masked scatter (redirects dead rows to page 0)
        — same split as the fp twins — so equivalence is asserted on what
        the serving engine can ever read back: live positions only.
        """
        pool = np.moveaxis(np.asarray(pool), pool.ndim - 3, 0)
        rows = []
        for z in range(tables.shape[0]):
            for pos in range(int(starts[z]), int(starts[z] + lens[z])):
                rows.append(pool[tables[z, pos // page_size], ..., pos % page_size, :])
        return np.stack(rows)

    @pytest.mark.parametrize("fmt", ["int8", "int4"])
    def test_prefill(self, rng, fmt):
        slots, heads, hkv, d, chunk, ps, mp, np_ = 2, 4, 2, 16, 32, 16, 4, 9
        cpp = chunk // ps
        # page 0 is the engine's reserved garbage page — never owned
        tables = (rng.permutation(np_ - 1)[: slots * mp] + 1).reshape(
            slots, mp
        ).astype(np.int32)
        starts = (rng.integers(0, mp - cpp + 1, size=slots) * ps).astype(np.int32)
        lens = rng.integers(chunk - ps + 1, chunk + 1, size=slots).astype(np.int32)
        q = rng.standard_normal((slots, heads, chunk, d), dtype=np.float32)
        k_new = rng.standard_normal((slots, hkv, chunk, d), dtype=np.float32)
        v_new = rng.standard_normal((slots, hkv, chunk, d), dtype=np.float32)
        kprior = rng.standard_normal((hkv, np_, ps, d), dtype=np.float32)
        vprior = rng.standard_normal((hkv, np_, ps, d), dtype=np.float32)
        kp, ks = ref.quantize_rows(kprior, fmt)
        vp, vs = ref.quantize_rows(vprior, fmt)
        outs = {}
        for be in ("xla", "pallas"):
            outs[be] = ops.prefill_attention_quant(
                q, k_new, v_new, kp, vp, ks, vs, tables, starts, lens,
                fmt=fmt, backend=be,
            )
        np.testing.assert_allclose(
            np.asarray(outs["pallas"][0]), np.asarray(outs["xla"][0]), atol=2e-3
        )
        ends = starts + lens
        for i in range(1, 5):
            a = self._live_rows(outs["xla"][i], tables, starts * 0, ends, ps)
            b = self._live_rows(outs["pallas"][i], tables, starts * 0, ends, ps)
            np.testing.assert_allclose(
                b.astype(np.float32), a.astype(np.float32), atol=1e-6
            )

    @pytest.mark.parametrize("fmt", ["int8", "int4"])
    def test_mla_paged_decode(self, rng, fmt):
        slots, heads, r, pe, ps, mp, np_ = 3, 4, 16, 8, 16, 2, 8
        tables = (rng.permutation(np_ - 1)[: slots * mp] + 1).reshape(
            slots, mp
        ).astype(np.int32)
        lens = rng.integers(1, mp * ps + 1, size=slots).astype(np.int32)
        q_lat = rng.standard_normal((slots, heads, r), dtype=np.float32)
        q_pe = rng.standard_normal((slots, heads, pe), dtype=np.float32)
        ckvf = rng.standard_normal((np_, ps, r), dtype=np.float32)
        kpef = rng.standard_normal((np_, ps, pe), dtype=np.float32)
        cp, cs = ref.quantize_rows(ckvf, fmt)
        pp, pss = ref.quantize_rows(kpef, fmt)
        x = ops.mla_paged_quant(q_lat, q_pe, cp, pp, cs, pss, tables, lens,
                                fmt=fmt, backend="xla", block_h=2)
        p = ops.mla_paged_quant(q_lat, q_pe, cp, pp, cs, pss, tables, lens,
                                fmt=fmt, backend="pallas", block_h=2)
        np.testing.assert_allclose(np.asarray(p), np.asarray(x), atol=2e-3)

    @pytest.mark.parametrize("fmt", ["int8", "int4"])
    def test_mla_prefill(self, rng, fmt):
        slots, heads, r, pe, chunk, ps, mp, np_ = 2, 2, 16, 8, 32, 16, 4, 10
        cpp = chunk // ps
        tables = (rng.permutation(np_ - 1)[: slots * mp] + 1).reshape(
            slots, mp
        ).astype(np.int32)
        starts = (rng.integers(0, mp - cpp + 1, size=slots) * ps).astype(np.int32)
        lens = rng.integers(chunk - ps + 1, chunk + 1, size=slots).astype(np.int32)
        q_lat = rng.standard_normal((slots, heads, chunk, r), dtype=np.float32)
        q_pe = rng.standard_normal((slots, heads, chunk, pe), dtype=np.float32)
        ckv_new = rng.standard_normal((slots, chunk, r), dtype=np.float32)
        kpe_new = rng.standard_normal((slots, chunk, pe), dtype=np.float32)
        ckvf = rng.standard_normal((np_, ps, r), dtype=np.float32)
        kpef = rng.standard_normal((np_, ps, pe), dtype=np.float32)
        cp, cs = ref.quantize_rows(ckvf, fmt)
        pp, pss = ref.quantize_rows(kpef, fmt)
        outs = {}
        for be in ("xla", "pallas"):
            outs[be] = ops.mla_prefill_quant(
                q_lat, q_pe, ckv_new, kpe_new, cp, pp, cs, pss, tables,
                starts, lens, fmt=fmt, backend=be,
            )
        np.testing.assert_allclose(
            np.asarray(outs["pallas"][0]), np.asarray(outs["xla"][0]), atol=2e-3
        )
        ends = starts + lens
        for i in range(1, 5):
            a = self._live_rows(outs["xla"][i], tables, starts * 0, ends, ps)
            b = self._live_rows(outs["pallas"][i], tables, starts * 0, ends, ps)
            np.testing.assert_allclose(
                b.astype(np.float32), a.astype(np.float32), atol=1e-6
            )

    @pytest.mark.parametrize("fmt", ["int8", "int4"])
    def test_quantize_roundtrip(self, rng, fmt):
        x = rng.standard_normal((5, 7, 16), dtype=np.float32)
        packed, scales = ref.quantize_rows(x, fmt)
        back = np.asarray(ref.dequantize_rows(packed, scales, fmt))
        qmax = ref.KV_QMAX[fmt]
        # symmetric per-row quantization: error bounded by scale/2 per entry
        bound = np.asarray(scales) / 2 + 1e-7
        assert np.all(np.abs(back - x) <= bound)
        # packed size really shrinks by the pack factor
        assert packed.shape[-1] == x.shape[-1] // ref.KV_PACK[fmt]
        # all-zero rows survive exactly
        z = np.zeros((2, 16), np.float32)
        zp, zs = ref.quantize_rows(z, fmt)
        np.testing.assert_array_equal(np.asarray(ref.dequantize_rows(zp, zs, fmt)), z)


# ---------------------------------------------------------------------------
# Mamba-2 SSD chunk kernels
# ---------------------------------------------------------------------------


class TestLinearAttention:
    @pytest.mark.parametrize("L,N,P", [(32, 16, 32), (64, 32, 64)])
    def test_chunk_state(self, rng, L, N, P):
        B, C = 2, 4
        prog = chunk_state_program(B, C, L, N, P)
        kern = tl_compile(prog, Schedule(interpret=True))
        bm = rng.standard_normal((B, C, L, N), dtype=np.float32)
        x = rng.standard_normal((B, C, L, P), dtype=np.float32)
        da = np.cumsum(
            np.abs(rng.standard_normal((B, C, L), dtype=np.float32)) * 0.1, axis=-1
        ).astype(np.float32)
        out = np.asarray(kern(bm, x, da))
        expect = np.asarray(ref.chunk_state(bm, x, da))
        np.testing.assert_allclose(out, expect, atol=2e-3)

    @pytest.mark.parametrize("L,N,P", [(32, 16, 32), (64, 32, 64)])
    def test_chunk_scan(self, rng, L, N, P):
        B, C = 2, 3
        prog = chunk_scan_program(B, C, L, N, P)
        kern = tl_compile(prog, Schedule(interpret=True))
        c = rng.standard_normal((B, C, L, N), dtype=np.float32)
        bm = rng.standard_normal((B, C, L, N), dtype=np.float32)
        x = rng.standard_normal((B, C, L, P), dtype=np.float32)
        da = np.cumsum(
            np.abs(rng.standard_normal((B, C, L), dtype=np.float32)) * 0.1, axis=-1
        ).astype(np.float32)
        prev = rng.standard_normal((B, C, N, P), dtype=np.float32)
        out = np.asarray(kern(c, bm, x, da, prev))
        expect = np.asarray(ref.chunk_scan(c, bm, x, da, prev))
        np.testing.assert_allclose(out, expect, atol=2e-3)

    def test_full_ssd_composition(self, rng):
        Bz, S, N, P, chunk = 2, 128, 16, 32, 32
        c = rng.standard_normal((Bz, S, N), dtype=np.float32)
        bm = rng.standard_normal((Bz, S, N), dtype=np.float32)
        x = rng.standard_normal((Bz, S, P), dtype=np.float32)
        dt = np.abs(rng.standard_normal((Bz, S), dtype=np.float32)) * 0.1
        yp = ops.ssd(c, bm, x, dt, np.float32(0.5), chunk=chunk, backend="pallas")
        yr = ref.ssd(c, bm, x, dt, np.float32(0.5), chunk=chunk)
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yr), atol=2e-3)

    def test_ssd_matches_naive_recurrence(self, rng):
        """The chunked SSD must equal the naive per-step SSM recurrence."""
        Bz, S, N, P, chunk = 1, 64, 8, 16, 16
        c = rng.standard_normal((Bz, S, N), dtype=np.float32) * 0.5
        bm = rng.standard_normal((Bz, S, N), dtype=np.float32) * 0.5
        x = rng.standard_normal((Bz, S, P), dtype=np.float32)
        dt = np.abs(rng.standard_normal((Bz, S), dtype=np.float32)) * 0.1
        a_log = np.float32(0.3)
        y = np.asarray(ref.ssd(c, bm, x, dt, a_log, chunk=chunk))
        # naive: h_t = exp(dA_t) h_{t-1} + B_t^T x_t ; y_t = C_t h_t
        da = dt * (-np.exp(a_log))
        h = np.zeros((Bz, N, P), np.float32)
        for t in range(S):
            h = np.exp(da[:, t])[:, None, None] * h + np.einsum(
                "bn,bp->bnp", bm[:, t], x[:, t]
            )
            np.testing.assert_allclose(
                y[:, t], np.einsum("bn,bnp->bp", c[:, t], h), atol=2e-2
            )
