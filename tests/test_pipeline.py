"""Tests for the pass-based lowering pipeline and the backend registry.

Two halves:

* unit tests for each pass over a hand-built ``TileProgram`` — every pass
  is a plain function over the :class:`LoweredModule` artifact, so they can
  be run (and asserted on) individually;
* the backend-parity suite: every kernel in ``repro.kernels`` compiled with
  both ``target="pallas"`` (interpret mode) and ``target="reference"`` on
  tiny shapes must agree numerically.
"""
import numpy as np
import pytest

from repro.core import (
    LoweringError,
    Schedule,
    TileProgram,
    analyze,
    available_backends,
    compile as tl_compile,
    get_backend,
    program_fingerprint,
    register_backend,
)
from repro.core import lang as T
from repro.core.lowering import (
    LOOP,
    PRE,
    POST,
    LoweredModule,
    PIPELINE,
    run_pipeline,
    schedule_key,
)
from repro.core.lowering.pipeline import (
    pass_collect_windows,
    pass_estimate_cost,
    pass_plan_grid,
    pass_plan_params,
    pass_plan_stages,
    pass_plan_vmem,
    pass_split_phases,
)
from repro.kernels import parity_inputs, parity_programs


def small_gemm_program(bm=16, bn=16, bk=16, kext=2):
    """Hand-built pipelined GEMM used by the per-pass unit tests."""
    M, N, K = 2 * bm, 2 * bn, kext * bk

    @T.prim_func
    def SmallGemm(
        A: T.Tensor((M, K), "float32"),
        B: T.Tensor((K, N), "float32"),
        C: T.Tensor((M, N), "float32"),
    ):
        with T.Kernel(N // bn, M // bm) as (bx, by):
            A_s = T.alloc_shared((bm, bk))
            B_s = T.alloc_shared((bk, bn))
            C_l = T.alloc_fragment((bm, bn))
            T.clear(C_l)
            for k in T.Pipelined(kext, num_stages=2):
                T.copy(A[by * bm, k * bk], A_s)
                T.copy(B[k * bk, bx * bn], B_s)
                T.gemm(A_s, B_s, C_l)
            T.copy(C_l, C[by * bm, bx * bn])

    return SmallGemm


# ---------------------------------------------------------------------------
# Per-pass unit tests
# ---------------------------------------------------------------------------


class TestPasses:
    def _module(self, *passes, schedule=None):
        m = LoweredModule(small_gemm_program(), schedule or Schedule())
        for p in passes:
            p(m)
        return m

    def test_split_phases(self):
        m = self._module(pass_split_phases)
        assert len(m.phases.pre) == 1  # the clear
        assert m.phases.pipeline is not None and m.phases.pipeline.extent == 2
        assert len(m.phases.post) == 1  # the store copy

    def test_collect_windows(self):
        m = self._module(pass_split_phases, pass_collect_windows)
        assert len(m.in_windows) == 2 and len(m.out_windows) == 1
        assert all(w.phase == LOOP for w in m.in_windows)
        assert m.out_windows[0].phase == POST
        assert set(m.fed_by) == {w.onchip.name for w in m.in_windows}

    def test_plan_grid_orders_axes(self):
        m = self._module(pass_split_phases, pass_collect_windows, pass_plan_grid)
        # (by, bx) reversed + the pipelined axis innermost
        assert m.grid == (2, 2, 2)
        assert m.grid_plan.dimension_semantics == ("parallel", "parallel", "arbitrary")
        assert m.grid_plan.kdim == 2
        env = m.grid_plan.env_builder(1, 0, 1)
        assert env["bx"] == 0 and env["by"] == 1

    def test_plan_stages_schedule_override(self):
        m = self._module(pass_split_phases, pass_plan_stages)
        assert m.num_stages == 2  # from T.Pipelined
        m2 = self._module(
            pass_split_phases, pass_plan_stages, schedule=Schedule(num_stages=3)
        )
        assert m2.num_stages == 3

    def test_plan_vmem_multibuffers_loop_windows(self):
        m = self._module(
            pass_split_phases,
            pass_collect_windows,
            pass_plan_stages,
            pass_plan_vmem,
        )
        copies = {b.name: b.copies for b in m.vmem.buffers}
        for w in m.in_windows:
            assert copies[w.onchip.name] == 2  # double-buffered
        # the accumulator is single-copy scratch
        frag = [b for b in m.vmem.buffers if b.scope == "fragment"]
        assert frag and all(b.copies == 1 for b in frag)

    def test_plan_params(self):
        m = self._module(
            pass_split_phases, pass_collect_windows, pass_plan_params
        )
        assert [p.name for p in m.arg_params] == ["A", "B"]
        assert [p.name for p in m.out_params] == ["C"]
        assert m.window_param_idx == [0, 1]
        # the fragment accumulator is scratch (not window-backed)
        assert [b.name for b in m.scratch_bufs] == [m.phases.pre[0].buffer.name]

    def test_estimate_cost(self):
        m = self._module(
            pass_split_phases,
            pass_collect_windows,
            pass_plan_grid,
            pass_plan_stages,
            pass_plan_vmem,
            pass_plan_params,
            pass_estimate_cost,
        )
        # 2*M*N*K flops for the full problem
        assert m.cost.flops == 2 * 32 * 32 * 32
        assert m.cost.hbm_bytes > 0
        assert m.cost.grid == (2, 2, 2)

    def test_run_pipeline_fills_everything(self):
        m = run_pipeline(small_gemm_program(), Schedule())
        for field in ("phases", "inference", "grid_plan", "vmem", "cost"):
            assert getattr(m, field) is not None, field
        assert PIPELINE[0][0] == "split_phases" and PIPELINE[-1][0] == "estimate_cost"


class TestFingerprintAndCache:
    def test_fingerprint_stable_across_retrace(self):
        assert program_fingerprint(small_gemm_program()) == program_fingerprint(
            small_gemm_program()
        )

    def test_fingerprint_distinguishes_structure(self):
        assert program_fingerprint(small_gemm_program(bk=16)) != program_fingerprint(
            small_gemm_program(bk=8, kext=4)
        )

    def test_schedule_key_excludes_notes(self):
        a, b = Schedule(), Schedule()
        b.notes["advisory"] = 1
        assert schedule_key(a) == schedule_key(b)
        assert schedule_key(Schedule(num_stages=3)) != schedule_key(a)

    def test_analysis_cache_shared_across_retrace(self):
        sched = Schedule(interpret=True)
        assert analyze(small_gemm_program(), sched) is analyze(
            small_gemm_program(), sched
        )

    def test_compile_cache_returns_same_kernel(self):
        sched = Schedule(interpret=True)
        k1 = tl_compile(small_gemm_program(), sched)
        k2 = tl_compile(small_gemm_program(), sched)
        assert k1 is k2
        # a different target is a different cache entry
        k3 = tl_compile(small_gemm_program(), sched, target="reference")
        assert k3 is not k1 and k3.backend == "reference"


class TestRegistry:
    def test_builtins_registered(self):
        assert {"pallas", "reference"} <= set(available_backends())

    def test_aliases(self):
        assert get_backend("ref") is get_backend("reference")
        assert get_backend("pallas_tpu") is get_backend("pallas")

    def test_unknown_backend_raises(self):
        with pytest.raises(LoweringError, match="Unknown backend"):
            tl_compile(small_gemm_program(), target="cuda")

    def test_register_third_party_backend(self):
        calls = {}

        @register_backend("_test_counting")
        def emit(module):
            calls["module"] = module
            return get_backend("reference")(module)

        try:
            kern = tl_compile(small_gemm_program(), target="_test_counting")
            assert calls["module"].program is kern.program
            a = np.ones((32, 32), np.float32)
            np.testing.assert_allclose(np.asarray(kern(a, a)), a @ a, rtol=1e-5)
        finally:
            from repro.core.backends import _REGISTRY

            _REGISTRY.pop("_test_counting", None)


# ---------------------------------------------------------------------------
# Backend parity: every kernel, pallas(interpret) vs reference
# ---------------------------------------------------------------------------

_CASES = dict(parity_programs())


def _make_input(param, rng):
    if param.dtype.startswith(("int", "uint")):
        return rng.integers(-4, 4, size=param.shape).astype(param.dtype)
    return rng.standard_normal(param.shape).astype(param.dtype)


@pytest.mark.parametrize("name", sorted(_CASES))
def test_backend_parity(name, rng):
    prog = _CASES[name]
    pk = tl_compile(prog, Schedule(interpret=True), target="pallas")
    rk = tl_compile(prog, target="reference")
    assert pk.backend == "pallas" and rk.backend == "reference"
    assert [p.name for p in pk.arg_params] == [p.name for p in rk.arg_params]
    args = parity_inputs(name, prog, rng)
    if args is None:
        args = [_make_input(p, rng) for p in pk.arg_params]
    pout, rout = pk(*args), rk(*args)
    if not isinstance(pout, tuple):
        pout, rout = (pout,), (rout,)
    for p, r in zip(pout, rout):
        np.testing.assert_allclose(
            np.asarray(p), np.asarray(r), rtol=1e-4, atol=2e-3
        )


# ---------------------------------------------------------------------------
# DequantStage lane padding (ROADMAP §3 residue): packed int8 scratch must
# land on the TPU lane width; window-backed packed buffers must not be
# padded (their block shape mirrors the global page layout).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["int8", "int4"])
def test_dequant_stage_scratch_is_lane_aligned(fmt):
    from repro.core.layout import LANE
    from repro.core.lowering import run_pipeline
    from repro.kernels.prefill_attention import (
        prefill_attention_quant_program,
    )

    # head_dim // pack = 64 (int8) / 32 (int4): both narrower than LANE,
    # exactly the misaligned minor dims Mosaic pays relayout copies for
    m = run_pipeline(
        prefill_attention_quant_program(
            slots=1, heads=2, kv_heads=1, head_dim=64, chunk=8,
            page_size=8, max_pages=4, num_pages=8, fmt=fmt),
        Schedule(),
    )
    packed_scratch = [b for b in m.scratch_bufs if b.dtype == "int8"]
    assert packed_scratch  # the dequant stages' local fragments
    for b in packed_scratch:
        assert b.shape[-1] % LANE == 0, (b.name, b.shape)
    # the shared staging buffers are BlockSpec windows over the packed
    # pools: their block shape must stay exactly the global page layout
    cols = 64 // {"int8": 1, "int4": 2}[fmt]
    packed_windows = [w.onchip for w in m.in_windows
                      if w.onchip.dtype == "int8"]
    assert packed_windows
    for b in packed_windows:
        assert b.shape[-1] == cols, (b.name, b.shape)
