"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train-grad step + one decode step on CPU; asserts output
shapes and absence of NaNs (the full configs are exercised only via the
dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import encdec, lm

B, S = 2, 32


def _tokens(cfg, rng):
    return jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)), jnp.int32)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, key, rng):
    cfg = get_config(arch).reduced()
    if cfg.is_encoder_decoder:
        params = encdec.init(cfg, key)
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_seq, cfg.d_model), dtype=np.float32)
        )
        tokens = _tokens(cfg, rng)
        labels = tokens

        def loss(p):
            return encdec.loss_fn(p, cfg, frames, tokens, labels)[0]

        l, grads = jax.value_and_grad(loss)(params)
        logits = encdec.decode_full(
            params, cfg, tokens, encdec.encode(params, cfg, frames)
        )
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        params = lm.init(cfg, key)
        tokens = _tokens(cfg, rng)
        prefix = None
        if cfg.frontend != "none":
            prefix = jnp.asarray(
                rng.standard_normal((B, cfg.frontend_seq, cfg.d_model), dtype=np.float32)
            )
        logits, _ = lm.forward(params, cfg, tokens, prefix_embeds=prefix)
        total = S + (cfg.frontend_seq if prefix is not None else 0)
        assert logits.shape == (B, total, cfg.vocab_size)
        assert not np.any(np.isnan(np.asarray(logits)))

        def loss(p):
            return lm.loss_fn(p, cfg, tokens, tokens, prefix_embeds=prefix)[0]

        l, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l))
    gnorm = jnp.sqrt(
        sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads))
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch, key, rng):
    cfg = get_config(arch).reduced()
    max_len = 16
    if cfg.is_encoder_decoder:
        params = encdec.init(cfg, key)
        frames = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_seq, cfg.d_model), dtype=np.float32)
        )
        enc = encdec.encode(params, cfg, frames)
        cross = encdec.cross_kv(params, cfg, enc)
        cache = encdec.init_cache(cfg, B, max_len)
        tok = jnp.zeros((B,), jnp.int32)
        logits, cache = encdec.decode_step(params, cfg, cache, tok, 0, cross)
        logits2, _ = encdec.decode_step(params, cfg, cache, tok + 1, 1, cross)
        assert logits.shape == (B, cfg.vocab_size)
        assert not np.any(np.isnan(np.asarray(logits2)))
        return
    params = lm.init(cfg, key)
    cache = lm.init_cache(cfg, B, max_len)
    tok = jnp.zeros((B,), jnp.int32)
    logits, cache = lm.decode_step(params, cfg, cache, tok, 0)
    logits2, cache = lm.decode_step(params, cfg, cache, tok + 1, 1)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits2)))


def test_decode_matches_forward_dense(key, rng):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_config("qwen2_1_5b").reduced()
    params = lm.init(cfg, key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)), jnp.int32)
    full_logits, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, 1, 8)
    for t in range(8):
        step_logits, cache = lm.decode_step(params, cfg, cache, toks[:, t], t)
        np.testing.assert_allclose(
            np.asarray(step_logits[0]),
            np.asarray(full_logits[0, t]),
            atol=2e-3,
            err_msg=f"position {t}",
        )


def test_decode_matches_forward_ssm(key, rng):
    """SSM decode recurrence must match the chunked SSD forward."""
    cfg = get_config("mamba2_2_7b").reduced()
    params = lm.init(cfg, key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)), jnp.int32)
    full_logits, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, 1, 8)
    for t in range(8):
        step_logits, cache = lm.decode_step(params, cfg, cache, toks[:, t], t)
        np.testing.assert_allclose(
            np.asarray(step_logits[0]),
            np.asarray(full_logits[0, t]),
            atol=5e-3,
            err_msg=f"position {t}",
        )


def test_moe_balanced_dispatch(key, rng):
    """MoE keeps shapes static and routes every token somewhere (cap allowing)."""
    from repro.models import layers as L

    cfg = get_config("granite_moe_3b_a800m").reduced()
    params = lm.init(cfg, key)
    p_moe = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model), dtype=np.float32))
    out, aux = L.moe(p_moe, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 0

def test_param_counts_in_range():
    """Full configs should land near their nameplate sizes."""
    expect = {
        "gemma_7b": (7.0e9, 10.5e9),     # 8.5B incl 786M embed (256k vocab)
        "deepseek_7b": (6.0e9, 8.0e9),
        "qwen2_1_5b": (1.2e9, 2.1e9),
        "mamba2_2_7b": (2.2e9, 3.2e9),
        "deepseek_v2_lite_16b": (13e9, 18e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_decode_matches_forward_whisper(key, rng):
    """Enc-dec decode path must match teacher-forced decode_full."""
    cfg = get_config("whisper_tiny").reduced()
    params = encdec.init(cfg, key)
    frames = jnp.asarray(
        rng.standard_normal((1, cfg.frontend_seq, cfg.d_model), dtype=np.float32)
    )
    enc = encdec.encode(params, cfg, frames)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)), jnp.int32)
    full = encdec.decode_full(params, cfg, toks, enc)
    cross = encdec.cross_kv(params, cfg, enc)
    cache = encdec.init_cache(cfg, 1, 8)
    for t in range(8):
        step, cache = encdec.decode_step(params, cfg, cache, toks[:, t], t, cross)
        np.testing.assert_allclose(
            np.asarray(step[0]), np.asarray(full[0, t]), atol=2e-3,
            err_msg=f"position {t}",
        )


def test_decode_matches_forward_mla(key, rng):
    """MLA latent-cache decode must match the expanded training attention.

    The MoE capacity factor is raised to dropless levels: capacity overflow
    drops tokens in the batched forward but never in one-token decode, which
    is expected GShard behavior, not an MLA bug (verified separately)."""
    import dataclasses

    cfg = get_config("deepseek_v2_lite_16b").reduced()
    cfg.moe = dataclasses.replace(cfg.moe, capacity_factor=16.0)
    params = lm.init(cfg, key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)), jnp.int32)
    full_logits, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, 1, 8)
    for t in range(8):
        step_logits, cache = lm.decode_step(params, cfg, cache, toks[:, t], t)
        np.testing.assert_allclose(
            np.asarray(step_logits[0]), np.asarray(full_logits[0, t]), atol=5e-3,
            err_msg=f"position {t}",
        )


def test_decode_matches_forward_hybrid(key, rng):
    """Hybrid (attn ring-buffer + SSM state) decode parity with forward."""
    cfg = get_config("hymba_1_5b").reduced()
    params = lm.init(cfg, key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)), jnp.int32)
    full_logits, _ = lm.forward(params, cfg, toks)
    cache = lm.init_cache(cfg, 1, 8)
    for t in range(8):
        step_logits, cache = lm.decode_step(params, cfg, cache, toks[:, t], t)
        np.testing.assert_allclose(
            np.asarray(step_logits[0]), np.asarray(full_logits[0, t]), atol=5e-3,
            err_msg=f"position {t}",
        )
