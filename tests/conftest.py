import os

# Smoke tests and benches must see the real (single) CPU device; ONLY
# launch/dryrun.py sets XLA_FORCE_HOST_PLATFORM_DEVICE_COUNT (to 512).
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), (
    "tests must not run with the dry-run's 512-device XLA_FLAGS"
)

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
