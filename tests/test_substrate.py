"""Substrate tests: data determinism, checkpoint atomicity/restart, fault
recovery, elastic remesh, optimizer, serving engine correctness."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.configs import get_config
from repro.data import DataConfig, SyntheticTokens, TokenFileDataset, make_loader
from repro.distributed.fault import (
    FaultConfig,
    SimulatedNodeFailure,
    StragglerMonitor,
    run_with_recovery,
)
from repro.models import lm
from repro.optim import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.serving import ServeConfig, ServingEngine


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestData:
    def test_synthetic_deterministic_resume(self):
        cfg = DataConfig(batch=4, seq=16, vocab_size=1000, seed=3)
        ds = SyntheticTokens(cfg)
        b5a = ds.batch_at(5)
        b5b = ds.batch_at(5)
        np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
        assert not np.array_equal(ds.batch_at(6)["tokens"], b5a["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(batch=2, seq=8, vocab_size=100)
        b = SyntheticTokens(cfg).batch_at(0)
        assert b["tokens"].shape == (2, 8) and b["labels"].shape == (2, 8)

    def test_host_sharding_differs(self):
        a = SyntheticTokens(DataConfig(4, 16, 1000, host_id=0, num_hosts=2)).batch_at(0)
        b = SyntheticTokens(DataConfig(4, 16, 1000, host_id=1, num_hosts=2)).batch_at(0)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_file_dataset(self, tmp_path):
        toks = np.arange(10_000, dtype=np.uint16) % 500
        p = tmp_path / "shard0.bin"
        toks.tofile(p)
        cfg = DataConfig(batch=2, seq=32, vocab_size=500)
        ds = TokenFileDataset(cfg, [str(p)])
        b = ds.batch_at(0)
        assert b["tokens"].shape == (2, 32)
        # windows are consecutive in the file
        assert np.all(b["labels"][:, :-1] == b["tokens"][:, 1:])

    def test_loader_prefetch_order(self):
        cfg = DataConfig(batch=2, seq=8, vocab_size=100)
        ds = SyntheticTokens(cfg)
        it = make_loader(ds, start_step=3)
        first = next(it)
        np.testing.assert_array_equal(first["tokens"], ds.batch_at(3)["tokens"])
        second = next(it)
        np.testing.assert_array_equal(second["tokens"], ds.batch_at(4)["tokens"])
        it.close()


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def _state(self):
        return {
            "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
            "step": jnp.asarray(7, jnp.int32),
        }

    def test_roundtrip(self, tmp_path):
        state = self._state()
        save(state, 7, tmp_path)
        out = restore(state, 7, tmp_path)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_ignores_incomplete(self, tmp_path):
        state = self._state()
        save(state, 1, tmp_path)
        save(state, 2, tmp_path)
        # corrupt step 2's manifest -> restart must pick step 1
        man = tmp_path / "step_00000002" / "manifest.json"
        m = json.loads(man.read_text())
        m["complete"] = False
        man.write_text(json.dumps(m))
        assert latest_step(tmp_path) == 1

    def test_keep_prunes_old(self, tmp_path):
        state = self._state()
        for s in (1, 2, 3, 4):
            save(state, s, tmp_path, keep=2)
        dirs = sorted(p.name for p in tmp_path.glob("step_*"))
        assert dirs == ["step_00000003", "step_00000004"]

    def test_shape_mismatch_rejected(self, tmp_path):
        state = self._state()
        save(state, 7, tmp_path)
        bad = {"params": {"w": jnp.zeros((3, 5)), "b": jnp.ones((4,))},
               "step": jnp.asarray(0)}
        with pytest.raises(ValueError):
            restore(bad, 7, tmp_path)

    def test_manager_async(self, tmp_path):
        mgr = CheckpointManager(tmp_path, interval=2, keep=2)
        state = self._state()
        assert not mgr.maybe_save(state, 1)
        assert mgr.maybe_save(state, 2)
        mgr.wait()
        assert mgr.latest() == 2


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


class TestOptimizer:
    def test_lr_schedule_shape(self):
        cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
        assert float(lr_schedule(cfg, jnp.asarray(0))) == pytest.approx(0.0)
        assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=1e-2)
        assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-2)

    def test_adamw_descends_quadratic(self):
        cfg = AdamWConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, grad_clip=0.0)
        params = {"x": jnp.asarray([3.0, -2.0])}
        opt = init_opt_state(params)
        for _ in range(200):
            grads = {"x": 2 * opt["master"]["x"]}
            params, opt, m = adamw_update(params, grads, opt, cfg)
        assert float(jnp.abs(params["x"]).max()) < 0.05

    def test_master_weights_are_f32(self):
        params = {"w": jnp.ones((4,), jnp.bfloat16)}
        opt = init_opt_state(params)
        assert opt["master"]["w"].dtype == jnp.float32
        new_p, new_opt, _ = adamw_update(
            params, {"w": jnp.ones((4,), jnp.bfloat16)}, opt, AdamWConfig()
        )
        assert new_p["w"].dtype == jnp.bfloat16  # compute dtype preserved
        assert new_opt["master"]["w"].dtype == jnp.float32


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


class TestFaultTolerance:
    def test_recovery_reaches_target_and_matches_clean_run(self, tmp_path):
        """A run with injected failures must produce EXACTLY the same final
        state as a failure-free run (checkpoint/restart + deterministic
        data)."""
        cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, total_steps=50,
                          weight_decay=0.0)

        def make_step():
            def step(state, batch):
                def loss(p):
                    pred = batch["tokens"].astype(jnp.float32) @ p["w"]
                    return jnp.mean((pred - batch["labels"][:, :1]) ** 2)

                l, g = jax.value_and_grad(loss)(state["params"])
                new_p, new_o, _ = adamw_update(state["params"], {"w": g["w"]},
                                               state["opt"], cfg)
                return {"params": new_p, "opt": new_o}, {"loss": l}
            return step

        def fresh_state():
            params = {"w": jnp.zeros((16, 1))}
            return {"params": params, "opt": init_opt_state(params)}

        data_cfg = DataConfig(batch=4, seq=16, vocab_size=100, seed=1)
        ds = SyntheticTokens(data_cfg)

        def loader_factory(start):
            return make_loader(ds, start)

        clean = run_with_recovery(
            make_step(), fresh_state(), loader_factory, steps=30,
            ckpt_manager=CheckpointManager(tmp_path / "clean", interval=10,
                                           async_save=False),
            fault=FaultConfig(failure_prob=0.0),
        )
        faulty = run_with_recovery(
            make_step(), fresh_state(), loader_factory, steps=30,
            ckpt_manager=CheckpointManager(tmp_path / "faulty", interval=10,
                                           async_save=False),
            fault=FaultConfig(failure_prob=0.15, seed=5),
        )
        assert faulty["restarts"] > 0, "failure injection never fired"
        np.testing.assert_allclose(
            np.asarray(clean["state"]["params"]["w"]),
            np.asarray(faulty["state"]["params"]["w"]),
            rtol=1e-6,
        )

    def test_straggler_monitor_flags_outlier(self):
        mon = StragglerMonitor(factor=3.0)
        for i in range(10):
            mon.observe(i, 0.01)
        assert mon.observe(10, 0.2)
        assert 10 in mon.flagged

    def test_elastic_remesh_roundtrip(self):
        from repro.distributed.fault import elastic_remesh
        from repro.launch.mesh import make_debug_mesh
        from jax.sharding import PartitionSpec as P

        mesh = make_debug_mesh(1, 1)
        state = {"w": np.arange(8.0).reshape(2, 4)}
        specs = {"w": P(None, None)}
        out = elastic_remesh(state, mesh, specs)
        np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------


class TestServing:
    def test_greedy_engine_matches_manual_decode(self, rng):
        cfg = get_config("qwen2_1_5b").reduced()
        params = lm.init(cfg, jax.random.PRNGKey(0))
        prompt = rng.integers(0, cfg.vocab_size, size=6).tolist()

        # manual greedy loop
        cache = lm.init_cache(cfg, 1, 64)
        toks = list(prompt)
        pos = 0
        for t in prompt:
            logits, cache = lm.decode_step(
                params, cfg, cache, jnp.asarray([t], jnp.int32), pos
            )
            pos += 1
        manual = []
        cur = int(jnp.argmax(logits[0]))
        for _ in range(5):
            manual.append(cur)
            logits, cache = lm.decode_step(
                params, cfg, cache, jnp.asarray([cur], jnp.int32), pos
            )
            pos += 1
            cur = int(jnp.argmax(logits[0]))

        engine = ServingEngine(
            cfg, params, ServeConfig(slots=2, max_len=64, max_new_tokens=5)
        )
        req = engine.submit(prompt)
        engine.run()
        assert req.done
        assert req.output == manual

    def test_continuous_batching_recycles_slots(self, rng):
        cfg = get_config("qwen2_1_5b").reduced()
        params = lm.init(cfg, jax.random.PRNGKey(0))
        engine = ServingEngine(
            cfg, params, ServeConfig(slots=2, max_len=32, max_new_tokens=3)
        )
        reqs = [
            engine.submit(rng.integers(0, cfg.vocab_size, size=4).tolist())
            for _ in range(5)
        ]
        done = engine.run()
        assert len(done) == 5
        assert all(len(r.output) == 3 for r in done)

    def test_staggered_positions_match_isolated(self, rng):
        """Two requests admitted at different ticks must decode exactly as
        they would alone (per-slot positions are independent)."""
        cfg = get_config("qwen2_1_5b").reduced()
        params = lm.init(cfg, jax.random.PRNGKey(0))
        p1 = rng.integers(0, cfg.vocab_size, size=5).tolist()
        p2 = rng.integers(0, cfg.vocab_size, size=3).tolist()

        def alone(prompt):
            e = ServingEngine(cfg, params, ServeConfig(slots=1, max_len=32,
                                                       max_new_tokens=4))
            r = e.submit(prompt)
            e.run()
            return r.output

        ref1, ref2 = alone(p1), alone(p2)
        e = ServingEngine(cfg, params, ServeConfig(slots=2, max_len=32,
                                                   max_new_tokens=4))
        r1 = e.submit(p1)
        e.step()  # r1 admitted first; r2 joins one tick later
        r2 = e.submit(p2)
        e.run()
        assert r1.output == ref1
        assert r2.output == ref2
