"""Unit tests for the tile language itself: expressions, layouts, tracing,
inference, scheduling — the paper's §3–§4 semantics."""
import numpy as np
import pytest

from repro.core import (
    Fragment,
    LoweringError,
    Schedule,
    ScheduleError,
    TileProgram,
    TraceError,
    compile as tl_compile,
    infer_layouts,
    padded,
    row_major,
    vreg_fragment,
)
from repro.core import lang as T
from repro.core.expr import ConstExpr, VarExpr, evaluate, linear_decompose, static_eval
from repro.core.layout import IterVar, Layout
from repro.core.schedule import swizzle_decode, physical_tile_shape


# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------


class TestExpr:
    def test_arithmetic_tree_and_eval(self):
        x, y = VarExpr("x"), VarExpr("y")
        e = (x * 3 + y) // 2 - 1
        val = evaluate(e, {"x": 5, "y": 7}, load_fn=None)
        assert val == (5 * 3 + 7) // 2 - 1

    def test_static_eval(self):
        e = ConstExpr(6) * 7 + 2
        assert static_eval(e) == 44
        assert static_eval(VarExpr("k") + 1) is None

    def test_linear_decompose(self):
        x, y = VarExpr("x"), VarExpr("y")
        dec = linear_decompose(2 * x + y * 3 + 5)
        assert dec == {"x": 2, "y": 3, "": 5}
        assert linear_decompose(x * y) is None

    def test_bool_coercion_raises(self):
        with pytest.raises(TraceError):
            bool(VarExpr("x") + 1)


# ---------------------------------------------------------------------------
# Layout algebra (paper §4.1, Fig. 5/6)
# ---------------------------------------------------------------------------


class TestLayout:
    def test_row_major_linearization(self):
        lay = row_major((4, 8))
        assert lay.map_concrete(2, 3) == (2 * 8 + 3,)
        assert lay.out_shape() == (32,)
        assert lay.is_bijective()

    def test_padding_layout_non_bijective(self):
        lay = padded((5, 100), (8, 128))
        assert lay.out_shape() == (8, 128)
        assert lay.map_concrete(4, 99) == (4, 99)
        assert not lay.is_bijective()  # padded box has holes

    def test_compose(self):
        inner = row_major((4, 8))  # 2d -> 1d
        outer = Layout([IterVar.make("f", 32)], (VarExpr("f", extent=32) % 32,))
        comp = outer.compose(inner)
        assert comp.map_concrete(1, 2) == ((1 * 8 + 2) % 32,)

    def test_fragment_repeat_grows_locals(self):
        # paper Fig. 6: repeat tiles new rows into the same partitions
        base = vreg_fragment((8, 128), "float32")
        assert base.threads() == 1
        rep = base.repeat(4, axis=0)
        assert rep.in_shape == (32, 128)
        assert rep.threads() == 1
        assert rep.locals_per_thread() == 4 * base.locals_per_thread()

    def test_fragment_repeat_on_thread_grows_partitions(self):
        base = vreg_fragment((8, 128), "float32")
        rep = base.repeat_on_thread(4, axis=0)
        assert rep.in_shape == (32, 128)
        assert rep.threads() == 4 * base.threads()
        assert rep.locals_per_thread() == base.locals_per_thread()

    def test_fragment_replicate(self):
        # paper Fig. 7: broadcast operands live in several partitions
        base = vreg_fragment((8, 128), "float32").repeat_on_thread(2, axis=0)
        rep = base.replicate(3)
        assert rep.replication == 3
        assert rep.threads() == 3 * base.threads()
        cond = rep.condense()
        assert cond.replication == 1
        assert cond.threads() == base.threads()

    def test_vreg_tile_shapes_by_dtype(self):
        from repro.core.layout import vreg_tile

        assert vreg_tile("float32") == (8, 128)
        assert vreg_tile("bfloat16") == (16, 128)
        assert vreg_tile("int8") == (32, 128)

    def test_physical_tile_padding(self):
        assert physical_tile_shape((5, 100), "float32") == (8, 128)
        assert physical_tile_shape((16, 256), "bfloat16") == (16, 256)
        assert physical_tile_shape((64,), "float32") == (128,)


# ---------------------------------------------------------------------------
# Tracing / program construction
# ---------------------------------------------------------------------------


def _simple_program(m=64, n=64):
    @T.prim_func
    def AddOne(X: T.Tensor((m, n), "float32"), Y: T.Tensor((m, n), "float32")):
        with T.Kernel(1) as bx:
            xs = T.alloc_shared((m, n), "float32")
            ys = T.alloc_fragment((m, n), "float32")
            T.copy(X[0, 0], xs)
            for i, j in T.Parallel(m, n):
                ys[i, j] = xs[i, j] + 1.0
            T.copy(ys, Y[0, 0])

    return AddOne


class TestTracing:
    def test_program_classification(self):
        prog = _simple_program()
        assert [p.name for p in prog.input_params()] == ["X"]
        assert [p.name for p in prog.output_params()] == ["Y"]

    def test_elementwise_program_runs(self, rng):
        prog = _simple_program(16, 128)
        kern = tl_compile(prog, Schedule(interpret=True))
        x = rng.standard_normal((16, 128), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(kern(x)), x + 1.0, rtol=1e-6)

    def test_primitive_outside_kernel_raises(self):
        with pytest.raises(TraceError):
            T.alloc_shared((8, 128), "float32")

    def test_gemm_shape_mismatch_raises(self):
        with pytest.raises(TraceError):

            @T.prim_func
            def Bad(A: T.Tensor((8, 16), "float32"), C: T.Tensor((8, 8), "float32")):
                with T.Kernel(1) as bx:
                    a = T.alloc_shared((8, 16), "float32")
                    b = T.alloc_shared((8, 16), "float32")  # K mismatch
                    c = T.alloc_fragment((8, 8), "float32")
                    T.gemm(a, b, c)

    def test_global_gemm_operand_raises(self):
        with pytest.raises(TraceError):

            @T.prim_func
            def Bad(A: T.Tensor((8, 8), "float32"), C: T.Tensor((8, 8), "float32")):
                with T.Kernel(1) as bx:
                    c = T.alloc_fragment((8, 8), "float32")
                    T.gemm(A, A, c)

    def test_two_kernels_raise(self):
        with pytest.raises(TraceError):

            @T.prim_func
            def Bad(A: T.Tensor((8, 8), "float32")):
                with T.Kernel(1) as bx:
                    pass
                with T.Kernel(1) as by:
                    pass

    def test_double_pipelined_lowering_error(self):
        @T.prim_func
        def TwoLoops(A: T.Tensor((64, 64), "float32"), B: T.Tensor((64, 64), "float32")):
            with T.Kernel(1) as bx:
                s = T.alloc_shared((64, 64), "float32")
                f = T.alloc_fragment((64, 64), "float32")
                for k in T.Pipelined(2):
                    T.copy(A[0, 0], s)
                for k in T.Pipelined(2):
                    T.copy(s, f)
                T.copy(f, B[0, 0])

        with pytest.raises(LoweringError):
            tl_compile(TwoLoops, Schedule(interpret=True))

    def test_vmem_budget_enforced(self):
        @T.prim_func
        def Huge(A: T.Tensor((8192, 8192), "float32"), B: T.Tensor((8192, 8192), "float32")):
            with T.Kernel(1) as bx:
                s = T.alloc_shared((8192, 8192), "float32")  # 256 MiB >> VMEM
                T.copy(A[0, 0], s)
                T.copy(s, B[0, 0])

        with pytest.raises(ScheduleError):
            tl_compile(Huge, Schedule(interpret=True))


# ---------------------------------------------------------------------------
# Layout inference (paper §4.2): priority, replication, vectorization
# ---------------------------------------------------------------------------


class TestInference:
    def test_bias_replication_fig7(self):
        """The Fig. 7 scenario: bias D indexed only by j must be replicated
        across the i-axis partitions."""

        @T.prim_func
        def BiasAdd(D: T.Tensor((1, 64), "float32"), O: T.Tensor((32, 64), "float32")):
            with T.Kernel(1) as bx:
                d = T.alloc_shared((1, 64), "float32", name="d")
                c = T.alloc_fragment((32, 64), "float32", name="c")
                T.copy(D[0, 0], d)
                T.fill(c, 1.0)
                for i, j in T.Parallel(32, 64):
                    c[i, j] = c[i, j] + d[0, j]
                T.copy(c, O[0, 0])

        res = infer_layouts(BiasAdd)
        binding = res.parallels[0]
        assert binding.replication["d"] == 32  # replicated across all i
        assert binding.replication["c"] == 1

    def test_gemm_pins_layouts_first(self):
        from repro.kernels.matmul import matmul_program

        prog = matmul_program(256, 256, 256, block_M=128, block_N=128, block_K=64)
        res = infer_layouts(prog)
        assert res.gemms[0].mxu_utilization == 1.0  # 128-aligned tiles
        # shared operands got padded physical layouts, accumulator a fragment
        assert "sbuf" in " ".join(res.layouts) or len(res.layouts) >= 3

    def test_mxu_utilization_penalizes_small_tiles(self):
        from repro.kernels.matmul import matmul_program

        prog = matmul_program(64, 64, 64, block_M=32, block_N=32, block_K=32)
        res = infer_layouts(prog)
        # M and N pad to 128 on the MXU; K only pads to the sublane granule.
        assert res.gemms[0].mxu_utilization == pytest.approx((32 / 128) ** 2)

    def test_vectorization_inferred(self):
        prog = _simple_program(16, 128)
        res = infer_layouts(prog)
        assert res.parallels[0].vector_width == 128


# ---------------------------------------------------------------------------
# Schedule: swizzle + vmem plan
# ---------------------------------------------------------------------------


class TestSchedule:
    @pytest.mark.parametrize("g0,g1,factor", [(8, 4, 2), (8, 8, 4), (16, 2, 8)])
    def test_swizzle_decode_is_permutation(self, g0, g1, factor):
        seen = set()
        for flat in range(g0 * g1):
            i0, i1 = swizzle_decode(flat, g0, g1, factor)
            assert 0 <= i0 < g0 and 0 <= i1 < g1
            seen.add((i0, i1))
        assert len(seen) == g0 * g1

    def test_swizzle_panel_locality(self):
        # within a panel, consecutive steps keep the same column block
        g0, g1, f = 8, 4, 4
        cols = [swizzle_decode(i, g0, g1, f)[1] for i in range(f)]
        assert len(set(cols)) == 1

    @pytest.mark.parametrize("g0,g1,factor", [(6, 3, 4), (10, 2, 4), (7, 5, 3)])
    def test_swizzle_ragged_int_path_is_permutation(self, g0, g1, factor):
        """The python-int path clamps the last (ragged) panel when ``factor``
        does not divide ``g0`` and must still be a bijection over the grid."""
        seen = {swizzle_decode(f, g0, g1, factor) for f in range(g0 * g1)}
        assert seen == {(i0, i1) for i0 in range(g0) for i1 in range(g1)}

    @pytest.mark.parametrize("g0,g1,factor", [(8, 4, 2), (6, 3, 3), (16, 2, 8)])
    def test_swizzle_traced_matches_int_when_divisible(self, g0, g1, factor):
        """The traced path requires ``g0 % factor == 0`` (validate_swizzle's
        precondition); under it, traced and int decodes must agree exactly —
        the int path's ragged clamp reduces to the traced arithmetic."""
        import jax.numpy as jnp

        from repro.core.schedule import validate_swizzle

        validate_swizzle(g0, g1, factor)  # precondition holds
        for flat in range(g0 * g1):
            ti0, ti1 = swizzle_decode(jnp.int32(flat), g0, g1, factor)
            i0, i1 = swizzle_decode(flat, g0, g1, factor)
            assert (int(ti0), int(ti1)) == (i0, i1)

    def test_swizzle_ragged_traced_precondition_rejected(self):
        from repro.core.errors import ScheduleError
        from repro.core.schedule import validate_swizzle

        with pytest.raises(ScheduleError, match="multiple of the factor"):
            validate_swizzle(6, 3, 4)  # ragged panel: traced path illegal

    def test_swizzled_matmul_correct(self, rng):
        from repro.kernels.matmul import matmul_program

        prog = matmul_program(
            256, 256, 128, block_M=64, block_N=64, block_K=64, swizzle=2
        )
        kern = tl_compile(prog, Schedule(interpret=True))
        a = rng.standard_normal((256, 128), dtype=np.float32)
        b = rng.standard_normal((128, 256), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(kern(a, b)), a @ b, atol=1e-3)

    def test_num_stages_multiplies_vmem(self):
        from repro.kernels.matmul import matmul_program

        prog2 = matmul_program(256, 256, 256, block_M=64, block_N=64, block_K=64, num_stages=2)
        prog4 = matmul_program(256, 256, 256, block_M=64, block_N=64, block_K=64, num_stages=4)
        k2 = tl_compile(prog2, Schedule(interpret=True))
        k4 = tl_compile(prog4, Schedule(interpret=True))
        assert k4.info.vmem.total_bytes > k2.info.vmem.total_bytes


# ---------------------------------------------------------------------------
# Autotune (cost model)
# ---------------------------------------------------------------------------


class TestAutotune:
    def test_autotune_prefers_larger_blocks(self):
        from repro.kernels.matmul import tune_matmul

        kern, cand = tune_matmul(1024, 1024, 1024, "bfloat16", "bfloat16")
        assert cand.feasible
        assert cand.config["block_M"] >= 128
        assert cand.mxu_util == 1.0

    def test_autotune_rejects_infeasible(self):
        from repro.core import autotune
        from repro.kernels.matmul import matmul_program

        def build(**cfg):
            return matmul_program(8192, 8192, 8192, **cfg)

        kern, cand, allc = autotune(
            build,
            [
                dict(block_M=8192, block_N=8192, block_K=64),  # VMEM blowout
                dict(block_M=128, block_N=128, block_K=64),
            ],
            return_all=True,
        )
        assert cand.config["block_M"] == 128
        assert not allc[0].feasible


# ---------------------------------------------------------------------------
# Remaining operator coverage: atomics (rewritten), cumsum, annotate_layout,
# serial/unroll loops, custom ops
# ---------------------------------------------------------------------------


class TestMoreOps:
    def test_atomic_add_accumulates_into_global(self, rng):
        """T.atomic on TPU lowers to an aliased in-out RMW window."""

        @T.prim_func
        def ColSum(X: T.Tensor((4, 16, 128), "float32"), O: T.Tensor((16, 128), "float32")):
            with T.Kernel(4) as bx:
                xs = T.alloc_shared((16, 128), "float32")
                T.copy(X[bx, 0, 0], xs)
                T.atomic_add(O[0, 0], xs)

        kern = tl_compile(ColSum, Schedule(interpret=True))
        x = rng.standard_normal((4, 16, 128), dtype=np.float32)
        o0 = np.ones((16, 128), np.float32)
        out = np.asarray(kern(x, o0))
        np.testing.assert_allclose(out, o0 + x.sum(0), atol=1e-5)

    def test_cumsum(self, rng):
        @T.prim_func
        def Cumsum(X: T.Tensor((8, 128), "float32"), O: T.Tensor((8, 128), "float32")):
            with T.Kernel(1) as bx:
                xs = T.alloc_shared((8, 128), "float32")
                cs = T.alloc_fragment((8, 128), "float32")
                T.copy(X[0, 0], xs)
                T.cumsum(xs, cs, dim=1)
                T.copy(cs, O[0, 0])

        kern = tl_compile(Cumsum, Schedule(interpret=True))
        x = rng.standard_normal((8, 128), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(kern(x)), np.cumsum(x, 1), atol=1e-4)

    def test_serial_unroll_loop(self, rng):
        @T.prim_func
        def FourX(X: T.Tensor((8, 128), "float32"), O: T.Tensor((8, 128), "float32")):
            with T.Kernel(1) as bx:
                acc = T.alloc_fragment((8, 128), "float32")
                xs = T.alloc_shared((8, 128), "float32")
                T.copy(X[0, 0], xs)
                T.clear(acc)
                for _ in T.unroll(4):
                    for i, j in T.Parallel(8, 128):
                        acc[i, j] = acc[i, j] + xs[i, j]
                T.copy(acc, O[0, 0])

        kern = tl_compile(FourX, Schedule(interpret=True))
        x = rng.standard_normal((8, 128), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(kern(x)), 4 * x, atol=1e-5)

    def test_annotate_layout_override(self):
        from repro.core import padded

        @T.prim_func
        def Annotated(X: T.Tensor((8, 100), "float32"), O: T.Tensor((8, 100), "float32")):
            with T.Kernel(1) as bx:
                xs = T.alloc_shared((8, 100), "float32", name="xs")
                T.annotate_layout({xs: padded((8, 100), (8, 256))})
                T.copy(X[0, 0], xs)
                T.copy(xs, O[0, 0])

        res = infer_layouts(Annotated)
        assert res.layouts["xs"].out_shape() == (8, 256)  # user layout won

    def test_custom_op_tile_library(self, rng):
        import jax.numpy as jnp

        @T.prim_func
        def Softmaxed(X: T.Tensor((8, 128), "float32"), O: T.Tensor((8, 128), "float32")):
            with T.Kernel(1) as bx:
                xs = T.alloc_shared((8, 128), "float32")
                sm = T.alloc_fragment((8, 128), "float32")
                T.copy(X[0, 0], xs)
                T.call_tile_lib(lambda v: jnp.exp(v) / jnp.exp(v).sum(-1, keepdims=True), sm, xs)
                T.copy(sm, O[0, 0])

        kern = tl_compile(Softmaxed, Schedule(interpret=True))
        x = rng.standard_normal((8, 128), dtype=np.float32)
        e = np.exp(x)
        np.testing.assert_allclose(np.asarray(kern(x)), e / e.sum(-1, keepdims=True), atol=1e-5)

    def test_reference_backend_flash_attention(self, rng):
        """The trace-interpreter backend agrees with the Pallas lowering on
        a stateful online-softmax kernel."""
        from repro.kernels.flash_attention import flash_attention_program

        prog = flash_attention_program(1, 2, 2, 32, 64, 16, True, 16, 32)
        pk = tl_compile(prog, Schedule(interpret=True))
        rk = tl_compile(prog, backend="reference")
        q = rng.standard_normal((1, 2, 32, 16), dtype=np.float32)
        k = rng.standard_normal((1, 2, 64, 16), dtype=np.float32)
        v = rng.standard_normal((1, 2, 64, 16), dtype=np.float32)
        np.testing.assert_allclose(
            np.asarray(pk(q, k, v)), np.asarray(rk(q, k, v)), atol=1e-4
        )
