"""Roofline machinery tests: HLO collective parsing, model FLOPs, the
analytic traffic model, and term arithmetic."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.cells import SHAPES
from repro.roofline.analysis import (
    RooflineTerms,
    analytic_hbm_bytes,
    attention_flops,
    chunked_attention_correction,
    collective_bytes,
    model_flops,
)

HLO_SAMPLE = """
ENTRY %main {
  %ag = bf16[8,128,3072]{2,1,0} all-gather(bf16[8,8,3072] %x), dimensions={1}
  %ar = f32[16000,3072]{1,0} all-reduce(f32[16000,3072] %g), to_apply=%add
  %rs = f32[4,3072]{1,0} reduce-scatter(f32[64,3072] %h), dimensions={0}
  %a2a = bf16[16,64,64]{2,1,0} all-to-all(bf16[16,64,64] %t), dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(f32[8,8] %u), source_target_pairs={{0,1}}
  %ars = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-reduce-start(f32[2,2] %v), to_apply=%add
  %ard = f32[2,2]{1,0} all-reduce-done(f32[2,2] %ars)
}
"""


class TestCollectiveParser:
    def test_kinds_and_bytes(self):
        out = collective_bytes(HLO_SAMPLE)
        counts = out.pop("_instruction_counts")
        assert out["all-gather"] == 8 * 128 * 3072 * 2
        assert out["all-reduce"] == 16000 * 3072 * 4 + 2 * (2 * 2 * 4)
        assert out["reduce-scatter"] == 4 * 3072 * 4
        assert out["all-to-all"] == 16 * 64 * 64 * 2
        assert out["collective-permute"] == 8 * 8 * 4
        assert counts["all-gather"] == 1
        # -start counted once; -done skipped
        assert counts["all-reduce"] == 2

    def test_empty_text(self):
        out = collective_bytes("ENTRY %main { %r = f32[2] add(f32[2] %a, f32[2] %b) }")
        out.pop("_instruction_counts")
        assert sum(out.values()) == 0


class TestModelFlops:
    def test_train_flops_scale_6nd(self):
        cfg = get_config("deepseek_7b")
        cell = SHAPES["train_4k"]
        mf = model_flops(cfg, cell)
        n = cfg.param_count(active_only=True)
        base = 6 * n * cell.batch * cell.seq
        assert mf >= base
        assert mf <= base * 2  # attention adds < 2x at 4k

    def test_moe_active_vs_total(self):
        cfg = get_config("deepseek_v2_lite_16b")
        assert cfg.param_count(active_only=True) < 0.4 * cfg.param_count()

    def test_window_clips_attention(self):
        hy = get_config("hymba_1_5b")
        cell = SHAPES["prefill_32k"]
        full = attention_flops(
            get_config("qwen2_1_5b"), cell, 1
        )
        win = attention_flops(hy, cell, 1)
        # hymba's 1k window at 32k seq must be far below quadratic
        assert win < full

    def test_chunk_correction_only_for_long(self):
        cfg = get_config("gemma_7b")
        assert chunked_attention_correction(cfg, SHAPES["train_4k"], 256) == 0
        assert chunked_attention_correction(cfg, SHAPES["prefill_32k"], 256) > 0


class TestAnalyticModel:
    MESH = {"data": 16, "model": 16}

    def test_flash_attention_removes_score_traffic(self):
        cfg = get_config("gemma_7b")
        cell = SHAPES["train_4k"]
        xla = analytic_hbm_bytes(cfg, cell, self.MESH, flash_attention=False)
        flash = analytic_hbm_bytes(cfg, cell, self.MESH, flash_attention=True)
        assert flash < xla
        # the delta is exactly the score-spill term: 4 passes * L * ...
        delta = xla - flash
        expect = 4 * cfg.num_layers * (256 / 16) * (cfg.num_heads / 16) * 4096 * 4096 * 4
        assert delta == pytest.approx(expect, rel=1e-6)

    def test_decode_traffic_tracks_cache(self):
        cfg = get_config("gemma_7b")
        small = analytic_hbm_bytes(cfg, SHAPES["decode_32k"], self.MESH)
        # same batch at half the seq -> cache term shrinks
        import dataclasses

        from repro.launch.cells import Cell

        half = dataclasses.replace(SHAPES["decode_32k"], seq=16384)
        assert analytic_hbm_bytes(cfg, half, self.MESH) < small

    def test_terms_dominant(self):
        t = RooflineTerms(
            arch="x", shape="y", mesh="m", flops=197e12, hbm_bytes=819e9 * 3,
            coll_bytes=50e9 * 0.5, coll_breakdown={}, model_flops=1e14, chips=256,
        )
        assert t.compute_s == pytest.approx(1.0)
        assert t.memory_s == pytest.approx(3.0)
        assert t.collective_s == pytest.approx(0.5)
        assert t.dominant == "memory"
        assert t.step_s == pytest.approx(3.0)

    def test_analytic_overrides_unfused_bound(self):
        t = RooflineTerms(
            arch="x", shape="y", mesh="m", flops=0, hbm_bytes=819e9 * 10,
            coll_bytes=0, coll_breakdown={}, model_flops=0, chips=256,
            analytic_bytes=819e9,
        )
        assert t.memory_s == pytest.approx(1.0)
        assert t.memory_ub_s == pytest.approx(10.0)
