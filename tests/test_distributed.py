"""Distribution-layer tests that run on a single CPU device: sharding rules
produce divisibility-valid specs for every arch on the production meshes
(validated against an AbstractMesh — no devices needed), ZeRO-1 adds data
sharding, cache rules hit heads/sequence fallbacks, pipeline math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.distributed import sharding as shd
from repro.distributed.pipeline import bubble_fraction
from repro.launch import cells as C


def abstract_mesh(multi_pod=False):
    if multi_pod:
        sizes, names = (2, 16, 16), ("pod", "data", "model")
    else:
        sizes, names = (16, 16), ("data", "model")
    try:
        return AbstractMesh(sizes, names)
    except TypeError:
        # older JAX (<0.5): AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(names, sizes)))


def _axis_size(mesh, ax):
    if ax is None:
        return 1
    names = ax if isinstance(ax, tuple) else (ax,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def assert_specs_divide(tree_shapes, tree_specs, mesh, where=""):
    flat_shapes = jax.tree.leaves(tree_shapes)
    flat_specs = jax.tree.leaves(tree_specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for leaf, spec in zip(flat_shapes, flat_specs):
        spec_t = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        for dim, ax in zip(leaf.shape, spec_t):
            size = _axis_size(mesh, ax)
            assert dim % size == 0, (
                f"{where}: dim {dim} not divisible by {ax} ({size}) "
                f"for leaf {leaf.shape} spec {spec}"
            )


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divide_all_archs(arch, multi):
    cfg = get_config(arch)
    mesh = abstract_mesh(multi)
    shapes = C.params_shapes(cfg)
    specs = shd.param_specs(shapes, cfg, mesh)
    assert_specs_divide(shapes, specs, mesh, where=f"{arch} params")


@pytest.mark.parametrize("arch", ["gemma_7b", "granite_moe_3b_a800m", "mamba2_2_7b"])
def test_zero1_adds_data_sharding(arch):
    cfg = get_config(arch)
    mesh = abstract_mesh()
    shapes = C.train_state_shapes(cfg)
    pspecs = shd.param_specs(shapes["params"], cfg, mesh)
    oz = shd.zero1_specs(shapes["opt"], pspecs, mesh)
    assert_specs_divide(shapes["opt"]["master"], oz["master"], mesh,
                        where=f"{arch} zero1 master")
    # at least the big 2D masters must pick up a data axis
    flat = [
        (l, s) for l, s in zip(
            jax.tree.leaves(shapes["opt"]["m"]),
            jax.tree.leaves(oz["m"], is_leaf=lambda x: isinstance(x, P)),
        )
        if np.prod(l.shape) > 1e6
    ]
    assert any("data" in str(s) for _, s in flat), "no ZeRO sharding applied"


@pytest.mark.parametrize("arch", ARCHS)
def test_cache_specs_divide(arch):
    cfg = get_config(arch)
    mesh = abstract_mesh()
    cell = C.SHAPES["decode_32k"]
    shapes = C.cache_shapes(cfg, cell.batch, cell.seq)
    specs = C.cache_specs(cfg, shapes, mesh, cell.batch)
    assert_specs_divide(shapes, specs, mesh, where=f"{arch} cache")


def test_kv_cache_head_vs_sequence_fallback():
    """gemma (16 kv heads) shards heads; internvl (8 kv heads) must fall
    back to split-KV over the sequence axis."""
    mesh = abstract_mesh()
    g = get_config("gemma_7b")
    shapes = C.cache_shapes(g, 128, 32768)
    specs = C.cache_specs(g, shapes, mesh, 128)
    flat = [tuple(s) for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))]
    # (L, B, H=16, S, hd): heads shard -> model at index -3
    assert all(s[-3] == "model" for s in flat if len(s) == 5), flat

    iv = get_config("internvl2_26b")
    shapes = C.cache_shapes(iv, 128, 32768)
    specs = C.cache_specs(iv, shapes, mesh, 128)
    flat = [tuple(s) for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))]
    # (L, B, H=8, S, hd): 8 heads don't divide 16 -> split-KV on S (index -2)
    assert all(s[-2] == "model" and s[-3] is None for s in flat if len(s) == 5), flat


def test_residual_spec_sequence_parallel():
    mesh = abstract_mesh()
    spec = shd.residual_spec(mesh, batch=256, seq=4096)
    assert tuple(spec) == ("data", "model", None)
    # odd seq: SP dropped
    spec = shd.residual_spec(mesh, batch=256, seq=1000)
    assert tuple(spec) == ("data", None, None)


def test_batch_spec_multi_pod():
    mesh = abstract_mesh(multi_pod=True)
    assert tuple(shd.batch_spec(mesh, 256)) == (("pod", "data"),)
    assert tuple(shd.batch_spec(mesh, 1)) == (None,)


def test_moe_ep_vs_tp_rule():
    mesh = abstract_mesh()
    ds = get_config("deepseek_v2_lite_16b")  # 64 experts % 16 == 0 -> EP
    shapes = C.params_shapes(ds)
    specs = shd.param_specs(shapes, ds, mesh)
    moe_spec = specs["layers"]["moe"]["w_gate"]
    assert "model" == tuple(moe_spec)[1]  # (L, E, D, F): EP on expert axis

    gr = get_config("granite_moe_3b_a800m")  # 40 experts -> TP inside expert
    shapes = C.params_shapes(gr)
    specs = shd.param_specs(shapes, gr, mesh)
    moe_spec = specs["layers"]["moe"]["w_gate"]
    t = tuple(moe_spec)
    assert t[1] is None and t[-1] == "model"


def test_pipeline_bubble_fraction():
    assert bubble_fraction(1, 4) == pytest.approx(3 / 4)
    assert bubble_fraction(16, 4) == pytest.approx(3 / 19)
    assert bubble_fraction(64, 2) < 0.02


def test_supported_matrix():
    """The 40-cell grid: long_500k runs only for sub-quadratic archs."""
    runs = {}
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape, cell in C.SHAPES.items():
            ok, _ = C.supported(cfg, cell)
            runs[(arch, shape)] = ok
    assert runs[("mamba2_2_7b", "long_500k")]
    assert runs[("hymba_1_5b", "long_500k")]
    assert not runs[("gemma_7b", "long_500k")]
    assert sum(runs.values()) == 10 * 4 - 8  # 8 full-attention skips
