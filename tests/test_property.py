"""Property-based tests (hypothesis) for the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import Schedule, compile as tl_compile
from repro.core import lang as T
from repro.core.expr import VarExpr, evaluate, linear_decompose
from repro.core.layout import round_up, row_major, vreg_fragment
from repro.core.schedule import physical_tile_shape, swizzle_decode
from repro.serving.paged_cache import (
    BlockPool,
    PoolExhausted,
    PrefixCache,
    SlotTables,
    blocks_for,
)

SMALL = st.integers(min_value=1, max_value=64)


class TestExprProperties:
    @given(
        st.integers(-100, 100), st.integers(-100, 100),
        st.integers(1, 100), st.integers(-100, 100),
    )
    @settings(max_examples=50, deadline=None)
    def test_eval_matches_python(self, x, y, d, c):
        vx, vy = VarExpr("x"), VarExpr("y")
        e = (vx * 3 + vy) // d + c - vy
        assert evaluate(e, {"x": x, "y": y}, None) == (x * 3 + y) // d + c - y

    @given(st.integers(-20, 20), st.integers(-20, 20), st.integers(-20, 20))
    @settings(max_examples=50, deadline=None)
    def test_linear_decompose_sound(self, a, b, c):
        """decompose(a*x + b*y + c) reproduces the coefficients exactly."""
        vx, vy = VarExpr("x"), VarExpr("y")
        dec = linear_decompose(a * vx + vy * b + c)
        assert dec is not None
        assert dec.get("x", 0) == a and dec.get("y", 0) == b and dec.get("", 0) == c


class TestLayoutProperties:
    @given(st.integers(1, 16), st.integers(1, 16))
    @settings(max_examples=30, deadline=None)
    def test_row_major_bijective(self, m, n):
        assert row_major((m, n)).is_bijective()

    @given(st.integers(1, 64), st.integers(1, 256), st.sampled_from(["float32", "bfloat16", "int8"]))
    @settings(max_examples=40, deadline=None)
    def test_physical_padding_is_aligned_superset(self, m, n, dtype):
        pm, pn = physical_tile_shape((m, n), dtype)
        assert pm >= m and pn >= n
        assert pn % 128 == 0

    @given(st.integers(1, 32), st.integers(1, 4), st.integers(1, 4))
    @settings(max_examples=30, deadline=None)
    def test_fragment_element_conservation(self, m, r, rt):
        """repeat/repeat_on_thread preserve elements-per-partition bookkeeping:
        threads * locals == total padded elements (x replication)."""
        base = vreg_fragment((8 * m, 128), "float32")
        frag = base.repeat(r, axis=0).repeat_on_thread(rt, axis=0)
        total = frag.threads() * frag.locals_per_thread()
        in_elems = 8 * m * r * rt * 128
        assert total >= in_elems  # padding can only add
        rep = frag.replicate(2)
        assert rep.threads() == 2 * frag.threads()


class TestSwizzleProperties:
    @given(st.integers(1, 12), st.integers(1, 12), st.integers(1, 8))
    @settings(max_examples=60, deadline=None)
    def test_decode_is_permutation(self, g0, g1, factor):
        pts = {swizzle_decode(f, g0, g1, factor) for f in range(g0 * g1)}
        assert len(pts) == g0 * g1
        assert all(0 <= i < g0 and 0 <= j < g1 for i, j in pts)


class TestPagedCacheProperties:
    """Invariants of the serving KV block allocator (serving/paged_cache.py):
    any interleaving of allocs and frees conserves blocks (no leak) and
    never hands the same block to two owners (no double-assign)."""

    @given(
        st.integers(1, 16),  # num_blocks
        st.integers(1, 8),  # page_size
        st.lists(
            st.tuples(st.booleans(), st.integers(0, 1 << 30)), max_size=60
        ),  # (alloc?, free-pick) op sequence
    )
    @settings(max_examples=60, deadline=None)
    def test_alloc_free_roundtrip_conserves_blocks(self, nb, ps, ops):
        pool = BlockPool(nb, ps)
        held = []
        for is_alloc, pick in ops:
            if is_alloc:
                if pool.free:
                    blk = pool.alloc()
                    assert blk not in held  # never double-assigned
                    assert 0 <= blk < nb
                    held.append(blk)
                else:
                    with pytest.raises(PoolExhausted):
                        pool.alloc()
            elif held:
                pool.release([held.pop(pick % len(held))])
            # conservation holds at every step
            assert pool.free + len(held) == nb
            assert pool.in_use == len(held)
        pool.release(held)
        assert pool.free == nb and pool.in_use == 0
        with pytest.raises(ValueError):  # everything is free now
            pool.release([0])

    @given(st.data())
    @settings(max_examples=50, deadline=None)
    def test_block_table_indexing(self, data):
        """Block tables map every live position to a page the slot owns, pad
        the tail with the reserved page 0, and never share a page between
        slots; releasing every slot drains the pool."""
        slots = data.draw(st.integers(1, 4))
        ps = data.draw(st.integers(1, 8))
        max_pages = data.draw(st.integers(1, 6))
        pool = BlockPool(slots * max_pages, ps, base=1)
        tables = SlotTables(pool, slots, max_pages)
        lens = [
            data.draw(st.integers(0, max_pages * ps), label=f"len[{s}]")
            for s in range(slots)
        ]
        for s, n in enumerate(lens):
            if n:
                tables.ensure_capacity(s, n)
        t = tables.tables()
        owned = [b for s in range(slots) for b in tables.blocks(s)]
        assert len(set(owned)) == len(owned)  # no page shared across slots
        assert all(b >= 1 for b in owned)  # page 0 reserved
        for s, n in enumerate(lens):
            live = blocks_for(n, ps)
            assert tables.num_blocks(s) == live
            for pos in range(n):
                phys = tables.lookup(s, pos)
                assert phys == t[s, pos // ps] and phys >= 1
            assert (t[s, live:] == 0).all()  # padding -> reserved page
        for s in range(slots):
            tables.release_slot(s)
        assert pool.in_use == 0 and pool.free == slots * max_pages

    @given(
        st.integers(1, 12),  # num_blocks
        st.lists(
            st.tuples(st.integers(0, 2), st.integers(0, 1 << 30)),
            max_size=80,
        ),  # (op: 0=alloc 1=retain 2=release, pick) sequence
    )
    @settings(max_examples=80, deadline=None)
    def test_refcount_conservation(self, nb, ops):
        """Any interleaving of alloc/retain/release conserves blocks: a
        block is live iff it holds references, the pool's refcounts match a
        shadow ledger exactly, and dropping every reference drains the pool
        (no leak, no early recycle)."""
        pool = BlockPool(nb, 4)
        refs = []  # one entry per outstanding reference (blocks repeat)
        for op, pick in ops:
            if op == 0:
                if pool.free:
                    blk = pool.alloc()
                    assert blk not in refs  # fresh block was really free
                    refs.append(blk)
                else:
                    with pytest.raises(PoolExhausted):
                        pool.alloc()
            elif op == 1 and refs:
                blk = refs[pick % len(refs)]
                pool.retain(blk)
                refs.append(blk)
            elif op == 2 and refs:
                pool.release([refs.pop(pick % len(refs))])
            live = set(refs)
            assert pool.in_use == len(live)
            assert pool.free == nb - len(live)
            for blk in live:
                assert pool.refcount(blk) == refs.count(blk)
        pool.release(refs)
        assert pool.in_use == 0 and pool.free == nb

    @given(st.data())
    @settings(max_examples=60, deadline=None)
    def test_cow_write_is_exclusively_reachable(self, data):
        """After the copy-on-write gate runs on a page index, that entry's
        page is reachable from exactly one slot table — no write can land
        in a page another table still maps."""
        ps = data.draw(st.integers(1, 4))
        max_pages = data.draw(st.integers(1, 5))
        pool = BlockPool(4 * max_pages + max_pages, ps, base=1)
        tables = SlotTables(pool, 2, max_pages)
        n_pages = data.draw(st.integers(1, max_pages), label="n_pages")
        tables.ensure_capacity(0, n_pages * ps, owner="a")
        # slot 1 shares an arbitrary subset of slot 0's pages and owns the
        # rest privately
        shared = [
            data.draw(st.booleans(), label=f"share[{i}]")
            for i in range(n_pages)
        ]
        for i, s in enumerate(shared):
            if s:
                tables.attach(1, [tables.blocks(0)[i]])
            else:
                tables.ensure_capacity(1, (i + 1) * ps, owner="b")
        writes = [
            i for i in range(n_pages)
            if data.draw(st.booleans(), label=f"write[{i}]")
        ]
        pairs = []
        for i in writes:
            pair = tables.ensure_writable(1, i, owner="b")
            if pair is not None:
                src, dst = pair
                assert src != dst
                assert shared[i]  # only genuinely shared pages copy
                pairs.append(pair)
        for i in writes:
            blk = tables.blocks(1)[i]
            assert pool.refcount(blk) == 1
            assert blk not in tables.blocks(0)  # exclusive reachability
        # idempotent: a second gate pass never copies again
        assert all(tables.ensure_writable(1, i, "b") is None for i in writes)
        tables.release_slot(0)
        tables.release_slot(1)
        assert pool.in_use == 0

    @given(st.data())
    @settings(max_examples=60, deadline=None)
    def test_eviction_never_reclaims_referenced_pages(self, data):
        """PrefixCache.evict only frees pages no slot table references
        (pool refcount 1) and never frees protected pages, however many
        pages are requested; attached pages survive with their references
        intact."""
        ps = data.draw(st.integers(1, 3))
        n_prompts = data.draw(st.integers(1, 4))
        prompts = [
            data.draw(
                st.lists(st.integers(0, 5), min_size=ps, max_size=4 * ps),
                label=f"prompt[{i}]",
            )
            for i in range(n_prompts)
        ]
        pool = BlockPool(64, ps, base=1)
        tables = SlotTables(pool, n_prompts, 8)
        cache = PrefixCache(pool, salt=("t", ps))
        for s, toks in enumerate(prompts):
            full = (len(toks) // ps) * ps
            if full == 0:
                continue
            tables.ensure_capacity(s, full, owner=s)
            for idx, cached in cache.insert(toks[:full], tables.blocks(s)):
                tables.repoint(s, idx, cached)
        # some slots finish: their references drop, cached pages go cold
        finished = [
            s for s in range(n_prompts)
            if data.draw(st.booleans(), label=f"finish[{s}]")
        ]
        for s in finished:
            tables.release_slot(s)
        held = {b for s in range(n_prompts) for b in tables.blocks(s)}
        protect = frozenset(
            b for b in held if data.draw(st.booleans(), label=f"prot[{b}]")
        )
        before = pool.in_use
        freed = cache.evict(data.draw(st.integers(0, 64)), protect=protect)
        assert pool.in_use == before - freed
        for b in held:  # table-referenced pages never reclaimed
            assert pool.refcount(b) >= 1
        for s in range(n_prompts):  # tables untouched by eviction
            for b in tables.blocks(s):
                assert b >= 1
        # a full-pressure evict leaves exactly the referenced pages
        cache.evict(64)
        for b in held:
            assert pool.refcount(b) >= 1


class TestTokenBudgetProperties:
    """Invariants of the Sarathi-style token-budget scheduler
    (serving/engine.py::plan_prefill_chunks): one budget token per
    generating slot is spent first, the leftover feeds prompt chunks
    oldest-admitted first, and the per-tick total never exceeds the
    (slot-count-floored) budget."""

    @given(
        st.integers(1, 64),  # budget
        st.integers(0, 16),  # generating slots
        st.lists(
            st.tuples(
                st.integers(0, 15),  # slot id
                st.integers(0, 1 << 20),  # admit seq
                st.integers(1, 4096),  # remaining replay tokens
            ),
            max_size=16,
            unique_by=lambda t: t[0],
        ),
        st.integers(1, 64),  # chunk
    )
    @settings(max_examples=200, deadline=None)
    def test_plan_never_exceeds_budget(self, budget, n_gen, pending, chunk):
        from repro.serving import plan_prefill_chunks

        plan = plan_prefill_chunks(budget, n_gen, pending, chunk)
        remaining = {s: r for s, _, r in pending}
        # hard ceiling: decode spend + prefill spend <= effective budget
        assert n_gen + sum(plan.values()) <= max(budget, n_gen)
        # grants are all-or-nothing: exactly min(chunk, remaining), never a
        # room-limited partial (the page-alignment contract of the prefill
        # kernel's table-directed writes)
        for s, n in plan.items():
            assert n == min(chunk, remaining[s])
        # grants form an age-ordered prefix (no head-of-line skipping)
        by_age = sorted(pending, key=lambda t: t[1])
        stopped = False
        for s, _seq, _rem in by_age:
            if s not in plan:
                stopped = True
            else:
                assert not stopped

    @given(
        st.integers(1, 2),  # slots
        st.integers(2, 24),  # token budget (pre-floor)
        st.integers(1, 8),  # prefill chunk
        st.lists(st.integers(1, 20), min_size=1, max_size=3),  # prompt lens
    )
    @settings(max_examples=8, deadline=None)
    def test_engine_tick_spend_bounded(self, slots, budget, chunk, plens):
        """End-to-end: a live engine's per-tick token spend (decode batch +
        prefill chunks) never exceeds its effective budget."""
        import jax

        from repro.configs import get_config
        from repro.models import lm as _lm
        from repro.serving import ServeConfig, ServingEngine

        cfg = get_config("qwen2_1_5b").reduced()
        if "qwen" not in _TINY_PARAMS:  # init once, not per hypothesis example
            _TINY_PARAMS["qwen"] = _lm.init(cfg, jax.random.PRNGKey(0))
        params = _TINY_PARAMS["qwen"]
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=slots, max_len=32, max_new_tokens=2, prefill="chunked",
            prefill_chunk=chunk, token_budget=budget))
        rng = np.random.default_rng(0)
        for n in plens:
            eng.submit(rng.integers(0, cfg.vocab_size, size=n).tolist())
        eng.run()
        assert eng.token_budget == max(budget, slots)
        assert eng.tick_tokens
        assert max(eng.tick_tokens) <= eng.token_budget


_TINY_PARAMS: dict = {}


class TestKernelProperties:
    @given(
        st.sampled_from([32, 64, 96]),
        st.sampled_from([32, 64]),
        st.sampled_from([32, 64, 128]),
    )
    @settings(max_examples=8, deadline=None)
    def test_matmul_random_shapes(self, M, N, K):
        from repro.kernels.matmul import matmul_program

        prog = matmul_program(M, N, K, block_M=32, block_N=32, block_K=32)
        kern = tl_compile(prog, Schedule(interpret=True))
        rng = np.random.default_rng(M * 1000 + N * 10 + K)
        a = rng.standard_normal((M, K), dtype=np.float32)
        b = rng.standard_normal((K, N), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(kern(a, b)), a @ b, atol=2e-3)

    @given(st.integers(0, 3))
    @settings(max_examples=4, deadline=None)
    def test_copy_roundtrip(self, seed):
        """global -> shared -> fragment -> global is the identity."""
        m, n = 16, 128

        @T.prim_func
        def RoundTrip(X: T.Tensor((m, n), "float32"), Y: T.Tensor((m, n), "float32")):
            with T.Kernel(1) as bx:
                s = T.alloc_shared((m, n), "float32")
                f = T.alloc_fragment((m, n), "float32")
                T.copy(X[0, 0], s)
                T.copy(s, f)
                T.copy(f, Y[0, 0])

        kern = tl_compile(RoundTrip, Schedule(interpret=True))
        rng = np.random.default_rng(seed)
        x = rng.standard_normal((m, n), dtype=np.float32)
        np.testing.assert_array_equal(np.asarray(kern(x)), x)


class TestDispatchGuardProperties:
    """Guard property: whatever single corruption hits a live block-table
    entry — out-of-range id, reserved page 0, or a duplicate of another
    row's page landing on a write position — ``guard_dispatch`` must
    reject the dispatch before any page is read or written, and a valid
    table must always pass (no false rejections)."""

    PS = 4

    @given(
        st.integers(0, 2**16),  # layout seed
        st.integers(2, 5),  # rows
        st.integers(2, 6),  # max_pages per row
        st.integers(0, 2),  # corruption flavor
    )
    @settings(max_examples=40, deadline=None)
    def test_corrupted_tables_always_rejected(self, seed, n_rows,
                                              max_pages, flavor):
        from repro.core.errors import GuardError
        from repro.kernels.ops import GUARDED_KINDS, guard_dispatch

        rng = np.random.default_rng(seed)
        num_pages = n_rows * max_pages + 1
        ids = rng.permutation(np.arange(1, num_pages))
        tb = np.zeros((n_rows, max_pages), np.int32)
        work, fill, k = [], [], 0
        for r in range(n_rows):
            n_live = int(rng.integers(1, max_pages + 1))
            pages = ids[k : k + n_live].tolist()
            k += n_live
            fill.append(pages)
            tb[r, : n_live] = pages
            end = int(rng.integers((n_live - 1) * self.PS + 1,
                                   n_live * self.PS + 1))
            work.append((r, end, end - 1, end))
        guard_dispatch(tb, num_pages, self.PS, work)  # valid: must pass
        victim = int(rng.integers(0, n_rows))
        live = -(-work[victim][1] // self.PS)
        if flavor == 0:
            tb[victim, int(rng.integers(0, live))] = (
                num_pages + int(rng.integers(0, 7))
            )
        elif flavor == 1:
            tb[victim, int(rng.integers(0, live))] = 0
        else:
            # duplicate another row's page onto the victim's write page
            other = (victim + 1) % n_rows
            tb[victim, live - 1] = fill[other][0]
        with pytest.raises(GuardError) as ei:
            guard_dispatch(tb, num_pages, self.PS, work)
        assert all(kind in GUARDED_KINDS
                   for _, kind, _ in ei.value.violations)
        assert any(r == victim for r, _, _ in ei.value.violations)


class TestFaultToleranceProperties:
    """Chaos property: *no* random fault schedule may leak pages or break
    refcount conservation.  The per-tick auditor (``audit=True``) checks
    the full ledger after every step, so any divergence raises at the
    tick that caused it; the end-state assertions pin the freed-page
    guarantee after drain and shutdown."""

    @given(
        st.integers(0, 2**16),  # schedule seed
        st.integers(1, 6),  # faults in the schedule
        st.booleans(),  # include poison faults (request-terminating)
    )
    @settings(max_examples=8, deadline=None)
    def test_random_fault_schedules_never_leak(self, seed, n_faults,
                                               with_poison):
        import jax

        from repro.configs import get_config
        from repro.models import lm as _lm
        from repro.serving import FaultInjector, ServeConfig, ServingEngine
        from repro.serving import random_schedule

        cfg = get_config("qwen2_1_5b").reduced()
        if "qwen" not in _TINY_PARAMS:
            _TINY_PARAMS["qwen"] = _lm.init(cfg, jax.random.PRNGKey(0))
        params = _TINY_PARAMS["qwen"]
        sites = ("pool_alloc", "grant") + (
            ("poison", "table_corrupt") if with_poison else ())
        inj = FaultInjector(random_schedule(
            seed, n_faults=n_faults, max_tick=16, sites=sites, slots=2))
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=32, max_new_tokens=4, page_size=4,
            num_blocks=10, sync_every=4, audit=True), injector=inj)
        rng = np.random.default_rng(seed)
        shared = rng.integers(0, cfg.vocab_size, size=4).tolist()
        for n in (3, 5, 2, 4):
            eng.submit(shared + rng.integers(0, cfg.vocab_size,
                                             size=n).tolist())
        eng.run(max_steps=200)  # audits every tick
        eng.drain()
        held = eng.prefix.pages if eng.prefix is not None else 0
        assert eng.pool.in_use == held  # only the index holds pages
        eng.shutdown()
        assert eng.pool.in_use == 0 and eng.pool.free == eng.pool.num_blocks
