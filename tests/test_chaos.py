"""Fault-tolerance tests: request lifecycle, fault injection, the live
invariant auditor, the seeded chaos harness, and crash-safe snapshot /
restore of the prefix cache (ISSUE-8 acceptance surface).

The contract under test:

* every request exits through exactly one terminal status, with its pages
  released on every exit path (cancel, deadline, retry exhaustion,
  poisoned logits, rejection, shutdown);
* pool and grant faults are output-preserving — requests they touch retry
  by recompute and finish byte-identical to a fault-free run;
* poison faults fail exactly the affected request;
* ``audit=True`` re-derives the refcount ledger every tick and raises
  :class:`AuditError` at the tick the books diverge;
* a restarted engine restored from ``snapshot()`` serves warm-prefix
  TTFT immediately (the crash-safety carry-over from the ROADMAP).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import (
    AuditError,
    Fault,
    FaultInjector,
    ServeConfig,
    ServingEngine,
    audit_engine,
    random_schedule,
)
from repro.serving.engine import (
    CANCELLED,
    COMPLETED,
    FAILED,
    QUEUED,
    REJECTED,
    TERMINAL,
    TIMED_OUT,
)
from repro.serving.faults import chaos_smoke


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen2_1_5b").reduced()
    return cfg, lm.init(cfg, jax.random.PRNGKey(0))


def _run(cfg, params, prompts, injector=None, submit_kw=None, **scfg_kw):
    eng = ServingEngine(cfg, params, ServeConfig(**scfg_kw),
                        injector=injector)
    submit_kw = submit_kw or [{}] * len(prompts)
    reqs = [eng.submit(p, **kw) for p, kw in zip(prompts, submit_kw)]
    eng.run()
    return reqs, eng


def _prompts(cfg, rng, sizes=(6, 3, 9, 2)):
    return [rng.integers(0, cfg.vocab_size, size=n).tolist() for n in sizes]


def _leftover(eng):
    """Pages still allocated beyond what the prefix index legitimately
    holds — zero means every request freed its pages."""
    held = eng.prefix.pages if eng.prefix is not None else 0
    return eng.pool.in_use - held


# ---------------------------------------------------------------------------
# Request lifecycle: terminal statuses and the freed-page guarantee
# ---------------------------------------------------------------------------


class TestLifecycle:
    BASE = dict(slots=1, max_len=48, max_new_tokens=6, page_size=4)

    def test_cancel_queued(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(**self.BASE))
        reqs = [eng.submit(p) for p in _prompts(cfg, rng, sizes=(6, 5, 4))]
        reqs[2].cancel()
        eng.run()
        assert reqs[2].status == CANCELLED and reqs[2].done
        assert reqs[2].output == []
        assert "cancel" in reqs[2].error
        assert all(r.status == COMPLETED for r in reqs[:2])
        assert _leftover(eng) == 0

    def test_cancel_running_preserves_partial_output(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(**self.BASE))
        req = eng.submit(rng.integers(0, cfg.vocab_size, size=6).tolist())
        while not req.output:  # step until mid-generation
            eng.step()
        req.cancel()
        eng.run()
        assert req.status == CANCELLED
        assert 0 < len(req.output) < self.BASE["max_new_tokens"]
        assert _leftover(eng) == 0

    def test_cancel_is_noop_after_terminal(self, qwen, rng):
        cfg, params = qwen
        reqs, _ = _run(cfg, params, _prompts(cfg, rng, sizes=(4,)),
                       **self.BASE)
        reqs[0].cancel()
        assert reqs[0].status == COMPLETED  # not flipped to CANCELLED

    def test_deadline_expires_in_queue(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(**self.BASE))
        hog = eng.submit(rng.integers(0, cfg.vocab_size, size=6).tolist())
        late = eng.submit(rng.integers(0, cfg.vocab_size, size=6).tolist(),
                          deadline_ticks=2)
        eng.run()
        assert hog.status == COMPLETED
        assert late.status == TIMED_OUT and late.admit_step is None
        assert "deadline" in late.error
        assert _leftover(eng) == 0

    def test_deadline_expires_mid_generation(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=48, max_new_tokens=20, page_size=4))
        req = eng.submit(rng.integers(0, cfg.vocab_size, size=4).tolist(),
                         deadline_ticks=4)
        eng.run()
        assert req.status == TIMED_OUT
        assert 0 < len(req.output) < 20  # partial output preserved
        assert _leftover(eng) == 0

    def test_reject_never_fits(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=16, max_new_tokens=2, page_size=4))
        ok = eng.submit(rng.integers(0, cfg.vocab_size, size=4).tolist())
        huge = eng.submit(rng.integers(0, cfg.vocab_size, size=64).tolist())
        eng.run()
        assert huge.status == REJECTED and "blocks" in huge.error
        assert ok.status == COMPLETED
        assert _leftover(eng) == 0

    def _pressure_engines(self, cfg, params, rng, **extra):
        """Two shared-prefix requests in a pool too small for both: the
        shared page is pinned (rc > 1) so the scheduler must preempt."""
        head = rng.integers(0, cfg.vocab_size, size=4).tolist()
        prompts = [head + rng.integers(0, cfg.vocab_size, size=4).tolist()
                   for _ in range(2)]
        refs = [_run(cfg, params, [p], slots=1, max_len=16,
                     max_new_tokens=6, page_size=4)[0][0].output
                for p in prompts]
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=16, max_new_tokens=6, page_size=4,
            num_blocks=5, **extra))
        return prompts, refs, eng

    def test_max_retries_exhaustion_fails_request(self, qwen, rng):
        cfg, params = qwen
        prompts, refs, eng = self._pressure_engines(cfg, params, rng)
        survivor = eng.submit(prompts[0])
        victim = eng.submit(prompts[1], max_retries=0)
        eng.run()
        assert victim.status == FAILED and "max_retries" in victim.error
        assert victim.preemptions == 1
        assert survivor.status == COMPLETED and survivor.output == refs[0]
        assert _leftover(eng) == 0

    def test_retry_backoff_still_completes_identically(self, qwen, rng):
        cfg, params = qwen
        prompts, refs, eng = self._pressure_engines(
            cfg, params, rng, retry_backoff=2, audit=True)
        reqs = [eng.submit(p) for p in prompts]
        eng.run()
        assert eng.preemptions >= 1
        assert [r.output for r in reqs] == refs  # recompute resume exact
        assert all(r.status == COMPLETED for r in reqs)
        assert getattr(reqs[1], "_not_before", 0) > 0  # backoff engaged
        assert _leftover(eng) == 0

    def test_drain_finishes_residents_keeps_queue(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(**self.BASE))
        reqs = [eng.submit(p) for p in _prompts(cfg, rng, sizes=(6, 5, 4))]
        eng.step()  # reqs[0] holds the single slot
        eng.drain()
        assert reqs[0].status == COMPLETED
        assert [r.status for r in reqs[1:]] == [QUEUED, QUEUED]
        assert not eng.admission_open and len(eng.queue) == 2
        eng.admission_open = True  # reopen: queued work resumes
        eng.run()
        assert all(r.status == COMPLETED for r in reqs)

    def test_shutdown_frees_every_page(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params,
                            ServeConfig(audit=True, **self.BASE))
        reqs = [eng.submit(p) for p in _prompts(cfg, rng)]
        eng.step()
        eng.shutdown()
        assert all(r.done and r.status in TERMINAL for r in reqs)
        assert sum(r.status == CANCELLED for r in reqs) >= 1  # the queued
        assert eng.pool.in_use == 0  # prefix index flushed too
        assert eng.prefix.pages == 0


# ---------------------------------------------------------------------------
# Fault injection at the allocation / dispatch sites
# ---------------------------------------------------------------------------


class TestFaultInjection:
    BASE = dict(slots=2, max_len=48, max_new_tokens=5, page_size=4)

    def test_fault_site_validated(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            Fault("cosmic_ray")

    def test_injector_fires_once_per_fault(self):
        inj = FaultInjector([Fault("pool_alloc", tick=3)], clock=lambda: 5)
        assert inj.pending("pool_alloc") and inj.remaining == 1
        f = inj.fire("pool_alloc")
        assert f is not None and f.fired_at == 5
        assert inj.fire("pool_alloc") is None  # consumed
        assert inj.fired == {"pool_alloc": 1, "grant": 0, "poison": 0,
                             "table_corrupt": 0, "spec_poison": 0}

    def test_injector_respects_clock(self):
        now = [0]
        inj = FaultInjector([Fault("grant", tick=4)], clock=lambda: now[0])
        assert inj.fire("grant") is None  # not due yet
        now[0] = 4
        assert inj.fire("grant") is not None

    def test_pool_fault_is_output_preserving(self, qwen, rng):
        cfg, params = qwen
        prompts = _prompts(cfg, rng)
        ref, _ = _run(cfg, params, prompts, **self.BASE)
        inj = FaultInjector([Fault("pool_alloc", tick=t)
                             for t in (0, 2, 4)])
        reqs, eng = _run(cfg, params, prompts, injector=inj,
                         audit=True, **self.BASE)
        assert inj.fired["pool_alloc"] == 3
        assert [r.output for r in reqs] == [r.output for r in ref]
        assert all(r.status == COMPLETED for r in reqs)
        assert _leftover(eng) == 0

    def test_grant_fault_forces_per_tick_fallback(self, qwen, rng):
        cfg, params = qwen
        prompts = _prompts(cfg, rng)
        ref, _ = _run(cfg, params, prompts, sync_every=4, **self.BASE)
        inj = FaultInjector([Fault("grant", tick=2)])
        reqs, eng = _run(cfg, params, prompts, injector=inj,
                         sync_every=4, audit=True, **self.BASE)
        assert inj.fired["grant"] == 1
        assert eng.window_fallbacks >= 1
        assert [r.output for r in reqs] == [r.output for r in ref]

    def test_poison_fails_exactly_the_hit_request(self, qwen, rng):
        cfg, params = qwen
        prompts = _prompts(cfg, rng)
        ref, _ = _run(cfg, params, prompts, **self.BASE)
        inj = FaultInjector([Fault("poison", tick=3, slot=0)])
        reqs, eng = _run(cfg, params, prompts, injector=inj,
                         audit=True, **self.BASE)
        assert eng.poisoned_rows == 1
        failed = [r for r in reqs if r.status == FAILED]
        assert len(failed) == 1 and "poisoned" in failed[0].error
        for r, rr in zip(reqs, ref):
            if r.status == COMPLETED:
                assert r.output == rr.output
        assert _leftover(eng) == 0

    def test_poison_inside_window_routes_per_tick(self, qwen, rng):
        """A pending poison fault closes the multi-step window (the scan
        has no per-row detection) so the poisoned row is still caught."""
        cfg, params = qwen
        inj = FaultInjector([Fault("poison", tick=3, slot=1)])
        reqs, eng = _run(cfg, params, _prompts(cfg, rng), injector=inj,
                         sync_every=8, audit=True, **self.BASE)
        assert eng.poisoned_rows == 1
        assert sum(r.status == FAILED for r in reqs) == 1
        assert _leftover(eng) == 0


# ---------------------------------------------------------------------------
# Invariant auditor
# ---------------------------------------------------------------------------


class TestAuditor:
    BASE = dict(slots=2, max_len=32, max_new_tokens=4, page_size=4)

    def test_clean_run_audits_every_tick(self, qwen, rng):
        cfg, params = qwen
        _, eng = _run(cfg, params, _prompts(cfg, rng), audit=True,
                      **self.BASE)
        # every tick audited (the final zero-work step audits too)
        assert eng.audits_run >= eng.steps_run > 0

    def test_orphan_allocation_detected(self, qwen, rng):
        cfg, params = qwen
        _, eng = _run(cfg, params, _prompts(cfg, rng, sizes=(6,)),
                      **self.BASE)
        eng.pool.alloc(owner="leak")  # allocated, referenced by nobody
        with pytest.raises(AuditError, match="referenced by no"):
            audit_engine(eng)

    def test_refcount_divergence_detected(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(**self.BASE))
        eng.submit(rng.integers(0, cfg.vocab_size, size=6).tolist())
        eng.step()  # slot 0 live and holding blocks
        audit_engine(eng)  # sane before corruption
        eng.pool.release([eng.tables.blocks(0)[0]])  # table -> freed page
        with pytest.raises(AuditError):
            audit_engine(eng)

    def test_terminal_request_in_slot_detected(self, qwen, rng):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(**self.BASE))
        req = eng.submit(rng.integers(0, cfg.vocab_size, size=6).tolist())
        eng.step()
        req.done = True  # bypassed _terminate: slot still held
        with pytest.raises(AuditError, match="terminal request"):
            audit_engine(eng)


# ---------------------------------------------------------------------------
# Dispatch guard: runtime obligations discharged before every paged launch
# ---------------------------------------------------------------------------


class TestGuardedDispatch:
    BASE = dict(slots=2, max_len=48, max_new_tokens=6, page_size=4,
                num_blocks=14, sync_every=4)

    def _workload(self, cfg, rng):
        shared = rng.integers(0, cfg.vocab_size, size=8).tolist()
        return [shared + rng.integers(0, cfg.vocab_size, size=n).tolist()
                for n in (3, 5, 2, 6)]

    def test_guards_off_matches_guards_on_when_clean(self, qwen, rng):
        """The guard observes — with no corruption it must not change a
        single token, whatever path (window / chunked / replay) runs."""
        cfg, params = qwen
        prompts = self._workload(cfg, rng)
        on, eng_on = _run(cfg, params, prompts, **self.BASE)
        off, eng_off = _run(cfg, params, prompts, guards=False, **self.BASE)
        assert [r.output for r in on] == [r.output for r in off]
        assert eng_on.guard_failures == 0
        assert eng_off.scfg.guards is False

    def test_table_corrupt_fails_only_the_hit_request(self, qwen, rng):
        """The acceptance scenario: an injected corrupt table entry FAILs
        exactly the dispatched request it hit — before any page is read or
        written — while every other request completes byte-identical to
        the fault-free run, under per-tick audit, leaking zero pages."""
        cfg, params = qwen
        prompts = self._workload(cfg, rng)
        ref, _ = _run(cfg, params, prompts, **self.BASE)
        inj = FaultInjector([Fault("table_corrupt", tick=3)])
        reqs, eng = _run(cfg, params, prompts, injector=inj, audit=True,
                         **self.BASE)
        assert eng.table_corruptions == 1
        assert eng.guard_failures == 1
        failed = [r for r in reqs if r.status == FAILED]
        assert len(failed) == 1
        assert "dispatch guard" in failed[0].error
        for r, base in zip(reqs, ref):
            if r.status == COMPLETED:
                assert r.output == base.output
        eng.drain()
        eng.shutdown()
        assert eng.pool.in_use == 0

    def test_every_corruption_flavor_is_caught(self, qwen, rng):
        """The injector cycles out-of-range / reserved-zero / duplicate
        corruption; each must be caught by the guard, never dispatched."""
        cfg, params = qwen
        prompts = self._workload(cfg, rng)
        # ticks spaced wider than sync_every so each fault lands on its
        # own dispatch (a multi-tick window advances the clock in jumps,
        # and co-due faults would corrupt one victim twice)
        inj = FaultInjector([Fault("table_corrupt", tick=t, slot=t)
                             for t in (2, 7, 12)])
        reqs, eng = _run(cfg, params, prompts, injector=inj, audit=True,
                         **self.BASE)
        assert eng.table_corruptions == 3
        assert eng.guard_failures >= 3
        assert sum(r.status == FAILED for r in reqs) >= 1
        eng.drain()
        eng.shutdown()
        assert eng.pool.in_use == 0

    def test_unguarded_corruption_caught_by_auditor(self, qwen, rng):
        """Satellite: guard and auditor agree on what corruption *is* —
        with guards off the same injected fault must trip the per-tick
        ledger audit instead of passing silently."""
        cfg, params = qwen
        prompts = self._workload(cfg, rng)
        inj = FaultInjector([Fault("table_corrupt", tick=3)])
        # per-tick stepping: inside a multi-tick window the corrupt entry
        # can be trimmed away with the grow-ahead before the boundary
        # audit looks (the guard checks *before* dispatch; the auditor
        # only sees state that survives the step)
        kw = {**self.BASE, "sync_every": 1}
        with pytest.raises(AuditError, match="diverged"):
            _run(cfg, params, prompts, injector=inj, audit=True,
                 guards=False, **kw)


# ---------------------------------------------------------------------------
# Chaos harness: seeded workloads x fault schedules
# ---------------------------------------------------------------------------


class TestChaos:
    def test_fixed_schedule_smoke(self, qwen):
        stats = chaos_smoke(seed=0, verbose=False)
        assert stats["mismatched"] == 0
        assert stats["leaked_pages"] == 0
        # only the poisoned and table-corrupted requests may be affected
        assert stats["affected"] <= 2
        assert stats["faults_fired"]["pool_alloc"] >= 1
        assert stats["faults_fired"]["table_corrupt"] == 1
        assert stats["guard_failures"] >= 1
        assert stats["audits_run"] > 0

    @pytest.mark.parametrize("seed", [1, 2, 3])
    def test_random_preserving_schedules_byte_identical(self, qwen, seed):
        """pool/grant faults only (both output-preserving): every request
        must complete with the exact fault-free tokens, under audit, and
        drain back to an empty pool."""
        cfg, params = qwen
        rng = np.random.default_rng(seed)
        shared = rng.integers(0, cfg.vocab_size, size=8).tolist()
        prompts = [shared + rng.integers(0, cfg.vocab_size, size=n).tolist()
                   for n in (3, 5, 2, 6)]
        kw = dict(slots=2, max_len=48, max_new_tokens=5, page_size=4,
                  num_blocks=14, sync_every=4)
        ref, _ = _run(cfg, params, prompts, **kw)
        inj = FaultInjector(random_schedule(
            seed, n_faults=5, max_tick=20, sites=("pool_alloc", "grant")))
        reqs, eng = _run(cfg, params, prompts, injector=inj, audit=True,
                         **kw)
        assert all(r.status == COMPLETED for r in reqs)
        assert [r.output for r in reqs] == [r.output for r in ref]
        eng.drain()
        assert _leftover(eng) == 0
        eng.shutdown()
        assert eng.pool.in_use == 0


# ---------------------------------------------------------------------------
# Crash-safe persistence: snapshot / restore
# ---------------------------------------------------------------------------


class TestSnapshotRestore:
    KW = dict(slots=1, max_len=48, max_new_tokens=3, page_size=4,
              prefill_chunk=4, token_budget=5)

    def _warm_engine(self, cfg, params, prompt):
        eng = ServingEngine(cfg, params, ServeConfig(**self.KW))
        cold = eng.submit(prompt)
        warm = eng.submit(prompt)
        eng.run()
        return eng, cold, warm

    def test_roundtrip_restores_warm_ttft(self, qwen, rng):
        cfg, params = qwen
        prompt = rng.integers(0, cfg.vocab_size, size=20).tolist()
        eng, cold, warm = self._warm_engine(cfg, params, prompt)
        assert warm.ttft_admit_ticks < cold.ttft_admit_ticks
        snap = eng.snapshot()
        eng2 = ServingEngine.restore(cfg, params, ServeConfig(**self.KW),
                                     snap)
        audit_engine(eng2)  # grafted pages are ledger-consistent
        restored = eng2.submit(prompt)
        eng2.run()
        assert restored.output == cold.output  # same tokens across restart
        assert restored.cached_tokens == warm.cached_tokens
        assert restored.ttft_admit_ticks == warm.ttft_admit_ticks
        eng2.shutdown()
        assert eng2.pool.in_use == 0

    def test_snapshot_pickles_to_disk(self, qwen, rng, tmp_path):
        cfg, params = qwen
        prompt = rng.integers(0, cfg.vocab_size, size=20).tolist()
        eng, cold, warm = self._warm_engine(cfg, params, prompt)
        path = str(tmp_path / "kv.snap")
        snap = eng.snapshot(path)
        assert len(snap["nodes"]) == eng.prefix.pages
        eng2 = ServingEngine.restore(cfg, params, ServeConfig(**self.KW),
                                     path)
        restored = eng2.submit(prompt)
        eng2.run()
        assert restored.output == cold.output
        assert restored.ttft_admit_ticks == warm.ttft_admit_ticks

    def test_partial_restore_when_pool_short(self, qwen, rng):
        cfg, params = qwen
        prompt = rng.integers(0, cfg.vocab_size, size=20).tolist()
        eng, _, _ = self._warm_engine(cfg, params, prompt)
        snap = eng.snapshot()
        assert len(snap["nodes"]) == 5  # the full 20-token prompt chain
        small = ServingEngine(cfg, params, ServeConfig(
            num_blocks=3, **self.KW))  # shorter than the snapshot chain
        got = small.load_snapshot(snap)
        assert got < len(snap["nodes"])
        audit_engine(small)  # the partial graft is still consistent

    def test_config_mismatch_is_loud(self, qwen, rng):
        cfg, params = qwen
        prompt = rng.integers(0, cfg.vocab_size, size=20).tolist()
        eng, _, _ = self._warm_engine(cfg, params, prompt)
        snap = eng.snapshot()
        other = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=48, max_new_tokens=3, page_size=8))
        with pytest.raises(ValueError, match="page_size"):
            other.load_snapshot(snap)
        bad = dict(snap, format=99)
        fresh = ServingEngine(cfg, params, ServeConfig(**self.KW))
        with pytest.raises(ValueError, match="format"):
            fresh.load_snapshot(bad)

    def test_snapshot_requires_prefix_cache(self, qwen):
        cfg, params = qwen
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=16, max_new_tokens=1, prefix_cache=False))
        with pytest.raises(ValueError, match="prefix cache"):
            eng.snapshot()


# ---------------------------------------------------------------------------
# Faults inside the speculative draft-verify window (ISSUE-10 satellite)
# ---------------------------------------------------------------------------


class TestSpecWindowFaults:
    """The accept/rollback path under fire: uncommitted draft tokens live
    only behind the position carry, so a fault mid-draft-window can cost
    throughput but never tokens or pages."""

    BASE = dict(slots=2, max_len=48, max_new_tokens=6, page_size=4,
                sync_every=4, spec_decode="ngram", draft_len=3)

    def test_spec_poison_fails_exactly_the_hit_request(self, qwen, rng):
        """Poisoned verify logits are detected on device inside the scan:
        the loop emits nothing for that row and reports it bad; the engine
        FAILs exactly that request, everyone else finishes byte-identical
        to the fault-free run, and rollback leaks zero pages."""
        cfg, params = qwen
        prompts = _prompts(cfg, rng)
        ref, _ = _run(cfg, params, prompts, **self.BASE)
        inj = FaultInjector([Fault("spec_poison", tick=3, slot=0)])
        reqs, eng = _run(cfg, params, prompts, injector=inj, audit=True,
                         **self.BASE)
        assert inj.fired["spec_poison"] == 1
        assert eng.poisoned_rows == 1
        failed = [r for r in reqs if r.status == FAILED]
        assert len(failed) == 1
        assert "poisoned verify logits" in failed[0].error
        for r, base in zip(reqs, ref):
            if r.status == COMPLETED:
                assert r.output == base.output
        assert _leftover(eng) == 0

    def test_grant_denial_mid_draft_window_is_output_preserving(
            self, qwen, rng):
        """A denied grow-ahead grant closes the draft window for that
        dispatch (spec_fallbacks counts it) but the engine degrades to the
        plain window / per-tick path and every request still completes
        byte-identical."""
        cfg, params = qwen
        prompts = _prompts(cfg, rng)
        ref, _ = _run(cfg, params, prompts, **self.BASE)
        inj = FaultInjector([Fault("grant", tick=2)])
        reqs, eng = _run(cfg, params, prompts, injector=inj, audit=True,
                         **self.BASE)
        assert inj.fired["grant"] == 1
        assert eng.spec_fallbacks >= 1
        assert all(r.status == COMPLETED for r in reqs)
        assert [r.output for r in reqs] == [r.output for r in ref]
        assert _leftover(eng) == 0

    def test_table_corrupt_under_spec_blames_the_hit_request(
            self, qwen, rng):
        """The dispatch guard runs on the draft window's page tables like
        any other dispatch: a corrupt entry FAILs exactly the request it
        hit before any page is touched."""
        cfg, params = qwen
        prompts = _prompts(cfg, rng)
        ref, _ = _run(cfg, params, prompts, **self.BASE)
        inj = FaultInjector([Fault("table_corrupt", tick=3)])
        reqs, eng = _run(cfg, params, prompts, injector=inj, audit=True,
                         **self.BASE)
        assert eng.table_corruptions == 1
        assert eng.guard_failures == 1
        failed = [r for r in reqs if r.status == FAILED]
        assert len(failed) == 1
        assert "dispatch guard" in failed[0].error
        for r, base in zip(reqs, ref):
            if r.status == COMPLETED:
                assert r.output == base.output
        eng.drain()
        eng.shutdown()
        assert eng.pool.in_use == 0
