"""Deterministic unit tests for the serving scheduler + paged KV cache.

Covers the ISSUE-2 acceptance surface:

* admission order (FIFO) and admission gating on free-block count;
* preemption-and-requeue when the pool is exhausted, including
  priority-aware victim selection and recompute-style resume;
* slot/block recycling at EOS (the pool drains back to empty);
* output equivalence between contiguous and paged cache modes across
  GQA / MQA / sliding-window / hybrid configs;
* the paged_attention kernel against its pure-JAX oracle.

Plus the ISSUE-4 device-resident decode loop:

* byte-identical outputs vs the per-tick engine across paged/contiguous,
  sync_every values, EOS mid-window, slots finishing mid-window, a pool
  too tight for the grow-ahead grant (per-tick fallback), preemption at a
  sync boundary, temperature sampling, and hybrid (recurrent-state) archs;
* the donation contract: the jit'd step consumes its cache argument;
* the cached device block-table tensor: re-uploaded only on mutation.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine
from repro.serving.paged_cache import (
    BlockPool,
    PoolExhausted,
    PrefixCache,
    SlotTables,
    blocks_for,
)


def _params(cfg, seed=0):
    return lm.init(cfg, jax.random.PRNGKey(seed))


def _qwen():
    return get_config("qwen2_1_5b").reduced()


# ---------------------------------------------------------------------------
# Block pool / tables (deterministic allocator unit tests; the hypothesis
# versions live in tests/test_property.py)
# ---------------------------------------------------------------------------


class TestBlockPool:
    def test_alloc_unique_and_exhaustion(self):
        pool = BlockPool(4, 8)
        got = [pool.alloc() for _ in range(4)]
        assert sorted(got) == [0, 1, 2, 3]
        assert pool.free == 0 and pool.in_use == 4
        with pytest.raises(PoolExhausted):
            pool.alloc()

    def test_release_roundtrip_and_double_free(self):
        pool = BlockPool(3, 4)
        a, b = pool.alloc("r1"), pool.alloc("r2")
        pool.release([a])
        assert pool.free == 2
        with pytest.raises(ValueError):
            pool.release([a])  # already free
        c = pool.alloc()
        assert c not in (b,)  # never double-assigned
        pool.release([b, c])
        assert pool.free == 3 and pool.in_use == 0

    def test_base_offset_reserves_page_zero(self):
        pool = BlockPool(4, 8, base=1)
        got = sorted(pool.alloc() for _ in range(4))
        assert got == [1, 2, 3, 4]  # page 0 never handed out

    def test_peak_accounting(self):
        pool = BlockPool(4, 8)
        xs = [pool.alloc() for _ in range(3)]
        pool.release(xs)
        pool.alloc()
        assert pool.peak_in_use == 3

    def test_blocks_for(self):
        assert blocks_for(1, 16) == 1
        assert blocks_for(16, 16) == 1
        assert blocks_for(17, 16) == 2


class TestSlotTables:
    def test_growth_lookup_and_table_tensor(self):
        pool = BlockPool(6, 4, base=1)
        st = SlotTables(pool, slots=2, max_pages=3)
        assert st.ensure_capacity(0, 5) == 2  # 5 tokens -> 2 pages
        assert st.ensure_capacity(0, 5) == 0  # idempotent
        assert st.ensure_capacity(1, 9) == 3
        t = st.tables()
        assert t.shape == (2, 3)
        assert t[0, 2] == 0  # padding entries point at the reserved page
        for pos in range(5):
            assert st.lookup(0, pos) == st.blocks(0)[pos // 4]
        owned = st.blocks(0) + st.blocks(1)
        assert len(set(owned)) == len(owned)  # no page shared across slots

    def test_exhaustion_allocates_nothing(self):
        pool = BlockPool(2, 4)
        st = SlotTables(pool, slots=2, max_pages=4)
        st.ensure_capacity(0, 8)
        with pytest.raises(PoolExhausted):
            st.ensure_capacity(1, 5)  # needs 2, pool has 0
        assert st.num_blocks(1) == 0 and pool.free == 0

    def test_release_slot_returns_blocks(self):
        pool = BlockPool(4, 4)
        st = SlotTables(pool, slots=1, max_pages=4)
        st.ensure_capacity(0, 16)
        assert pool.free == 0
        assert st.release_slot(0) == 4
        assert pool.free == 4
        assert not st.tables().any()

    def test_trim_releases_tail_only(self):
        pool = BlockPool(6, 4, base=1)
        st = SlotTables(pool, slots=1, max_pages=6)
        st.ensure_capacity(0, 20)  # 5 blocks (grow-ahead grant)
        kept = st.blocks(0)[:2]
        assert st.trim(0, 7) == 3  # 7 tokens -> 2 blocks
        assert st.blocks(0) == kept  # prefix untouched, order preserved
        assert pool.free == 4
        assert not st.tables()[0, 2:].any()
        assert st.trim(0, 7) == 0  # idempotent
        assert st.trim(0, 0) == 2  # trim-to-zero == full release


class TestRefcountedSharing:
    """Page sharing between tables: retain/attach/repoint and the
    copy-on-write gate (ISSUE-6)."""

    def test_retain_release_lifecycle(self):
        pool = BlockPool(2, 4)
        blk = pool.alloc("a")
        pool.retain(blk)
        assert pool.refcount(blk) == 2
        pool.release([blk])
        assert pool.refcount(blk) == 1 and pool.in_use == 1  # still live
        pool.release([blk])
        assert pool.refcount(blk) == 0 and pool.in_use == 0  # recycled
        with pytest.raises(ValueError):
            pool.retain(blk)  # can't retain a free block

    def test_attach_shares_pages_across_slots(self):
        pool = BlockPool(4, 4, base=1)
        st = SlotTables(pool, slots=2, max_pages=4)
        st.ensure_capacity(0, 8, owner="a")  # 2 pages
        shared = st.blocks(0)
        st.attach(1, shared)
        assert st.blocks(1) == shared
        assert all(pool.refcount(b) == 2 for b in shared)
        assert pool.in_use == 2  # physical pages, not references
        st.release_slot(0)
        assert all(pool.refcount(b) == 1 for b in shared)  # slot 1 holds on
        st.release_slot(1)
        assert pool.in_use == 0

    def test_attach_respects_max_pages(self):
        pool = BlockPool(8, 4, base=1)
        st = SlotTables(pool, slots=2, max_pages=2)
        st.ensure_capacity(0, 8, owner="a")
        with pytest.raises(ValueError):
            st.attach(1, st.blocks(0) + st.blocks(0))

    def test_repoint_swaps_reference(self):
        pool = BlockPool(4, 4, base=1)
        st = SlotTables(pool, slots=2, max_pages=2)
        st.ensure_capacity(0, 4, owner="a")
        st.ensure_capacity(1, 4, owner="b")
        canonical, dup = st.blocks(0)[0], st.blocks(1)[0]
        st.repoint(1, 0, canonical)
        assert st.blocks(1) == [canonical]
        assert pool.refcount(canonical) == 2
        assert pool.refcount(dup) == 0  # duplicate recycled
        assert st.tables()[1, 0] == canonical  # device tensor follows
        st.repoint(1, 0, canonical)  # same-page repoint is a no-op
        assert pool.refcount(canonical) == 2

    def test_ensure_writable_copies_only_shared_pages(self):
        pool = BlockPool(4, 4, base=1)
        st = SlotTables(pool, slots=2, max_pages=2)
        st.ensure_capacity(0, 8, owner="a")
        st.attach(1, st.blocks(0)[:1])  # share page 0 only
        st.ensure_capacity(1, 8, owner="b")  # private page 1
        assert st.ensure_writable(1, 1, "b") is None  # private: no copy
        src, dst = st.ensure_writable(1, 0, "b")  # shared: COW
        assert src == st.blocks(0)[0] and dst == st.blocks(1)[0]
        assert src != dst
        assert pool.refcount(src) == 1 and pool.refcount(dst) == 1
        assert st.tables()[1, 0] == dst
        assert st.ensure_writable(1, 0, "b") is None  # now exclusive

    def test_ensure_writable_exhaustion_frees_nothing(self):
        pool = BlockPool(2, 4, base=1)
        st = SlotTables(pool, slots=2, max_pages=2)
        st.ensure_capacity(0, 8, owner="a")  # pool drained
        st.attach(1, st.blocks(0)[:1])
        with pytest.raises(PoolExhausted):
            st.ensure_writable(1, 0, "b")
        # the failed gate changed nothing: still shared, still consistent
        assert st.blocks(1)[0] == st.blocks(0)[0]
        assert pool.refcount(st.blocks(0)[0]) == 2

    def test_trim_and_release_respect_sharing(self):
        pool = BlockPool(4, 4, base=1)
        st = SlotTables(pool, slots=2, max_pages=4)
        st.ensure_capacity(0, 16, owner="a")
        st.attach(1, st.blocks(0))
        st.trim(0, 4)  # slot 0 keeps 1 page; the other 3 survive via slot 1
        assert pool.in_use == 4
        assert st.num_blocks(1) == 4
        st.release_slot(1)
        assert pool.in_use == 1  # only slot 0's kept page remains


class TestPrefixIndex:
    """The radix index over token ids (unit level — engine integration is
    TestPrefixCaching below)."""

    def _cache(self, ps=4, nb=16):
        pool = BlockPool(nb, ps, base=1)
        return pool, PrefixCache(pool, salt=("test", ps))

    def test_insert_then_match_longest_chain(self):
        pool, pc = self._cache()
        toks = list(range(12))  # 3 full pages
        pages = [pool.alloc() for _ in range(3)]
        assert pc.insert(toks, pages) == []
        assert pc.pages == 3
        assert all(pool.refcount(p) == 2 for p in pages)  # index holds one
        assert pc.match(toks, max_pages=8) == pages
        assert pc.match(toks[:8] + [99, 99, 99, 99], 8) == pages[:2]
        assert pc.match([99] * 12, 8) == []
        # partial trailing page never matches (page granularity)
        assert pc.match(toks[:6], 8) == pages[:1]
        assert pc.hits == 3 and pc.lookups == 4

    def test_match_respects_cap(self):
        pool, pc = self._cache()
        toks = list(range(12))
        pc.insert(toks, [pool.alloc() for _ in range(3)])
        assert len(pc.match(toks, max_pages=1)) == 1
        assert pc.match(toks, max_pages=0) == []

    def test_insert_dedups_concurrent_prefills(self):
        pool, pc = self._cache()
        toks = list(range(8))
        first = [pool.alloc(), pool.alloc()]
        dup = [pool.alloc(), pool.alloc()]
        pc.insert(toks, first)
        # a second request prefilled the same prompt into its own pages:
        # the index reports the canonical pages so the caller repoints
        assert pc.insert(toks, dup) == [(0, first[0]), (1, first[1])]
        assert pc.pages == 2  # no duplicate nodes

    def test_hash_collision_cannot_alias(self):
        """Chain identity is content-checked: two different token blocks
        never resolve to the same cached page even if their hashes collide
        (lookup is by exact token tuple, the hash is only the chain key)."""
        pool, pc = self._cache()
        a, b = [0, 1, 2, 3], [4, 5, 6, 7]
        pa, pb = pool.alloc(), pool.alloc()
        pc.insert(a, [pa])
        pc.insert(b, [pb])
        assert pc.match(a, 1) == [pa]
        assert pc.match(b, 1) == [pb]

    def test_salt_keys_chains_per_model_config(self):
        pool = BlockPool(8, 4, base=1)
        pc1 = PrefixCache(pool, salt=("model-a", 4))
        pc2 = PrefixCache(pool, salt=("model-b", 4))
        assert pc1._root.key != pc2._root.key

    def test_evict_lru_leaves_first(self):
        pool, pc = self._cache()
        cold = list(range(8))
        hot = list(range(100, 108))
        cold_pages = [pool.alloc() for _ in range(2)]
        hot_pages = [pool.alloc() for _ in range(2)]
        pc.insert(cold, cold_pages)
        pc.insert(hot, hot_pages)
        pool.release(cold_pages + hot_pages)  # only the index holds them
        pc.match(cold, 2)
        pc.match(hot, 2)  # hot is most-recent
        assert pc.evict(1) == 1
        # the cold chain's leaf went first
        assert pc.match(cold, 2) == cold_pages[:1]
        assert pc.match(hot, 2) == hot_pages

    def test_evict_walks_chains_tail_first(self):
        pool, pc = self._cache()
        toks = list(range(12))
        pages = [pool.alloc() for _ in range(3)]
        pc.insert(toks, pages)
        pool.release(pages)
        assert pc.evict(3) == 3  # leaf, then exposed parent, then root child
        assert pc.pages == 0
        assert pool.in_use == 0

    def test_evict_skips_referenced_and_protected(self):
        pool, pc = self._cache()
        toks = list(range(8))
        pages = [pool.alloc() for _ in range(2)]
        pc.insert(toks, pages)  # rc 2 everywhere: caller + index
        assert pc.evict(8) == 0  # a table still references both
        pool.release([pages[1]])  # tail page goes cold (rc 1)
        assert pc.evict(8, protect=frozenset([pages[1]])) == 0  # protected
        assert pc.evict(8) == 1  # now reclaimable
        assert pool.refcount(pages[0]) == 2  # head survives untouched


# ---------------------------------------------------------------------------
# Scheduler behavior
# ---------------------------------------------------------------------------


class TestScheduler:
    def test_admission_order_fifo(self, rng):
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=2, max_len=32, max_new_tokens=2))
        reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=3).tolist())
                for _ in range(4)]
        eng.step()
        assert [eng.slot_req[0].uid, eng.slot_req[1].uid] == [reqs[0].uid, reqs[1].uid]
        assert [r.uid for r in eng.queue] == [reqs[2].uid, reqs[3].uid]
        done = eng.run()
        assert [r.uid for r in done] == [r.uid for r in reqs]  # FIFO completion

    def test_admission_gated_by_free_blocks(self, rng):
        # prefix_cache off: this test pins the free-block admission gate,
        # which sharing the identical prompt would legitimately bypass
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=2, max_len=16, max_new_tokens=2,
            page_size=4, num_blocks=4, prefix_cache=False))
        long_prompt = rng.integers(0, cfg.vocab_size, size=10).tolist()
        r1 = eng.submit(long_prompt)
        r2 = eng.submit(long_prompt)
        eng.step()
        # r1 holds 3 of 4 blocks; r2 (needs 3) must wait despite a free slot
        assert eng.slot_req[0] is r1 and eng.slot_req[1] is None
        assert list(eng.queue) == [r2]
        done = eng.run()
        assert [r.uid for r in done] == [r1.uid, r2.uid]
        assert eng.pool.in_use == 0  # everything recycled

    def test_preemption_requeue_and_recompute(self, rng):
        cfg = _qwen()
        params = _params(cfg)
        prompt1 = rng.integers(0, cfg.vocab_size, size=6).tolist()
        prompt2 = rng.integers(0, cfg.vocab_size, size=6).tolist()

        def alone(prompt):
            e = ServingEngine(cfg, params, ServeConfig(
                slots=1, max_len=16, max_new_tokens=6, page_size=4))
            r = e.submit(prompt)
            e.run()
            return r.output

        ref1, ref2 = alone(prompt1), alone(prompt2)

        # pool of 4 blocks: both requests admit at 2 blocks each, but each
        # needs a 3rd block mid-generation -> forced preemption
        # (prefix_cache off: published prompt pages would relieve exactly
        # the pool pressure this test constructs)
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=16, max_new_tokens=6,
            page_size=4, num_blocks=4, prefix_cache=False))
        r1 = eng.submit(prompt1)
        r2 = eng.submit(prompt2)
        done = eng.run()
        assert eng.preemptions >= 1
        assert r2.preemptions >= 1  # younger same-priority request evicted
        assert r1.preemptions == 0
        assert [r.uid for r in done] == [r1.uid, r2.uid]
        # recompute resume is lossless: outputs match isolated runs exactly
        assert r1.output == ref1
        assert r2.output == ref2
        assert eng.pool.in_use == 0

    def test_preemption_respects_priority(self, rng):
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=2, max_len=16, max_new_tokens=6,
            page_size=4, num_blocks=4))
        prompt = rng.integers(0, cfg.vocab_size, size=6).tolist()
        low = eng.submit(prompt, priority=0)
        high = eng.submit(prompt, priority=1)
        done = eng.run()
        # the older-but-lower-priority request is the victim
        assert low.preemptions >= 1 and high.preemptions == 0
        assert [r.uid for r in done] == [high.uid, low.uid]

    def test_blocks_recycled_at_eos(self, rng):
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=2, max_len=32, max_new_tokens=3, page_size=4))
        for _ in range(5):
            eng.submit(rng.integers(0, cfg.vocab_size, size=5).tolist())
        done = eng.run()
        assert len(done) == 5
        # everything recycled at EOS except the pages the prefix index
        # deliberately keeps (one full prompt page per unique 5-token prompt)
        assert eng.pool.in_use == eng.prefix.pages
        # 5 requests through a 2-slot engine only ever hold 2 slots of blocks
        # (+ the retained cache pages of completed requests)
        assert eng.peak_kv_blocks() <= 2 * blocks_for(5 + 3, 4) + eng.prefix.pages

    def test_unservable_request_fails_fast(self, rng):
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=1, max_len=64, max_new_tokens=2,
            page_size=4, num_blocks=2))  # pool holds 8 tokens
        big = eng.submit(rng.integers(0, cfg.vocab_size, size=20).tolist())
        ok = eng.submit(rng.integers(0, cfg.vocab_size, size=4).tolist())
        done = eng.run()
        assert big.error is not None and big.output == []
        assert ok.error is None and len(ok.output) == 2
        assert {r.uid for r in done} == {big.uid, ok.uid}

    def test_prompt_beyond_max_len_fails_fast(self, rng):
        """A prompt that outsizes the per-slot table (max_len) must fail the
        one request, not crash the engine — the pool may be big enough while
        the table is not."""
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=2, max_len=32, max_new_tokens=2, page_size=16))  # 4-block pool
        big = eng.submit(rng.integers(0, cfg.vocab_size, size=40).tolist())
        ok = eng.submit(rng.integers(0, cfg.vocab_size, size=4).tolist())
        done = eng.run()
        assert big.error is not None and big.output == []
        assert ok.error is None and len(ok.output) == 2
        assert {r.uid for r in done} == {big.uid, ok.uid}

    def test_mla_serves_paged(self):
        """MLA archs page their latent cache — the PR-2 era contiguous
        downgrade is gone."""
        cfg = get_config("deepseek_v2_lite_16b").reduced()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=1, max_len=16, max_new_tokens=2))
        assert eng.cache_mode == "paged"
        assert eng.cache.layout == "paged"

    def test_paged_without_attention_is_loud(self):
        """An arch with no attention KV state cannot page: asking for the
        paged layout raises instead of silently handing back a different
        memory layout than requested."""
        cfg = get_config("mamba2_2_7b").reduced()
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(cfg, _params(cfg), ServeConfig(
                slots=1, max_len=16, max_new_tokens=2, cache="paged"))
        # contiguous still serves the recurrent-state arch
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=1, max_len=16, max_new_tokens=2, cache="contiguous"))
        assert eng.cache_mode == "contiguous"


# ---------------------------------------------------------------------------
# Device-resident multi-step decode loop (ISSUE-4)
# ---------------------------------------------------------------------------


def _run_engine(cfg, params, prompts, **scfg_kw):
    eng = ServingEngine(cfg, params, ServeConfig(**scfg_kw))
    reqs = [eng.submit(p) for p in prompts]
    eng.run()
    assert all(r.done for r in reqs)
    return [r.output for r in reqs], reqs, eng


class TestMultiStepDecode:
    """The multi-step window is an *optimization*, never a behavior change:
    every test drives the same requests through the per-tick engine and the
    device-resident loop and asserts byte-identical outputs."""

    def _prompts(self, cfg, rng, sizes=(6, 3, 9, 2)):
        return [rng.integers(0, cfg.vocab_size, size=n).tolist() for n in sizes]

    @pytest.mark.parametrize("cache", ["paged", "contiguous"])
    @pytest.mark.parametrize("sync", [4, 16])
    def test_matches_per_tick(self, cache, sync, rng):
        # max_new=5 is deliberately not a multiple of sync: slots finish
        # mid-window and the drained tail must line up with per-tick
        cfg = _qwen()
        params = _params(cfg)
        prompts = self._prompts(cfg, rng)
        base = dict(slots=2, max_len=48, max_new_tokens=5, cache=cache,
                    page_size=16)
        ref, ref_reqs, _ = _run_engine(cfg, params, prompts, **base)
        out, reqs, eng = _run_engine(cfg, params, prompts,
                                     sync_every=sync, **base)
        assert out == ref
        assert eng.decode_windows > 0  # the loop actually engaged
        assert ([r.ttft_ticks for r in reqs]
                == [r.ttft_ticks for r in ref_reqs])
        if cache == "paged":
            assert eng.pool.in_use == 0  # grow-ahead pages all recycled

    def test_eos_mid_window(self, rng):
        cfg = _qwen()
        params = _params(cfg)
        prompts = self._prompts(cfg, rng)
        # temperature makes the greedy-degenerate streams diverse so the
        # chosen EOS token fires mid-generation, not on the first token
        base = dict(slots=2, max_len=48, max_new_tokens=8, page_size=16,
                    temperature=0.9, seed=11)
        free, _, _ = _run_engine(cfg, params, prompts, **base)
        eos = free[0][3]  # a token the model actually emits mid-stream
        ref, _, _ = _run_engine(cfg, params, prompts, eos_id=eos, **base)
        out, _, eng = _run_engine(cfg, params, prompts, eos_id=eos,
                                  sync_every=8, **base)
        assert out == ref
        assert eng.decode_windows > 0
        # EOS genuinely cut at least one stream short of its token limit
        assert any(len(o) < 8 for o in out)

    def test_temperature_matches_per_tick(self, rng):
        """The PRNG-key carry advances exactly like the per-tick engine's
        when the window covers the same ticks per-tick would run (queue
        empty, so no admission can be deferred past a mid-window finish —
        the one case where the key streams legitimately diverge, see
        lm.decode_loop).  temperature=8.0 so streams are genuinely diverse:
        random-init logits are peaked enough that lower temperatures emit
        constant streams, which would mask a shifted subkey."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = self._prompts(cfg, rng, sizes=(6, 3))  # <= slots: no queue
        base = dict(slots=2, max_len=48, max_new_tokens=6, page_size=16,
                    temperature=8.0, seed=3)
        ref, _, ref_eng = _run_engine(cfg, params, prompts, **base)
        out, _, eng = _run_engine(cfg, params, prompts, sync_every=4, **base)
        assert out == ref
        assert eng.decode_windows > 0
        # the sampled streams must be diverse enough to catch a shifted
        # subkey, and the final keys must agree bit for bit
        assert any(len(set(o)) > 1 for o in out)
        assert np.array_equal(np.asarray(eng._key), np.asarray(ref_eng._key))

    def test_hybrid_recurrent_state_matches_per_tick(self, rng):
        """Hybrid (attention + SSM) archs replay prompts and carry
        recurrent state: dead window iterations must not evolve a stopped
        slot's SSM state (the live mask inside decode_step)."""
        cfg = get_config("hymba_1_5b").reduced()
        params = _params(cfg)
        prompts = self._prompts(cfg, rng, sizes=(5, 3, 7, 2))
        base = dict(slots=2, max_len=48, max_new_tokens=5, page_size=16)
        ref, _, ref_eng = _run_engine(cfg, params, prompts, **base)
        out, _, eng = _run_engine(cfg, params, prompts, sync_every=4, **base)
        assert ref_eng.prefill_mode == "replay"  # SSM gates off chunking
        assert out == ref
        assert eng.decode_windows > 0

    def test_pool_too_tight_for_grow_ahead_falls_back(self, rng):
        """The pool exactly fits the per-tick footprint (page_size=1,
        2 slots x 8-token peak = 16 blocks), so a window whose
        allowance-clamped ask still includes the dead-iteration write
        (rem + 1) over-asks by one block per slot: the all-or-nothing
        grant must fail, fall back to per-tick stepping (never preempt),
        and still finish with per-tick-identical outputs.  Once the
        remaining allowance clamps the window to exactly fit, a window may
        legitimately run — fallback and windows coexist."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=3).tolist()
                   for _ in range(2)]
        base = dict(slots=2, max_len=16, max_new_tokens=6, page_size=1,
                    num_blocks=16, prefix_cache=False)
        ref, _, _ = _run_engine(cfg, params, prompts, **base)
        out, _, eng = _run_engine(cfg, params, prompts, sync_every=8, **base)
        assert out == ref
        assert eng.window_fallbacks > 0  # the 8-wide ask never fit
        assert eng.preemptions == 0  # the grant degrades, it doesn't evict
        assert eng.pool.in_use == 0

    def test_preemption_at_sync_boundary(self, rng):
        """Pool pressure mid-generation with the multi-step engine: growth
        (and so preemption + recompute resume) happens at sync boundaries
        and stays lossless."""
        cfg = _qwen()
        params = _params(cfg)
        prompt1 = rng.integers(0, cfg.vocab_size, size=6).tolist()
        prompt2 = rng.integers(0, cfg.vocab_size, size=6).tolist()
        ref1, _, _ = _run_engine(cfg, params, [prompt1], slots=1, max_len=16,
                                 max_new_tokens=6, page_size=4)
        ref2, _, _ = _run_engine(cfg, params, [prompt2], slots=1, max_len=16,
                                 max_new_tokens=6, page_size=4)
        out, reqs, eng = _run_engine(
            cfg, params, [prompt1, prompt2], slots=2, max_len=16,
            max_new_tokens=6, page_size=4, num_blocks=4, sync_every=4,
            prefix_cache=False)
        assert eng.preemptions >= 1
        assert reqs[1].preemptions >= 1 and reqs[0].preemptions == 0
        assert out == [ref1[0], ref2[0]]  # recompute resume is lossless
        assert eng.pool.in_use == 0

    def test_step_donates_cache(self, rng):
        """The jit'd steps consume their cache argument (donate_argnums):
        after a tick every pre-step buffer is invalidated — XLA reused it
        in place instead of copying the KV cache."""
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=1, max_len=32, max_new_tokens=4))
        eng.submit(rng.integers(0, cfg.vocab_size, size=3).tolist())
        before = jax.tree.leaves((eng.cache.prefix, eng.cache.rest))
        eng.step()
        assert all(leaf.is_deleted() for leaf in before)
        after = jax.tree.leaves((eng.cache.prefix, eng.cache.rest))
        assert not any(leaf.is_deleted() for leaf in after)

    def test_device_table_uploaded_only_on_mutation(self, rng):
        """One block covers the whole request, so after admission no tick
        mutates the tables: the engine must reuse the cached device tensor
        for the entire run instead of re-uploading it per tick."""
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=1, max_len=32, max_new_tokens=6, page_size=32))
        eng.submit(rng.integers(0, cfg.vocab_size, size=3).tolist())
        eng.run()
        assert eng.steps_run > 3  # several ticks actually ran
        assert eng.table_uploads == 1  # exactly the admission upload

    def test_greedy_never_splits_key(self, rng):
        """temperature <= 0 skips jax.random.split entirely: the PRNG key
        comes back from every fused step bit-identical."""
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=2, max_len=32, max_new_tokens=4, seed=7))
        for n in (5, 3):
            eng.submit(rng.integers(0, cfg.vocab_size, size=n).tolist())
        eng.run()
        assert np.array_equal(
            np.asarray(eng._key), np.asarray(jax.random.PRNGKey(7))
        )


# ---------------------------------------------------------------------------
# Contiguous vs paged equivalence across attention variants
# ---------------------------------------------------------------------------


def _variants():
    q = _qwen()
    return [
        ("gqa", q),
        ("mqa", dataclasses.replace(q, num_kv_heads=1)),
        ("sliding_window", dataclasses.replace(
            q, sliding_window=12, global_attn_every=2)),
        ("soft_cap", dataclasses.replace(q, logit_soft_cap=5.0)),
        ("hybrid_windowed", get_config("hymba_1_5b").reduced()),
        ("mla", get_config("deepseek_v2_lite_16b").reduced()),
    ]


@pytest.mark.parametrize("name,cfg", _variants(), ids=[n for n, _ in _variants()])
def test_paged_matches_contiguous(name, cfg, rng):
    params = _params(cfg)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=n).tolist() for n in (6, 3, 9, 2)
    ]

    def drive(mode):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=48, max_new_tokens=5, cache=mode, page_size=16))
        reqs = [eng.submit(p) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.output for r in reqs]

    contig = drive("contiguous")
    paged = drive("paged")
    assert paged == contig  # identical decode outputs, token for token


# ---------------------------------------------------------------------------
# MLA end-to-end: the paged latent cache + chunked prefill (ISSUE-5)
# ---------------------------------------------------------------------------


def test_mla_paged_chunked_matches_contiguous_replay(rng):
    """The acceptance matrix: an MLA config serves through the paged latent
    cache and chunked prefill with outputs byte-identical to the legacy
    contiguous/replay path — all four layout x prefill combinations agree,
    and the paged runs recycle every block."""
    cfg = get_config("deepseek_v2_lite_16b").reduced()
    params = _params(cfg)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=n).tolist() for n in (22, 3, 17, 9)
    ]

    def drive(cache, prefill):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=48, max_new_tokens=5, cache=cache,
            prefill=prefill, prefill_chunk=16, page_size=16))
        reqs = [eng.submit(p) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.output for r in reqs], eng

    ref_out, _ = drive("contiguous", "replay")
    for cache, prefill in [("contiguous", "chunked"), ("paged", "replay"),
                           ("paged", "chunked")]:
        out, eng = drive(cache, prefill)
        assert out == ref_out, f"{cache}/{prefill} diverged"
        assert eng.prefill_mode == prefill
        if cache == "paged":
            # every latent page recycled except the full prompt pages the
            # prefix index retains (22- and 17-token prompts @ ps=16 -> one
            # each); byte-identity above covers caching-on vs contiguous
            assert eng.pool.in_use == eng.prefix.pages
            assert eng.prefix.pages == 2


def test_mla_paged_multistep_matches_per_tick(rng):
    """The device-resident decode window over the **latent** page layout:
    grow-ahead grants/trims must account the head-axis-free ckv/kpe pools
    exactly like GQA KV pages — byte-identical to per-tick stepping, every
    latent page recycled, and the window genuinely engaged."""
    cfg = get_config("deepseek_v2_lite_16b").reduced()
    params = _params(cfg)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (6, 3, 9, 2)]
    base = dict(slots=2, max_len=48, max_new_tokens=5, cache="paged",
                page_size=16)
    ref, _, _ = _run_engine(cfg, params, prompts, **base)
    for sync in (4, 16):
        out, _, eng = _run_engine(cfg, params, prompts, sync_every=sync,
                                  **base)
        assert out == ref
        assert eng.decode_windows > 0
        assert eng.pool.in_use == 0


def test_mla_paged_preemption_lossless(rng):
    """Pool pressure on the latent pages: preemption + recompute resume
    must stay lossless for MLA exactly as for GQA."""
    cfg = get_config("deepseek_v2_lite_16b").reduced()
    params = _params(cfg)
    prompt1 = rng.integers(0, cfg.vocab_size, size=6).tolist()
    prompt2 = rng.integers(0, cfg.vocab_size, size=6).tolist()

    def alone(prompt):
        e = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=16, max_new_tokens=6, page_size=4))
        r = e.submit(prompt)
        e.run()
        return r.output

    ref1, ref2 = alone(prompt1), alone(prompt2)
    eng = ServingEngine(cfg, params, ServeConfig(
        slots=2, max_len=16, max_new_tokens=6, page_size=4, num_blocks=4))
    r1, r2 = eng.submit(prompt1), eng.submit(prompt2)
    eng.run()
    assert eng.preemptions >= 1
    assert r1.output == ref1 and r2.output == ref2
    # only the prefix-cached prompt pages outlive the requests (6-token
    # prompts @ ps=4 -> one full page each, shared with nobody)
    assert eng.pool.in_use == eng.prefix.pages


# ---------------------------------------------------------------------------
# Prefix caching: refcounted sharing + COW through the engine (ISSUE-6)
# ---------------------------------------------------------------------------


def test_copy_pages_copies_every_page_leaf(rng):
    """lm.copy_pages duplicates physical pages across every ``*_pages``
    leaf (GQA k/v pages, MLA latent + rope pages) and leaves all other
    pages untouched — the device half of copy-on-write."""
    import jax.numpy as jnp

    for name in ("qwen2_1_5b", "deepseek_v2_lite_16b"):
        cfg = get_config(name).reduced()
        cache = lm.init_cache(cfg, 1, 16, layout="paged", page_size=4,
                              num_blocks=6)

        def fill(leaf):
            vals = np.arange(leaf.size, dtype=np.float32) % 251
            return jnp.asarray(vals.reshape(leaf.shape), leaf.dtype)

        cache = lm.Cache(
            jax.tree_util.tree_map(fill, cache.prefix),
            jax.tree_util.tree_map(fill, cache.rest),
            cache.stacked, cache.max_len, cache.layout, cache.page_size,
            cache.tables,
        )
        out = lm.copy_pages(cache, [1, 2], [4, 5])

        def check(path, before, after):
            names = [
                str(p.key) for p in path
                if isinstance(p, jax.tree_util.DictKey)
            ]
            b = np.asarray(jnp.moveaxis(before, before.ndim - 3, 0))
            a = np.asarray(jnp.moveaxis(after, after.ndim - 3, 0))
            if any(n.endswith("_pages") for n in names):
                np.testing.assert_array_equal(a[4], b[1])
                np.testing.assert_array_equal(a[5], b[2])
                np.testing.assert_array_equal(a[3], b[3])  # bystander
            else:
                np.testing.assert_array_equal(a, b)  # non-page leaves

        jax.tree_util.tree_map_with_path(check, cache.prefix, out.prefix)
        jax.tree_util.tree_map_with_path(check, cache.rest, out.rest)


class TestPrefixCaching:
    """Engine-level prefix caching: cache-hit chunks never dispatch, shared
    pages are refcounted, divergence goes through copy-on-write, and every
    mode stays byte-identical to a caching-disabled run."""

    def _shared_prompts(self, cfg, rng, prefix_len=12, tails=(7, 3, 10, 1)):
        shared = rng.integers(0, cfg.vocab_size, size=prefix_len).tolist()
        return [
            shared + rng.integers(0, cfg.vocab_size, size=t).tolist()
            for t in tails
        ]

    def test_warm_prefix_ttft_collapses_to_one_chunk(self, rng):
        """The tentpole number: a warm shared prefix skips its cached pages
        entirely at admission, so TTFT falls from ceil(prompt/chunk) ticks
        to ~one chunk's worth for the divergent tail."""
        cfg = _qwen()
        params = _params(cfg)
        prompt = rng.integers(0, cfg.vocab_size, size=20).tolist()
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=48, max_new_tokens=3, page_size=4,
            prefill_chunk=4, token_budget=5))
        r_cold = eng.submit(prompt)
        r_warm = eng.submit(prompt)  # slots=1: strictly after r_cold
        eng.run()
        assert r_cold.output == r_warm.output
        assert r_cold.cached_tokens == 0
        # 20-token prompt, 4-token chunks: cold prefill takes 5 ticks
        assert r_cold.ttft_admit_ticks == 5
        # warm: 4 of 5 pages cached (the last is held back so one replay
        # token remains); the 4-token tail is exactly one chunk
        assert r_warm.cached_tokens == 16
        assert r_warm.ttft_admit_ticks == 1
        assert eng.pages_shared == 4
        assert eng.prefix.hits >= 1

    def test_byte_identity_against_caching_disabled(self, rng):
        """Acceptance matrix: shared-prefix traffic produces byte-identical
        tokens with the prefix cache on vs off, across chunked and replay
        prefill."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = self._shared_prompts(cfg, rng)
        for prefill in ("chunked", "replay"):
            base = dict(slots=2, max_len=48, max_new_tokens=4, page_size=4,
                        prefill=prefill)
            ref, _, off = _run_engine(cfg, params, prompts,
                                      prefix_cache=False, **base)
            out, reqs, on = _run_engine(cfg, params, prompts, **base)
            assert out == ref, f"{prefill}: caching changed tokens"
            assert on.pages_shared > 0  # sharing actually engaged
            assert off.pages_shared == 0
            # warm requests hold fewer fresh pages than the no-share path
            assert on.pool.peak_in_use < off.pool.peak_in_use + \
                on.prefix.pages

    def test_multistep_window_with_prefix_cache(self, rng):
        """sync_every > 1 over shared-prefix traffic: the device-resident
        window composes with attached cache pages, byte-identically."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = self._shared_prompts(cfg, rng)
        base = dict(slots=2, max_len=48, max_new_tokens=6, page_size=4)
        ref, _, _ = _run_engine(cfg, params, prompts, prefix_cache=False,
                                **base)
        out, _, eng = _run_engine(cfg, params, prompts, sync_every=4, **base)
        assert out == ref
        assert eng.decode_windows > 0 and eng.pages_shared > 0

    def test_preemption_with_shared_pages_lossless(self, rng):
        """Mid-generation preemption while prefix pages are shared: the
        victim's references drop without disturbing the survivor or the
        index, and recompute resume (which re-matches the cache) stays
        byte-identical to isolated runs."""
        cfg = _qwen()
        params = _params(cfg)
        # shared first page, divergent second page: the shared page stays
        # pinned (refcount > 1) so eviction cannot relieve the pressure and
        # the scheduler must preempt the younger request mid-generation
        head = rng.integers(0, cfg.vocab_size, size=4).tolist()
        prompts = [head + rng.integers(0, cfg.vocab_size, size=4).tolist()
                   for _ in range(2)]
        refs = [_run_engine(cfg, params, [p], slots=1, max_len=16,
                            max_new_tokens=6, page_size=4)[0][0]
                for p in prompts]
        out, reqs, eng = _run_engine(
            cfg, params, prompts, slots=2, max_len=16,
            max_new_tokens=6, page_size=4, num_blocks=5)
        assert eng.preemptions >= 1
        assert reqs[1].preemptions >= 1
        assert out == refs
        assert eng.pages_shared > 0

    def test_cow_on_divergent_write_chunked(self, rng):
        """A write landing in a genuinely shared page triggers exactly one
        copy-on-write — fresh page, device copy, repoint — with outputs
        byte-identical to an unshared run.  (The scheduler's page-aligned
        sharing never produces this naturally, so the test constructs the
        alias directly.)"""
        cfg = _qwen()
        params = _params(cfg)
        prompt = rng.integers(0, cfg.vocab_size, size=6).tolist()
        ref, _, _ = _run_engine(cfg, params, [prompt], slots=1, max_len=32,
                                max_new_tokens=4, page_size=4)
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=32, max_new_tokens=4, page_size=4,
            prefix_cache=False))
        r1, r2 = eng.submit(prompt), eng.submit(prompt)
        eng._admit()  # both resident, nothing dispatched yet
        # alias slot 1's first page onto slot 0's: the first prefill write
        # into it must now copy
        eng.tables.repoint(1, 0, eng.tables.blocks(0)[0])
        eng._tables_dirty = True
        eng.run()
        assert eng.pages_copied == 1
        assert r1.output == ref[0] and r2.output == ref[0]
        assert eng.pool.in_use == 0  # the COW copy was released too

    def test_cow_on_divergent_write_multistep(self, rng):
        """COW under the sync_every>1 decode window: a page shared
        mid-generation is copied before the on-device loop dispatches."""
        cfg = _qwen()
        params = _params(cfg)
        prompt = rng.integers(0, cfg.vocab_size, size=6).tolist()
        ref, _, _ = _run_engine(cfg, params, [prompt], slots=1, max_len=32,
                                max_new_tokens=6, page_size=4)
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=32, max_new_tokens=6, page_size=4,
            sync_every=4, prefix_cache=False))
        r1, r2 = eng.submit(prompt), eng.submit(prompt)
        eng.step()  # prefill tick: both slots transition to gen
        assert all(st == "gen" for st in eng.slot_state)
        # identical prompts -> identical KV: alias slot 1's live tail page
        # onto slot 0's (content-preserving), forcing COW at the next write
        eng.tables.repoint(1, 1, eng.tables.blocks(0)[1])
        eng._tables_dirty = True
        eng.run()
        assert eng.pages_copied >= 1
        assert eng.decode_windows > 0
        assert r1.output == ref[0] and r2.output == ref[0]

    def test_pool_pressure_evicts_cold_cache_pages(self, rng):
        """Graceful degradation: when fresh requests need blocks the cold
        cached pages hold, eviction reclaims them (LRU) instead of refusing
        admission — the hot pool serves like an uncached engine."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=8).tolist()
                   for _ in range(3)]
        out, reqs, eng = _run_engine(
            cfg, params, prompts, slots=1, max_len=16, max_new_tokens=2,
            page_size=4, num_blocks=4)
        assert all(r.error is None for r in reqs)
        assert [len(o) for o in out] == [2, 2, 2]
        assert eng.prefix.evictions >= 1  # cold pages made room
        assert eng.pool.in_use == eng.prefix.pages

    def test_contiguous_and_recurrent_archs_skip_the_index(self):
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=1, max_len=16, cache="contiguous"))
        assert eng.prefix is None
        cfg2 = get_config("mamba2_2_7b").reduced()
        eng2 = ServingEngine(cfg2, _params(cfg2), ServeConfig(
            slots=1, max_len=16, cache="contiguous"))
        assert eng2.prefix is None


# ---------------------------------------------------------------------------
# paged kernels vs their pure-JAX oracles
# ---------------------------------------------------------------------------


def test_mla_paged_kernel_matches_oracle(rng):
    from repro.core import Schedule, compile as tl_compile
    from repro.kernels import ref
    from repro.kernels.mla import (
        PARITY_CASES,
        mla_paged_program,
        parity_inputs,
    )

    for name, cfg in PARITY_CASES:
        if not name.startswith("mla_paged") or "quant" in name:
            continue
        prog = mla_paged_program(**cfg)
        kern = tl_compile(prog, Schedule(interpret=True), target="pallas")
        tbl, lens, q, qpe, ckv, kpe = parity_inputs(name, prog, rng)
        out = np.asarray(kern(tbl, lens, q, qpe, ckv, kpe))
        oracle = np.asarray(
            ref.mla_paged(q, qpe, ckv, kpe, tbl, lens,
                          window=cfg.get("window"))
        )
        np.testing.assert_allclose(out, oracle, rtol=1e-4, atol=2e-3)


def test_mla_soft_cap_routes_to_oracle(rng):
    """Soft-capped MLA decode takes the oracle path (same policy as GQA
    paged_attention) and the cap visibly changes the scores."""
    from repro.kernels import ops, ref
    from repro.kernels.mla import PARITY_CASES, parity_inputs, mla_paged_program

    cfg = dict(PARITY_CASES)["mla_paged"]
    prog = mla_paged_program(**cfg)
    tbl, lens, q, qpe, ckv, kpe = parity_inputs("mla_paged", prog, rng)
    capped = ops.mla_paged(q, qpe, ckv, kpe, tbl, lens,
                           logit_soft_cap=1.0, backend="pallas")
    oracle = ref.mla_paged(q, qpe, ckv, kpe, tbl, lens, logit_soft_cap=1.0)
    np.testing.assert_allclose(np.asarray(capped), np.asarray(oracle),
                               rtol=1e-5, atol=1e-6)
    uncapped = ref.mla_paged(q, qpe, ckv, kpe, tbl, lens)
    assert not np.allclose(np.asarray(capped), np.asarray(uncapped), atol=1e-4)


def test_paged_attention_kernel_matches_oracle(rng):
    from repro.core import Schedule, compile as tl_compile
    from repro.kernels import ref
    from repro.kernels.paged_attention import (
        PARITY_CASES,
        paged_attention_program,
        parity_inputs,
    )

    for name, cfg in PARITY_CASES:
        if "quant" in name:
            continue
        prog = paged_attention_program(**cfg)
        kern = tl_compile(prog, Schedule(interpret=True), target="pallas")
        tbl, lens, q, kp, vp = parity_inputs(name, prog, rng)
        out = np.asarray(kern(tbl, lens, q, kp, vp))
        oracle = np.asarray(
            ref.paged_attention(q, kp, vp, tbl, lens, window=cfg.get("window"))
        )
        np.testing.assert_allclose(out, oracle, rtol=1e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# Quantized KV cache (ISSUE-7): int8/int4 page pools behind the same engine
# ---------------------------------------------------------------------------


class TestQuantizedKV:
    """The quantized page pools are a storage-format swap, not a scheduler
    change: admission, sharing, COW and the multi-step loop all run
    unchanged over packed ``*_pages`` + fp ``*_scale_pages`` leaves, while
    ``kv_bytes`` shrinks by the pack factor (plus the scale column)."""

    def _run(self, cfg, params, prompts, **kw):
        kw.setdefault("slots", 2)
        kw.setdefault("max_len", 64)
        kw.setdefault("max_new_tokens", 6)
        kw.setdefault("page_size", 8)
        return _run_engine(cfg, params, prompts, **kw)

    def test_int8_outputs_and_bytes(self, rng):
        """At the reduced config int8 holds greedy decode token-for-token
        while the cache drops below 0.55x of the fp footprint (ISSUE-7
        acceptance: <= 0.55x for int8)."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
                   for n in (13, 7, 19)]
        out_fp, _, eng_fp = self._run(cfg, params, prompts)
        out_q, _, eng_q = self._run(cfg, params, prompts, kv_dtype="int8")
        assert out_q == out_fp
        ratio = eng_q.cache.kv_bytes() / eng_fp.cache.kv_bytes()
        assert ratio <= 0.55
        # the scale pools ride along as *_pages leaves (COW-visible)
        kv = (eng_q.cache.rest["kv"] if eng_q.cache.stacked
              else eng_q.cache.rest[0]["kv"])
        assert sorted(kv.keys()) == [
            "k_pages", "k_scale_pages", "v_pages", "v_scale_pages"]
        assert str(kv["k_pages"].dtype) == "int8"

    def test_int4_bytes_ratio(self, rng):
        """int4 packs two values per byte: cache <= 0.30x fp (ISSUE-7
        acceptance) and the engine still serves to completion."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
                   for n in (9, 14)]
        out_fp, _, eng_fp = self._run(cfg, params, prompts)
        out_q, reqs, eng_q = self._run(cfg, params, prompts, kv_dtype="int4")
        assert all(len(o) == 6 for o in out_q)
        assert eng_q.cache.kv_bytes() / eng_fp.cache.kv_bytes() <= 0.30

    def test_fp_cache_shape_unchanged(self):
        """kv_dtype=None is byte-identical to before: no scale leaves, pool
        dtype = cfg.dtype (quantization is strictly opt-in)."""
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=1, max_len=16, max_new_tokens=1))
        kv = (eng.cache.rest["kv"] if eng.cache.stacked
              else eng.cache.rest[0]["kv"])
        assert sorted(kv.keys()) == ["k_pages", "v_pages"]
        assert str(kv["k_pages"].dtype) == cfg.dtype

    def test_prefix_sharing_and_cow_on_quant_pages(self, rng):
        """Refcounted sharing + copy-on-write work on quantized pools: the
        scale pools are ``*_pages`` leaves, so ``lm.copy_pages`` duplicates
        packed bytes and scales together and a COW'd slot keeps decoding
        the same tokens as the fp engine."""
        cfg = _qwen()
        params = _params(cfg)
        shared = rng.integers(0, cfg.vocab_size, size=32).tolist()  # 4 pages
        prompts = [shared + [100 + i] for i in range(3)]
        out_fp, _, eng_fp = self._run(cfg, params, prompts, sync_every=4)
        out_q, reqs, eng_q = self._run(cfg, params, prompts, kv_dtype="int8",
                                       sync_every=4)
        assert out_q == out_fp
        assert eng_q.pages_shared > 0
        # force a COW mid-generation (same idiom as the fp COW tests):
        # identical prompts -> identical quantized KV, alias a live page
        prompt = rng.integers(0, cfg.vocab_size, size=6).tolist()
        ref_out, _, _ = self._run(cfg, params, [prompt], slots=1,
                                  kv_dtype="int8")
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=32, max_new_tokens=6, page_size=4,
            prefix_cache=False, kv_dtype="int8"))
        r1, r2 = eng.submit(prompt), eng.submit(prompt)
        eng.step()  # prefill tick: both slots to gen
        eng.tables.repoint(1, 1, eng.tables.blocks(0)[1])
        eng._tables_dirty = True
        eng.run()
        assert eng.pages_copied >= 1
        assert r1.output == ref_out[0] and r2.output == ref_out[0]

    def test_mla_int8_matches_fp(self, rng):
        """The MLA latent pools quantize through the same composition point
        (latent + rope pages each carry their own scales)."""
        cfg = get_config("deepseek_v2_lite_16b").reduced()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
                   for n in (11, 6)]
        out_fp, _, eng_fp = self._run(cfg, params, prompts)
        out_q, _, eng_q = self._run(cfg, params, prompts, kv_dtype="int8")
        assert out_q == out_fp
        assert eng_q.cache.kv_bytes() < eng_fp.cache.kv_bytes()

    def test_contiguous_cache_rejects_kv_dtype(self):
        """No silent downgrade: the contiguous strips store fp only."""
        cfg = _qwen()
        with pytest.raises(ValueError, match="paged"):
            ServingEngine(cfg, _params(cfg), ServeConfig(
                slots=1, max_len=16, max_new_tokens=1, cache="contiguous",
                kv_dtype="int8"))

    def test_page_bytes_and_budget_sizing(self):
        """``BlockPool.page_bytes`` reflects the storage format; at a fixed
        byte budget the quantized pool affords strictly more pages
        (``blocks_for_bytes``) — the capacity win the pressure bench
        measures as fewer preemptions."""
        from repro.serving.paged_cache import blocks_for_bytes
        cfg = _qwen()
        params = _params(cfg)
        mk = lambda kv: ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=32, max_new_tokens=1, page_size=8, kv_dtype=kv))
        fp, q8 = mk(None), mk("int8")
        assert q8.pool.page_bytes < fp.pool.page_bytes
        budget = 64 * fp.pool.page_bytes
        assert blocks_for_bytes(budget, q8.pool.page_bytes) > \
            blocks_for_bytes(budget, fp.pool.page_bytes) == 64
        with pytest.raises(ValueError):
            blocks_for_bytes(budget, 0)


# ---------------------------------------------------------------------------
# ServeConfig construction validation (fail loud, not mid-serve)
# ---------------------------------------------------------------------------


class TestServeConfigValidation:
    OK = dict(slots=2, max_len=32, max_new_tokens=4)

    def test_defaults_construct(self):
        ServeConfig(**self.OK)  # the happy path stays happy

    @pytest.mark.parametrize("field", [
        "slots", "max_len", "max_new_tokens", "page_size", "prefill_chunk",
        "num_blocks", "draft_len",
    ])
    @pytest.mark.parametrize("bad", [0, -3])
    def test_nonpositive_sizes_rejected(self, field, bad):
        with pytest.raises(ValueError, match=field):
            ServeConfig(**{**self.OK, field: bad})

    def test_budget_below_slots_rejected(self):
        with pytest.raises(ValueError, match="token_budget"):
            ServeConfig(slots=4, max_len=32, max_new_tokens=2,
                        token_budget=3)

    def test_unknown_kv_dtype_rejected(self):
        with pytest.raises(ValueError, match="kv_dtype"):
            ServeConfig(**self.OK, kv_dtype="fp8")

    def test_unknown_cache_and_prefill_rejected(self):
        with pytest.raises(ValueError, match="cache"):
            ServeConfig(**self.OK, cache="unified")
        with pytest.raises(ValueError, match="prefill"):
            ServeConfig(**self.OK, prefill="speculative")

    def test_negative_backoff_rejected(self):
        with pytest.raises(ValueError, match="retry_backoff"):
            ServeConfig(**self.OK, retry_backoff=-1)


def test_int8_prefix_shared_preemption_resumes_exactly(rng):
    """A request holding prefix-shared *quantized* pages is preempted under
    pool pressure and resumes by recompute: the shared int8 page stays
    pinned in the index (refcount intact), the resumed replay re-attaches
    it, and the tokens match isolated single-slot int8 runs bit-for-bit —
    sharing + COW bookkeeping is format-agnostic."""
    cfg = _qwen()
    params = _params(cfg)
    head = rng.integers(0, cfg.vocab_size, size=4).tolist()
    prompts = [head + rng.integers(0, cfg.vocab_size, size=4).tolist()
               for _ in range(2)]
    base = dict(max_len=16, max_new_tokens=6, page_size=4, kv_dtype="int8")
    refs = [_run_engine(cfg, params, [p], slots=1, **base)[0][0]
            for p in prompts]
    out, reqs, eng = _run_engine(cfg, params, prompts, slots=2,
                                 num_blocks=5, audit=True, **base)
    assert eng.preemptions >= 1 and reqs[1].preemptions >= 1
    assert out == refs  # recompute resume over quantized pages is lossless
    assert eng.pages_shared > 0
    assert eng.pool.in_use == eng.prefix.pages  # only the index holds pages


# ---------------------------------------------------------------------------
# Speculative decoding (ISSUE-10): draft-verify inside the multi-step window
# ---------------------------------------------------------------------------


def _spec_mode_base(mode):
    """(cfg_name, extra ServeConfig kwargs) for the byte-identity matrix."""
    return {
        "gqa_paged": ("qwen2_1_5b", {}),
        "mla": ("deepseek_v2_lite_16b", {}),
        "int8_kv": ("qwen2_1_5b", {"kv_dtype": "int8"}),
    }[mode]


class TestSpeculativeDecode:
    """Speculative decoding is an *optimization*, never a behavior change:
    greedy verify emits only tokens that are the model's own argmax after a
    committed prefix, so every test drives the same requests through the
    plain per-tick engine and the draft-verify window and asserts
    byte-identical outputs."""

    BASE = dict(slots=2, max_len=64, max_new_tokens=6, page_size=4,
                temperature=0.0)

    _REF_CACHE: dict = {}

    def _ref(self, mode, cfg, params, prompts):
        key = mode
        if key not in self._REF_CACHE:
            name, extra = _spec_mode_base(mode)
            self._REF_CACHE[key] = _run_engine(
                cfg, params, prompts, **self.BASE, **extra)
        return self._REF_CACHE[key]

    def _setup(self, mode, rng):
        name, extra = _spec_mode_base(mode)
        cfg = get_config(name).reduced()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
                   for n in (5, 7, 3, 6)]
        return cfg, params, prompts, extra

    @pytest.mark.parametrize("mode", ["gqa_paged", "mla", "int8_kv"])
    @pytest.mark.parametrize("draft", [1, 2, 4])
    @pytest.mark.parametrize("sync", [1, 4])
    def test_greedy_byte_identity(self, mode, draft, sync, rng):
        cfg, params, prompts, extra = self._setup(mode, rng)
        ref, _, _ = self._ref(mode, cfg, params, prompts)
        out, _, eng = _run_engine(
            cfg, params, prompts, sync_every=sync, spec_decode="ngram",
            draft_len=draft, audit=True, **self.BASE, **extra)
        assert out == ref
        assert eng.spec_windows > 0  # the draft-verify loop actually engaged
        assert eng.pool.in_use == eng.prefix.pages  # rollback leaked nothing

    def test_composes_with_sync_every_fewer_dispatches(self, rng):
        """The acceptance-criterion shape at unit scale: on a self-similar
        prompt the n-gram proposer lands drafts, so the spec engine spends
        strictly fewer host dispatches than the sync-matched plain engine
        for the same (byte-identical) output."""
        cfg = _qwen()
        params = _params(cfg)
        motif = rng.integers(0, cfg.vocab_size, size=4).tolist()
        prompts = [motif * 3 for _ in range(2)]
        base = dict(slots=2, max_len=96, max_new_tokens=16, page_size=4,
                    temperature=0.0, sync_every=4, prefix_cache=False)
        ref, _, ref_eng = _run_engine(cfg, params, prompts, **base)
        out, _, eng = _run_engine(cfg, params, prompts, spec_decode="ngram",
                                  draft_len=4, **base)
        assert out == ref
        assert eng.spec_accepted > 0
        assert eng.dispatches < ref_eng.dispatches
        assert eng.pool.in_use == 0

    def test_eos_mid_window(self, rng):
        """A verified EOS must stop the stream inside the round: later
        targets of the same round (and all later rounds) are discarded by
        the on-device emit mask, exactly like plain decode stopping at
        EOS."""
        cfg = _qwen()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
                   for n in (5, 7, 3, 6)]
        base = dict(slots=2, max_len=64, max_new_tokens=8, page_size=4,
                    temperature=0.0)
        free, _, _ = _run_engine(cfg, params, prompts, **base)
        eos = free[0][2]  # a token the greedy model actually emits mid-stream
        ref, _, _ = _run_engine(cfg, params, prompts, eos_id=eos, **base)
        out, _, eng = _run_engine(cfg, params, prompts, eos_id=eos,
                                  sync_every=4, spec_decode="ngram",
                                  draft_len=4, **base)
        assert out == ref
        assert eng.spec_windows > 0
        assert any(len(o) < 8 for o in out)  # EOS genuinely cut a stream

    def test_all_rejected_rounds(self, rng):
        """A proposer that drafts garbage must cost speed only: every round
        still emits the model's own next token (the bonus position), so the
        output is byte-identical even when acceptance is zero."""
        import jax.numpy as jnp
        bad_name = "_test_pessimal"

        def pessimal(history, pos, feed, draft_len):
            # shift every draft off the feed token: near-certain mismatch
            k = jnp.arange(draft_len, dtype=jnp.int32)[None, :]
            return (jnp.asarray(feed, jnp.int32)[:, None] + 17 + k) % 101

        lm.DRAFT_PROPOSERS[bad_name] = pessimal
        try:
            cfg = _qwen()
            params = _params(cfg)
            prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
                       for n in (5, 3)]
            base = dict(slots=2, max_len=64, max_new_tokens=6, page_size=4,
                        temperature=0.0)
            ref, _, _ = _run_engine(cfg, params, prompts, **base)
            out, _, eng = _run_engine(cfg, params, prompts, sync_every=4,
                                      spec_decode=bad_name, draft_len=4,
                                      audit=True, **base)
        finally:
            del lm.DRAFT_PROPOSERS[bad_name]
        assert out == ref
        assert eng.spec_all_rejected > 0  # whole rounds accepted zero drafts
        # progress is still >= 1 token per live round: the loop never stalls
        assert all(len(o) == 6 for o in out)

    def test_preemption_resume_with_uncommitted_drafts(self, rng):
        """Pool pressure mid-draft-window: the victim's uncommitted draft
        tail lives only in pages behind the position carry, so recompute
        resume (which replays prompt + *committed* output) is lossless."""
        cfg = _qwen()
        params = _params(cfg)
        prompt1 = rng.integers(0, cfg.vocab_size, size=6).tolist()
        prompt2 = rng.integers(0, cfg.vocab_size, size=6).tolist()
        solo = dict(slots=1, max_len=16, max_new_tokens=6, page_size=4,
                    temperature=0.0)
        ref1, _, _ = _run_engine(cfg, params, [prompt1], **solo)
        ref2, _, _ = _run_engine(cfg, params, [prompt2], **solo)
        # pool of 4 blocks: both admit at 2 blocks, both need a 3rd
        # mid-generation -> forced preemption while drafts are in flight
        out, reqs, eng = _run_engine(
            cfg, params, [prompt1, prompt2], slots=2, max_len=16,
            max_new_tokens=6, page_size=4, num_blocks=4, sync_every=4,
            spec_decode="ngram", draft_len=4, prefix_cache=False,
            temperature=0.0)
        assert eng.preemptions >= 1
        assert out == [ref1[0], ref2[0]]  # recompute resume is lossless
        assert eng.pool.in_use == 0

    def test_temperature_stream_independent_of_acceptance(self, rng):
        """The key-stream determinism rule: a gated round always splits the
        key draft_len + 2 ways regardless of acceptance length, so one
        slot's token stream cannot depend on another slot's drafts.  Same
        seed, slot B's prompt fixed, slot A's prompt varied (same length,
        so prefill ticks match): B's output must not move."""
        cfg = _qwen()
        params = _params(cfg)
        pa1 = rng.integers(0, cfg.vocab_size, size=6).tolist()
        pa2 = rng.integers(0, cfg.vocab_size, size=6).tolist()
        pb = rng.integers(0, cfg.vocab_size, size=6).tolist()
        base = dict(slots=2, max_len=64, max_new_tokens=12, page_size=4,
                    temperature=0.8, seed=7, sync_every=4,
                    spec_decode="ngram", draft_len=3)
        out1, _, _ = _run_engine(cfg, params, [pa1, pb], **base)
        out2, _, _ = _run_engine(cfg, params, [pa2, pb], **base)
        assert out1[0] != out2[0]  # slot A genuinely diverged
        assert out1[1] == out2[1]  # slot B's stream never moved

    def test_temperature_runs_are_reproducible(self, rng):
        cfg = _qwen()
        params = _params(cfg)
        prompts = [rng.integers(0, cfg.vocab_size, size=n).tolist()
                   for n in (5, 3)]
        base = dict(slots=2, max_len=64, max_new_tokens=8, page_size=4,
                    temperature=0.8, seed=3, sync_every=4,
                    spec_decode="ngram", draft_len=4)
        out1, _, eng1 = _run_engine(cfg, params, prompts, **base)
        out2, _, eng2 = _run_engine(cfg, params, prompts, **base)
        assert out1 == out2
        assert np.array_equal(np.asarray(eng1._key), np.asarray(eng2._key))

    def test_greedy_never_splits_key(self, rng):
        cfg = _qwen()
        eng = ServingEngine(cfg, _params(cfg), ServeConfig(
            slots=2, max_len=32, max_new_tokens=4, seed=7, page_size=4,
            spec_decode="ngram", draft_len=2))
        before = np.asarray(eng._key).copy()
        for n in (5, 3):
            eng.submit(rng.integers(0, cfg.vocab_size, size=n).tolist())
        eng.run()
        assert eng.spec_windows > 0
        assert np.array_equal(np.asarray(eng._key), before)

    def test_spec_requires_chunked_prefill_arch(self, rng):
        """The verify pass *is* chunked prefill, so an arch that cannot
        chunk-prefill (recurrent state) fails loudly at engine init."""
        cfg = get_config("mamba2_2_7b").reduced()
        with pytest.raises(ValueError, match="spec_decode"):
            ServingEngine(cfg, _params(cfg), ServeConfig(
                slots=1, max_len=16, max_new_tokens=2, cache="contiguous",
                spec_decode="ngram"))

    def test_unknown_proposer_rejected(self):
        with pytest.raises(ValueError, match="spec_decode"):
            ServeConfig(slots=2, max_len=32, max_new_tokens=4,
                        spec_decode="crystal_ball")


class TestNgramProposer:
    """The draft proposer in isolation: pure function of the history."""

    def test_bigram_match_preferred_and_most_recent(self):
        import jax.numpy as jnp
        hist = np.zeros((1, 16), np.int32)
        # ... 5 6 7 ... 5 6 9 ... cursor after a fresh (5, 6) bigram
        hist[0, :9] = [1, 5, 6, 7, 2, 5, 6, 9, 5]
        drafts = np.asarray(lm.ngram_propose(
            jnp.asarray(hist), jnp.asarray([9]), jnp.asarray([6]), 2))
        # most recent earlier (5,6) is at j=6 -> propose history[7:9] = 9, 5
        assert drafts.tolist() == [[9, 5]]

    def test_unigram_fallback(self):
        import jax.numpy as jnp
        hist = np.zeros((1, 16), np.int32)
        hist[0, :5] = [3, 8, 4, 2, 8]  # feed 8, prev 2: bigram (2,8) unseen
        drafts = np.asarray(lm.ngram_propose(
            jnp.asarray(hist), jnp.asarray([4]), jnp.asarray([8]), 2))
        # unigram 8 at j=1 -> propose history[2:4] = 4, 2
        assert drafts.tolist() == [[4, 2]]

    def test_no_match_repeats_feed(self):
        import jax.numpy as jnp
        hist = np.zeros((2, 8), np.int32)
        hist[0, :3] = [1, 2, 3]
        hist[1, :1] = [9]
        drafts = np.asarray(lm.ngram_propose(
            jnp.asarray(hist), jnp.asarray([2, 0]), jnp.asarray([3, 9]), 3))
        assert drafts.tolist() == [[3, 3, 3], [9, 9, 9]]

    def test_match_near_cursor_truncates_to_feed(self):
        import jax.numpy as jnp
        hist = np.zeros((1, 8), np.int32)
        hist[0, :4] = [5, 6, 5, 6]  # bigram (5,6) at j=1; only j=2..3 known
        drafts = np.asarray(lm.ngram_propose(
            jnp.asarray(hist), jnp.asarray([3]), jnp.asarray([6]), 4))
        # history[2:4] = 5, 6 then past the cursor -> repeat feed
        assert drafts.tolist() == [[5, 6, 6, 6]]
