"""Kernel guardrails: the static verifier pass, the sanitizing reference
interpreter, and the dispatch guard (ISSUE-9 acceptance surface).

Three layers, one contract:

* ``core/lowering/verify.py`` proves what is provable at lowering time —
  static window bounds, cross-cell write disjointness, alias wiring — and
  emits a structured :class:`Obligation` for every check that depends on
  runtime scalars (paged block tables);
* the ``sanitize`` backend executes the same dataflow as ``reference``
  with out-of-bounds, duplicate-write, uninitialized-read and non-finite
  detection on every region access;
* ``kernels/ops.guard_dispatch`` discharges the emitted obligations
  against concrete block tables before any page is touched.
"""
import numpy as np
import pytest

from repro.core import Schedule, analyze, compile as tl_compile
from repro.core import lang as T
from repro.core.backends.reference import (
    _check_region_starts,
    _check_scalar_index,
)
from repro.core.errors import GuardError, SanitizeError, VerifyError
from repro.core.lowering.verify import alias_wiring, interval
from repro.kernels import parity_inputs, parity_programs
from repro.kernels.ops import GUARDED_KINDS, guard_dispatch


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# Planted-defect programs
# ---------------------------------------------------------------------------


def racy_program():
    """Both grid cells store to O[0:16] — a proven write race."""

    @T.prim_func
    def Racy(A: T.Tensor((32, 128), "float32"),
             O: T.Tensor((16, 128), "float32")):
        with T.Kernel(2) as bx:
            s = T.alloc_shared((16, 128), "float32")
            T.copy(A[bx * 16, 0], s)
            T.copy(s, O[0, 0])

    return Racy


def escaping_program():
    """bx=1 reads rows [24, 48) of a 32-row buffer — provably OOB."""

    @T.prim_func
    def Escape(A: T.Tensor((32, 128), "float32"),
               O: T.Tensor((48, 128), "float32")):
        with T.Kernel(2) as bx:
            s = T.alloc_shared((24, 128), "float32")
            T.copy(A[bx * 24, 0], s)
            T.copy(s, O[bx * 24, 0])

    return Escape


def dup_write_program():
    """(bx // 2) * 16 defeats the affine disjointness proof (accepted by
    the static verifier) but lands both cells on O[0:16] at runtime — the
    sanitizer's cross-cell duplicate-write check catches what the static
    pass documents as unprovable."""

    @T.prim_func
    def DupWrite(A: T.Tensor((32, 128), "float32"),
                 O: T.Tensor((16, 128), "float32")):
        with T.Kernel(2) as bx:
            s = T.alloc_shared((16, 128), "float32")
            T.copy(A[bx * 16, 0], s)
            T.copy(s, O[(bx // 2) * 16, 0])

    return DupWrite


def half_written_program():
    """Only rows [0, 16) of a 32-row output are ever written."""

    @T.prim_func
    def HalfOut(A: T.Tensor((16, 128), "float32"),
                O: T.Tensor((32, 128), "float32")):
        with T.Kernel(1) as bx:
            s = T.alloc_shared((16, 128), "float32")
            T.copy(A[0, 0], s)
            T.copy(s, O[0, 0])

    return HalfOut


def gather_program(pages=4, rows=8):
    """Minimal table-directed kernel: page axis of Src positioned by the
    scalar-prefetch Tbl — the static verifier cannot bound it and must
    emit a ``table_in_range`` obligation instead."""

    @T.prim_func
    def Gather(Tbl: T.ScalarTensor((pages,), "int32"),
               Src: T.Tensor((pages, rows, 128), "float32"),
               Out: T.Tensor((pages, rows, 128), "float32")):
        with T.Kernel(pages) as bx:
            s = T.alloc_shared((rows, 128), "float32")
            T.copy(Src[Tbl[bx], 0, 0], s)
            T.copy(s, Out[bx, 0, 0])

    return Gather


# ---------------------------------------------------------------------------
# Layer 1: the static verifier pass
# ---------------------------------------------------------------------------


class TestStaticVerifier:
    def test_every_kernel_verifies_clean(self):
        """The full parity corpus lowers with the verify pass in the
        pipeline — no false positives — and every emitted obligation is a
        kind the dispatch guard knows how to discharge."""
        count = 0
        for name, prog in parity_programs():
            m = analyze(prog, Schedule())
            count += 1
            for ob in m.obligations:
                assert ob.kind in GUARDED_KINDS, (name, ob)
        assert count > 0

    def test_planted_write_race_rejected(self):
        with pytest.raises(VerifyError, match="write race"):
            tl_compile(racy_program(), target="reference")

    def test_planted_oob_window_rejected(self):
        with pytest.raises(VerifyError, match="escape"):
            tl_compile(escaping_program(), target="reference")

    def test_error_context_names_program_and_pass(self):
        """Satellite: a mid-pipeline failure carries the program name and
        the failing pass on the exception."""
        with pytest.raises(VerifyError) as ei:
            tl_compile(racy_program(), target="reference")
        assert ei.value.context is not None
        assert "Racy" in ei.value.context and "verify" in ei.value.context
        assert "Racy" in str(ei.value)

    def test_unprovable_affine_pattern_accepted(self):
        # the documented limitation: present-but-unprovable is accepted
        m = analyze(dup_write_program(), Schedule())
        assert m.obligations == []

    def test_table_directed_axis_becomes_obligation(self):
        m = analyze(gather_program(), Schedule())
        kinds = {ob.kind for ob in m.obligations}
        assert "table_in_range" in kinds
        ob = next(o for o in m.obligations if o.kind == "table_in_range")
        assert ob.tables == ("Tbl",) and ob.param == "Src" and ob.axis == 0
        assert "Tbl" in ob.describe()

    def test_paged_attention_obligations(self):
        from repro.kernels.paged_attention import paged_attention_program

        prog = paged_attention_program(
            slots=2, heads=2, kv_heads=1, head_dim=128,
            page_size=8, max_pages=4, num_pages=9,
        )
        m = analyze(prog, Schedule())
        assert m.obligations, "paged kernel must owe runtime checks"
        assert {ob.kind for ob in m.obligations} <= GUARDED_KINDS
        assert all("Tables" in ob.tables for ob in m.obligations)

    def test_alias_wiring_matches_backend(self):
        """The verifier's wiring is what the Pallas backend asserts its
        own ``input_output_aliases`` against; for an atomic kernel the
        aliased operand sits after scalars + input windows."""

        @T.prim_func
        def ColSum(X: T.Tensor((4, 16, 128), "float32"),
                   O: T.Tensor((16, 128), "float32")):
            with T.Kernel(4) as bx:
                xs = T.alloc_shared((16, 128), "float32")
                T.copy(X[bx, 0, 0], xs)
                T.atomic_add(O[0, 0], xs)

        m = analyze(ColSum, Schedule())
        wiring = alias_wiring(m)
        assert wiring == {len(m.scalar_params) + len(m.in_windows): 0}
        # and the pallas backend accepts it (the cross-check would raise)
        kern = tl_compile(ColSum, Schedule(interpret=True))
        assert kern.backend == "pallas"

    def test_interval_arithmetic(self):
        from repro.core.expr import VarExpr

        v = VarExpr("i", extent=8)
        assert interval(v * 4 + 2) == (2.0, 30.0)
        assert interval((v - 4) * -1) == (-3.0, 4.0)
        assert interval(v % 3) == (0.0, 2.0)
        assert interval(v // 2) == (0.0, 3.0)
        lo, hi = interval(VarExpr("free"))
        assert lo == -np.inf and hi == np.inf


# ---------------------------------------------------------------------------
# Layer 2: the sanitizing interpreter
# ---------------------------------------------------------------------------

_CASES = dict(parity_programs())


def _make_input(param, rng):
    if param.dtype.startswith(("int", "uint")):
        return rng.integers(-4, 4, size=param.shape).astype(param.dtype)
    return rng.standard_normal(param.shape).astype(param.dtype)


class TestSanitizer:
    @pytest.mark.parametrize("name", sorted(_CASES))
    def test_sanitize_parity(self, name, rng):
        """Every kernel in the corpus runs clean under the sanitizer and
        matches the plain reference interpreter bit-for-bit (the sanitizer
        observes, it must not perturb)."""
        prog = _CASES[name]
        sk = tl_compile(prog, target="sanitize")
        rk = tl_compile(prog, target="reference")
        assert sk.backend == "sanitize"
        args = parity_inputs(name, prog, rng)
        if args is None:
            args = [_make_input(p, rng) for p in sk.arg_params]
        sout, rout = sk(*args), rk(*args)
        if not isinstance(sout, tuple):
            sout, rout = (sout,), (rout,)
        for s, r in zip(sout, rout):
            np.testing.assert_array_equal(np.asarray(s), np.asarray(r))

    def test_duplicate_write_detected(self, rng):
        kern = tl_compile(dup_write_program(), target="sanitize")
        a = rng.standard_normal((32, 128)).astype(np.float32)
        with pytest.raises(SanitizeError, match="duplicate write"):
            kern(a)
        # the plain reference interpreter runs the same program silently —
        # the hazard the sanitizer exists to surface
        tl_compile(dup_write_program(), target="reference")(a)

    def test_unwritten_output_detected(self, rng):
        kern = tl_compile(half_written_program(), target="sanitize")
        a = rng.standard_normal((16, 128)).astype(np.float32)
        with pytest.raises(SanitizeError, match="never written"):
            kern(a)

    def test_nonfinite_output_named_with_origin(self, rng):
        @T.prim_func
        def Copy(X: T.Tensor((16, 128), "float32"),
                 O: T.Tensor((16, 128), "float32")):
            with T.Kernel(1) as bx:
                s = T.alloc_shared((16, 128), "float32")
                T.copy(X[0, 0], s)
                T.copy(s, O[0, 0])

        kern = tl_compile(Copy, target="sanitize")
        x = rng.standard_normal((16, 128)).astype(np.float32)
        x[3, 7] = np.nan
        with pytest.raises(SanitizeError, match="non-finite"):
            kern(x)

    def test_gather_parity_with_valid_table(self, rng):
        kern = tl_compile(gather_program(), target="sanitize")
        tbl = np.array([2, 0, 3, 1], np.int32)
        src = rng.standard_normal((4, 8, 128)).astype(np.float32)
        out = np.asarray(kern(tbl, src))
        np.testing.assert_array_equal(out, src[tbl])

    def test_negative_table_entry_rejected(self, rng):
        """Satellite: a negative dynamic start previously hit Python's
        silent negative-index wrap in the reference interpreter; both the
        plain and sanitizing interpreters now reject it loudly."""
        src = rng.standard_normal((4, 8, 128)).astype(np.float32)
        bad = np.array([2, -1, 3, 1], np.int32)
        for target in ("reference", "sanitize"):
            kern = tl_compile(gather_program(), target=target)
            with pytest.raises(SanitizeError, match="out of bounds"):
                kern(bad, src)

    def test_oversized_table_entry_rejected(self, rng):
        src = rng.standard_normal((4, 8, 128)).astype(np.float32)
        bad = np.array([2, 9, 3, 1], np.int32)  # page 9 of 4
        kern = tl_compile(gather_program(), target="reference")
        with pytest.raises(SanitizeError, match="out of bounds"):
            kern(bad, src)

    def test_region_start_checks_unit(self):
        buf = type("B", (), {"name": "X", "shape": (8, 16)})()
        _check_region_starts(buf, (0, 8), (8, 8), "copy")  # in bounds
        with pytest.raises(SanitizeError, match="out of bounds"):
            _check_region_starts(buf, (-1, 0), (4, 4), "copy")
        with pytest.raises(SanitizeError, match="out of bounds"):
            _check_region_starts(buf, (6, 0), (4, 4), "copy")
        _check_scalar_index(buf, (7, 15))
        with pytest.raises(SanitizeError, match="scalar load"):
            _check_scalar_index(buf, (8, 0))
        with pytest.raises(SanitizeError, match="scalar load"):
            _check_scalar_index(buf, (0, -2))


# ---------------------------------------------------------------------------
# Layer 3: the dispatch guard (unit level; engine level in test_chaos.py)
# ---------------------------------------------------------------------------


def _tables(rows, max_pages, fill):
    tb = np.zeros((rows, max_pages), np.int32)
    for r, pages in enumerate(fill):
        tb[r, : len(pages)] = pages
    return tb


class TestDispatchGuard:
    PS = 4  # page size
    NP = 9  # pool pages: valid ids [1, 9)

    def test_clean_dispatch_passes(self):
        tb = _tables(2, 4, [[1, 2, 3], [4, 5]])
        guard_dispatch(tb, self.NP, self.PS,
                       [(0, 10, 9, 10), (1, 6, 5, 6)])

    def test_out_of_range_entry_blames_the_row(self):
        tb = _tables(2, 4, [[1, 99, 3], [4, 5]])
        with pytest.raises(GuardError) as ei:
            guard_dispatch(tb, self.NP, self.PS,
                           [(0, 10, 9, 10), (1, 6, 5, 6)])
        rows = {r for r, _, _ in ei.value.violations}
        kinds = {k for _, k, _ in ei.value.violations}
        assert rows == {0} and kinds == {"table_in_range"}
        assert "99" in str(ei.value)

    def test_reserved_page0_in_live_prefix_rejected(self):
        tb = _tables(1, 4, [[1, 0, 3]])
        with pytest.raises(GuardError, match="reserved"):
            guard_dispatch(tb, self.NP, self.PS, [(0, 10, 9, 10)])

    def test_capacity_overflow_rejected(self):
        tb = _tables(1, 4, [[1, 2, 3, 4]])
        with pytest.raises(GuardError, match="capacity"):
            guard_dispatch(tb, self.NP, self.PS, [(0, 17, 16, 17)])

    def test_duplicate_writable_page_blames_both_rows(self):
        tb = _tables(2, 4, [[1, 2, 7], [4, 5, 7]])
        with pytest.raises(GuardError) as ei:
            guard_dispatch(tb, self.NP, self.PS,
                           [(0, 10, 9, 10), (1, 10, 9, 10)])
        rows = {r for r, _, _ in ei.value.violations}
        kinds = {k for _, k, _ in ei.value.violations}
        assert rows == {0, 1} and kinds == {"table_writes_disjoint"}

    def test_write_into_another_rows_live_page_blames_writer(self):
        # rows share page 1 read-only in their prefixes (legal prefix
        # sharing) but row 1 *writes* into page 2, live in row 0
        tb = _tables(2, 4, [[1, 2, 3], [1, 6, 2]])
        with pytest.raises(GuardError) as ei:
            guard_dispatch(tb, self.NP, self.PS,
                           [(0, 10, 9, 10), (1, 10, 9, 10)])
        assert {r for r, _, _ in ei.value.violations} == {1}

    def test_readonly_prefix_sharing_is_legal(self):
        tb = _tables(2, 4, [[1, 2, 3], [1, 2, 6]])
        guard_dispatch(tb, self.NP, self.PS,
                       [(0, 10, 9, 10), (1, 10, 9, 10)])

    def test_random_corruptions_always_rejected(self, rng):
        """Seeded sweep of the guard property (the hypothesis twin lives
        in test_property.py): whatever live entry is corrupted — out of
        range, reserved zero, or a duplicate of another row's page — the
        guard rejects the dispatch before any page write."""
        for trial in range(50):
            n_rows = int(rng.integers(2, 5))
            max_pages = int(rng.integers(3, 7))
            num_pages = n_rows * max_pages + 1
            ids = rng.permutation(np.arange(1, num_pages))
            work, fill, k = [], [], 0
            for r in range(n_rows):
                n_live = int(rng.integers(1, max_pages + 1))
                fill.append(ids[k : k + n_live].tolist())
                k += n_live
                end = int(
                    rng.integers((n_live - 1) * self.PS + 1,
                                 n_live * self.PS + 1)
                )
                work.append((r, end, end - 1, end))
            tb = _tables(n_rows, max_pages, fill)
            guard_dispatch(tb, num_pages, self.PS, work)  # valid: passes
            victim = int(rng.integers(0, n_rows))
            live = -(-work[victim][1] // self.PS)
            mode = trial % 3
            if mode == 0:
                tb[victim, int(rng.integers(0, live))] = (
                    num_pages + int(rng.integers(0, 5))
                )
            elif mode == 1:
                tb[victim, int(rng.integers(0, live))] = 0
            else:
                # land the duplicate on the victim's *write* page so the
                # corruption is a write hazard, not legal read sharing
                other = (victim + 1) % n_rows
                tb[victim, live - 1] = fill[other][0]
            with pytest.raises(GuardError) as ei:
                guard_dispatch(tb, num_pages, self.PS, work)
            assert any(k in GUARDED_KINDS
                       for _, k, _ in ei.value.violations)
