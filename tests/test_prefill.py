"""Chunked-prefill fast path (ISSUE-3): kernel, model step, scheduler.

Covers the acceptance surface:

* the prefill_attention tile kernel against its pure-JAX oracle, page
  writes included (the scalar-prefetch *output* BlockSpec path);
* chunked-prefill vs token-replay token-equality across GQA / MQA /
  sliding-window configs, over both cache layouts;
* engine tick counts: chunked needs <= ceil(prompt/chunk)+gen ticks where
  replay needs prompt+gen;
* TTFT accounting and the token-budget invariant (the hypothesis version
  of the budget property lives in test_property.py).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine, plan_prefill_chunks


def _params(cfg, seed=0):
    return lm.init(cfg, jax.random.PRNGKey(seed))


def _qwen():
    return get_config("qwen2_1_5b").reduced()


# ---------------------------------------------------------------------------
# Kernel vs oracle (the Pallas path writes K/V pages from inside the kernel)
# ---------------------------------------------------------------------------


def test_prefill_kernel_matches_oracle_and_writes_pages(rng):
    from repro.kernels import ops

    for (b, hq, hkv, d, chunk, ps, mp, num_pages, window) in [
        (2, 2, 1, 16, 16, 16, 4, 10, None),   # MQA
        (2, 4, 2, 16, 32, 16, 4, 10, None),   # GQA, multi-page chunk
        (2, 2, 2, 16, 16, 16, 4, 10, 20),     # sliding window
    ]:
        tables = rng.permutation(num_pages - 1)[: b * mp]
        tables = (tables + 1).reshape(b, mp).astype("int32")  # page 0 reserved
        starts = (rng.integers(0, mp - chunk // ps + 1, size=b) * ps).astype("int32")
        lens = rng.integers(1, chunk + 1, size=b).astype("int32")
        q = rng.standard_normal((b, hq, chunk, d)).astype("float32")
        kn = rng.standard_normal((b, hkv, chunk, d)).astype("float32")
        vn = rng.standard_normal((b, hkv, chunk, d)).astype("float32")
        kp = rng.standard_normal((hkv, num_pages, ps, d)).astype("float32")
        vp = rng.standard_normal((hkv, num_pages, ps, d)).astype("float32")
        outs = {}
        for be in ("pallas", "xla"):
            outs[be] = ops.prefill_attention(
                q, kn, vn, jnp.asarray(kp), jnp.asarray(vp), tables, starts,
                lens, window=window, backend=be,
            )
        np.testing.assert_allclose(
            np.asarray(outs["pallas"][0]), np.asarray(outs["xla"][0]),
            rtol=1e-4, atol=2e-3,
        )
        # both backends place the chunk's live K/V in the table-mapped pages
        for name, (_, k_new_pages, v_new_pages) in outs.items():
            k_new_pages = np.asarray(k_new_pages)
            v_new_pages = np.asarray(v_new_pages)
            for bi in range(b):
                for c in range(int(lens[bi])):
                    pos = int(starts[bi]) + c
                    pg, of = tables[bi, pos // ps], pos % ps
                    np.testing.assert_allclose(
                        k_new_pages[:, pg, of], kn[bi, :, c], atol=1e-6,
                        err_msg=f"{name} K page write ({bi},{c})")
                    np.testing.assert_allclose(
                        v_new_pages[:, pg, of], vn[bi, :, c], atol=1e-6,
                        err_msg=f"{name} V page write ({bi},{c})")
            # pages owned by nobody's chunk keep their contents (in-out alias)
            written = {
                int(tables[bi, (int(starts[bi]) + c) // ps])
                for bi in range(b) for c in range(chunk)
            } | {0}
            for pg in range(num_pages):
                if pg not in written:
                    np.testing.assert_array_equal(
                        k_new_pages[:, pg], kp[:, pg],
                        err_msg=f"{name} clobbered unowned page {pg}")


def test_prefill_kernel_idle_slot_never_clobbers(rng):
    """A lens=0 slot riding in a batched tick — with an arbitrary,
    non-page-aligned, even table-overflowing position — must leave every
    real page untouched on BOTH backends (its writes land in the reserved
    garbage page 0; its table index is clamped in range)."""
    from repro.kernels import ops

    b, hq, hkv, d, chunk, ps, mp, num_pages = 2, 2, 1, 16, 16, 16, 4, 10
    tables = (rng.permutation(num_pages - 1)[: b * mp] + 1)
    tables = tables.reshape(b, mp).astype("int32")
    starts = np.array([0, 61], np.int32)  # slot 1 idle at an unaligned pos
    lens = np.array([chunk, 0], np.int32)
    q = rng.standard_normal((b, hq, chunk, d)).astype("float32")
    kn = rng.standard_normal((b, hkv, chunk, d)).astype("float32")
    vn = rng.standard_normal((b, hkv, chunk, d)).astype("float32")
    kp = rng.standard_normal((hkv, num_pages, ps, d)).astype("float32")
    vp = rng.standard_normal((hkv, num_pages, ps, d)).astype("float32")
    for be in ("pallas", "xla"):
        _, k2, v2 = ops.prefill_attention(
            q, kn, vn, jnp.asarray(kp), jnp.asarray(vp), tables, starts,
            lens, backend=be,
        )
        k2, v2 = np.asarray(k2), np.asarray(v2)
        slot0_pages = {int(tables[0, c // ps]) for c in range(chunk)}
        for pg in range(1, num_pages):
            if pg not in slot0_pages:  # everything slot 0 didn't own
                np.testing.assert_array_equal(
                    k2[:, pg], kp[:, pg],
                    err_msg=f"{be}: idle slot clobbered page {pg}")
                np.testing.assert_array_equal(v2[:, pg], vp[:, pg])


# ---------------------------------------------------------------------------
# Engine: chunked vs replay token equality across attention variants
# ---------------------------------------------------------------------------


def _variants():
    q = _qwen()
    return [
        ("gqa", q),
        ("mqa", dataclasses.replace(q, num_kv_heads=1)),
        ("sliding_window", dataclasses.replace(
            q, sliding_window=12, global_attn_every=2)),
        ("mla", get_config("deepseek_v2_lite_16b").reduced()),
    ]


@pytest.mark.parametrize("name,cfg", _variants(), ids=[n for n, _ in _variants()])
@pytest.mark.parametrize("cache", ["paged", "contiguous"])
def test_chunked_matches_replay(name, cfg, cache, rng):
    params = _params(cfg)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=n).tolist() for n in (22, 3, 17, 9)
    ]

    def drive(prefill):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=2, max_len=48, max_new_tokens=4, cache=cache,
            prefill=prefill, prefill_chunk=8, page_size=16))
        assert eng.prefill_mode == prefill
        reqs = [eng.submit(p) for p in prompts]
        eng.run()
        assert all(r.done for r in reqs)
        return [r.output for r in reqs], eng

    replay, eng_r = drive("replay")
    chunked, eng_c = drive("chunked")
    assert chunked == replay  # token-for-token identical
    assert eng_c.steps_run < eng_r.steps_run


def test_unsupported_arch_falls_back_to_replay():
    cfg = get_config("hymba_1_5b").reduced()  # hybrid: recurrent SSM state
    eng = ServingEngine(cfg, _params(cfg), ServeConfig(
        slots=1, max_len=16, max_new_tokens=2))
    assert eng.prefill_mode == "replay"
    with pytest.raises(NotImplementedError):
        lm.prefill_step(
            _params(cfg), cfg, eng.cache,
            jnp.zeros((1, 4), jnp.int32), jnp.zeros((1,), jnp.int32),
            jnp.ones((1,), jnp.int32),
        )


def test_mla_supports_chunked_prefill():
    """MLA archs take the chunked fast path now (the mla_prefill latent
    chunk write) — the PR-3 era replay fallback is gone."""
    cfg = get_config("deepseek_v2_lite_16b").reduced()
    assert lm.supports_chunked_prefill(cfg)
    eng = ServingEngine(cfg, _params(cfg), ServeConfig(
        slots=1, max_len=16, max_new_tokens=2))
    assert eng.prefill_mode == "chunked"


# ---------------------------------------------------------------------------
# Tick counts + TTFT accounting
# ---------------------------------------------------------------------------


def test_tick_bound_and_ttft(rng):
    cfg = _qwen()
    params = _params(cfg)
    prompt_len, gen, chunk = 32, 3, 16
    prompt = rng.integers(0, cfg.vocab_size, size=prompt_len).tolist()

    def drive(prefill):
        eng = ServingEngine(cfg, params, ServeConfig(
            slots=1, max_len=64, max_new_tokens=gen, prefill=prefill,
            prefill_chunk=chunk))
        req = eng.submit(prompt)
        eng.run()
        return req, eng

    req_r, eng_r = drive("replay")
    req_c, eng_c = drive("chunked")
    # replay: one tick per prompt token; the tick consuming the last prompt
    # token emits the first output token
    assert eng_r.steps_run == prompt_len + gen - 1
    assert req_r.ttft_ticks == prompt_len
    # chunked: ceil(prompt/chunk) prefill ticks, then decode
    n_chunks = -(-prompt_len // chunk)
    assert eng_c.steps_run == n_chunks + gen - 1
    assert req_c.ttft_ticks == n_chunks
    assert req_c.output == req_r.output


def test_ttft_counts_queue_wait(rng):
    """A request stuck behind a full engine accrues TTFT while queued."""
    cfg = _qwen()
    eng = ServingEngine(cfg, _params(cfg), ServeConfig(
        slots=1, max_len=64, max_new_tokens=2, prefill="chunked",
        prefill_chunk=16))
    first = eng.submit(rng.integers(0, cfg.vocab_size, size=16).tolist())
    second = eng.submit(rng.integers(0, cfg.vocab_size, size=16).tolist())
    eng.run()
    assert first.ttft_ticks == 1  # one chunk covers the whole prompt
    assert second.ttft_ticks > first.ttft_ticks  # waited for the slot


# ---------------------------------------------------------------------------
# Token budget
# ---------------------------------------------------------------------------


def test_budget_never_exceeded(rng):
    cfg = _qwen()
    params = _params(cfg)
    eng = ServingEngine(cfg, params, ServeConfig(
        slots=3, max_len=64, max_new_tokens=3, prefill="chunked",
        prefill_chunk=16, token_budget=20))
    for n in (40, 25, 9, 33, 2):
        eng.submit(rng.integers(0, cfg.vocab_size, size=n).tolist())
    eng.run()
    assert eng.token_budget == 20
    assert eng.tick_tokens and max(eng.tick_tokens) <= eng.token_budget

    # a budget below the slot count can never fit a full generation batch
    # in one tick — rejected at construction rather than silently floored
    with pytest.raises(ValueError, match="token_budget"):
        ServeConfig(slots=4, max_len=32, max_new_tokens=2, token_budget=1)

    # budget == slots is the legal floor and clamps the chunk to 1
    eng2 = ServingEngine(cfg, params, ServeConfig(
        slots=4, max_len=32, max_new_tokens=2, token_budget=4))
    assert eng2.token_budget == 4
    assert eng2.prefill_chunk == 1


def test_tiny_budget_still_makes_progress(rng):
    """budget == slots forces chunk=1; the engine must still drain (the
    all-or-nothing planner may never starve a prefilling slot)."""
    cfg = _qwen()
    eng = ServingEngine(cfg, _params(cfg), ServeConfig(
        slots=2, max_len=32, max_new_tokens=2, prefill="chunked",
        prefill_chunk=16, token_budget=2))
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, size=10).tolist())
            for _ in range(3)]
    eng.run(max_steps=500)
    assert all(r.done for r in reqs)
    assert max(eng.tick_tokens) <= eng.token_budget == 2


def test_plan_prefill_chunks_budget_split():
    # oldest request first; grants are all-or-nothing min(chunk, remaining)
    plan = plan_prefill_chunks(32, 4, [(0, 7, 30), (1, 3, 10), (2, 9, 5)], 16)
    assert plan == {1: 10, 0: 16}  # seq 3 first (its final partial), then 16
    assert 4 + sum(plan.values()) <= 32
    # a room-limited *partial* is never granted (chunk starts stay aligned):
    # room = 20-4 = 16 fits seq3's 10 but not seq7's full 16 -> stop there
    assert plan_prefill_chunks(20, 4, [(0, 7, 30), (1, 3, 10)], 16) == {1: 10}
    # decode saturating the budget starves prefill entirely
    assert plan_prefill_chunks(8, 8, [(0, 0, 100)], 16) == {}
