"""GEMM benchmark — paper Table 2 shapes (M0–M7 training GEMMs, V0–V7
decode GEMVs), Fig. 13.

The autotuner picks block shapes per shape; rows report the cost-model
roofline time and the achieved fraction of the dominant bound.  V-shapes
(m=1) are memory-bound by construction — the cost model shows AI < 1
FLOP/B and the roofline time tracking HBM traffic, matching the paper's
observation that decode GEMMs are bandwidth-limited.
"""
import numpy as np

from repro.core import Schedule, compile as tl_compile
from repro.kernels.matmul import matmul_program, tune_matmul

from .common import Row, check, emit, kernel_row

M_SHAPES = {
    "M0": (4096, 1024, 8192), "M1": (4096, 8192, 8192),
    "M2": (4096, 28672, 8192), "M3": (4096, 8192, 28672),
    "M4": (8192, 1024, 8192), "M5": (8192, 8192, 8192),
    "M6": (8192, 28672, 8192), "M7": (8192, 8192, 28672),
}
V_SHAPES = {
    "V0": (1, 16384, 16384), "V1": (1, 43008, 14336),
    "V2": (1, 14336, 14336), "V3": (1, 57344, 14336),
    "V4": (1, 14336, 57344), "V5": (1, 9216, 9216),
    "V6": (1, 36864, 9216), "V7": (1, 9216, 36864),
}


def _pad_to_block(n, b=8):
    return max(b, -(-n // b) * b)


def run():
    rows = []
    for name, (m, n, k) in M_SHAPES.items():
        kern, cand = tune_matmul(m, n, k, "bfloat16", "bfloat16")
        cfg = cand.config
        rows.append(
            kernel_row(
                f"gemm_{name}_{m}x{n}x{k}",
                matmul_program(m, n, k, "bfloat16", "bfloat16", "float32", **cfg),
                extra=f"tuned=bM{cfg['block_M']}/bN{cfg['block_N']}/bK{cfg['block_K']}/s{cfg['num_stages']}",
            )
        )
    for name, (m, n, k) in V_SHAPES.items():
        mp = _pad_to_block(m)  # GEMV rides an 8-row padded tile
        prog = matmul_program(mp, n, k, "bfloat16", "bfloat16", "float32",
                              block_M=8, block_N=512, block_K=512)
        rows.append(kernel_row(f"gemv_{name}_m1_{n}x{k}", prog, extra="m=1 (padded 8)"))

    # correctness anchor: interpret-mode matmul vs numpy at a reduced shape
    def _ok():
        rng = np.random.default_rng(0)
        prog = matmul_program(128, 128, 128, block_M=64, block_N=64, block_K=64)
        kern = tl_compile(prog, Schedule(interpret=True))
        a = rng.standard_normal((128, 128), dtype=np.float32)
        b = rng.standard_normal((128, 128), dtype=np.float32)
        return np.allclose(np.asarray(kern(a, b)), a @ b, atol=1e-3)

    check(_ok, "gemm-interpret-vs-numpy")
    emit(rows, "Table 2 / Fig 13: GEMM (cost-model roofline on TPU v5e)")
    return rows


if __name__ == "__main__":
    run()
