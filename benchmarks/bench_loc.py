"""Lines-of-code benchmark — the paper's usability axis (Fig. 14 right).

Counts non-comment source lines of each tile-DSL kernel program and
compares the MLA kernel against the paper's ~70-line claim.
"""
from repro.kernels.dequant_matmul import dequant_matmul_program
from repro.kernels.flash_attention import flash_attention_program
from repro.kernels.linear_attention import chunk_scan_program, chunk_state_program
from repro.kernels.matmul import matmul_program
from repro.kernels.mla import mla_program

from .common import Row, check, emit


def run():
    programs = {
        "matmul": matmul_program(256, 256, 256, block_M=64, block_N=64, block_K=64),
        "flash_attention": flash_attention_program(1, 2, 2, 128, 128, 64, True, 64, 64),
        "flash_mla": mla_program(1, 16, 1, 128, 64, 16, 64, 16),
        "dequant_int4": dequant_matmul_program(64, 64, 128, "int4", block_M=32, block_N=32, block_K=64),
        "chunk_state": chunk_state_program(1, 2, 64, 32, 64),
        "chunk_scan": chunk_scan_program(1, 2, 64, 32, 64),
    }
    rows = [
        Row(f"loc_{name}", float(p.source_lines), f"source_lines={p.source_lines}")
        for name, p in programs.items()
    ]

    check(lambda: programs["flash_mla"].source_lines <= 80,
          "mla-loc-within-paper-claim")
    emit(rows, "Fig 14 (right): kernel lines of code")
    return rows


if __name__ == "__main__":
    run()
