"""Lines-of-code benchmark — the paper's usability axis (Fig. 14 right).

Counts non-comment source lines of each tile-DSL kernel program and pins
two claims in CI (via the ``--compare`` gate in tools/ci.sh):

* the MLA kernel stays within the paper's ~70-line budget (<= 80 here);
* the composable-attention refactor (ISSUE-5) is a net simplification —
  the four attention programs *plus* the shared online-softmax template
  (kernels/attention_core.py, counted once) together are no larger than
  the pre-refactor hand-rolled loops, even though the template also
  powers two brand-new kernels (paged MLA decode, MLA chunked prefill);
* the quantized KV variants (ISSUE-7) stay cheap: the dequant stage is
  written once (``attention_core.DequantStage``, counted once) and each
  quantized kernel adds bounded marginal lines over its fp twin — the
  unpack/scale logic never gets copy-pasted per kernel.
"""
from repro.kernels import attention_core
from repro.kernels.dequant_matmul import dequant_matmul_program
from repro.kernels.flash_attention import flash_attention_program
from repro.kernels.linear_attention import chunk_scan_program, chunk_state_program
from repro.kernels.matmul import matmul_program
from repro.kernels.mla import (
    mla_paged_program,
    mla_paged_quant_program,
    mla_prefill_program,
    mla_prefill_quant_program,
    mla_program,
)
from repro.kernels.paged_attention import (
    paged_attention_program,
    paged_attention_quant_program,
)
from repro.kernels.prefill_attention import (
    prefill_attention_program,
    prefill_attention_quant_program,
)

from .common import Row, check, emit

# Sum of the four hand-rolled attention programs at PR 4 (flash 57 +
# paged 60 + prefill 110 + mla 64), before the template extraction: the
# refactor's net-LoC ceiling.
PRE_REFACTOR_ATTENTION_LOC = 291

# The programs sharing the online-softmax template.
ATTENTION_KERNELS = ("flash_attention", "flash_mla", "paged_attention",
                     "prefill_attention")

# (quantized variant, fp twin) pairs sharing the dequant stage; the budget
# bounds the *marginal* cost of quantization per kernel (stage calls, scale
# params, page-write plumbing) — the unpack loops themselves live in
# DequantStage and are counted once.
QUANT_KERNEL_PAIRS = (
    ("paged_attention_quant", "paged_attention"),
    ("prefill_attention_quant", "prefill_attention"),
    ("mla_paged_quant", "mla_paged"),
    ("mla_prefill_quant", "mla_prefill"),
)
QUANT_MARGINAL_LOC_BUDGET = 40  # max extra lines per quantized variant


def run():
    programs = {
        "matmul": matmul_program(256, 256, 256, block_M=64, block_N=64, block_K=64),
        "flash_attention": flash_attention_program(1, 2, 2, 128, 128, 64, True, 64, 64),
        "flash_mla": mla_program(1, 16, 1, 128, 64, 16, 64, 16),
        "paged_attention": paged_attention_program(4, 8, 2, 64, 64, 8, 32),
        "prefill_attention": prefill_attention_program(4, 8, 2, 64, 128, 64, 8, 64),
        "mla_paged": mla_paged_program(4, 16, 64, 16, 64, 8, 32),
        "mla_prefill": mla_prefill_program(4, 16, 64, 16, 128, 64, 8, 64),
        "dequant_int4": dequant_matmul_program(64, 64, 128, "int4", block_M=32, block_N=32, block_K=64),
        "chunk_state": chunk_state_program(1, 2, 64, 32, 64),
        "chunk_scan": chunk_scan_program(1, 2, 64, 32, 64),
        "paged_attention_quant": paged_attention_quant_program(4, 8, 2, 64, 64, 8, 32, "int8"),
        "prefill_attention_quant": prefill_attention_quant_program(4, 8, 2, 64, 128, 64, 8, 64, "int8"),
        "mla_paged_quant": mla_paged_quant_program(4, 16, 64, 16, 64, 8, 32),
        "mla_prefill_quant": mla_prefill_quant_program(4, 16, 64, 16, 128, 64, 8, 64),
    }
    template = attention_core.source_lines()
    dequant_stage = attention_core.dequant_stage_lines()
    rows = [
        Row(f"loc_{name}", float(p.source_lines), f"source_lines={p.source_lines}")
        for name, p in programs.items()
    ]
    rows.append(Row("loc_attention_template", float(template),
                    f"source_lines={template} (shared, counted once)"))
    rows.append(Row("loc_dequant_stage", float(dequant_stage),
                    f"source_lines={dequant_stage} (shared, counted once)"))
    attention_total = template + sum(
        programs[k].source_lines for k in ATTENTION_KERNELS
    )
    rows.append(Row(
        "loc_attention_net", float(attention_total),
        f"4 kernels + template vs {PRE_REFACTOR_ATTENTION_LOC} pre-refactor",
    ))
    quant_marginal = max(
        programs[q].source_lines - programs[fp].source_lines
        for q, fp in QUANT_KERNEL_PAIRS
    )
    rows.append(Row(
        "loc_quant_marginal_max", float(quant_marginal),
        f"max extra lines of a quantized variant over its fp twin "
        f"(budget {QUANT_MARGINAL_LOC_BUDGET})",
    ))

    # Speculative decoding (ISSUE-10) routes its verify pass through the
    # chunked-prefill programs above (prefill_attention / mla_prefill and
    # their quant twins): scoring all draft positions in one dispatch is
    # just a C-wide chunk, so the kernel registry gains no spec-specific
    # program and the feature's kernel LoC cost is zero by construction.
    import repro.kernels as _kernels

    spec_factories = [n for n in dir(_kernels) if not n.startswith("__")
                      and ("spec" in n.lower() or "draft" in n.lower())]
    rows.append(Row(
        "loc_spec_verify_kernels", float(len(spec_factories)),
        "spec decode verify reuses chunked prefill; zero new kernel programs",
    ))

    check(lambda: programs["flash_mla"].source_lines <= 80,
          "mla-loc-within-paper-claim")
    check(lambda: not spec_factories,
          "spec-verify-zero-new-kernel-lines")
    check(lambda: attention_total <= PRE_REFACTOR_ATTENTION_LOC,
          "attention-refactor-net-simplification")
    check(lambda: quant_marginal <= QUANT_MARGINAL_LOC_BUDGET,
          "quant-kernels-bounded-marginal-loc")
    emit(rows, "Fig 14 (right): kernel lines of code")
    return rows


def derived_metrics(rows):
    """Higher-is-better ratios for the ``--compare`` regression gate:
    headroom under the paper's MLA line budget, and how much smaller the
    composed attention programs are than the pre-refactor loops."""
    by = {r.name: r.us for r in rows}
    return {
        "mla_loc_headroom": round(80.0 / max(by["loc_flash_mla"], 1.0), 3),
        "attention_refactor_loc_ratio": round(
            PRE_REFACTOR_ATTENTION_LOC / max(by["loc_attention_net"], 1.0), 3
        ),
        "quant_marginal_loc_headroom": round(
            QUANT_MARGINAL_LOC_BUDGET
            / max(by["loc_quant_marginal_max"], 1.0), 3
        ),
        # 1.0 = speculative decoding added zero kernel programs (its
        # verify pass is the chunked-prefill kernels, dispatched C-wide)
        "spec_verify_kernel_reuse": round(
            1.0 / (1.0 + by["loc_spec_verify_kernels"]), 3
        ),
    }


if __name__ == "__main__":
    run()
