"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV blocks (cost-model microseconds on
TPU v5e — see common.py for why structural numbers on a CPU host) plus an
inline correctness check per table.

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run --only gemm,mla
"""
import argparse
import sys
import time

from . import (
    bench_attention,
    bench_dequant,
    bench_gemm,
    bench_linear_attention,
    bench_loc,
    bench_mla,
    bench_serving,
)

TABLES = {
    "gemm": bench_gemm,
    "attention": bench_attention,
    "linear_attention": bench_linear_attention,
    "dequant": bench_dequant,
    "mla": bench_mla,
    "serving": bench_serving,
    "loc": bench_loc,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(TABLES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(TABLES)
    t0 = time.time()
    total_rows = 0
    for name in names:
        mod = TABLES[name]
        rows = mod.run()
        total_rows += len(rows)
    print(f"# benchmarks complete: {total_rows} rows in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
