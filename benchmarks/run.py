"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV blocks (cost-model microseconds on
TPU v5e — see common.py for why structural numbers on a CPU host) plus an
inline correctness check per table.

``--json`` additionally writes one ``BENCH_<table>.json`` per table — rows,
cross-row derived metrics and the git sha — so the perf trajectory is
recorded across PRs, not just printed and lost (tools/ci.sh passes it).

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run --only gemm,mla
    PYTHONPATH=src python -m benchmarks.run --only serving --smoke --json
"""
import argparse
import dataclasses
import inspect
import json
import pathlib
import subprocess
import sys
import time

from . import (
    bench_attention,
    bench_dequant,
    bench_gemm,
    bench_linear_attention,
    bench_loc,
    bench_mla,
    bench_serving,
)

TABLES = {
    "gemm": bench_gemm,
    "attention": bench_attention,
    "linear_attention": bench_linear_attention,
    "dequant": bench_dequant,
    "mla": bench_mla,
    "serving": bench_serving,
    "loc": bench_loc,
}


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=pathlib.Path(__file__).resolve().parent.parent, check=True,
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _jsonable(row):
    if dataclasses.is_dataclass(row):
        return dataclasses.asdict(row)
    return row


def write_json(name: str, rows, derived=None, out_dir=".",
               smoke: bool = False) -> pathlib.Path:
    """Write ``BENCH_<name>.json``: rows + derived metrics + git sha.

    ``smoke`` is recorded in the payload so trajectory comparisons never
    silently mix smoke-shape and full-shape numbers."""
    path = pathlib.Path(out_dir) / f"BENCH_{name}.json"
    payload = {
        "table": name,
        "git_sha": git_sha(),
        "smoke": smoke,
        "rows": [_jsonable(r) for r in rows],
        "derived": derived or {},
    }
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
    print(f"# wrote {path}")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(TABLES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes where a table supports it")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<table>.json per table")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(TABLES)
    t0 = time.time()
    total_rows = 0
    for name in names:
        mod = TABLES[name]
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        rows = mod.run(**kwargs)
        if args.json:
            derive = getattr(mod, "derived_metrics", None)
            write_json(name, rows, derive(rows) if derive else None,
                       smoke=bool(kwargs.get("smoke")))
        total_rows += len(rows)
    print(f"# benchmarks complete: {total_rows} rows in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
