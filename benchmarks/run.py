"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV blocks (cost-model microseconds on
TPU v5e — see common.py for why structural numbers on a CPU host) plus an
inline correctness check per table.

``--json`` additionally writes one ``BENCH_<table>.json`` per table — rows,
cross-row derived metrics and the git sha — so the perf trajectory is
recorded across PRs, not just printed and lost (tools/ci.sh passes it).

``--compare <baseline>`` is the regression gate: fresh derived metrics are
checked against a committed ``BENCH_<table>.json`` and the run fails when
any metric drops more than 20% below the baseline.  Derived metrics are
higher-is-better ratios by convention (each table's ``derived_metrics``
documents this), so no per-metric direction table is needed.  Baselines
are read up front (``--json`` may overwrite the same path afterwards), and
a baseline recorded at a different ``--smoke`` setting is skipped with a
note rather than compared against mismatched shapes.  ``<baseline>`` is a
``BENCH_<table>.json`` file when one table is selected, else a directory
holding one per table.

    PYTHONPATH=src python -m benchmarks.run            # all tables
    PYTHONPATH=src python -m benchmarks.run --only gemm,mla
    PYTHONPATH=src python -m benchmarks.run --only serving --smoke --json
    PYTHONPATH=src python -m benchmarks.run --only serving --smoke \
        --compare BENCH_serving.json
"""
import argparse
import dataclasses
import inspect
import json
import pathlib
import subprocess
import sys
import time

from . import (
    bench_attention,
    bench_dequant,
    bench_gemm,
    bench_linear_attention,
    bench_loc,
    bench_mla,
    bench_serving,
)

TABLES = {
    "gemm": bench_gemm,
    "attention": bench_attention,
    "linear_attention": bench_linear_attention,
    "dequant": bench_dequant,
    "mla": bench_mla,
    "serving": bench_serving,
    "loc": bench_loc,
}


def git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=pathlib.Path(__file__).resolve().parent.parent, check=True,
        ).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def _jsonable(row):
    if dataclasses.is_dataclass(row):
        return dataclasses.asdict(row)
    return row


def write_json(name: str, rows, derived=None, out_dir=".",
               smoke: bool = False) -> pathlib.Path:
    """Write ``BENCH_<name>.json``: rows + derived metrics + git sha.

    ``smoke`` is recorded in the payload so trajectory comparisons never
    silently mix smoke-shape and full-shape numbers."""
    path = pathlib.Path(out_dir) / f"BENCH_{name}.json"
    payload = {
        "table": name,
        "git_sha": git_sha(),
        "smoke": smoke,
        "rows": [_jsonable(r) for r in rows],
        "derived": derived or {},
    }
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
    print(f"# wrote {path}")
    return path


REGRESSION_THRESHOLD = 0.2  # fail when a metric drops >20% vs baseline


def load_baselines(arg: str, names) -> dict:
    """Map table name -> committed baseline payload.  Read eagerly so a
    later ``--json`` overwrite of the same path cannot corrupt the gate.
    A missing path is a hard error: a typo'd or renamed baseline must not
    silently disable the regression gate."""
    p = pathlib.Path(arg)
    if not p.exists():
        raise SystemExit(f"--compare baseline {arg!r} does not exist")
    if p.is_file() and len(names) > 1:
        raise SystemExit(
            "--compare got a single file but multiple tables are "
            "selected; pass a directory of BENCH_<table>.json files"
        )
    out = {}
    for name in names:
        path = p if p.is_file() else p / f"BENCH_{name}.json"
        if path.is_file():
            out[name] = json.loads(path.read_text())
        else:
            print(f"# compare[{name}]: no baseline at {path}; skipping")
    return out


def compare_derived(name: str, current: dict, baseline: dict,
                    smoke: bool) -> list:
    """Regression check for one table; returns failure strings.  Every
    derived metric is a higher-is-better ratio by convention."""
    if bool(baseline.get("smoke")) != smoke:
        print(f"# compare[{name}]: baseline smoke={baseline.get('smoke')} "
              f"!= current smoke={smoke}; shapes differ, skipping gate")
        return []
    failures = []
    for k, base in (baseline.get("derived") or {}).items():
        if not isinstance(base, (int, float)):
            continue
        cur = current.get(k)
        if not isinstance(cur, (int, float)):
            # a vanished metric must not silently defeat the gate: renaming
            # or dropping a tracked metric requires updating the baseline
            failures.append(
                f"{name}.{k}: missing from current run (baseline {base} @ "
                f"{baseline.get('git_sha', '?')[:12]})"
            )
            continue
        floor = base * (1.0 - REGRESSION_THRESHOLD)
        if base > 0 and cur < floor:
            failures.append(
                f"{name}.{k}: {cur} < {floor:.3f} "
                f"(baseline {base} @ {baseline.get('git_sha', '?')[:12]})"
            )
        else:
            print(f"# compare[{name}]: {k} = {cur} vs baseline {base}: ok")
    # metrics introduced after the baseline was recorded pass trivially
    # this run (nothing to gate against) — name them so the trajectory
    # shows they become gated once the baseline is regenerated
    for k in sorted(set(current) - set(baseline.get("derived") or {})):
        print(f"# compare[{name}]: {k} = {current[k]} is new "
              "(no baseline; gated after the next baseline refresh)")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(TABLES))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes where a table supports it")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<table>.json per table")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="BENCH_<table>.json (or a directory of them) to "
                         "gate derived metrics against; >20% regression "
                         "fails the run")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(TABLES)
    baselines = load_baselines(args.compare, names) if args.compare else {}
    t0 = time.time()
    total_rows = 0
    failures = []
    for name in names:
        mod = TABLES[name]
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        rows = mod.run(**kwargs)
        derive = getattr(mod, "derived_metrics", None)
        derived = derive(rows) if derive else {}
        if name in baselines:
            failures += compare_derived(
                name, derived, baselines[name], bool(kwargs.get("smoke"))
            )
        if args.json:
            write_json(name, rows, derived, smoke=bool(kwargs.get("smoke")))
        total_rows += len(rows)
    print(f"# benchmarks complete: {total_rows} rows in {time.time()-t0:.1f}s")
    if failures:
        for f in failures:
            print(f"# REGRESSION: {f}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
