"""FlashAttention benchmark — paper Table 3 (FA0–FA4), Fig. 12."""
import numpy as np

from repro.core import Schedule, compile as tl_compile
from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_program

from .common import Row, check, emit, kernel_row

# (batch, heads, seq_len, head_dim, causal) — Table 3
FA_SHAPES = {
    "FA0": (1, 32, 512, 128, True),
    "FA1": (1, 32, 512, 128, False),
    "FA2": (1, 32, 1024, 128, True),
    "FA3": (1, 32, 1024, 128, False),
    "FA4": (1, 32, 4096, 128, True),
}


def run():
    rows = []
    for name, (b, h, s, d, causal) in FA_SHAPES.items():
        bm = bn = min(128, s)
        prog = flash_attention_program(b, h, h, s, s, d, causal, bm, bn,
                                       dtype="bfloat16", num_stages=2)
        rows.append(
            kernel_row(
                f"flash_attn_{name}_b{b}h{h}s{s}d{d}" + ("_causal" if causal else ""),
                prog,
                extra=f"blocks={bm}x{bn}",
            )
        )

    def _ok():
        rng = np.random.default_rng(0)
        prog = flash_attention_program(1, 2, 2, 64, 64, 32, True, 32, 32)
        kern = tl_compile(prog, Schedule(interpret=True))
        q = rng.standard_normal((1, 2, 64, 32), dtype=np.float32)
        k = rng.standard_normal((1, 2, 64, 32), dtype=np.float32)
        v = rng.standard_normal((1, 2, 64, 32), dtype=np.float32)
        return np.allclose(
            np.asarray(kern(q, k, v)),
            np.asarray(ref.attention(q, k, v, causal=True)),
            atol=2e-3,
        )

    check(_ok, "flash-attn-interpret-vs-oracle")
    emit(rows, "Table 3 / Fig 12: FlashAttention (cost-model roofline, v5e)")
    return rows


if __name__ == "__main__":
    run()
