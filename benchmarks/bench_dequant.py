"""Dequantized GEMM benchmark — paper Fig. 15 (A100 W_INTx/NF4 study).

The paper's headline (up to 7.65× over cuBLAS-FP16 for W_INT2) comes from
HBM-traffic reduction at memory-bound shapes.  We reproduce the structure:
for decode-like GEMVs the cost model's roofline time is traffic-dominated,
so the speedup over the FP16 kernel approaches the weight-compression
ratio.  Each row reports that predicted speedup.
"""
import numpy as np

from repro.core import Schedule, compile as tl_compile
from repro.core.autotune import score_kernel
from repro.kernels import ref
from repro.kernels.dequant_matmul import dequant_matmul_program
from repro.kernels.matmul import matmul_program

from .common import Row, check, emit

SHAPES = {  # (M, N, K): decode GEMV + a small-batch GEMM per Fig. 15
    "m1_n16384_k16384": (8, 16384, 16384),
    "m1_n8192_k28672": (8, 8192, 28672),
    "m256_n8192_k8192": (256, 8192, 8192),
}
FMTS = ["int8", "int4", "int2", "nf4"]


def _roofline_us(prog):
    kern = tl_compile(prog, Schedule())
    total, *_ = score_kernel(kern)
    return total * 1e6, kern


def run():
    rows = []
    for sname, (m, n, k) in SHAPES.items():
        base_us, _ = _roofline_us(
            matmul_program(m, n, k, "float16", "float16", "float32",
                           block_M=min(64, m), block_N=128, block_K=256)
        )
        # weight-only (activation fp16) formats + the paper's headline
        # W_INT2 A_INT8 config (int8 activations ride the 2x MXU path)
        for fmt, adt in [(f, "float16") for f in FMTS] + [("int2", "int8"), ("int4", "int8")]:
            us, kern = _roofline_us(
                dequant_matmul_program(
                    m, n, k, fmt, in_dtype=adt,
                    block_M=min(64, m), block_N=128, block_K=256,
                )
            )
            speedup = base_us / us if us else 0.0
            cost = kern.info.cost
            tag = f"W{fmt.upper()}A{'INT8' if adt == 'int8' else 'FP16'}"
            rows.append(
                Row(
                    f"dequant_{tag}_{sname}",
                    us,
                    f"speedup_vs_fp16={speedup:.2f}x hbm={cost.hbm_bytes:.3g}B "
                    f"AI={cost.arithmetic_intensity:.1f}",
                )
            )

    def _ok():
        rng = np.random.default_rng(0)
        prog = dequant_matmul_program(32, 32, 64, "int4", block_M=16,
                                      block_N=16, block_K=32)
        kern = tl_compile(prog, Schedule(interpret=True))
        a = rng.standard_normal((32, 64), dtype=np.float32)
        bp = rng.integers(-128, 128, size=(32, 32)).astype(np.int8)
        return np.allclose(
            np.asarray(kern(a, bp)),
            np.asarray(ref.dequant_matmul(a, bp, "int4")).T,
            atol=2e-2,
        )

    check(_ok, "dequant-int4-interpret-vs-oracle")
    emit(rows, "Fig 15: weight-only-quantized GEMM (cost model, v5e)")
    return rows


if __name__ == "__main__":
    run()
