"""Shared benchmark machinery.

This container is CPU-only, so per-kernel numbers are *structural*: the
tile-level cost model (FLOPs / HBM traffic / VMEM plan / MXU utilization
from the compiled tile program) evaluated against TPU v5e peaks — the same
three-term methodology as the dry-run roofline, applied per kernel.  Each
row also carries an interpret-mode correctness check at a reduced shape so
the numbers always describe a *working* kernel.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import Schedule, compile as tl_compile
from repro.core.autotune import HBM_BW, PEAK_FLOPS_BF16, score_kernel


@dataclasses.dataclass
class Row:
    name: str
    us: float  # cost-model microseconds on v5e
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us:.2f},{self.derived}"


def kernel_row(name: str, program, extra: str = "", schedule=None) -> Row:
    kern = tl_compile(program, schedule or Schedule())
    total, cs, ms, mxu = score_kernel(kern)
    cost = kern.info.cost
    bound = "compute" if cs >= ms else "memory"
    ai = cost.arithmetic_intensity
    frac = max(cs, ms) / total if total else 0.0
    derived = (
        f"bound={bound} flops={cost.flops:.3g} hbm={cost.hbm_bytes:.3g}B "
        f"AI={ai:.1f} mxu={mxu:.0%} vmem={cost.vmem_bytes/2**20:.1f}MiB"
        + (f" {extra}" if extra else "")
    )
    return Row(name, total * 1e6, derived)


def blocks_half(slots: int, max_len: int, page_size: int) -> int:
    """Pool sized at half the contiguous footprint, rounded down (min 1) —
    bench_serving's oversubscription setting."""
    from repro.serving.paged_cache import blocks_for

    return max(1, slots * blocks_for(max_len, page_size) // 2)


def check(fn: Callable[[], bool], label: str):
    ok = fn()
    status = "ok" if ok else "FAIL"
    print(f"# correctness[{label}]: {status}")
    if not ok:
        raise AssertionError(f"benchmark correctness check failed: {label}")


def emit(rows: List[Row], header: str):
    print(f"# {header}")
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
    print()
