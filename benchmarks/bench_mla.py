"""MLA benchmark — paper Fig. 14 (H100/MI300X MLA decode + LOC study).

DeepSeek-V2 decode shapes: 128 query heads sharing one latent KV
(dim=512, rope 64).  Also reproduces the usability axis: our tile-DSL
FlashMLA is ~70 lines of Python (paper: "around 70 lines ... 98% of
hand-optimized FlashMLA").
"""
import numpy as np

from repro.core import Schedule, compile as tl_compile
from repro.kernels import ref
from repro.kernels.mla import mla_program

from .common import Row, check, emit, kernel_row

# batch, heads, kv_heads, seqlen_kv, dim, pe_dim
SHAPES = {
    "b64_s1024": (64, 128, 1, 1024, 512, 64),
    "b64_s4096": (64, 128, 1, 4096, 512, 64),
    "b128_s8192": (128, 128, 1, 8192, 512, 64),
}


def run():
    rows = []
    for name, (b, h, hkv, s, d, pe) in SHAPES.items():
        prog = mla_program(b, h, hkv, s, d, pe, block_N=128, block_H=64,
                           dtype="bfloat16", num_stages=2)
        rows.append(
            kernel_row(
                f"flash_mla_{name}",
                prog,
                extra=f"LOC={prog.source_lines}",
            )
        )

    def _ok():
        rng = np.random.default_rng(0)
        prog = mla_program(2, 16, 1, 128, 64, 16, 32, 16)
        kern = tl_compile(prog, Schedule(interpret=True))
        q = rng.standard_normal((2, 16, 64), dtype=np.float32)
        qpe = rng.standard_normal((2, 16, 16), dtype=np.float32)
        kv = rng.standard_normal((2, 128, 1, 64), dtype=np.float32)
        kpe = rng.standard_normal((2, 128, 1, 16), dtype=np.float32)
        return np.allclose(
            np.asarray(kern(q, qpe, kv, kpe)),
            np.asarray(ref.mla(q, qpe, kv, kpe)),
            atol=2e-3,
        )

    check(_ok, "mla-interpret-vs-oracle")
    emit(rows, "Fig 14: FlashMLA (cost model, v5e)")
    return rows


if __name__ == "__main__":
    run()
