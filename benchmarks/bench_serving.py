"""Serving-engine benchmark: cache layouts + the chunked-prefill fast path.

Unlike the per-kernel tables (cost-model numbers), this drives the real
engine end-to-end on CPU and reports measured behavior:

Workload 1 — *contiguous vs paged* (ISSUE-2): a skewed prompt-length mix
(many short, a few near-``max_len``) with the paged pool sized at half the
contiguous footprint, exercising admission gating and preemption while
asserting both layouts emit identical tokens.  Reports ``tok_per_s``,
``kv_bytes`` (allocated) and ``peak_kv_bytes`` (resident high-water mark).

Workload 2 — *prefill-heavy: replay vs chunked* (ISSUE-3): long prompts,
short generations — the regime where one-token-per-tick prompt replay
drowns the engine.  Chunked prefill feeds ``prefill_chunk``-token blocks
through one forward pass per tick under a token budget, so engine ticks
collapse from ``prompt + gen`` to ``ceil(prompt/chunk) + gen`` per request.
Reports engine ticks, mean TTFT (in ticks — deterministic on any host) and
tok/s, asserting byte-identical outputs across replay/chunked and
paged/contiguous, and a >= 8x tick reduction at the default chunk of 16.

Workload 3 — *decode-heavy: per-tick vs multi-step* (ISSUE-4): short
prompts, long generations — the regime where the per-tick host round trip
(feed build, upload, sample download, table refresh) dominates.  The
device-resident loop (``sync_every > 1``) runs up to N decode ticks per
dispatch via ``jax.lax.scan``.  Reports wall-clock tok/s and per-token
*delivery* latency percentiles (each token is charged its dispatch's wall
time — multi-step trades worst-case latency for throughput, and the p50/p95
shows exactly that) for ``sync_every in {1, 4, 16}`` on the paged layout
plus per-tick/multi-step contiguous baselines, asserting byte-identical
outputs across every variant; the full (non-smoke) run additionally asserts
the >= 2x multi-step throughput win at ``sync_every=16``.

Workload 4 — *MLA serving matrix* (ISSUE-5): a deepseek_v2_lite-style MLA
config through the paged **latent** cache and chunked prefill (the
composable attention core's new composition points), asserting
byte-identical outputs across paged/contiguous and replay/chunked with the
latent pool at half the contiguous footprint.

Workload 5 — *shared-system-prompt prefix caching* (ISSUE-6): every request
carries the same 112-token system prompt (7 full pages at ``page_size=16``)
plus a short page-unaligned unique tail.  With the prefix cache on, warm
requests attach the cached prefix pages at admission and prefill only their
tail — TTFT-from-admission collapses from ``ceil(prompt/chunk)`` ticks to
~one chunk, and fresh block allocations per request drop to the tail+gen
footprint.  Runs prefix on/off x chunked/replay and asserts byte-identical
outputs (caching must never change tokens), warm TTFT <= 25% of cold, and
fewer allocations per request than the uncached engine.

Workload 6 — *MLA decode-heavy: per-tick vs multi-step* (ISSUE-5/6 rider):
workload 3's regime on the MLA latent cache — the device-resident decode
loop composes with paged latent attention, reported as wall-clock tok/s,
delivery-latency percentiles and the deterministic dispatch-amortization
ratio.

Workload 7 — *quantized KV cache* (ISSUE-7): the same requests through fp,
int8 and int4 page pools.  Reports the memory ratios (asserted <= 0.55x /
<= 0.30x of fp), the accuracy story (greedy token-match rate vs the fp
engine plus the teacher-forced max logit error), and a pool-pressure run
where fp and int8 pools are sized to the *same byte budget* — the
quantized pool holds ~3x the pages, so preemptions drop at fixed memory.

Workload 8 — *chaos + crash-safe restore* (ISSUE-8): the fault-tolerance
contract as numbers.  Phase A replays a shared-prefix workload fault-free,
then again under an injected fault schedule (pool exhaustion, failed
grow-ahead grants, one poisoned logits row) plus a cancel and an expiring
deadline, with the invariant auditor on every tick — asserting every
unaffected request finishes byte-identical and shutdown leaves zero
allocated pages.  Phase B snapshots the warm prefix cache, restores it
into a fresh engine, and checks the restored warm TTFT matches the
pre-restart warm hit instead of paying the cold prefill.

Workload 9 — *guarded dispatch under table corruption* (ISSUE-9): the same
shared-prefix workload fault-free, then under a schedule of injected
block-table corruptions (out-of-range id / reserved page 0 / duplicated
page, cycling).  The dispatch guard must intercept every corruption before
a page is touched, FAILing exactly the hit request, with all surviving
requests byte-identical to the fault-free run and zero pages leaked —
recorded as the regression-gated ``guard_unaffected_byte_identity``.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke] [--json]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine


def skewed_prompt_lengths(rng, n: int, max_len: int):
    """~80% short prompts, ~20% long (near half of max_len)."""
    lens = []
    for _ in range(n):
        if rng.random() < 0.8:
            lens.append(int(rng.integers(2, max(3, max_len // 16))))
        else:
            lens.append(int(rng.integers(max_len // 4, max_len // 2)))
    return lens


def _drive(cfg, params, prompts, scfg_kw, label=None):
    engine = ServingEngine(cfg, params, ServeConfig(**scfg_kw))
    reqs = [engine.submit(p) for p in prompts]
    t0 = time.time()
    engine.run(max_steps=100_000)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in reqs)
    ttfts = [r.ttft_ticks for r in reqs if r.ttft_ticks is not None]
    page_bytes = 0
    if engine.pool is not None:
        per_tok = engine.kv_cache_bytes() // max(
            (engine.pool.num_blocks + 1) * engine.pool.page_size, 1
        )
        page_bytes = engine.pool.page_size * per_tok
    peak = (
        engine.peak_kv_blocks() * page_bytes
        if engine.pool is not None
        else engine.kv_cache_bytes()
    )
    return {
        "mode": label or scfg_kw.get("cache", "paged"),
        "tok_per_s": round(toks / max(dt, 1e-9), 2),
        "kv_bytes": engine.kv_cache_bytes(),
        "peak_kv_bytes": peak,
        "steps": engine.steps_run,
        "ttft_ticks_mean": round(float(np.mean(ttfts)), 2) if ttfts else None,
        "preemptions": engine.preemptions,
        "outputs": [r.output for r in reqs],
    }


def _drive_timed(cfg, params, prompts, scfg_kw, label, repeats: int = 3):
    """Like ``_drive`` but steps the engine manually, charging every emitted
    token its dispatch's wall-clock time (delivery latency: a token emitted
    mid-window is only visible to the host when the window drains).

    The timed drive runs ``repeats`` times and keeps the fastest run: the
    workloads are short enough that a single OS scheduler stall would
    otherwise dominate the tok/s ratio the ``--compare`` regression gate
    checks (outputs are deterministic, so every repeat emits identical
    tokens — asserted)."""
    # warm the jit caches (trace + compile) outside the timed runs
    warm = ServingEngine(cfg, params, ServeConfig(**scfg_kw))
    warm.submit(prompts[0][: max(2, len(prompts[0]) // 2)])
    warm.run(max_steps=1_000)

    best = None
    for _ in range(repeats):
        engine = ServingEngine(cfg, params, ServeConfig(**scfg_kw))
        reqs = [engine.submit(p) for p in prompts]
        lat = []
        emitted_before = 0
        t0 = time.perf_counter()
        for _ in range(100_000):
            ts = time.perf_counter()
            n = engine.step()
            dt = time.perf_counter() - ts
            emitted_now = sum(len(r.output) for r in reqs)
            lat.extend([dt] * (emitted_now - emitted_before))
            emitted_before = emitted_now
            if n == 0 and not engine.queue:
                break
        wall = time.perf_counter() - t0
        outputs = [r.output for r in reqs]
        if best is not None and outputs != best["outputs"]:
            raise AssertionError(f"{label}: nondeterministic outputs across repeats")
        if best is None or wall < best["wall"]:
            best = {"wall": wall, "lat": lat, "engine": engine,
                    "outputs": outputs}
    engine, lat = best["engine"], best["lat"]
    toks = sum(len(o) for o in best["outputs"])
    return {
        "mode": label,
        "tok_per_s": round(toks / max(best["wall"], 1e-9), 2),
        "lat_p50_ms": round(float(np.percentile(lat, 50)) * 1e3, 3),
        "lat_p95_ms": round(float(np.percentile(lat, 95)) * 1e3, 3),
        "steps": engine.steps_run,
        "dispatches": engine.dispatches,
        "decode_windows": engine.decode_windows,
        "window_fallbacks": engine.window_fallbacks,
        "table_uploads": engine.table_uploads,
        "spec_windows": engine.spec_windows,
        "spec_proposed": engine.spec_proposed,
        "spec_accepted": engine.spec_accepted,
        "outputs": best["outputs"],
    }


def _decode_workload(cfg, params, smoke: bool):
    """Decode-heavy: short prompts, long generations — per-tick host
    round-trip overhead is the bottleneck the device-resident loop removes."""
    if smoke:
        slots, max_len, n_req, prompt_len, max_new = 2, 64, 6, 4, 32
    else:
        slots, max_len, n_req, prompt_len, max_new = 4, 128, 12, 6, 48
    rng = np.random.default_rng(2)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=prompt_len).tolist()
        for _ in range(n_req)
    ]
    base = dict(slots=slots, max_len=max_len, max_new_tokens=max_new)
    variants = [
        ("decode_sync1_paged", dict(base, cache="paged", sync_every=1)),
        ("decode_sync4_paged", dict(base, cache="paged", sync_every=4)),
        ("decode_sync16_paged", dict(base, cache="paged", sync_every=16)),
        ("decode_sync1_contiguous", dict(base, cache="contiguous", sync_every=1)),
        ("decode_sync16_contiguous", dict(base, cache="contiguous", sync_every=16)),
    ]
    rows = [_drive_timed(cfg, params, prompts, kw, label)
            for label, kw in variants]
    ref_out = rows[0]["outputs"]
    for r in rows[1:]:
        if r["outputs"] != ref_out:
            raise AssertionError(
                f"decode outputs diverged: {r['mode']} vs {rows[0]['mode']}"
            )
    by = {r["mode"]: r for r in rows}
    speedup = (
        by["decode_sync16_paged"]["tok_per_s"]
        / max(by["decode_sync1_paged"]["tok_per_s"], 1e-9)
    )
    if not smoke and speedup < 2.0:
        raise AssertionError(
            f"multi-step decode speedup {speedup:.2f}x < 2x at sync_every=16"
        )
    gap = (
        by["decode_sync16_paged"]["tok_per_s"]
        / max(by["decode_sync16_contiguous"]["tok_per_s"], 1e-9)
    )
    print(f"# serving: decode-heavy per-tick vs multi-step "
          f"({n_req} reqs x {prompt_len} prompt + {max_new} gen, slots={slots})")
    print("mode,tok_per_s,lat_p50_ms,lat_p95_ms,steps,dispatches,"
          "decode_windows,table_uploads")
    for r in rows:
        print(f"{r['mode']},{r['tok_per_s']},{r['lat_p50_ms']},"
              f"{r['lat_p95_ms']},{r['steps']},{r['dispatches']},"
              f"{r['decode_windows']},{r['table_uploads']}")
    print(f"# multi-step decode: {speedup:.2f}x tok/s at sync_every=16; "
          f"paged/contiguous = {gap:.2f}; identical outputs: ok")
    print()
    return rows


def _layout_workload(cfg, params, smoke: bool):
    if smoke:
        slots, max_len, n_req, max_new = 2, 64, 5, 4
    else:
        slots, max_len, n_req, max_new = 4, 128, 24, 12
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=n).tolist()
        for n in skewed_prompt_lengths(rng, n_req, max_len)
    ]
    base = dict(slots=slots, max_len=max_len, max_new_tokens=max_new)

    from .common import blocks_half  # late import keeps -m module runnable

    contig = _drive(cfg, params, prompts, dict(base, cache="contiguous"))
    paged = _drive(
        cfg, params, prompts,
        dict(base, cache="paged",
             num_blocks=blocks_half(slots, max_len, page_size=16)),
    )
    if contig["outputs"] != paged["outputs"]:
        raise AssertionError(
            "contiguous and paged cache modes diverged on identical requests"
        )
    print("# serving: contiguous vs paged KV "
          f"({n_req} reqs, slots={slots}, max_len={max_len}, skewed prompts)")
    print("mode,tok_per_s,kv_bytes,peak_kv_bytes,steps,preemptions")
    for r in (contig, paged):
        print(
            f"{r['mode']},{r['tok_per_s']},{r['kv_bytes']},"
            f"{r['peak_kv_bytes']},{r['steps']},{r['preemptions']}"
        )
    saving = 1.0 - paged["kv_bytes"] / max(contig["kv_bytes"], 1)
    print(f"# paged pool allocates {saving:.0%} less KV memory "
          f"({paged['preemptions']} preemptions); identical outputs: ok")
    print()
    return [contig, paged]


def _prefill_workload(cfg, params, smoke: bool, chunk: int = 16):
    """Prefill-heavy: slots=1 so ticks decompose per request and the
    replay-vs-chunked tick bound is exact, not scheduling-dependent."""
    if smoke:
        n_req, prompt_len, max_new, max_len = 2, 32, 2, 64
    else:
        n_req, prompt_len, max_new, max_len = 3, 64, 4, 128
    rng = np.random.default_rng(1)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=prompt_len).tolist()
        for _ in range(n_req)
    ]
    base = dict(slots=1, max_len=max_len, max_new_tokens=max_new,
                prefill_chunk=chunk)
    replay = _drive(cfg, params, prompts,
                    dict(base, cache="paged", prefill="replay"),
                    label="replay_paged")
    chunked = _drive(cfg, params, prompts,
                     dict(base, cache="paged", prefill="chunked"),
                     label="chunked_paged")
    chunked_c = _drive(cfg, params, prompts,
                       dict(base, cache="contiguous", prefill="chunked"),
                       label="chunked_contiguous")
    if not (replay["outputs"] == chunked["outputs"] == chunked_c["outputs"]):
        raise AssertionError(
            "prefill modes / cache layouts diverged on identical requests"
        )
    # tick bounds (slots=1 => requests run back to back): replay needs
    # prompt+gen ticks per request, chunked ceil(prompt/chunk)+gen — minus
    # one each, since the tick consuming the last prompt token also emits
    # the first output token.
    gen = max_new
    replay_bound = n_req * (prompt_len + gen - 1)
    chunked_bound = n_req * (-(-prompt_len // chunk) + gen)
    assert replay["steps"] == replay_bound, (replay["steps"], replay_bound)
    assert chunked["steps"] <= chunked_bound, (chunked["steps"], chunked_bound)
    speedup = replay["steps"] / max(chunked["steps"], 1)
    if chunk == 16 and speedup < 8.0:
        raise AssertionError(
            f"chunked prefill tick reduction {speedup:.1f}x < 8x at chunk=16"
        )
    print(f"# serving: prefill-heavy replay vs chunked "
          f"({n_req} reqs x {prompt_len} prompt + {max_new} gen, chunk={chunk})")
    print("mode,ticks,ttft_ticks_mean,tok_per_s")
    for r in (replay, chunked, chunked_c):
        print(f"{r['mode']},{r['steps']},{r['ttft_ticks_mean']},{r['tok_per_s']}")
    print(f"# chunked prefill: {speedup:.1f}x fewer engine ticks, TTFT "
          f"{replay['ttft_ticks_mean']:.0f} -> {chunked['ttft_ticks_mean']:.0f} "
          "ticks; identical outputs: ok")
    print()
    return [replay, chunked, chunked_c]


def _mla_workload(smoke: bool):
    """MLA serving matrix (ISSUE-5): a deepseek_v2_lite-style tiny config
    through the **paged latent cache** and **chunked prefill** — the model
    family the attention-core refactor admitted to the serving stack.
    Drives all four layout x prefill combinations and asserts byte-identical
    outputs across paged/contiguous and replay/chunked; the paged pool is
    sized at half the contiguous footprint, so the run also exercises
    latent-page admission gating/preemption under real pressure."""
    from repro.configs import get_config as _get

    cfg = _get("deepseek_v2_lite_16b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    if smoke:
        slots, max_len, n_req, prompt_len, max_new = 2, 64, 3, 24, 3
    else:
        slots, max_len, n_req, prompt_len, max_new = 2, 128, 6, 48, 6
    rng = np.random.default_rng(3)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=prompt_len).tolist()
        for _ in range(n_req)
    ]
    from .common import blocks_half

    base = dict(slots=slots, max_len=max_len, max_new_tokens=max_new,
                prefill_chunk=16)
    paged = dict(base, cache="paged",
                 num_blocks=blocks_half(slots, max_len, page_size=16))
    variants = [
        ("mla_paged_chunked", dict(paged, prefill="chunked")),
        ("mla_paged_replay", dict(paged, prefill="replay")),
        ("mla_contiguous_chunked", dict(base, cache="contiguous",
                                        prefill="chunked")),
        ("mla_contiguous_replay", dict(base, cache="contiguous",
                                       prefill="replay")),
    ]
    rows = [_drive(cfg, params, prompts, kw, label) for label, kw in variants]
    ref_out = rows[0]["outputs"]
    for r in rows[1:]:
        if r["outputs"] != ref_out:
            raise AssertionError(
                f"MLA outputs diverged: {r['mode']} vs {rows[0]['mode']}"
            )
    by = {r["mode"]: r for r in rows}
    speedup = by["mla_paged_replay"]["steps"] / max(
        by["mla_paged_chunked"]["steps"], 1
    )
    saving = 1.0 - by["mla_paged_chunked"]["kv_bytes"] / max(
        by["mla_contiguous_chunked"]["kv_bytes"], 1
    )
    print(f"# serving: MLA paged latent cache + chunked prefill "
          f"({n_req} reqs x {prompt_len} prompt + {max_new} gen, slots={slots})")
    print("mode,ticks,ttft_ticks_mean,tok_per_s,kv_bytes,preemptions")
    for r in rows:
        print(f"{r['mode']},{r['steps']},{r['ttft_ticks_mean']},"
              f"{r['tok_per_s']},{r['kv_bytes']},{r['preemptions']}")
    print(f"# MLA chunked prefill: {speedup:.1f}x fewer engine ticks; "
          f"latent pool allocates {saving:.0%} less KV memory; identical "
          "outputs across all four layout x prefill modes: ok")
    print()
    return rows


def _prefix_workload(cfg, params, smoke: bool, chunk: int = 16):
    """Workload 5 — shared-system-prompt prefix caching.  slots=1 keeps the
    runs sequential, so the first request is the cold miss that populates
    the index and every later request is a pure warm hit (and tick counts
    decompose exactly, scheduling-free)."""
    shared_len = 7 * 16  # 7 full pages at page_size=16
    if smoke:
        n_req, max_new, max_len = 4, 3, 160
    else:
        n_req, max_new, max_len = 6, 4, 192
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab_size, size=shared_len).tolist()
    # page-unaligned tails: the cached prefix ends mid-page from the
    # engine's point of view, exercising the partial-page admission path
    tails = [rng.integers(0, cfg.vocab_size, size=int(t)).tolist()
             for t in rng.integers(3, 14, size=n_req)]
    prompts = [shared + t for t in tails]
    base = dict(slots=1, max_len=max_len, max_new_tokens=max_new,
                prefill_chunk=chunk, cache="paged", page_size=16,
                num_blocks=24)

    def drive(label, **kw):
        engine = ServingEngine(cfg, params, ServeConfig(**dict(base, **kw)))
        reqs = [engine.submit(p) for p in prompts]
        t0 = time.time()
        engine.run(max_steps=100_000)
        dt = time.time() - t0
        toks = sum(len(r.output) for r in reqs)
        ttfts = [r.ttft_admit_ticks for r in reqs]
        return {
            "mode": label,
            "tok_per_s": round(toks / max(dt, 1e-9), 2),
            "steps": engine.steps_run,
            "n_req": n_req,
            "ttft_cold_ticks": ttfts[0],
            "ttft_warm_ticks_mean": round(float(np.mean(ttfts[1:])), 2),
            "pages_shared": engine.pages_shared,
            "pages_copied": engine.pages_copied,
            "allocs_per_req": round(engine.pool.total_allocs / n_req, 2),
            "peak_kv_blocks": engine.pool.peak_in_use,
            "outputs": [r.output for r in reqs],
        }

    rows = [
        drive("prefix_chunked", prefill="chunked"),
        drive("noprefix_chunked", prefill="chunked", prefix_cache=False),
        drive("prefix_replay", prefill="replay"),
        drive("noprefix_replay", prefill="replay", prefix_cache=False),
    ]
    ref_out = rows[0]["outputs"]
    for r in rows[1:]:
        if r["outputs"] != ref_out:
            raise AssertionError(
                f"prefix caching changed tokens: {r['mode']} vs {rows[0]['mode']}"
            )
    by = {r["mode"]: r for r in rows}
    on = by["prefix_chunked"]
    cold, warm = on["ttft_cold_ticks"], on["ttft_warm_ticks_mean"]
    if warm > 0.25 * cold:
        raise AssertionError(
            f"warm TTFT {warm} ticks > 25% of cold {cold} at chunk={chunk}"
        )
    if on["allocs_per_req"] >= by["noprefix_chunked"]["allocs_per_req"]:
        raise AssertionError(
            "prefix sharing did not reduce block allocations per request"
        )
    print(f"# serving: shared-system-prompt prefix caching "
          f"({n_req} reqs x {shared_len}-token shared prefix + unique tail, "
          f"chunk={chunk})")
    print("mode,tok_per_s,steps,ttft_cold_ticks,ttft_warm_ticks_mean,"
          "pages_shared,allocs_per_req,peak_kv_blocks")
    for r in rows:
        print(f"{r['mode']},{r['tok_per_s']},{r['steps']},"
              f"{r['ttft_cold_ticks']},{r['ttft_warm_ticks_mean']},"
              f"{r['pages_shared']},{r['allocs_per_req']},"
              f"{r['peak_kv_blocks']}")
    print(f"# warm TTFT {cold} -> {warm} ticks "
          f"({cold / max(warm, 1e-9):.1f}x); allocations/request "
          f"{by['noprefix_chunked']['allocs_per_req']} -> "
          f"{on['allocs_per_req']}; identical outputs across "
          "prefix on/off x chunked/replay: ok")
    print()
    return rows


def _mla_decode_workload(smoke: bool):
    """Workload 6 — decode-heavy MLA: the device-resident multi-step loop
    over the paged latent cache (workload 3's regime, MLA arch)."""
    from repro.configs import get_config as _get

    cfg = _get("deepseek_v2_lite_16b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    if smoke:
        slots, max_len, n_req, prompt_len, max_new = 2, 64, 4, 4, 24
    else:
        slots, max_len, n_req, prompt_len, max_new = 2, 128, 8, 6, 48
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=prompt_len).tolist()
        for _ in range(n_req)
    ]
    base = dict(slots=slots, max_len=max_len, max_new_tokens=max_new,
                cache="paged")
    variants = [
        ("mla_decode_sync1_paged", dict(base, sync_every=1)),
        ("mla_decode_sync16_paged", dict(base, sync_every=16)),
    ]
    rows = [_drive_timed(cfg, params, prompts, kw, label)
            for label, kw in variants]
    if rows[0]["outputs"] != rows[1]["outputs"]:
        raise AssertionError("MLA multi-step decode outputs diverged")
    amort = rows[0]["dispatches"] / max(rows[1]["dispatches"], 1)
    print(f"# serving: MLA decode-heavy per-tick vs multi-step "
          f"({n_req} reqs x {prompt_len} prompt + {max_new} gen, slots={slots})")
    print("mode,tok_per_s,lat_p50_ms,lat_p95_ms,steps,dispatches,"
          "decode_windows,table_uploads")
    for r in rows:
        print(f"{r['mode']},{r['tok_per_s']},{r['lat_p50_ms']},"
              f"{r['lat_p95_ms']},{r['steps']},{r['dispatches']},"
              f"{r['decode_windows']},{r['table_uploads']}")
    print(f"# MLA multi-step decode: {amort:.1f}x fewer host dispatches at "
          "sync_every=16; identical outputs: ok")
    print()
    return rows


def _teacher_forced_logits(cfg, params, seq, max_len, page_size=16):
    """Per-position logits for ``seq`` replayed one token at a time against
    a single-slot paged cache — the probe the logit-error metric uses.
    Identical code path for fp and quantized configs (the storage format
    lives in the cache pytree), so any logit difference is attributable to
    KV quantization alone."""
    import jax.numpy as jnp

    max_pages = -(-max_len // page_size)
    cache = lm.init_cache(cfg, 1, max_len, layout="paged",
                          page_size=page_size, num_blocks=max_pages + 1)
    cache = cache.with_tables(jnp.arange(1, max_pages + 1,
                                         dtype=jnp.int32)[None, :])
    step = jax.jit(lambda c, tok, pos: lm.decode_step(params, cfg, c, tok, pos))
    logits = []
    for i, tok in enumerate(seq[:-1]):
        lg, cache = step(cache, jnp.asarray([tok], jnp.int32),
                         jnp.asarray(i, jnp.int32))
        logits.append(np.asarray(lg[0], np.float32))
    return np.stack(logits)


def _quant_workload(cfg, params, smoke: bool):
    """Workload 7 — quantized KV cache (ISSUE-7): int8/int4 page pools with
    inline dequantization at the attention gather.

    Three measurements per format:

    * **memory** — ``kv_bytes`` vs the fp pool, asserted at the acceptance
      ratios (int8 <= 0.55x, int4 <= 0.30x: packed bytes + one fp scale
      column per token per pool);
    * **accuracy** — end-to-end greedy token match rate vs the fp engine on
      the same requests, plus the max teacher-forced logit error replaying
      one request's full token stream against each cache format;
    * **capacity** — a fixed byte budget converts to pool blocks through
      each format's ``page_bytes`` (``blocks_for_bytes``): the quantized
      pool holds ~3x the pages, so the same over-committed workload
      preempts less (asserted strictly fewer than fp)."""
    from repro.serving.paged_cache import blocks_for_bytes

    if smoke:
        slots, max_len, n_req, prompt_len, max_new = 2, 64, 5, 10, 10
    else:
        slots, max_len, n_req, prompt_len, max_new = 2, 128, 8, 16, 16
    ps = 8
    rng = np.random.default_rng(6)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=prompt_len).tolist()
        for _ in range(n_req)
    ]
    base = dict(slots=slots, max_len=max_len, max_new_tokens=max_new,
                page_size=ps, cache="paged")
    formats = [("kv_fp", None), ("kv_int8", "int8"), ("kv_int4", "int4")]
    rows = []
    for label, fmt in formats:
        r = _drive(cfg, params, prompts, dict(base, kv_dtype=fmt), label)
        r["kv_dtype"] = fmt or "fp"
        rows.append(r)
    by = {r["mode"]: r for r in rows}
    fp = by["kv_fp"]
    total = sum(len(o) for o in fp["outputs"])
    for r in rows:
        match = sum(
            a == b
            for of, oq in zip(fp["outputs"], r["outputs"])
            for a, b in zip(of, oq)
        )
        r["token_match"] = round(match / max(total, 1), 4)
    r8 = by["kv_int8"]["kv_bytes"] / max(fp["kv_bytes"], 1)
    r4 = by["kv_int4"]["kv_bytes"] / max(fp["kv_bytes"], 1)
    if r8 > 0.55:
        raise AssertionError(f"int8 kv_bytes ratio {r8:.3f} > 0.55")
    if r4 > 0.30:
        raise AssertionError(f"int4 kv_bytes ratio {r4:.3f} > 0.30")
    if by["kv_int8"]["token_match"] < 0.95:
        raise AssertionError(
            f"int8 token match {by['kv_int8']['token_match']} < 0.95"
        )

    # teacher-forced max logit error on one request's full token stream
    import dataclasses as _dc

    seq = prompts[0] + fp["outputs"][0]
    ref_logits = _teacher_forced_logits(cfg, params, seq, max_len, ps)
    for r, fmt in zip(rows, [f for _, f in formats]):
        if fmt is None:
            r["max_logit_err"] = 0.0
            continue
        qcfg = _dc.replace(cfg, kv_dtype=fmt)
        q_logits = _teacher_forced_logits(qcfg, params, seq, max_len, ps)
        r["max_logit_err"] = round(
            float(np.max(np.abs(q_logits - ref_logits))), 4)

    # pool pressure at a fixed byte budget: size each pool to the same
    # bytes, let the engine over-commit, count preemptions
    def page_bytes_of(fmt):
        probe = ServingEngine(cfg, params, ServeConfig(
            **dict(base, kv_dtype=fmt, num_blocks=2)))
        return probe.pool.page_bytes

    fp_pb = page_bytes_of(None)
    budget = (5 if smoke else 9) * fp_pb  # tight for fp, roomy quantized
    pressure_prompts = prompts + prompts  # double the load
    for label, fmt in (("kv_fp_pressure", None), ("kv_int8_pressure", "int8")):
        nb = blocks_for_bytes(budget, page_bytes_of(fmt))
        r = _drive(cfg, params, pressure_prompts,
                   dict(base, kv_dtype=fmt, num_blocks=nb,
                        prefix_cache=False), label)
        r["kv_dtype"] = fmt or "fp"
        r["num_blocks"] = nb
        r["token_match"] = None
        r["max_logit_err"] = None
        rows.append(r)
    by = {r["mode"]: r for r in rows}
    fp_pre = by["kv_fp_pressure"]["preemptions"]
    q_pre = by["kv_int8_pressure"]["preemptions"]
    if not (fp_pre > q_pre):
        raise AssertionError(
            f"quantized pool did not reduce preemptions at fixed memory "
            f"(fp={fp_pre}, int8={q_pre})"
        )
    print(f"# serving: quantized KV cache fp vs int8 vs int4 "
          f"({n_req} reqs x {prompt_len} prompt + {max_new} gen, slots={slots}, "
          f"page_size={ps}; pressure runs at a {budget}-byte pool budget)")
    print("mode,tok_per_s,kv_bytes,preemptions,token_match,max_logit_err")
    for r in rows:
        print(f"{r['mode']},{r['tok_per_s']},{r['kv_bytes']},"
              f"{r['preemptions']},{r['token_match']},{r['max_logit_err']}")
    print(f"# kv_bytes: int8 {r8:.3f}x / int4 {r4:.3f}x of fp; pressure "
          f"preemptions {fp_pre} -> {q_pre} at fixed bytes; int8 token "
          f"match {by['kv_int8']['token_match']:.0%}")
    print()
    return rows


def _chaos_workload(cfg, params, smoke: bool):
    """Workload 8 — chaos + crash-safe restore (ISSUE-8)."""
    from repro.serving import Fault, FaultInjector
    from repro.serving.faults import audit_engine

    if smoke:
        n_req, max_new = 6, 5
    else:
        n_req, max_new = 9, 7
    rng = np.random.default_rng(8)
    shared = rng.integers(0, cfg.vocab_size, size=8).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=int(t)).tolist()
               for t in rng.integers(2, 7, size=n_req)]
    base = dict(slots=2, max_len=48, max_new_tokens=max_new, page_size=4,
                num_blocks=14, sync_every=4)

    def drive(label, injector=None, chaos=False, **kw):
        eng = ServingEngine(cfg, params, ServeConfig(**dict(base, **kw)),
                            injector=injector)
        reqs = [eng.submit(p) for p in prompts]
        if chaos:
            reqs[2].cancel()  # lifecycle exits ride along with the faults
            reqs[-1].deadline_ticks = 2  # expires while queued (slots=2)
        t0 = time.time()
        eng.run(max_steps=10_000)
        eng.drain()
        eng.shutdown()
        dt = time.time() - t0
        toks = sum(len(r.output) for r in reqs)
        return eng, reqs, {
            "mode": label,
            "tok_per_s": round(toks / max(dt, 1e-9), 2),
            "steps": eng.steps_run,
            "n_req": n_req,
            "preemptions": eng.preemptions,
            "poisoned_rows": eng.poisoned_rows,
            "audits_run": eng.audits_run,
            "leaked_pages": eng.pool.in_use,  # after shutdown: must be 0
            "outputs": [r.output for r in reqs],
        }

    # phase A: fault-free reference, then the same workload under fire
    _, ref_reqs, ref_row = drive("chaos_faultfree")
    schedule = [
        Fault("pool_alloc", tick=1), Fault("poison", tick=3, slot=0),
        Fault("pool_alloc", tick=5), Fault("grant", tick=6),
        Fault("pool_alloc", tick=8),
    ]
    eng, reqs, row = drive("chaos_injected", injector=FaultInjector(schedule),
                           chaos=True, audit=True)
    completed = [r for r in reqs if r.status == "completed"]
    identical = sum(r.output == ref_reqs[reqs.index(r)].output
                    for r in completed)
    row["completed"] = len(completed)
    row["affected"] = n_req - len(completed)
    row["unaffected_identical"] = round(identical / max(len(completed), 1), 4)
    row["faults_fired"] = sum(eng.injector.fired.values())
    if identical != len(completed):
        raise AssertionError(
            f"{len(completed) - identical} unaffected requests diverged "
            "under injected faults")
    if row["leaked_pages"] != 0:
        raise AssertionError(f"shutdown leaked {row['leaked_pages']} pages")
    if not any(r.status == "cancelled" for r in reqs):
        raise AssertionError("the cancelled request did not exit CANCELLED")
    if not any(r.status == "timed_out" for r in reqs):
        raise AssertionError("the deadline request did not time out")

    # phase B: snapshot the warm prefix index, restore into a fresh engine
    snap_kw = dict(slots=1, max_len=48, max_new_tokens=3, page_size=4,
                   prefill_chunk=4, token_budget=5)
    prompt = rng.integers(0, cfg.vocab_size, size=20).tolist()
    warm_eng = ServingEngine(cfg, params, ServeConfig(**snap_kw))
    cold = warm_eng.submit(prompt)
    warm = warm_eng.submit(prompt)
    warm_eng.run()
    snap = warm_eng.snapshot()
    restored_eng = ServingEngine.restore(cfg, params, ServeConfig(**snap_kw),
                                         snap)
    audit_engine(restored_eng)
    restored = restored_eng.submit(prompt)
    restored_eng.run()
    if restored.output != cold.output:
        raise AssertionError("restored engine changed tokens")
    if restored.ttft_admit_ticks != warm.ttft_admit_ticks:
        raise AssertionError(
            f"restored warm TTFT {restored.ttft_admit_ticks} != pre-restart "
            f"warm {warm.ttft_admit_ticks}")
    snap_row = {
        "mode": "snapshot_restore",
        "tok_per_s": None,
        "steps": restored_eng.steps_run,
        "pages_restored": len(snap["nodes"]),
        "ttft_cold_ticks": cold.ttft_admit_ticks,
        "ttft_warm_ticks": warm.ttft_admit_ticks,
        "ttft_restored_ticks": restored.ttft_admit_ticks,
    }
    rows = [ref_row, row, snap_row]
    print(f"# serving: chaos + crash-safe restore ({n_req} reqs x shared "
          f"prefix, {len(schedule)} injected faults + cancel + deadline, "
          "audit every tick)")
    print("mode,tok_per_s,steps,preemptions,poisoned_rows,leaked_pages,"
          "completed,affected,unaffected_identical,faults_fired")
    for r in rows[:2]:
        print(f"{r['mode']},{r['tok_per_s']},{r['steps']},"
              f"{r['preemptions']},{r['poisoned_rows']},{r['leaked_pages']},"
              f"{r.get('completed', n_req)},{r.get('affected', 0)},"
              f"{r.get('unaffected_identical', 1.0)},"
              f"{r.get('faults_fired', 0)}")
    print(f"# snapshot/restore: {snap_row['pages_restored']} pages; TTFT "
          f"cold {snap_row['ttft_cold_ticks']} / warm "
          f"{snap_row['ttft_warm_ticks']} / restored "
          f"{snap_row['ttft_restored_ticks']} ticks — restored == warm; "
          "unaffected outputs byte-identical; shutdown leaked 0 pages")
    print()
    return rows


def _guard_workload(cfg, params, smoke: bool):
    """Workload 9 — guarded dispatch under table corruption (ISSUE-9)."""
    from repro.serving import Fault, FaultInjector

    if smoke:
        n_req, max_new = 6, 5
    else:
        n_req, max_new = 9, 7
    rng = np.random.default_rng(9)
    shared = rng.integers(0, cfg.vocab_size, size=8).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=int(t)).tolist()
               for t in rng.integers(2, 7, size=n_req)]
    base = dict(slots=2, max_len=48, max_new_tokens=max_new, page_size=4,
                num_blocks=14, sync_every=4)

    def drive(label, injector=None, **kw):
        eng = ServingEngine(cfg, params, ServeConfig(**dict(base, **kw)),
                            injector=injector)
        reqs = [eng.submit(p) for p in prompts]
        t0 = time.time()
        eng.run(max_steps=10_000)
        eng.drain()
        eng.shutdown()
        dt = time.time() - t0
        toks = sum(len(r.output) for r in reqs)
        return eng, reqs, {
            "mode": label,
            "tok_per_s": round(toks / max(dt, 1e-9), 2),
            "steps": eng.steps_run,
            "n_req": n_req,
            "table_corruptions": eng.table_corruptions,
            "guard_failures": eng.guard_failures,
            "leaked_pages": eng.pool.in_use,  # after shutdown: must be 0
            "outputs": [r.output for r in reqs],
        }

    _, ref_reqs, ref_row = drive("guard_faultfree")
    # spaced wider than sync_every so each corruption lands on its own
    # dispatch and the injector cycles through all three flavors
    schedule = [
        Fault("table_corrupt", tick=3),
        Fault("table_corrupt", tick=9, slot=1),
        Fault("table_corrupt", tick=15),
    ]
    eng, reqs, row = drive("guard_injected",
                           injector=FaultInjector(schedule), audit=True)
    completed = [r for r in reqs if r.status == "completed"]
    identical = sum(r.output == ref_reqs[reqs.index(r)].output
                    for r in completed)
    failed = [r for r in reqs if r.status == "failed"]
    row["completed"] = len(completed)
    row["affected"] = len(failed)
    row["unaffected_identical"] = round(identical / max(len(completed), 1), 4)
    if row["table_corruptions"] < 1:
        raise AssertionError("no table corruption came due (run too short)")
    if row["guard_failures"] < 1 or not failed:
        raise AssertionError("injected corruption was never caught")
    if any("dispatch guard" not in r.error for r in failed):
        raise AssertionError("a FAILED request does not blame the guard")
    if identical != len(completed):
        raise AssertionError(
            f"{len(completed) - identical} guard-survivor requests diverged")
    if row["leaked_pages"] != 0:
        raise AssertionError(f"shutdown leaked {row['leaked_pages']} pages")
    rows = [ref_row, row]
    print(f"# serving: guarded dispatch under table corruption ({n_req} "
          f"reqs, {len(schedule)} injected corruptions, audit every tick)")
    print("mode,tok_per_s,steps,table_corruptions,guard_failures,"
          "completed,affected,unaffected_identical,leaked_pages")
    for r in rows:
        print(f"{r['mode']},{r['tok_per_s']},{r['steps']},"
              f"{r['table_corruptions']},{r['guard_failures']},"
              f"{r.get('completed', n_req)},{r.get('affected', 0)},"
              f"{r.get('unaffected_identical', 1.0)},{r['leaked_pages']}")
    print()
    return rows


def _spec_workload(cfg, params, smoke: bool):
    """Workload 10: speculative decoding inside the multi-step window.

    Two prompt regimes against the same sync-matched plain engine:

    * repetitive — each prompt repeats a short motif, so the n-gram
      proposer keeps landing drafts and one dispatch commits up to
      sync_every * (draft_len + 1) tokens;
    * incompressible — i.i.d. random prompts, the proposer's worst case:
      rounds still emit the model's own bonus token, bounding the loss.

    Greedy verify is byte-identical to plain decode by construction, so
    both regimes assert exact output equality; the repetitive regime also
    asserts the dispatch-amortization payoff (tokens-per-dispatch >= 2x
    plain with strictly fewer host dispatches — deterministic counts, not
    wall clock, so the bound holds in CI smoke mode too)."""
    if smoke:
        slots, max_len, n_req, max_new = 2, 96, 4, 32
    else:
        slots, max_len, n_req, max_new = 4, 160, 8, 64
    rng = np.random.default_rng(10)
    motif = rng.integers(0, cfg.vocab_size, size=4).tolist()
    repetitive = [(motif[i % 4:] + motif[: i % 4]) * 3 for i in range(n_req)]
    random_p = [rng.integers(0, cfg.vocab_size, size=12).tolist()
                for _ in range(n_req)]
    base = dict(slots=slots, max_len=max_len, max_new_tokens=max_new,
                sync_every=4)
    spec = dict(base, spec_decode="ngram", draft_len=4)
    rows = []
    for regime, prompts in (("repetitive", repetitive),
                            ("incompressible", random_p)):
        plain = _drive_timed(cfg, params, prompts, base,
                             f"spec_plain_{regime}")
        ngram = _drive_timed(cfg, params, prompts, spec,
                             f"spec_ngram_{regime}")
        identical = ngram["outputs"] == plain["outputs"]
        if not identical:
            raise AssertionError(
                f"spec decode diverged from plain on {regime} prompts")
        ngram["spec_byte_identity"] = 1.0
        for r in (plain, ngram):
            toks = sum(len(o) for o in r["outputs"])
            r["tok_per_dispatch"] = round(toks / max(r["dispatches"], 1), 2)
        rows += [plain, ngram]
    by = {r["mode"]: r for r in rows}
    rep_plain = by["spec_plain_repetitive"]
    rep_ngram = by["spec_ngram_repetitive"]
    if rep_ngram["dispatches"] >= rep_plain["dispatches"]:
        raise AssertionError(
            f"spec decode did not save dispatches: "
            f"{rep_ngram['dispatches']} vs {rep_plain['dispatches']}")
    amort = (rep_ngram["tok_per_dispatch"]
             / max(rep_plain["tok_per_dispatch"], 1e-9))
    if amort < 2.0:
        raise AssertionError(
            f"repetitive-prompt tokens-per-dispatch {amort:.2f}x < 2x plain")
    print(f"# serving: speculative decode vs plain, sync_every=4 "
          f"({n_req} reqs x 12 prompt + {max_new} gen, draft_len=4)")
    print("mode,tok_per_s,dispatches,tok_per_dispatch,spec_windows,"
          "spec_accepted,spec_proposed")
    for r in rows:
        print(f"{r['mode']},{r['tok_per_s']},{r['dispatches']},"
              f"{r['tok_per_dispatch']},{r['spec_windows']},"
              f"{r['spec_accepted']},{r['spec_proposed']}")
    print(f"# spec decode: {amort:.2f}x tokens-per-dispatch on repetitive "
          f"prompts; identical outputs both regimes: ok")
    print()
    return rows


def derived_metrics(rows):
    """Cross-row metrics for the BENCH_serving.json trajectory record.

    Convention (relied on by ``benchmarks.run --compare``): every derived
    metric is a **higher-is-better** ratio, so the regression gate can
    compare them against a committed baseline without per-metric
    direction knowledge."""
    by_mode = {r["mode"]: r for r in rows}
    out = {}
    if "contiguous" in by_mode and "paged" in by_mode:
        out["paged_kv_saving"] = round(
            1.0 - by_mode["paged"]["kv_bytes"]
            / max(by_mode["contiguous"]["kv_bytes"], 1), 4)
    if "replay_paged" in by_mode and "chunked_paged" in by_mode:
        r, c = by_mode["replay_paged"], by_mode["chunked_paged"]
        out["prefill_tick_speedup"] = round(r["steps"] / max(c["steps"], 1), 2)
        if r["ttft_ticks_mean"] and c["ttft_ticks_mean"]:
            out["ttft_improvement"] = round(
                r["ttft_ticks_mean"] / c["ttft_ticks_mean"], 2)
    if "decode_sync1_paged" in by_mode and "decode_sync16_paged" in by_mode:
        out["decode_multistep_speedup"] = round(
            by_mode["decode_sync16_paged"]["tok_per_s"]
            / max(by_mode["decode_sync1_paged"]["tok_per_s"], 1e-9), 2)
        # deterministic companion to the wall-clock ratio above: host
        # dispatches collapsed by the device-resident loop (a window counts
        # once however many ticks it covers) — immune to box noise
        out["decode_dispatch_amortization"] = round(
            by_mode["decode_sync1_paged"]["dispatches"]
            / max(by_mode["decode_sync16_paged"]["dispatches"], 1), 2)
    if ("decode_sync16_paged" in by_mode
            and "decode_sync16_contiguous" in by_mode):
        out["decode_paged_vs_contiguous"] = round(
            by_mode["decode_sync16_paged"]["tok_per_s"]
            / max(by_mode["decode_sync16_contiguous"]["tok_per_s"], 1e-9), 2)
    if "mla_paged_replay" in by_mode and "mla_paged_chunked" in by_mode:
        out["mla_prefill_tick_speedup"] = round(
            by_mode["mla_paged_replay"]["steps"]
            / max(by_mode["mla_paged_chunked"]["steps"], 1), 2)
        out["mla_paged_kv_saving"] = round(
            1.0 - by_mode["mla_paged_chunked"]["kv_bytes"]
            / max(by_mode["mla_contiguous_chunked"]["kv_bytes"], 1), 4)
    if "prefix_chunked" in by_mode:
        p = by_mode["prefix_chunked"]
        # warm-hit TTFT collapse: cold (index miss) over warm (prefix
        # attached at admission) ticks-to-first-token, chunked prefill
        out["prefix_warm_ttft_speedup"] = round(
            p["ttft_cold_ticks"] / max(p["ttft_warm_ticks_mean"], 1e-9), 2)
        # physical pages each request borrowed from the index instead of
        # allocating (block-allocation pressure the cache absorbed)
        out["shared_pages_per_request"] = round(
            p["pages_shared"] / max(p["n_req"], 1), 2)
    if ("mla_decode_sync1_paged" in by_mode
            and "mla_decode_sync16_paged" in by_mode):
        out["mla_decode_dispatch_amortization"] = round(
            by_mode["mla_decode_sync1_paged"]["dispatches"]
            / max(by_mode["mla_decode_sync16_paged"]["dispatches"], 1), 2)
    if "kv_fp" in by_mode and "kv_int8" in by_mode:
        # memory compression (fp bytes over quantized bytes) and fidelity:
        # greedy token agreement with the fp cache, and a bounded transform
        # of the teacher-forced max logit error (1/(1+err): 1.0 = exact)
        out["int8_kv_saving"] = round(
            by_mode["kv_fp"]["kv_bytes"]
            / max(by_mode["kv_int8"]["kv_bytes"], 1), 2)
        out["int8_token_match"] = by_mode["kv_int8"]["token_match"]
        out["int8_logit_fidelity"] = round(
            1.0 / (1.0 + by_mode["kv_int8"]["max_logit_err"]), 4)
    if "kv_fp" in by_mode and "kv_int4" in by_mode:
        out["int4_kv_saving"] = round(
            by_mode["kv_fp"]["kv_bytes"]
            / max(by_mode["kv_int4"]["kv_bytes"], 1), 2)
    if ("kv_fp_pressure" in by_mode and "kv_int8_pressure" in by_mode):
        # capacity win at fixed bytes: +1 smoothing keeps the ratio finite
        # when the quantized pool preempts nothing at all (the usual case)
        out["quant_pressure_preemption_drop"] = round(
            (by_mode["kv_fp_pressure"]["preemptions"] + 1)
            / (by_mode["kv_int8_pressure"]["preemptions"] + 1), 2)
    if "chaos_injected" in by_mode:
        c = by_mode["chaos_injected"]
        # fraction of fault-survivor requests byte-identical to the
        # fault-free run (1.0 = pool/grant faults fully output-preserving)
        out["chaos_unaffected_byte_identity"] = c["unaffected_identical"]
        # freed-page guarantee as a bounded ratio: 1.0 = zero pages still
        # allocated after shutdown (a raw leak count would be lower-is-
        # better and slip past the regression gate)
        out["drain_leaked_pages"] = round(
            1.0 / (1.0 + c["leaked_pages"]), 4)
    if "guard_injected" in by_mode:
        g = by_mode["guard_injected"]
        # fraction of guard-survivor requests byte-identical to the
        # fault-free run (1.0 = the guard FAILs only the hit request and
        # perturbs nobody else)
        out["guard_unaffected_byte_identity"] = g["unaffected_identical"]
    if ("spec_plain_repetitive" in by_mode
            and "spec_ngram_repetitive" in by_mode):
        p = by_mode["spec_plain_repetitive"]
        s = by_mode["spec_ngram_repetitive"]
        # draft acceptance on the proposer's favorable regime, and the
        # headline payoff: tokens committed per host dispatch vs the
        # sync-matched plain engine (deterministic counts, not wall clock)
        out["spec_accept_rate"] = round(
            s["spec_accepted"] / max(s["spec_proposed"], 1), 4)
        out["spec_dispatch_amortization"] = round(
            s["tok_per_dispatch"] / max(p["tok_per_dispatch"], 1e-9), 2)
        # 1.0 = greedy spec decode byte-identical to plain on every
        # request of both regimes (asserted in-workload; recorded so the
        # regression gate notices if the assert is ever weakened)
        out["spec_byte_identity"] = min(
            by_mode[m].get("spec_byte_identity", 0.0)
            for m in ("spec_ngram_repetitive", "spec_ngram_incompressible"))
    if "snapshot_restore" in by_mode:
        s = by_mode["snapshot_restore"]
        # crash-safety payoff: cold prefill ticks over the restored
        # engine's warm-hit ticks (== the pre-restart warm hit, asserted)
        out["restore_warm_ttft_speedup"] = round(
            s["ttft_cold_ticks"] / max(s["ttft_restored_ticks"], 1e-9), 2)
    return out


def run(smoke: bool = False):
    cfg = get_config("qwen2_1_5b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rows = _layout_workload(cfg, params, smoke)
    rows += _prefill_workload(cfg, params, smoke)
    rows += _decode_workload(cfg, params, smoke)
    rows += _mla_workload(smoke)
    rows += _prefix_workload(cfg, params, smoke)
    rows += _mla_decode_workload(smoke)
    rows += _quant_workload(cfg, params, smoke)
    rows += _chaos_workload(cfg, params, smoke)
    rows += _guard_workload(cfg, params, smoke)
    rows += _spec_workload(cfg, params, smoke)
    # outputs are asserted above; keep the JSON/return rows lean
    for r in rows:
        r.pop("outputs", None)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (CPU interpret mode)")
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_serving.json (rows + derived + sha)")
    args = ap.parse_args(argv)
    rows = run(smoke=args.smoke)
    if args.json:
        from .run import write_json

        write_json("serving", rows, derived_metrics(rows), smoke=args.smoke)


if __name__ == "__main__":
    main()
