"""Serving-engine benchmark: contiguous vs paged KV cache.

Unlike the per-kernel tables (cost-model numbers), this drives the real
engine end-to-end on CPU and reports measured throughput plus KV memory:

* ``tok_per_s``   — generated tokens / wall-clock over the whole run;
* ``kv_bytes``    — attention KV state actually allocated on device;
* ``peak_kv_bytes`` — bytes *resident* at the high-water mark (paged mode:
  peak blocks in use x block bytes; contiguous: the full preallocation,
  that's the point).

The request mix is a skewed prompt-length distribution (many short, a few
near-``max_len``) — the regime where ``slots x max_len`` preallocation
wastes most of its memory and paging shines.  The paged pool is sized at
half the contiguous footprint, so the run also exercises admission gating
and preemption while asserting both modes emit identical tokens.

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import ServeConfig, ServingEngine


def skewed_prompt_lengths(rng, n: int, max_len: int):
    """~80% short prompts, ~20% long (near half of max_len)."""
    lens = []
    for _ in range(n):
        if rng.random() < 0.8:
            lens.append(int(rng.integers(2, max(3, max_len // 16))))
        else:
            lens.append(int(rng.integers(max_len // 4, max_len // 2)))
    return lens


def _drive(cfg, params, mode: str, prompts, scfg_kw):
    engine = ServingEngine(cfg, params, ServeConfig(cache=mode, **scfg_kw))
    reqs = [engine.submit(p) for p in prompts]
    t0 = time.time()
    engine.run(max_steps=100_000)
    dt = time.time() - t0
    toks = sum(len(r.output) for r in reqs)
    page_bytes = 0
    if engine.pool is not None:
        per_tok = engine.kv_cache_bytes() // max(
            (engine.pool.num_blocks + 1) * engine.pool.page_size, 1
        )
        page_bytes = engine.pool.page_size * per_tok
    peak = (
        engine.peak_kv_blocks() * page_bytes
        if engine.pool is not None
        else engine.kv_cache_bytes()
    )
    return {
        "mode": mode,
        "tok_per_s": toks / max(dt, 1e-9),
        "kv_bytes": engine.kv_cache_bytes(),
        "peak_kv_bytes": peak,
        "steps": engine.steps_run,
        "preemptions": engine.preemptions,
        "outputs": [r.output for r in reqs],
    }


def run(smoke: bool = False):
    if smoke:
        slots, max_len, n_req, max_new = 2, 64, 5, 4
    else:
        slots, max_len, n_req, max_new = 4, 128, 24, 12
    cfg = get_config("qwen2_1_5b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(0, cfg.vocab_size, size=n).tolist()
        for n in skewed_prompt_lengths(rng, n_req, max_len)
    ]
    scfg_kw = dict(slots=slots, max_len=max_len, max_new_tokens=max_new)

    from .common import blocks_half  # late import keeps -m module runnable

    rows = []
    contig = _drive(cfg, params, "contiguous", prompts, scfg_kw)
    paged = _drive(
        cfg, params, "paged", prompts,
        dict(scfg_kw, num_blocks=blocks_half(slots, max_len, page_size=16)),
    )
    for r in (contig, paged):
        rows.append(r)

    if contig["outputs"] != paged["outputs"]:
        raise AssertionError(
            "contiguous and paged cache modes diverged on identical requests"
        )
    print("# serving: contiguous vs paged KV "
          f"({n_req} reqs, slots={slots}, max_len={max_len}, skewed prompts)")
    print("mode,tok_per_s,kv_bytes,peak_kv_bytes,steps,preemptions")
    for r in rows:
        print(
            f"{r['mode']},{r['tok_per_s']:.1f},{r['kv_bytes']},"
            f"{r['peak_kv_bytes']},{r['steps']},{r['preemptions']}"
        )
    saving = 1.0 - paged["kv_bytes"] / max(contig["kv_bytes"], 1)
    print(f"# paged pool allocates {saving:.0%} less KV memory "
          f"({paged['preemptions']} preemptions); identical outputs: ok")
    print()
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (CPU interpret mode)")
    args = ap.parse_args(argv)
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
