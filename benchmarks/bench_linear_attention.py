"""Mamba-2 linear attention benchmark — paper Table 4 (CC0–5 chunk_scan,
CT0–5 chunk_state), Fig. 12.

Shapes fold (batch × heads) into the kernel grid's batch dim, exactly as the
model layer dispatches them; chunk length 64 matches Mamba-2's default.
"""
import numpy as np

from repro.core import Schedule, compile as tl_compile
from repro.kernels import ref
from repro.kernels.linear_attention import chunk_scan_program, chunk_state_program

from .common import Row, check, emit, kernel_row

# batch, nheads, seq_len, head_dim, d_state — Table 4
SHAPES = {
    "0": (1, 64, 1024, 64, 128),
    "1": (1, 64, 2048, 64, 128),
    "2": (1, 64, 8192, 64, 128),
    "3": (64, 64, 1024, 64, 128),
    "4": (64, 64, 2048, 64, 128),
    "5": (64, 64, 8192, 64, 128),
}
CHUNK = 64


def run():
    rows = []
    for idx, (b, h, s, p, n) in SHAPES.items():
        bf = b * h  # heads folded into batch (model-layer dispatch)
        nc = s // CHUNK
        rows.append(
            kernel_row(
                f"chunk_state_CT{idx}_b{b}h{h}s{s}",
                chunk_state_program(bf, nc, CHUNK, n, p, dtype="bfloat16"),
            )
        )
        rows.append(
            kernel_row(
                f"chunk_scan_CC{idx}_b{b}h{h}s{s}",
                chunk_scan_program(bf, nc, CHUNK, n, p, dtype="bfloat16"),
            )
        )

    def _ok():
        rng = np.random.default_rng(0)
        prog = chunk_scan_program(2, 2, 32, 16, 32)
        kern = tl_compile(prog, Schedule(interpret=True))
        c = rng.standard_normal((2, 2, 32, 16), dtype=np.float32)
        bm = rng.standard_normal((2, 2, 32, 16), dtype=np.float32)
        x = rng.standard_normal((2, 2, 32, 32), dtype=np.float32)
        da = np.cumsum(np.abs(rng.standard_normal((2, 2, 32), dtype=np.float32)) * 0.1, -1)
        prev = rng.standard_normal((2, 2, 16, 32), dtype=np.float32)
        return np.allclose(
            np.asarray(kern(c, bm, x, da.astype(np.float32), prev)),
            np.asarray(ref.chunk_scan(c, bm, x, da, prev)),
            atol=2e-3,
        )

    check(_ok, "chunk-scan-interpret-vs-oracle")
    emit(rows, "Table 4 / Fig 12: Mamba-2 SSD linear attention (cost model, v5e)")
    return rows


if __name__ == "__main__":
    run()
