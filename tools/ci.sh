#!/usr/bin/env bash
# Tier-1 CI: run the test suite on CPU.
#
# Kernels execute through the interpreter backends — Pallas interpret mode
# (the same kernel body the TPU runs, executed by XLA:CPU) and the reference
# trace interpreter — so no accelerator is needed.  Mirrors ROADMAP.md's
# "Tier-1 verify" line; used by .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# DeprecationWarnings from the serving modules are errors: the scheduler is
# the newest surface and must not rot against jax/numpy API churn.
# The suite includes the kernel guardrails (ISSUE-9): the full parity
# corpus re-runs under the sanitizing interpreter (tests/test_verify.py),
# so every kernel executes with OOB / duplicate-write / uninitialized-read
# / non-finite detection on, not just the planted-defect programs.
python -m pytest -x -q -W 'error::DeprecationWarning:repro\.serving' "$@"

# Seeded chaos smoke (ISSUE-8/9): a fixed workload x fault schedule with
# the invariant auditor on every tick — unaffected requests must stay
# byte-identical to the fault-free run and shutdown must free every page.
# The schedule includes a table_corrupt fault, so the dispatch guard's
# graceful degradation (FAIL exactly the hit request) is proved here too.
python -m repro.serving.faults --seed 0

# Exercise the serving path end-to-end on a tiny config: engine + paged
# cache + scheduler + both cache layouts asserting identical outputs, the
# chunked-prefill fast path (asserts chunked prefill finishes within
# ceil(prompt/chunk)+gen engine ticks where replay needs prompt+gen, with
# byte-identical tokens), the device-resident multi-step decode loop
# (byte-identical outputs across sync_every in {1,4,16} and both layouts),
# the MLA serving matrix (paged latent cache + chunked prefill
# byte-identical to contiguous/replay), the shared-system-prompt prefix
# caching workload (warm TTFT <= 25% of cold, fewer block allocations per
# request, byte-identical outputs with caching on/off), and the MLA
# decode-heavy multi-step loop.  The loc table rides along so the
# paper's MLA line-budget claim and the attention-core net-simplification
# claim are pinned by the same gate.
# --json records the perf trajectory rows; --compare gates fresh derived
# metrics against the committed baselines (>20% regression fails CI).  The
# baselines come from HEAD, not the working tree — a previous local run
# leaves its own (noisy) numbers on disk, and gating against those would
# drift the gate away from the committed trajectory; working-tree files
# are only the fallback outside a git checkout.
baseline_dir="$(mktemp -d)"
for table in serving loc; do
  if ! git show "HEAD:BENCH_${table}.json" > "$baseline_dir/BENCH_${table}.json" 2>/dev/null \
      || ! [ -s "$baseline_dir/BENCH_${table}.json" ]; then
    if [ -s "BENCH_${table}.json" ]; then
      cp "BENCH_${table}.json" "$baseline_dir/BENCH_${table}.json"
    else
      rm -f "$baseline_dir/BENCH_${table}.json"
    fi
  fi
  rm -f "BENCH_${table}.json"  # a stale record must not satisfy the check below
done
python -m benchmarks.run --only serving,loc --smoke --json \
  --compare "$baseline_dir"
test -s BENCH_serving.json  # the trajectory records must actually land
test -s BENCH_loc.json
