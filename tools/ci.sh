#!/usr/bin/env bash
# Tier-1 CI: run the test suite on CPU.
#
# Kernels execute through the interpreter backends — Pallas interpret mode
# (the same kernel body the TPU runs, executed by XLA:CPU) and the reference
# trace interpreter — so no accelerator is needed.  Mirrors ROADMAP.md's
# "Tier-1 verify" line; used by .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q "$@"
