#!/usr/bin/env bash
# Tier-1 CI: run the test suite on CPU.
#
# Kernels execute through the interpreter backends — Pallas interpret mode
# (the same kernel body the TPU runs, executed by XLA:CPU) and the reference
# trace interpreter — so no accelerator is needed.  Mirrors ROADMAP.md's
# "Tier-1 verify" line; used by .github/workflows/ci.yml.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# DeprecationWarnings from the serving modules are errors: the scheduler is
# the newest surface and must not rot against jax/numpy API churn.
python -m pytest -x -q -W 'error::DeprecationWarning:repro\.serving' "$@"

# Exercise the serving path end-to-end on a tiny config: engine + paged
# cache + scheduler + both cache layouts asserting identical outputs, the
# chunked-prefill fast path (asserts chunked prefill finishes within
# ceil(prompt/chunk)+gen engine ticks where replay needs prompt+gen, with
# byte-identical tokens), and the device-resident multi-step decode loop
# (byte-identical outputs across sync_every in {1,4,16} and both layouts).
# --json records the perf trajectory row; --compare gates fresh derived
# metrics against the committed baseline (>20% regression fails CI).  The
# baseline comes from HEAD, not the working tree — a previous local run
# leaves its own (noisy) numbers on disk, and gating against those would
# drift the gate away from the committed trajectory; the working-tree file
# is only the fallback outside a git checkout.
baseline="$(mktemp)"
if ! git show HEAD:BENCH_serving.json > "$baseline" 2>/dev/null || ! [ -s "$baseline" ]; then
  if [ -s BENCH_serving.json ]; then
    cp BENCH_serving.json "$baseline"
  else
    rm -f "$baseline"
    baseline=""
  fi
fi
rm -f BENCH_serving.json  # a stale record must not satisfy the check below
python -m benchmarks.run --only serving --smoke --json \
  ${baseline:+--compare "$baseline"}
test -s BENCH_serving.json  # the trajectory record must actually land
