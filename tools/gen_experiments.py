"""Compose EXPERIMENTS.md from the dry-run records + hand-written sections.

    PYTHONPATH=src python tools/gen_experiments.py > EXPERIMENTS.md
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.roofline import report as R  # noqa: E402

HEADER = """# EXPERIMENTS

Paper: *TileLang: A Composable Tiled Programming Model for AI Systems* —
reproduced as a TPU-native JAX/Pallas framework (see DESIGN.md for the
GPU→TPU mapping).  Hardware target: **TPU v5e** — 197 TFLOP/s bf16 (394
int8), 819 GB/s HBM, ~50 GB/s/link ICI, 16 GiB HBM/chip, ~128 MiB VMEM.
This container is CPU-only; how each number is obtained is stated per
section.

## Methodology

* **Kernel correctness** — every tile-DSL kernel runs in Pallas
  `interpret=True` mode (the kernel body executes on CPU against the same
  BlockSpec/grid machinery that Mosaic compiles on TPU) and is asserted
  allclose against a pure-jnp oracle (`kernels/ref.py`) over shape/dtype
  sweeps (`tests/test_kernels.py`), plus an independent trace-interpreter
  backend for the DSL itself.
* **Kernel performance** — the static cost model of the tile compiler
  (FLOPs, HBM traffic, VMEM plan, MXU-tile utilization, int8 2× path),
  evaluated against v5e peaks.  This is the paper's own thesis — explicit
  tile programs make hardware behavior statically analyzable (§6) — applied
  as the measurement instrument.
* **System performance** — the multi-pod dry-run compiles every
  (arch × shape × mesh) cell's *real* step function via
  `jit(...).lower().compile()` with production shardings, then derives:
  - `compute_s` = per-device HLO FLOPs / peak (layer scans fully unrolled in
    a dedicated cost pass so while-loop bodies are not undercounted; the
    lax.map-chunked long-sequence attention is analytically corrected),
  - `memory_s` = per-device HBM traffic / bandwidth.  Two estimates are
    shown: a fusion-aware analytic model (params+optimizer+activations+
    score-spill+cache terms — the realistic number on a fusing backend) and
    the raw HLO "bytes accessed" (an unfused upper bound),
  - `collective_s` = Σ collective result bytes (parsed from the partitioned
    HLO: all-reduce / all-gather / reduce-scatter / all-to-all /
    collective-permute) / ICI link bandwidth.
  Memory *fit* is taken from a separate scan-form compile (loop buffers are
  reused per iteration, matching steady-state residency).
* **MFU@roofline** = MODEL_FLOPS / (roofline step time × peak × chips) —
  the model-FLOPs utilization *if the dominant roofline term were the step
  time*; an upper bound, used to rank cells and steer the perf loop.
  MODEL_FLOPS = 6·N·D (train) / 2·N_active·D (inference) + exact attention
  terms.
"""

CLAIMS = """
## Paper-claims validation (the faithful-reproduction baseline)

| paper claim | our result | where |
|---|---|---|
| GEMM at/near vendor-library performance with ~20-line kernels (Fig. 13) | tile-DSL GEMM reaches 100% MXU tile utilization and compute-bound roofline at all M-shapes (Table 2 sweep); 18 source lines | `benchmarks/bench_gemm.py`, `bench_loc` |
| FlashAttention competitive across seq lengths (Fig. 12) | online-softmax flash kernel validated vs oracle (causal/GQA/MQA); FA0–FA4 cost-model rows show the memory→compute crossover at longer S | `benchmarks/bench_attention.py` |
| MLA at 98% of hand-optimized FlashMLA in ~70 LOC (Fig. 14) | Fig. 18 kernel ported near-verbatim: **64 lines**, allclose vs oracle; serving path uses the same latent-attention structure (W_uk absorption) | `kernels/mla.py`, `tests/test_kernels.py::TestMLA` |
| Dequant GEMM up to 7.65× over FP16 (W_INT2A_INT8, Fig. 15) | traffic-roofline reproduction: W_INT2A_INT8 reaches **3.55–3.86×** over W_FP16A_FP16 on v5e; the gap to 7.65× is the v5e GEMV MXU wall at m≈1 (n=8/128 tile occupancy) — an *architectural* difference from A100 tensor cores, quantified in the rows | `benchmarks/bench_dequant.py` |
| Linear attention (Mamba-2 chunk kernels) ~1.8–2.1× vs Triton (Fig. 12) | both chunk kernels validated vs oracle and vs a naive per-step SSM recurrence; CC/CT Table-4 sweep reported via cost model | `benchmarks/bench_linear_attention.py` |
| Decoupling lets schedules change without touching dataflow | same GEMM program re-scheduled by autotuner/block shapes/swizzle/num_stages with bit-identical semantics (tests) | `tests/test_tile_language.py::TestSchedule` |
| Layout inference binds strict ops first (Fig. 7 bias replication) | replication/vectorization inference reproduced and unit-tested | `TestInference::test_bias_replication_fig7` |
"""

PERF = """
## Perf (hypothesis → change → measure → validate)

Hillclimb cells (per the assignment: worst roofline fraction, most
collective-bound, most paper-representative):

1. **granite-moe-3b-a800m × train_4k** (worst useful-fraction: 0.2%)
2. **gemma-7b × train_4k** (most collective-bound: 6.25 s collective term)
3. **deepseek-v2-lite-16b × decode_32k** (paper-representative: the MLA
   serving path is TileLang's headline kernel)

### Iteration log

**P1. MoE dispatch partitioning (granite train_4k)** —
*Hypothesis:* per-layer HLO FLOPs are 773× the expert-FFN cost because
GSPMD rewrites the global token→expert scatter into a cross-shard
contraction.
*Change:* grouped (GShard-style) dispatch — tokens split into G groups
aligned with the data shards; scatters become vmapped (batched-local);
expert buffers (G,E,cap,D) shard G×E over (data, model).
*Measure:* per-layer HLO FLOPs **9.05e16 → 3.88e14 (233×)**; cell flops/dev
1.13e16 → 4.3e14; useful fraction 0.4% → ≈50%; all-reduce traffic
1154 GiB → (re-swept below).  **Confirmed.**

**P2. Decode cache donation (all decode cells)** —
*Hypothesis:* decode holds input+output KV caches (2× residency) because
the cache argument is not donated; gemma decode_32k showed 32.0 GiB/chip
vs ~7.5 analytic (params 1.1 + cache 6.4).
*Change:* `donate_argnums` on the cache (and the train state) — which only
took effect once the output cache's `out_shardings` were pinned to match
the donated input's (aliasing requires identical layouts; the first attempt
with auto output sharding silently aliased nothing).
*Measure:* gemma decode_32k {GEMMA_DECODE} GiB/chip with **7.0 GiB
registered as aliased** (deepseek-7b {DS7B_DECODE}).  **Partially
confirmed:** the cache is in-place on a fusing backend (alias bytes prove
the buffer contract), but the CPU backend's buffer assignment still
materializes the per-layer `dynamic_update_slice` chain as temps — the
residual gap is backend scheduling, not the sharding/aliasing design.
Steady-state v5e residency ≈ params/TP + cache shard ≈ 7.5 GiB.

**P3. Collective dedupe + reduce-scatter placement (gemma train_4k)** —
*Hypothesis A:* each of q/k/v separately all-gathers the
sequence-parallel residual (3 gathers/layer) — constraining the normed
attention input once should dedupe them.  *Measured (8-layer probe):*
all-gather instrs 184 → 88, all-to-all 2.8 → 0.8 GiB; total collective
bytes 88.4 → 82.9 GiB (**1.07×**).  **Partially confirmed** — instruction
count halves but bytes are dominated elsewhere.
*Hypothesis B:* the 48× f32[3072,24576] all-gathers are ZeRO-1 master
gathers placed before the fp32→bf16 convert; pinning the convert first
(sharding-constraining the casted params to the ZeRO spec) should halve
those bytes.  *Measured:* **no change — refuted.**  XLA elides the
intermediate constraint; the gathers belong to the wgrad reduction
decomposition, not the param pipeline.  *Lesson:* constraint-based collective
steering works on activations (A) but not on optimizer-boundary tensors;
the durable fix is storing params ZeRO-sharded (FSDP-style) — future work.

**P4. Whisper train memory (whisper × train_4k)** —
*Hypothesis:* 94 GiB/chip comes from no remat + full (B,S,V) f32 logits in
the enc-dec loss.
*Change:* per-layer checkpointing + chunked CE (shared pattern with the
LM stack).
*Measure:* 93.97 → {WHISPER_TRAIN} GiB/chip.  **{WHISPER_VERDICT}**

**P5. Flash-attention memory term (modeled)** — the analytic roofline
splits attention score traffic out explicitly: on the XLA path the S²
scores spill to HBM (e.g. gemma train_4k: ~4 passes × L × B_loc × H_loc ×
S² × 4 B ≈ dominant activation term); routing attention through the
tile-DSL flash kernel (the TPU deployment path) removes that term —
`roofline.analysis.analytic_hbm_bytes(..., flash_attention=True)`
quantifies the per-cell delta in the table's "memory" column.

### Baseline → optimized (paper-faithful vs beyond-paper), full cells

| cell | metric | paper-faithful baseline | optimized | Δ |
|---|---|---|---|---|
| granite × train_4k | compute term | 57.42 s (useful 0.2%) | **264.9 ms (useful 51%)** | 217× |
| granite × train_4k | per-chip flops | 1.13e16 | 5.22e13 | 217× |
| whisper × train_4k | GiB/chip | 93.97 | **15.34 (fits)** | 6.1× |
| gemma × decode_32k | cache residency | un-aliased (2× cache) | aliased (7.0 GiB registered) | 2× on-wire |
| gemma × train_4k (probe, 8L) | collective instrs | 184 AG / 65 A2A | 88 AG / 1 A2A | 2.1× instrs, 1.07× bytes |
| dsv2-lite × prefill_32k | status | FAIL (chunked-attn dv bug) | ok, 12.47 GiB | — |

The "paper-faithful baseline" is the direct dataflow implementation; every
optimization keeps the dataflow byte-identical (tests re-validate) and only
changes dispatch structure, aliasing, or sharding — exactly the
dataflow/scheduling decoupling the paper argues for, applied at the
distributed-system level.

### Stopping criterion
Three consecutive <5% iterations not yet reached when the turn budget
ended; P3-B's refutation redirected the remaining effort to P1/P2-class
structural fixes, which moved their dominant terms by 217× and ~2×
respectively.  The next queued iterations, in predicted-win order: (1)
store params ZeRO-sharded to convert the wgrad AG+AR chain to
reduce-scatter (predicted ~1.8× on the train collective term); (2) route
attention through the Pallas flash kernel on TPU (removes the S² score
spill — the analytic memory column already quantifies the per-cell delta);
(3) banded attention for Hymba's 1k window at 32k+ context (≥8× attention
FLOPs at prefill_32k).
"""


def _gib(arch, shape, default="n/a"):
    rec = R.load(arch, shape, "single_pod")
    if rec and rec.get("status") == "ok":
        return f"{rec['per_chip_bytes']/2**30:.2f}"
    return default


def main():
    global PERF
    whisper = _gib("whisper_tiny", "train_4k")
    PERF_FILLED = (
        PERF.replace("{GEMMA_DECODE}", _gib("gemma_7b", "decode_32k"))
        .replace("{DS7B_DECODE}", _gib("deepseek_7b", "decode_32k"))
        .replace("{WHISPER_TRAIN}", whisper)
        .replace(
            "{WHISPER_VERDICT}",
            "Confirmed." if whisper != "n/a" and float(whisper) < 30 else
            "Measured post-fix (see table).",
        )
    )
    PERF = PERF_FILLED
    print(HEADER)
    print(CLAIMS)
    for mesh in ("single_pod", "multi_pod"):
        print(f"\n## Dry-run ({mesh})\n")
        print(
            "Every cell is `jit(step).lower(**input_specs).compile()` on the "
            f"{'(2,16,16) pod×data×model' if mesh == 'multi_pod' else '(16,16) data×model'} mesh. "
            "`GiB/chip` = arguments + outputs + temps − aliased, from the "
            "scan-form memory pass (⚠ = exceeds 16 GiB on the CPU-backend "
            "estimate; see Methodology).\n"
        )
        print(R.dryrun_table(mesh))
    print("\n## Roofline (single_pod — the analysis mesh)\n")
    print(R.roofline_table("single_pod"))
    picks = R.pick_hillclimb("single_pod")
    if picks:
        print(
            "\nDominant-term ranking feeds §Perf; hillclimb picks: "
            + ", ".join(f"**{t.arch} × {t.shape}** ({t.dominant})" for t in picks)
        )
    print(PERF)


if __name__ == "__main__":
    main()
