"""Assigned architecture configs (--arch <id>).  Each module defines CONFIG.

All parameters from the assignment block (public literature, [source] noted
in each file).  ``get_config(name)`` returns a fresh ModelConfig; shapes are
defined in repro.launch.shapes.
"""
import importlib

ARCHS = [
    "gemma_7b",
    "chatglm3_6b",
    "deepseek_7b",
    "qwen2_1_5b",
    "internvl2_26b",
    "hymba_1_5b",
    "mamba2_2_7b",
    "granite_moe_3b_a800m",
    "deepseek_v2_lite_16b",
    "whisper_tiny",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({"qwen2-1.5b": "qwen2_1_5b", "mamba2-2.7b": "mamba2_2_7b",
                 "hymba-1.5b": "hymba_1_5b", "internvl2-26b": "internvl2_26b",
                 "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
                 "granite-moe-3b-a800m": "granite_moe_3b_a800m",
                 "whisper-tiny": "whisper_tiny", "gemma-7b": "gemma_7b",
                 "chatglm3-6b": "chatglm3_6b", "deepseek-7b": "deepseek_7b"})


def get_config(name: str):
    key = _ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{key}")
    import dataclasses
    return dataclasses.replace(mod.CONFIG)


def all_configs():
    return {a: get_config(a) for a in ARCHS}
