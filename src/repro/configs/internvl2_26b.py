"""internvl2-26b [arXiv:2404.16821; hf]: InternViT frontend (STUB per the
assignment — input_specs provides precomputed patch embeddings) + InternLM2
backbone: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92553."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    act="silu",
    frontend="patch",
    frontend_seq=256,  # ViT patch tokens delivered by the stub frontend
)
