"""chatglm3-6b [arXiv:2406.12793; hf]: 28L d=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2d RoPE (rotary over half the head dim), strong GQA."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    act="silu",
    qkv_bias=True,  # chatglm adds qkv bias
)
