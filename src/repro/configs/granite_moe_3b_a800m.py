"""granite-moe-3b-a800m [hf:ibm-granite]: 32L d=1536 24H (GQA kv=8)
d_ff(expert)=512, vocab=49155, 40 experts top-8."""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=0,  # all FFN capacity lives in the experts
    vocab_size=49155,
    act="silu",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, experts_per_token=8, d_ff_expert=512),
)
