"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]: 27L d=2048 16H MLA
(kv_lora=512, rope_dim=64) — MoE 64 routed experts top-6 + 2 shared,
d_ff(expert)=1408, first layer dense, vocab=102400.

Assignment header says "64e top-6"; the bracket note "160 routed" refers to
the full V2 — we follow the headline lite config (64 routed)."""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=102400,
    attention="mla",
    act="silu",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=64, experts_per_token=6, d_ff_expert=1408,
                  num_shared_experts=2, first_dense_layers=1),
)
