"""mamba2-2.7b [arXiv:2405.21060]: 64L d=2560 attention-free,
ssm_state=128 — SSD (state-space duality), expand=2, head_dim=64."""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=128),
)
