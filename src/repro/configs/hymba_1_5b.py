"""hymba-1.5b [arXiv:2411.13676; hf]: 32L d=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention+mamba heads per block;
sliding-window attention except first/middle/last global layers.
(Meta-token prompt tuning is out of scope — noted in DESIGN.md.)"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    act="silu",
    sliding_window=1024,
    ssm=SSMConfig(state_dim=16, head_dim=50, expand=2, chunk=128),
)
