"""whisper-tiny [arXiv:2212.04356]: enc-dec, 4L each, d=384 6H d_ff=1536
vocab=51865 — conv audio frontend is a STUB (input_specs provides frame
embeddings); decoder position table sized for the 32k decode cells."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    act="gelu",
    is_encoder_decoder=True,
    encoder_layers=4,
    frontend="audio",
    frontend_seq=1500,
    tie_embeddings=True,
)
