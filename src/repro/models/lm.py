"""Decoder-only language model covering dense / MoE / MLA / SSM / hybrid
families (all assigned architectures except whisper, which lives in
encdec.py).

Layers are homogeneous and scanned (``jax.lax.scan`` over stacked params) —
the standard trick for O(1) HLO size at hundreds of layers; heterogeneous
prefixes (e.g. DeepSeek-V2's first dense FFN layer) are kept as unscanned
python-list layers in front.  Decode caches carry static metadata (ring
windows, stacking) in pytree aux data so jit boundaries stay stable.

All functions are pure; params/caches are pytrees of jnp arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import ModelConfig

BIG_WINDOW = 1 << 30  # "no window" sentinel for traced mask arithmetic


# ---------------------------------------------------------------------------
# per-layer param init
# ---------------------------------------------------------------------------


def _init_block(key, cfg: ModelConfig, layer_idx: int, dense_ffn: bool) -> Dict:
    ks = L._split(key, 4)
    p: Dict[str, Any] = {"norm1": jnp.ones((cfg.d_model,), cfg.dtype)}
    if cfg.attention == "gqa":
        p["attn"] = L.init_attention(ks[0], cfg)
    elif cfg.attention == "mla":
        p["attn"] = L.init_mla(ks[0], cfg)
    if cfg.family in ("ssm", "hybrid"):
        p["mamba"] = L.init_mamba2(ks[1], cfg)
        if cfg.family == "hybrid":
            p["norm_m"] = jnp.ones((cfg.d_model,), cfg.dtype)
    if cfg.d_ff or (cfg.moe and cfg.moe.num_experts):
        p["norm2"] = jnp.ones((cfg.d_model,), cfg.dtype)
        if cfg.moe and cfg.moe.num_experts and not dense_ffn:
            p["moe"] = L.init_moe(ks[2], cfg)
        elif cfg.d_ff:
            p["mlp"] = L.init_mlp(ks[2], cfg)
        elif cfg.moe:
            # dense prefix layer of an MoE model: widen to ~active-expert FLOPs
            p["mlp"] = L.init_mlp(
                ks[2], cfg,
                d_ff=cfg.moe.d_ff_expert
                * max(cfg.moe.experts_per_token + cfg.moe.num_shared_experts, 1),
            )
    return p


def init(cfg: ModelConfig, key) -> Dict:
    ks = L._split(key, cfg.num_layers + 2)
    params: Dict[str, Any] = {"embed": L.init_embedding(ks[0], cfg)}
    n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    prefix = [
        _init_block(ks[1 + i], cfg, i, dense_ffn=True) for i in range(n_prefix)
    ]
    rest = [
        _init_block(ks[1 + i], cfg, i, dense_ffn=False)
        for i in range(n_prefix, cfg.num_layers)
    ]
    params["prefix_layers"] = prefix
    params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *rest)
    params["final_norm"] = jnp.ones((cfg.d_model,), cfg.dtype)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# per-layer windows
# ---------------------------------------------------------------------------


def static_windows(cfg: ModelConfig) -> List[Optional[int]]:
    """Python-level per-layer window (None = global attention)."""
    out: List[Optional[int]] = []
    for i in range(cfg.num_layers):
        w = cfg.window_for_layer(i)
        # Hymba-style hybrids keep first / middle / last layers global
        if cfg.family == "hybrid" and i in (0, cfg.num_layers // 2, cfg.num_layers - 1):
            w = None
        out.append(w)
    return out


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) int32 traced windows for the full-sequence (scan) path."""
    return jnp.asarray(
        [w if w is not None else BIG_WINDOW for w in static_windows(cfg)], jnp.int32
    )


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _block_full(p, x, cfg: ModelConfig, positions, window, rope_fraction):
    """One transformer block, full-sequence.  Returns (x, aux_loss).

    Sharding-hint hooks (models.layers.shard_hints):
      * "attn_in": re-shard the normed input ONCE before the q/k/v
        projections (otherwise each projection re-gathers the SP residual).
      * "block_out": constrain attention/FFN outputs to the residual (SP)
        spec so GSPMD lowers the row-parallel psum as reduce-scatter
        instead of a full all-reduce.
    """
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    delta = jnp.zeros_like(x)
    if cfg.attention == "gqa":
        w = None if cfg.sliding_window is None else window
        delta = L.attention_full(
            p["attn"], L._hint("attn_in", h), cfg, positions, window=w,
            rope_fraction=rope_fraction,
        )
        delta = L._hint("block_out", delta)
    elif cfg.attention == "mla":
        delta = L._hint("block_out", L.mla_full(p["attn"], L._hint("attn_in", h), cfg, positions))
    if cfg.family == "ssm":
        delta = L._hint("block_out", L.mamba2_full(p["mamba"], h, cfg))
    elif cfg.family == "hybrid":
        hm = L.rmsnorm(x, p["norm_m"], cfg.norm_eps)
        delta = 0.5 * (delta + L._hint("block_out", L.mamba2_full(p["mamba"], hm, cfg)))
    x = x + delta
    if "moe" in p:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        out, aux = L.moe(p["moe"], h2, cfg)
        x = x + L._hint("block_out", out)
    elif "mlp" in p:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L._hint("block_out", L.mlp(p["mlp"], h2, cfg))
    return x, aux


def rope_fraction(cfg: ModelConfig) -> float:
    # ChatGLM's "2d RoPE" rotates half the head dim
    return 0.5 if "chatglm" in cfg.name else 1.0


def hidden_forward(
    params,
    cfg: ModelConfig,
    tokens,  # (B, S) int32
    prefix_embeds=None,  # (B, P, d) modality-stub prefix
    remat: bool = False,
    residual_constraint=None,  # fn(x)->x: SP sharding hint between layers
    unroll: int = 1,  # scan unroll factor (dry-run uses full unroll so HLO
                      # cost analysis sees every layer, not one loop body)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (final hidden states (B, S_total, d), aux_loss)."""
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    windows = layer_windows(cfg)
    rf = rope_fraction(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    n_prefix = len(params["prefix_layers"])
    for i, p in enumerate(params["prefix_layers"]):
        x, aux = _block_full(p, x, cfg, positions, windows[i], rf)
        aux_total += aux

    def body(carry, inp):
        x, aux_acc = carry
        p, w = inp
        if residual_constraint is not None:
            x = residual_constraint(x)
        x, aux = _block_full(p, x, cfg, positions, w, rf)
        if residual_constraint is not None:
            # constrain the *outgoing* carry too: this is the tensor the
            # per-layer checkpoint saves for the backward pass — without the
            # hint it inherits the block's natural output sharding and the
            # saved residuals blow up by the TP degree.
            x = residual_constraint(x)
        return (x, aux_acc + aux), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux_total), _ = jax.lax.scan(
        body_fn, (x, aux_total), (params["layers"], windows[n_prefix:]),
        unroll=unroll,
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, aux_total


def _logits_of(params, cfg: ModelConfig, x):
    logits = L.unembed(params["embed"], x, cfg)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits


def forward(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            remat: bool = False, residual_constraint=None, unroll: int = 1):
    """Returns (logits (B, S_total, V) f32, aux_loss)."""
    x, aux = hidden_forward(params, cfg, tokens, prefix_embeds, remat,
                            residual_constraint, unroll)
    return _logits_of(params, cfg, x), aux


def _ce(params, cfg, x, labels):
    logits = _logits_of(params, cfg, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = labels >= 0
    safe = jnp.where(mask, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask), jnp.sum(mask).astype(jnp.float32)


def loss_fn(params, cfg: ModelConfig, tokens, labels, prefix_embeds=None,
            remat: bool = False, residual_constraint=None,
            logits_chunk: int = 0, unroll: int = 1):
    """Causal LM loss; labels < 0 are masked out.

    ``logits_chunk`` > 0 streams the unembedding + softmax over sequence
    chunks (rematerialized in the backward pass), bounding the live logits
    tensor to (B, chunk, V) instead of (B, S, V) — essential for the 256k
    vocab archs at 4k sequence."""
    x, aux = hidden_forward(params, cfg, tokens, prefix_embeds, remat,
                            residual_constraint, unroll)
    if prefix_embeds is not None:
        x = x[:, prefix_embeds.shape[1]:]
    s = x.shape[1]
    if logits_chunk and s % logits_chunk == 0 and s > logits_chunk:
        nchunks = s // logits_chunk
        xc = x.reshape(x.shape[0], nchunks, logits_chunk, -1)
        lc = labels.reshape(labels.shape[0], nchunks, logits_chunk)

        @jax.checkpoint
        def chunk_ce(carry, inp):
            xi, li = inp
            nll, cnt = _ce(params, cfg, xi, li)
            return (carry[0] + nll, carry[1] + cnt), None

        (nll, cnt), _ = jax.lax.scan(
            chunk_ce,
            (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xc.swapaxes(0, 1), lc.swapaxes(0, 1)),
        )
    else:
        nll, cnt = _ce(params, cfg, x, labels)
    ce = nll / jnp.maximum(cnt, 1)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
class Cache:
    """Decode cache with static layout metadata (aux data, not leaves).

    Two layouts behind one interface (``decode_step`` accepts either):

    * ``"contiguous"`` — per-slot strips of ``max_len`` (ring buffers for
      sliding-window layers), the lockstep/simple-batching layout;
    * ``"paged"`` — per-layer page pools plus a ``tables`` leaf, the
      (B, max_pages) int32 block table mapping each slot's logical KV
      blocks to physical pages (serving/paged_cache.py owns the host-side
      allocation; the engine refreshes ``tables`` via :meth:`with_tables`).
      GQA pages its KV heads; MLA pages its shared latent+rope cache
      (DESIGN.md §5.4).
    """

    def __init__(self, prefix, rest, stacked: bool, max_len: int,
                 layout: str = "contiguous", page_size: int = 0, tables=None):
        self.prefix = prefix
        self.rest = rest
        self.stacked = stacked
        self.max_len = max_len
        self.layout = layout
        self.page_size = page_size
        self.tables = tables

    def tree_flatten(self):
        return (
            (self.prefix, self.rest, self.tables),
            (self.stacked, self.max_len, self.layout, self.page_size),
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1], aux[2], aux[3],
                   tables=children[2])

    def with_tables(self, tables) -> "Cache":
        """Same cache contents under refreshed block tables."""
        return Cache(self.prefix, self.rest, self.stacked, self.max_len,
                     self.layout, self.page_size, tables)

    def kv_bytes(self) -> int:
        """Bytes held by attention KV state (pages or strips)."""
        total = 0
        for leaf in jax.tree.leaves((self.prefix, self.rest)):
            total += leaf.size * leaf.dtype.itemsize
        return total


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               layout: str = "contiguous", page_size: int = 16,
               num_blocks: Optional[int] = None) -> Cache:
    wlist = static_windows(cfg)
    if layout not in ("contiguous", "paged"):
        raise ValueError(f"unknown cache layout {layout!r}")
    if layout == "paged" and not cfg.attends:
        # loud, not a silent downgrade: the caller asked for paging and
        # this arch has no attention KV state to page
        raise ValueError(
            f"layout='paged' needs an attention KV cache; {cfg.name} "
            f"(attention={cfg.attention!r}) keeps only recurrent state — "
            "use layout='contiguous'."
        )
    max_pages = -(-max_len // page_size) if layout == "paged" else 0
    if layout == "paged" and num_blocks is None:
        num_blocks = batch * max_pages

    def one(layer_idx: int) -> Dict:
        c: Dict[str, Any] = {}
        if cfg.attention == "gqa":
            if layout == "paged":
                c["kv"] = L.init_paged_kv_cache(cfg, num_blocks, page_size)
            else:
                c["kv"] = L.init_kv_cache(
                    cfg, batch, max_len, window=wlist[layer_idx]
                )
        elif cfg.attention == "mla":
            if layout == "paged":
                c["mla"] = L.init_mla_paged_cache(cfg, num_blocks, page_size)
            else:
                c["mla"] = L.init_mla_cache(cfg, batch, max_len)
        if cfg.family in ("ssm", "hybrid"):
            c["ssm"] = L.init_mamba2_cache(cfg, batch)
        return c

    n_prefix = cfg.moe.first_dense_layers if cfg.moe else 0
    prefix = [one(i) for i in range(n_prefix)]
    rest = [one(i) for i in range(n_prefix, cfg.num_layers)]
    tables = (
        jnp.zeros((batch, max_pages), jnp.int32) if layout == "paged" else None
    )
    homogeneous = len({w for w in wlist[n_prefix:]}) <= 1
    if homogeneous and len(rest) > 1:
        rest_t = jax.tree.map(lambda *xs: jnp.stack(xs), *rest)
        return Cache(prefix, rest_t, True, max_len, layout, page_size, tables)
    return Cache(prefix, rest, False, max_len, layout, page_size, tables)


def copy_pages(cache: Cache, src, dst) -> Cache:
    """Device-side copy-on-write: duplicate physical pages ``src[i]`` onto
    ``dst[i]`` in every page-pool leaf of a paged cache.

    The serving engine calls this when a slot must write into a page shared
    with another table (``SlotTables.ensure_writable`` handed out a fresh
    page): the shared contents are copied on device — never staged through
    the host — and the repointed table is uploaded afterwards.  Page pools
    are identified by their leaf names (``*_pages``: GQA's k/v pools, MLA's
    latent/rope pools); every pool keeps its page axis at ``ndim - 3``
    (pages × page_size × feature, with optional head/layer-stack axes in
    front), so one gather/scatter covers both families, stacked or not.
    Pairs may be padded with ``(0, 0)`` — copying the reserved garbage page
    onto itself is a no-op.
    """
    if cache.layout != "paged":
        raise ValueError("copy_pages needs a paged cache")
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)

    def visit(path, leaf):
        if not _is_pool_leaf(path):
            return leaf
        pool = jnp.moveaxis(leaf, leaf.ndim - 3, 0)
        pool = pool.at[dst].set(pool[src])
        return jnp.moveaxis(pool, 0, leaf.ndim - 3)

    prefix = jax.tree_util.tree_map_with_path(visit, cache.prefix)
    rest = jax.tree_util.tree_map_with_path(visit, cache.rest)
    return Cache(prefix, rest, cache.stacked, cache.max_len, cache.layout,
                 cache.page_size, cache.tables)


def _is_pool_leaf(path) -> bool:
    """A cache leaf is a physical page pool iff some dict key on its path
    ends in ``_pages`` (GQA's k/v pools, MLA's latent/rope pools, and the
    quantized variants' ``*_scale_pages``) — the same contract
    :func:`copy_pages` keys on, with the page axis at ``ndim - 3``."""
    return any(
        str(p.key).endswith("_pages")
        for p in path if isinstance(p, jax.tree_util.DictKey)
    )


def gather_pages(cache: Cache, pages) -> list:
    """Contents of physical ``pages`` from every pool leaf, page axis
    leading — ``(len(pages), *per_page_shape)`` numpy arrays in the
    cache's flatten order (prefix leaves then rest), matching
    :func:`scatter_pages` and :func:`page_leaf_shapes`.  This is the
    serializable payload of the serving engine's ``snapshot()``."""
    if cache.layout != "paged":
        raise ValueError("gather_pages needs a paged cache")
    idx = jnp.asarray(list(pages), jnp.int32)
    out: list = []

    def visit(path, leaf):
        if _is_pool_leaf(path):
            pool = jnp.moveaxis(leaf, leaf.ndim - 3, 0)
            out.append(np.asarray(pool[idx]))
        return leaf

    jax.tree_util.tree_map_with_path(visit, cache.prefix)
    jax.tree_util.tree_map_with_path(visit, cache.rest)
    return out


def scatter_pages(cache: Cache, pages, values) -> Cache:
    """Inverse of :func:`gather_pages`: write ``values`` (one array per
    pool leaf, page axis leading) into physical ``pages`` of every pool
    leaf.  The engine's snapshot restore path — page *ids* are remapped by
    the caller, contents land wherever the fresh pool allocated them."""
    if cache.layout != "paged":
        raise ValueError("scatter_pages needs a paged cache")
    idx = jnp.asarray(list(pages), jnp.int32)
    vals = iter(values)

    def visit(path, leaf):
        if not _is_pool_leaf(path):
            return leaf
        v = jnp.asarray(next(vals)).astype(leaf.dtype)
        pool = jnp.moveaxis(leaf, leaf.ndim - 3, 0)
        pool = pool.at[idx].set(v)
        return jnp.moveaxis(pool, 0, leaf.ndim - 3)

    prefix = jax.tree_util.tree_map_with_path(visit, cache.prefix)
    rest = jax.tree_util.tree_map_with_path(visit, cache.rest)
    return Cache(prefix, rest, cache.stacked, cache.max_len, cache.layout,
                 cache.page_size, cache.tables)


def page_leaf_shapes(cache: Cache) -> list:
    """``(per_page_shape, dtype_name)`` for every pool leaf in gather
    order — the layout fingerprint snapshot loading validates before
    scattering foreign page contents into this cache."""
    if cache.layout != "paged":
        raise ValueError("page_leaf_shapes needs a paged cache")
    out: list = []

    def visit(path, leaf):
        if _is_pool_leaf(path):
            dims = list(leaf.shape)
            dims.pop(leaf.ndim - 3)
            out.append((tuple(dims), str(leaf.dtype)))
        return leaf

    jax.tree_util.tree_map_with_path(visit, cache.prefix)
    jax.tree_util.tree_map_with_path(visit, cache.rest)
    return out


def _per_slot(mask, tree_a, tree_b):
    """Select ``tree_a`` where the (B,) ``mask`` holds, else ``tree_b``
    (leaves are batch-major)."""
    return jax.tree.map(
        lambda a, b: jnp.where(
            mask.reshape(mask.shape + (1,) * (a.ndim - 1)), a, b
        ),
        tree_a, tree_b,
    )


def _block_decode(p, x, cfg: ModelConfig, cache, pos, window,
                  layout="contiguous", tables=None, live=None):
    """``window`` must be a static python value here (ring layout / mask).

    ``live`` (optional (B,) bool) marks the slots actually taking a step.
    Positional caches (KV strips/pages, MLA latents) never need it — a dead
    slot's write lands beyond its live length and is masked on read — but
    *recurrent* SSM/conv state has no position to hide behind: without the
    mask a parked slot's state would keep evolving every batched tick.
    With ``live``, dead slots hold their state and a slot stepping at
    ``pos == 0`` starts from zeroed state, so a request's outputs do not
    depend on what previously occupied its slot.
    """
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    new_cache: Dict[str, Any] = {}
    delta = jnp.zeros_like(x)
    if cfg.attention == "gqa":
        if layout == "paged":
            delta, kv = L.attention_decode_paged(
                p["attn"], h, cfg, cache["kv"], pos, tables, window=window,
                rope_fraction=rope_fraction(cfg),
            )
        else:
            delta, kv = L.attention_decode(
                p["attn"], h, cfg, cache["kv"], pos, window=window,
                rope_fraction=rope_fraction(cfg),
            )
        new_cache["kv"] = kv
    elif cfg.attention == "mla":
        if layout == "paged":
            delta, mc = L.mla_decode_paged(
                p["attn"], h, cfg, cache["mla"], pos, tables, window=window
            )
        else:
            delta, mc = L.mla_decode(
                p["attn"], h, cfg, cache["mla"], pos, window=window
            )
        new_cache["mla"] = mc
    if cfg.family in ("ssm", "hybrid"):
        ssm_in = cache["ssm"]
        if live is not None:
            posb = jnp.broadcast_to(
                jnp.asarray(pos, jnp.int32), (x.shape[0],)
            )
            fresh = live & (posb == 0)
            ssm_in = _per_slot(
                fresh, jax.tree.map(jnp.zeros_like, ssm_in), ssm_in
            )
        if cfg.family == "ssm":
            md, sc = L.mamba2_decode(p["mamba"], h, cfg, ssm_in)
            delta = md
        else:
            hm = L.rmsnorm(x, p["norm_m"], cfg.norm_eps)
            md, sc = L.mamba2_decode(p["mamba"], hm, cfg, ssm_in)
            delta = 0.5 * (delta + md)
        if live is not None:
            sc = _per_slot(live, sc, cache["ssm"])
        new_cache["ssm"] = sc
    x = x + delta
    if "moe" in p:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        out, _ = L.moe(p["moe"], h2, cfg)
        x = x + out
    elif "mlp" in p:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h2, cfg)
    return x, new_cache


def decode_step(params, cfg: ModelConfig, cache: Cache, token, pos,
                unroll: int = 1, live=None):
    """One decode step: token (B,) int32, pos scalar int32 -> (logits, cache).

    ``live`` (optional (B,) bool) marks slots genuinely stepping — see
    :func:`_block_decode`; serving passes it so parked slots cannot mutate
    recurrent state and recycled slots start from clean state.
    """
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    wlist = static_windows(cfg)
    n_prefix = len(params["prefix_layers"])
    layout, tables = cache.layout, cache.tables
    new_prefix = []
    for i, p in enumerate(params["prefix_layers"]):
        x, c = _block_decode(p, x, cfg, cache.prefix[i], pos, wlist[i],
                             layout, tables, live)
        new_prefix.append(c)

    if cache.stacked:
        wcommon = wlist[n_prefix] if cfg.num_layers > n_prefix else None

        def body(x, inp):
            p, c = inp
            x, cnew = _block_decode(p, x, cfg, c, pos, wcommon, layout,
                                    tables, live)
            return x, cnew

        x, new_rest = jax.lax.scan(
            body, x, (params["layers"], cache.rest), unroll=unroll
        )
    else:
        new_rest = []
        layer_list = _unstack(params["layers"], cfg.num_layers - n_prefix)
        for j, (p, c) in enumerate(zip(layer_list, cache.rest)):
            x, cnew = _block_decode(p, x, cfg, c, pos, wlist[n_prefix + j],
                                    layout, tables, live)
            new_rest.append(cnew)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits, Cache(new_prefix, new_rest, cache.stacked, cache.max_len,
                         layout, cache.page_size, tables)


def decode_loop(params, cfg: ModelConfig, cache: Cache, feed, pos, key,
                live, remaining, *, n_steps: int, sample_fn, eos_id: int,
                max_len: int, unroll: int = 1):
    """Run up to ``n_steps`` decode ticks in one ``jax.lax.scan`` — the
    device-resident decode loop.  Everything the per-tick engine round-trips
    through the host each tick (feed build, upload, sample, download) lives
    in the scan carry instead; the host dispatches once and drains once.

    ``feed`` (B,) is each slot's last known token, ``pos`` (B,) its next
    write position, ``live`` (B,) bool the slots generating, ``remaining``
    (B,) each slot's token allowance.  ``sample_fn(logits, key, gate) ->
    (tokens, key)`` folds sampling into the loop body (serving passes
    :func:`repro.serving.sampling.sample_step`); ``gate`` is the any-slot-
    live flag so fully-dead tail iterations leave the key untouched.

    Per iteration, mirroring the per-tick engine's ``_emit_token`` exactly:
    a live slot feeds its token, samples the next, advances ``pos`` and
    burns one ``remaining``; it stops when the sampled token equals
    ``eos_id``, its allowance hits zero, or ``pos`` reaches ``max_len``.
    Greedy outputs are therefore byte-identical to per-tick stepping
    unconditionally.  At ``temperature > 0`` the key stream matches the
    per-tick engine's whenever the window covers the same ticks it would
    have run; if a slot frees mid-window while work is queued, per-tick
    admission would interleave a prefill key split before the boundary, so
    the streams are equally-valid draws but not bit-equal — scheduling
    deferral is visible through the PRNG, and callers needing bit-equality
    under sampling must keep windows off or the queue empty.
    Dead slots keep re-feeding their frozen token at their frozen ``pos``:
    the write lands beyond their live length (masked on read, overwritten
    on slot reuse) and recurrent state is held by the ``live`` mask inside
    ``decode_step``, so a dead iteration is behaviorally a no-op.

    Returns ``(tokens (n_steps, B), emitted (n_steps, B) bool, key, cache)``
    — ``emitted[t, b]`` marks a token the host must deliver; rows after the
    last live iteration are all-False.
    """
    def body(carry, _):
        cache, feed, pos, key, live, remaining = carry
        logits, cache = decode_step(params, cfg, cache, feed, pos,
                                    unroll=unroll, live=live)
        tok, key = sample_fn(logits, key, live.any())
        tok = jnp.where(live, tok, feed)
        pos = jnp.where(live, pos + 1, pos)
        remaining = jnp.where(live, remaining - 1, remaining)
        stop = (tok == eos_id) | (remaining <= 0) | (pos >= max_len)
        return (cache, tok, pos, key, live & ~stop, remaining), (tok, live)

    carry = (cache, jnp.asarray(feed, jnp.int32), jnp.asarray(pos, jnp.int32),
             key, live, jnp.asarray(remaining, jnp.int32))
    (cache, _, _, key, _, _), (toks, emitted) = jax.lax.scan(
        body, carry, None, length=n_steps
    )
    return toks, emitted, key, cache


def _block_prefill(p, x, cfg: ModelConfig, cache, pos, lens, window,
                   layout="contiguous", tables=None):
    """One transformer block over a (B, C) prefill chunk.  Mirrors
    ``_block_decode`` (same cache contract) with chunk-wide attention;
    ``window`` must be a static python value."""
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
    new_cache: Dict[str, Any] = {}
    if cfg.attention == "mla":
        if layout == "paged":
            delta, mc = L.mla_prefill_paged(
                p["attn"], h, cfg, cache["mla"], pos, tables, lens,
                window=window
            )
        else:
            delta, mc = L.mla_prefill(
                p["attn"], h, cfg, cache["mla"], pos, lens, window=window
            )
        new_cache["mla"] = mc
    elif layout == "paged":
        delta, kv = L.attention_prefill_paged(
            p["attn"], h, cfg, cache["kv"], pos, tables, lens, window=window,
            rope_fraction=rope_fraction(cfg),
        )
        new_cache["kv"] = kv
    else:
        delta, kv = L.attention_prefill(
            p["attn"], h, cfg, cache["kv"], pos, lens, window=window,
            rope_fraction=rope_fraction(cfg),
        )
        new_cache["kv"] = kv
    x = x + delta
    if "moe" in p:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        out, _ = L.moe(p["moe"], h2, cfg)
        x = x + out
    elif "mlp" in p:
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp(p["mlp"], h2, cfg)
    return x, new_cache


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Chunked prefill covers the attention families — GQA through the
    prefill_attention kernel and MLA through mla_prefill (latent chunk
    writes).  SSM/hybrid state still replays token by token (recurrent
    state has no chunk-parallel write)."""
    return cfg.attention in ("gqa", "mla") and cfg.family not in ("ssm", "hybrid")


def _prefill_trunk(params, cfg: ModelConfig, cache: Cache, tokens, pos, lens,
                   unroll: int = 1):
    """The shared chunk-wide forward pass behind :func:`prefill_step` and
    :func:`verify_step`: embed, every block's chunk attention + KV page
    writes, final norm.  Returns ``(x (B, C, d), Cache)`` — the hidden
    states of every chunk position, before any logits projection."""
    if not supports_chunked_prefill(cfg):
        raise NotImplementedError(
            f"chunked prefill supports attention archs (GQA/MLA); {cfg.name} "
            f"(attention={cfg.attention}, family={cfg.family}) replays "
            "prompts through decode_step instead."
        )
    x = L.embed(params["embed"], tokens).astype(cfg.dtype)
    wlist = static_windows(cfg)
    n_prefix = len(params["prefix_layers"])
    layout, tables = cache.layout, cache.tables
    lens = jnp.asarray(lens, jnp.int32)
    new_prefix = []
    for i, p in enumerate(params["prefix_layers"]):
        x, c = _block_prefill(p, x, cfg, cache.prefix[i], pos, lens, wlist[i],
                              layout, tables)
        new_prefix.append(c)

    if cache.stacked:
        wcommon = wlist[n_prefix] if cfg.num_layers > n_prefix else None

        def body(x, inp):
            p, c = inp
            x, cnew = _block_prefill(p, x, cfg, c, pos, lens, wcommon,
                                     layout, tables)
            return x, cnew

        x, new_rest = jax.lax.scan(
            body, x, (params["layers"], cache.rest), unroll=unroll
        )
    else:
        new_rest = []
        layer_list = _unstack(params["layers"], cfg.num_layers - n_prefix)
        for j, (p, c) in enumerate(zip(layer_list, cache.rest)):
            x, cnew = _block_prefill(p, x, cfg, c, pos, lens,
                                     wlist[n_prefix + j], layout, tables)
            new_rest.append(cnew)

    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x, Cache(new_prefix, new_rest, cache.stacked, cache.max_len,
                    layout, cache.page_size, tables)


def prefill_step(params, cfg: ModelConfig, cache: Cache, tokens, pos, lens,
                 unroll: int = 1):
    """One chunked-prefill step: a (B, C) block of prompt tokens advances
    every slot with ``lens[b] > 0`` by ``lens[b]`` positions in a single
    forward pass (vs C batched decode steps under token replay).

    ``tokens`` (B, C) int32 (dead tail arbitrary), ``pos`` (B,) chunk start
    positions, ``lens`` (B,) live tokens per slot (0 = slot idle this step).
    Returns ``(logits, cache)`` where ``logits`` (B, V) belong to each
    slot's *last live* chunk token — exactly what sampling needs when a
    chunk completes its prompt.  Works against both cache layouts through
    the same ``Cache`` interface as ``decode_step``.
    """
    lens = jnp.asarray(lens, jnp.int32)
    x, cache = _prefill_trunk(params, cfg, cache, tokens, pos, lens,
                              unroll=unroll)
    # each slot's last live chunk position feeds the logits (idle slots
    # gather row 0 — garbage the engine ignores)
    last = jnp.clip(lens - 1, 0, x.shape[1] - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
    logits = L.unembed(params["embed"], x_last, cfg)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits, cache


def verify_step(params, cfg: ModelConfig, cache: Cache, tokens, pos, lens,
                unroll: int = 1):
    """Speculative-decode verify: score every chunk position in one pass.

    This *is* chunked prefill — the same ``_prefill_trunk`` (same kernels,
    same table-directed KV page writes) — differing only in the logits
    projection: where :func:`prefill_step` unembeds each slot's last live
    token, verify unembeds the whole chunk, because accept/rollback needs
    the model's next-token distribution after *every* draft prefix.
    Returns ``(logits (B, C, V), cache)``; rows of idle slots
    (``lens == 0``) are garbage the caller masks.
    """
    x, cache = _prefill_trunk(params, cfg, cache, tokens, pos, lens,
                              unroll=unroll)
    logits = L.unembed(params["embed"], x, cfg)
    if cfg.logit_soft_cap:
        logits = cfg.logit_soft_cap * jnp.tanh(logits / cfg.logit_soft_cap)
    return logits, cache


def ngram_propose(history, pos, feed, draft_len: int):
    """Self-speculation draft proposer: n-gram lookahead over the slot's own
    token history (prompt + committed output) — no second model, no weights.

    ``history`` (B, H) int32 holds each slot's tokens by sequence index
    (``history[b, pos[b]] == feed[b]``, entries past ``pos`` undefined).
    For each slot, find the most recent earlier occurrence of the current
    ``(prev, last)`` bigram, falling back to a unigram match on ``last``,
    and propose the ``draft_len`` tokens that followed it.  No match (or a
    match too close to the end) degrades to repeating ``feed`` — proposals
    are always *valid* token ids, and verify rejects wrong ones, so
    proposer quality only ever affects speed, never output.
    """
    b, h = history.shape
    last = jnp.asarray(feed, jnp.int32)
    pos = jnp.asarray(pos, jnp.int32)
    js = jnp.arange(h, dtype=jnp.int32)[None, :]
    known = js < pos[:, None]  # strictly-past indices only
    uni = known & (history == last[:, None])
    prev = jnp.where(
        pos > 0,
        jnp.take_along_axis(history, jnp.maximum(pos - 1, 0)[:, None],
                            axis=1)[:, 0],
        -1,
    )
    shifted = jnp.concatenate(
        [jnp.full((b, 1), -1, history.dtype), history[:, :-1]], axis=1
    )
    bi = uni & (shifted == prev[:, None])
    j_bi = jnp.max(jnp.where(bi, js, -1), axis=1)
    j_uni = jnp.max(jnp.where(uni, js, -1), axis=1)
    j = jnp.where(j_bi >= 0, j_bi, j_uni)
    cols = j[:, None] + 1 + jnp.arange(draft_len, dtype=jnp.int32)[None, :]
    ok = (j[:, None] >= 0) & (cols <= pos[:, None])
    cand = jnp.take_along_axis(history, jnp.clip(cols, 0, h - 1), axis=1)
    return jnp.where(ok, cand, last[:, None])


# Draft-proposer registry (ServeConfig.spec_decode names an entry): the plug
# point where a tiny draft *model* slots in later — any (history, pos, feed,
# draft_len) -> (B, draft_len) proposals function qualifies, because the
# verify/accept machinery never trusts a proposal.
DRAFT_PROPOSERS = {"ngram": ngram_propose}


def spec_decode_loop(params, cfg: ModelConfig, cache: Cache, feed, pos, key,
                     live, remaining, history, *, n_rounds: int,
                     draft_len: int, propose_fn, sample_fn, accept_fn,
                     eos_id: int, max_len: int, poison=None, unroll: int = 1):
    """``n_rounds`` draft-verify rounds in one ``jax.lax.scan`` dispatch —
    the speculative twin of :func:`decode_loop`, composing with it
    multiplicatively: where a decode-loop iteration emits one token, a
    round here drafts ``draft_len`` tokens (``propose_fn``), scores all of
    them plus the feed token in one chunk forward (:func:`verify_step` —
    batched verify *is* chunked prefill), and emits the accepted prefix
    plus the model's own next token, so one host dispatch covers up to
    ``n_rounds * (draft_len + 1)`` tokens.

    Accept/rollback are carry masks, not copies: the verify chunk writes
    KV for all ``draft_len + 1`` positions through the block tables, and a
    rejected tail is *logically* truncated by not advancing ``pos`` past
    the accepted prefix — the stale pages sit beyond the slot's live
    length, invisible to the ragged masks, and the next round's chunk
    write overwrites them (the engine's ``SlotTables.trim`` returns the
    unused grow-ahead at the sync boundary).

    ``sample_fn(logits (B, C, V), key, gate) -> (targets (B, C), key)``
    must advance the key by a *fixed* number of splits per gated round
    (``sampling.spec_sample_step``), so the stream is deterministic
    regardless of acceptance lengths; ``accept_fn(drafts, targets) ->
    (B, C) bool`` is the leading-accept mask (``sampling.spec_accept``).
    Greedy targets make the emitted stream byte-identical to plain decode
    by construction: every emitted token is the argmax after a committed,
    fully-verified prefix.

    ``poison`` (B,) bool overwrites a slot's verify logits with NaN (fault
    injection); slots whose logits hold no finite value — injected or
    genuine — emit nothing and stop, reported through ``bad`` for the
    engine to FAIL exactly that request.

    Returns ``(targets (n, B, C), emitted (n, B, C) bool, bad (n, B) bool,
    key, cache)`` with ``C = draft_len + 1``; ``emitted[t, b, i]`` marks
    target ``i`` of round ``t`` as a token the host must deliver, in order.
    """
    c = draft_len + 1
    feed = jnp.asarray(feed, jnp.int32)
    if poison is None:
        poison = jnp.zeros(feed.shape, bool)
    idx = jnp.arange(c, dtype=jnp.int32)
    h = history.shape[1]

    def body(carry, _):
        cache, feed, pos, key, live, remaining, history = carry
        drafts = propose_fn(history, pos, feed, draft_len)
        chunk = jnp.concatenate([feed[:, None], drafts], axis=1)
        lens = jnp.where(live, c, 0).astype(jnp.int32)
        logits, cache = verify_step(params, cfg, cache, chunk, pos, lens,
                                    unroll=unroll)
        logits = jnp.where(poison[:, None, None], jnp.nan, logits)
        bad = jnp.any(~jnp.any(jnp.isfinite(logits), axis=-1), axis=-1) & live
        tgt, key = sample_fn(logits, key, live.any())
        eos_hit = tgt == eos_id
        ieos = eos_hit.astype(jnp.int32)
        prev_eos = (jnp.cumsum(ieos, axis=1) - ieos) > 0
        # target i is emitted iff every draft before it verified, no earlier
        # target was EOS, and the slot still had allowance/room — exactly
        # decode_loop's per-tick stop conditions, applied per position
        emit = (
            accept_fn(drafts, tgt)
            & ~prev_eos
            & ((pos[:, None] + idx[None, :]) < max_len)
            & (idx[None, :] < remaining[:, None])
            & live[:, None]
            & ~bad[:, None]
        )
        nem = emit.sum(axis=1, dtype=jnp.int32)
        last_tok = jnp.take_along_axis(
            tgt, jnp.clip(nem - 1, 0, c - 1)[:, None], axis=1
        )[:, 0]
        feed = jnp.where(nem > 0, last_tok, feed)
        # append the emitted tokens to the history so the next round's
        # n-gram lookahead sees them (rejected targets never land)
        wcols = jnp.where(emit, pos[:, None] + 1 + idx[None, :], h)
        history = history.at[jnp.arange(history.shape[0])[:, None], wcols].set(
            tgt, mode="drop"
        )
        pos = pos + nem
        remaining = remaining - nem
        stop = (
            (emit & eos_hit).any(axis=1)
            | (remaining <= 0)
            | (pos >= max_len)
            | bad
        )
        return (cache, feed, pos, key, live & ~stop, remaining, history), (
            tgt, emit, bad,
        )

    carry = (cache, feed, jnp.asarray(pos, jnp.int32), key, live,
             jnp.asarray(remaining, jnp.int32),
             jnp.asarray(history, jnp.int32))
    (cache, _, _, key, _, _, _), (toks, emitted, bad) = jax.lax.scan(
        body, carry, None, length=n_rounds
    )
    return toks, emitted, bad, key, cache


def _unstack(tree, n):
    return [jax.tree.map(lambda a, i=i: a[i], tree) for i in range(n)]
