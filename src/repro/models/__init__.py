# Model zoo: unified config + decoder-only LM (dense/moe/mla/ssm/hybrid) and
# encoder-decoder (whisper).  Pure-function APIs over param pytrees.
from . import encdec, layers, lm
from .config import MLAConfig, ModelConfig, MoEConfig, SSMConfig

__all__ = [
    "encdec",
    "layers",
    "lm",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "MLAConfig",
]
