"""Encoder-decoder transformer (whisper-tiny family, paper-assigned audio arch).

The audio conv frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, T_frames, d_model).  Learned absolute
positions, GELU MLPs, causal decoder with cross-attention; decode uses a
self-attention KV cache plus per-layer precomputed cross K/V.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from . import layers as L
from .config import ModelConfig

MAX_FRAMES = 1500  # whisper-tiny encoder positions (30 s of audio)


def _attn_proj(params, x, heads, kv_heads, head_dim):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, heads, head_dim)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, s, kv_heads, head_dim)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, s, kv_heads, head_dim)
    return q, k, v


def init(cfg: ModelConfig, key) -> Dict:
    ks = L._split(key, 6 + cfg.encoder_layers + cfg.num_layers)
    d = cfg.d_model
    # whisper's own decoder caps at 448 positions; the assigned decode_32k /
    # long-context cells need 32k, so the table is sized to the largest cell.
    max_dec_pos = 32768 if cfg.vocab_size > 10000 else 2048
    params: Dict[str, Any] = {
        "embed": {"embedding": L._dense_init(ks[0], cfg.vocab_size, d, cfg.dtype, 1.0)},
        "enc_pos": L._dense_init(ks[1], MAX_FRAMES, d, cfg.dtype, 0.02),
        "dec_pos": L._dense_init(ks[2], max_dec_pos, d, cfg.dtype, 0.02),
        "enc_final_norm": jnp.ones((d,), cfg.dtype),
        "final_norm": jnp.ones((d,), cfg.dtype),
    }

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": jnp.ones((d,), cfg.dtype),
            "attn": L.init_attention(k1, cfg),
            "norm2": jnp.ones((d,), cfg.dtype),
            "mlp": L.init_mlp(k2, cfg),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": jnp.ones((d,), cfg.dtype),
            "attn": L.init_attention(k1, cfg),
            "norm_x": jnp.ones((d,), cfg.dtype),
            "xattn": L.init_attention(k2, cfg),
            "norm2": jnp.ones((d,), cfg.dtype),
            "mlp": L.init_mlp(k3, cfg),
        }

    enc = [enc_layer(ks[3 + i]) for i in range(cfg.encoder_layers)]
    dec = [dec_layer(ks[3 + cfg.encoder_layers + i]) for i in range(cfg.num_layers)]
    params["enc_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
    params["dec_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *dec)
    return params


def _self_attn(p, x, cfg, causal, kv_len=None, cache=None, pos=None):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _attn_proj(p, x, h, hkv, hd)
    if cache is not None:
        knew = jax.lax.dynamic_update_slice(
            cache["k"], k.transpose(0, 2, 1, 3), (0, 0, pos, 0)
        )
        vnew = jax.lax.dynamic_update_slice(
            cache["v"], v.transpose(0, 2, 1, 3), (0, 0, pos, 0)
        )
        out = ref.attention(
            q.transpose(0, 2, 1, 3), knew, vnew, causal=False,
            kv_len=jnp.full((b,), pos + 1, jnp.int32),
        )
        out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
        return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), p["wo"]), {
            "k": knew, "v": vnew,
        }
    out = ops.attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal,
        backend=cfg.kernel_backend if cfg.kernel_backend != "auto" else None,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), p["wo"]), None


def _cross_attn(p, x, enc_kv, cfg):
    """enc_kv: precomputed (k, v) each (B, H, T, hd)."""
    b, s, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, hd)
    out = ref.attention(q.transpose(0, 2, 1, 3), enc_kv[0], enc_kv[1], causal=False)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), p["wo"])


def encode(params, cfg: ModelConfig, frames, unroll: int = 1):
    """frames: (B, T, d_model) precomputed embeddings (conv frontend stub)."""
    t = frames.shape[1]
    x = frames.astype(cfg.dtype) + params["enc_pos"][None, :t]

    def body(x, p):
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        a, _ = _self_attn(p["attn"], h, cfg, causal=False)
        x = x + a
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        return x + L.mlp(p["mlp"], h2, cfg), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=unroll)
    return L.rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def cross_kv(params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross K/V from the encoder output."""
    b, t, _ = enc_out.shape
    h, hd = cfg.num_heads, cfg.head_dim

    def one(p):
        k = jnp.einsum("btd,de->bte", enc_out, p["xattn"]["wk"]).reshape(
            b, t, cfg.num_kv_heads, hd
        )
        v = jnp.einsum("btd,de->bte", enc_out, p["xattn"]["wv"]).reshape(
            b, t, cfg.num_kv_heads, hd
        )
        return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)

    return jax.lax.map(one, params["dec_layers"])


def decode_hidden(params, cfg: ModelConfig, tokens, enc_out, unroll: int = 1,
                  remat: bool = False):
    """Teacher-forced decoder pass -> final hidden (B, S, d)."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens).astype(cfg.dtype) + params["dec_pos"][None, :s]
    ckv = cross_kv(params, cfg, enc_out)

    def body(x, inp):
        p, (ck, cv) = inp
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        a, _ = _self_attn(p["attn"], h, cfg, causal=True)
        x = x + a
        hx = L.rmsnorm(x, p["norm_x"], cfg.norm_eps)
        x = x + _cross_attn(p["xattn"], hx, (ck, cv), cfg)
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        return x + L.mlp(p["mlp"], h2, cfg), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["dec_layers"], ckv), unroll=unroll)
    return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)


def decode_full(params, cfg: ModelConfig, tokens, enc_out, unroll: int = 1):
    """Teacher-forced decoder pass -> logits (B, S, V)."""
    x = decode_hidden(params, cfg, tokens, enc_out, unroll)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params, cfg: ModelConfig, frames, tokens, labels, unroll: int = 1,
            remat: bool = False, logits_chunk: int = 0):
    enc = encode(params, cfg, frames, unroll)
    x = decode_hidden(params, cfg, tokens, enc, unroll, remat)
    s = x.shape[1]

    def ce_of(xc, lc):
        logits = L.unembed(params["embed"], xc, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        mask = lc >= 0
        safe = jnp.where(mask, lc, 0)
        nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        return jnp.sum(nll * mask), jnp.sum(mask).astype(jnp.float32)

    if logits_chunk and s % logits_chunk == 0 and s > logits_chunk:
        nchunks = s // logits_chunk
        xc = x.reshape(x.shape[0], nchunks, logits_chunk, -1).swapaxes(0, 1)
        lc = labels.reshape(labels.shape[0], nchunks, logits_chunk).swapaxes(0, 1)

        @jax.checkpoint
        def chunk(carry, inp):
            n, c = ce_of(*inp)
            return (carry[0] + n, carry[1] + c), None

        (nll, cnt), _ = jax.lax.scan(
            chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (xc, lc),
        )
    else:
        nll, cnt = ce_of(x, labels)
    ce = nll / jnp.maximum(cnt, 1)
    return ce, {"ce": ce}


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked self-attention caches for every decoder layer (cross K/V is
    precomputed separately by `cross_kv` and passed to decode_step)."""
    kv = {
        "k": jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim), cfg.dtype),
        "v": jnp.zeros((cfg.num_layers, batch, cfg.num_kv_heads, max_len, cfg.head_dim), cfg.dtype),
    }
    return {"self": kv}


def decode_step(params, cfg: ModelConfig, cache, token, pos, cross, unroll: int = 1):
    """cache: {"self": stacked per-layer kv}; cross: precomputed cross_kv."""
    b = token.shape[0]
    x = L.embed(params["embed"], token[:, None]).astype(cfg.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0)[None]

    def body(x, inp):
        p, kv, (ck, cv) = inp
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        a, kv_new = _self_attn(p["attn"], h, cfg, causal=False, cache=kv, pos=pos)
        x = x + a
        hx = L.rmsnorm(x, p["norm_x"], cfg.norm_eps)
        x = x + _cross_attn(p["xattn"], hx, (ck, cv), cfg)
        h2 = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        return x + L.mlp(p["mlp"], h2, cfg), kv_new

    kvs = {"k": cache["self"]["k"], "v": cache["self"]["v"]}
    x, kv_new = jax.lax.scan(
        body, x, (params["dec_layers"], kvs, cross), unroll=unroll
    )
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)[:, 0]
    return logits, {"self": kv_new}
