"""Unified model configuration covering all assigned architecture families.

One dataclass; family-specific fields are ignored by families that don't use
them.  Every assigned architecture instantiates this in
``repro/configs/<id>.py``; ``reduced()`` derives the CPU smoke-test config.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass
class MoEConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    num_shared_experts: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128

    def num_heads(self, d_model: int) -> int:
        return self.expand * d_model // self.head_dim

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclasses.dataclass
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 = full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    attention: str = "gqa"  # gqa | mla | none
    act: str = "silu"  # silu | gelu | geglu(=gelu-gated)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_soft_cap: Optional[float] = None
    sliding_window: Optional[int] = None  # applied to non-global attn layers
    global_attn_every: int = 0  # 0 = all layers global (no windowing)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # encoder-decoder (audio family)
    encoder_layers: int = 0
    is_encoder_decoder: bool = False
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: str = "none"  # none | patch(vlm) | audio(frames)
    frontend_seq: int = 0  # prefix length delivered by the stub frontend
    dtype: str = "bfloat16"
    kernel_backend: str = "auto"  # pallas | xla | auto (see kernels.ops)
    # Paged-KV storage format: None = store cfg.dtype; "int8"/"int4" = packed
    # symmetric per-token quantization with per-row scales kept in the page
    # pools (see kernels.ref.quantize_rows / DESIGN.md §5.6).  Only the paged
    # layouts support this; contiguous caches reject it loudly.
    kv_dtype: Optional[str] = None

    # -- derived -----------------------------------------------------------
    def __post_init__(self):
        if self.head_dim is None:
            self.head_dim = self.d_model // max(self.num_heads, 1)

    @property
    def attends(self) -> bool:
        return self.attention != "none"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode? (SSM / hybrid / windowed.)"""
        if self.family in ("ssm",):
            return True
        if self.family == "hybrid":
            return True
        return False

    def window_for_layer(self, layer: int) -> Optional[int]:
        if self.sliding_window is None:
            return None
        if self.global_attn_every and (layer + 1) % self.global_attn_every == 0:
            return None  # periodic global layer
        return self.sliding_window

    # -- parameter counting (roofline MODEL_FLOPS = 6*N*D uses these) --------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n = 0
        n += v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.attends and self.attention == "gqa":
            per_layer += d * self.num_heads * hd  # q
            per_layer += 2 * d * self.num_kv_heads * hd  # kv
            per_layer += self.num_heads * hd * d  # o
        elif self.attention == "mla":
            m = self.mla
            qd = self.num_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
            per_layer += d * qd if not m.q_lora_rank else d * m.q_lora_rank + m.q_lora_rank * qd
            per_layer += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.num_heads * m.v_head_dim * d
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            nh = self.ssm.num_heads(d)
            per_layer += d * (2 * di + 2 * self.ssm.state_dim * (1 if self.family == "ssm" else 1) + nh)
            per_layer += di * d
        if self.moe is not None and self.moe.num_experts:
            fe = self.moe.d_ff_expert
            experts = self.moe.experts_per_token if active_only else self.moe.num_experts
            per_layer += experts * 3 * d * fe
            per_layer += self.moe.num_shared_experts * 3 * d * fe
            per_layer += d * self.moe.num_experts  # router
        elif f:
            mult = 3 if self.act in ("silu", "geglu") else 2
            per_layer += mult * d * f
        per_layer += 2 * d  # norms
        n += self.num_layers * per_layer
        if self.is_encoder_decoder:
            # encoder self-attn + ffn, and decoder cross-attention extras
            n += self.encoder_layers * (
                2 * d * self.num_heads * hd
                + 2 * d * self.num_kv_heads * hd
                + 2 * d * f
                + 2 * d
            )
            n += self.num_layers * (
                2 * d * self.num_heads * hd  # cross-attn q & o
                + 2 * d * self.num_kv_heads * hd  # cross-attn k & v
                + 2 * d
            )
        return n

    # -- smoke-test reduction -------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        cfg = dataclasses.replace(
            self,
            num_layers=2,
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 1,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            encoder_layers=2 if self.is_encoder_decoder else 0,
            sliding_window=32 if self.sliding_window else None,
            frontend_seq=8 if self.frontend != "none" else 0,
            dtype="float32",
            kernel_backend="xla",
        )
        if cfg.moe is not None:
            cfg.moe = dataclasses.replace(
                cfg.moe, num_experts=4, experts_per_token=2, d_ff_expert=32,
                num_shared_experts=min(cfg.moe.num_shared_experts, 1),
                first_dense_layers=min(cfg.moe.first_dense_layers, 1),
            )
        if cfg.ssm is not None:
            cfg.ssm = dataclasses.replace(
                cfg.ssm, state_dim=16, head_dim=16, conv_width=4, chunk=16
            )
        if cfg.mla is not None:
            cfg.mla = MLAConfig(
                kv_lora_rank=32, q_lora_rank=0, qk_nope_head_dim=16,
                qk_rope_head_dim=8, v_head_dim=16,
            )
        return cfg
