"""Composable model layers (pure functions over param pytrees).

Every block exists in two execution modes:

* full-sequence (training / prefill) — uses the tile-DSL kernels through
  ``repro.kernels.ops`` when ``kernel_backend`` allows, else the XLA path;
* single-token decode — operates against static-shape caches (contiguous KV,
  ring-buffer KV for sliding windows, paged KV/latent pools behind block
  tables, SSM state for Mamba).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from .config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Sharding hints: step builders install activation constraints that apply
# while their step function traces (GSPMD needs interior hints when the
# natural propagation would replicate — e.g. attention with head counts not
# divisible by the TP degree, or MoE expert buffers).
# ---------------------------------------------------------------------------

import contextlib

_HINT_STACK: list = []


@contextlib.contextmanager
def shard_hints(**hooks):
    """hooks: name -> fn(x) -> x (usually with_sharding_constraint)."""
    _HINT_STACK.append(hooks)
    try:
        yield
    finally:
        _HINT_STACK.pop()


def _hint(name: str, x):
    for h in reversed(_HINT_STACK):
        if name in h and h[name] is not None:
            return h[name](x)
    return x


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def _split(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norm / embeddings / rope
# ---------------------------------------------------------------------------


def rmsnorm(x, weight, eps=1e-6):
    return ops.rmsnorm(x, weight, eps)


def init_embedding(key, cfg: ModelConfig) -> Params:
    k1, k2 = _split(key, 2)
    p = {"embedding": _dense_init(k1, cfg.vocab_size, cfg.d_model, cfg.dtype, 1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(k2, cfg.d_model, cfg.vocab_size, cfg.dtype)
    return p


def embed(params: Params, tokens):
    return jnp.take(params["embedding"], tokens, axis=0)


def unembed(params: Params, x, cfg: ModelConfig):
    w = params.get("unembed")
    if w is None:
        w = params["embedding"].T
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32), w.astype(jnp.float32))


def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    inv, rot = rope_freqs(d, theta, fraction)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, rot/2)
    if x.ndim == ang.ndim + 1:  # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1).reshape(*x1.shape[:-1], rot)
    if rot < d:
        out = jnp.concatenate([out, x[..., rot:].astype(jnp.float32)], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> Params:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = _split(key, 4)
    p = {
        "wq": _dense_init(ks[0], d, h * hd, cfg.dtype),
        "wk": _dense_init(ks[1], d, hkv * hd, cfg.dtype),
        "wv": _dense_init(ks[2], d, hkv * hd, cfg.dtype),
        "wo": _dense_init(ks[3], h * hd, d, cfg.dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.dtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.dtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.dtype)
    return p


def _qkv(params, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"])
    k = jnp.einsum("bsd,de->bse", x, params["wk"])
    v = jnp.einsum("bsd,de->bse", x, params["wv"])
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    return (
        q.reshape(b, s, h, hd),
        k.reshape(b, s, hkv, hd),
        v.reshape(b, s, hkv, hd),
    )


def attention_full(params, x, cfg: ModelConfig, positions, window=None,
                   rope_fraction=1.0):
    """Full-sequence causal attention (training / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta, rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, rope_fraction)
    qt = _hint("attn_q", q.transpose(0, 2, 1, 3))
    kt = _hint("attn_kv", k.transpose(0, 2, 1, 3))
    vt = _hint("attn_kv", v.transpose(0, 2, 1, 3))
    out = ops.attention(
        qt, kt, vt, causal=True, window=window,
        backend=cfg.kernel_backend if cfg.kernel_backend != "auto" else None,
        logit_soft_cap=cfg.logit_soft_cap,
    )
    out = _hint("attn_q", out)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"])


def _require_fp_cache(cfg: ModelConfig, layout: str):
    if cfg.kv_dtype is not None:
        raise ValueError(
            f"kv_dtype={cfg.kv_dtype!r} requires a paged cache layout; "
            f"the {layout} cache stores {cfg.dtype} only"
        )


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window=None):
    _require_fp_cache(cfg, "contiguous")
    size = min(max_len, window) if window else max_len
    return {
        "k": jnp.zeros((batch, cfg.num_kv_heads, size, cfg.head_dim), cfg.dtype),
        "v": jnp.zeros((batch, cfg.num_kv_heads, size, cfg.head_dim), cfg.dtype),
    }


def init_paged_kv_cache(cfg: ModelConfig, num_blocks: int, page_size: int):
    """Page pool for one layer: ``(kv_heads, num_blocks, page_size, head_dim)``.

    Unlike the contiguous cache there is no per-layer ring sizing — sliding
    windows are enforced by the attention mask over gathered pages, so every
    layer shares one pool geometry.  (Layer *stacking* still requires
    uniform windows: the scanned decode body bakes the window statically.)

    With ``cfg.kv_dtype`` set ("int8"/"int4") the pools store packed int8
    bytes plus per-row scales (``*_scale_pages``, fp, shape ``(..., ps, 1)``).
    Scale leaves keep the page axis at ``ndim - 3`` so the serving layer's
    ``copy_pages`` COW treats them like any other ``*_pages`` leaf."""
    if cfg.kv_dtype is not None:
        pack = ref.KV_PACK[cfg.kv_dtype]
        pshape = (cfg.num_kv_heads, num_blocks, page_size, cfg.head_dim // pack)
        sshape = (cfg.num_kv_heads, num_blocks, page_size, 1)
        return {
            "k_pages": jnp.zeros(pshape, jnp.int8),
            "v_pages": jnp.zeros(pshape, jnp.int8),
            "k_scale_pages": jnp.zeros(sshape, cfg.dtype),
            "v_scale_pages": jnp.zeros(sshape, cfg.dtype),
        }
    shape = (cfg.num_kv_heads, num_blocks, page_size, cfg.head_dim)
    return {
        "k_pages": jnp.zeros(shape, cfg.dtype),
        "v_pages": jnp.zeros(shape, cfg.dtype),
    }


def attention_decode_paged(params, x, cfg: ModelConfig, cache, pos, tables,
                           window=None, rope_fraction=1.0):
    """One-token decode against a paged KV pool.

    ``tables`` is the (B, max_pages) int32 block table (padded with page 0);
    ``pos`` is the absolute position per slot.  The new K/V land in the page
    holding position ``pos`` (scattered per slot through the table), then the
    query attends over the gathered pages with a ragged length mask."""
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _qkv(params, x, cfg)  # (b, 1, ...)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    posv = posb[:, None]
    q = apply_rope(q, posv, cfg.rope_theta, rope_fraction)
    k = apply_rope(k, posv, cfg.rope_theta, rope_fraction)
    page_size = cache["k_pages"].shape[2]
    logical = posb // page_size
    offset = posb % page_size
    phys = jnp.take_along_axis(tables, logical[:, None], axis=1)[:, 0]
    backend = cfg.kernel_backend if cfg.kernel_backend != "auto" else None
    if cfg.kv_dtype is not None:
        # Quantize the appended row per (head, slot) and scatter packed bytes
        # plus the per-token scale; attention dequantizes inline at gather.
        sdt = cache["k_scale_pages"].dtype
        kq, ks = ref.quantize_rows(k[:, 0].transpose(1, 0, 2), cfg.kv_dtype)
        vq, vs = ref.quantize_rows(v[:, 0].transpose(1, 0, 2), cfg.kv_dtype)
        knew = cache["k_pages"].at[:, phys, offset].set(kq)
        vnew = cache["v_pages"].at[:, phys, offset].set(vq)
        ksnew = cache["k_scale_pages"].at[:, phys, offset].set(ks.astype(sdt))
        vsnew = cache["v_scale_pages"].at[:, phys, offset].set(vs.astype(sdt))
        out = ops.paged_attention_quant(
            q[:, 0], knew, vnew, ksnew, vsnew, tables, posb + 1,
            fmt=cfg.kv_dtype, window=window,
            logit_soft_cap=cfg.logit_soft_cap, backend=backend,
        )
        out = out.reshape(b, 1, h * hd)
        proj = jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"])
        return proj, {"k_pages": knew, "v_pages": vnew,
                      "k_scale_pages": ksnew, "v_scale_pages": vsnew}
    # (b, 1, hkv, hd) -> (hkv, b, hd) scatter rows into their pages
    kdt = cache["k_pages"].dtype
    knew = cache["k_pages"].at[:, phys, offset].set(
        k[:, 0].transpose(1, 0, 2).astype(kdt)
    )
    vnew = cache["v_pages"].at[:, phys, offset].set(
        v[:, 0].transpose(1, 0, 2).astype(kdt)
    )
    out = ops.paged_attention(
        q[:, 0], knew, vnew, tables, posb + 1, window=window,
        logit_soft_cap=cfg.logit_soft_cap, backend=backend,
    )
    out = out.reshape(b, 1, h * hd)
    proj = jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"])
    return proj, {"k_pages": knew, "v_pages": vnew}


def attention_prefill_paged(params, x, cfg: ModelConfig, cache, pos, tables,
                            lens, window=None, rope_fraction=1.0):
    """Chunk-wide prefill against a paged KV pool.

    ``x`` is a (B, C, d) block of prompt tokens per slot; ``pos`` (B,) is
    each slot's chunk start (its prior resident length), ``lens`` (B,) the
    live tokens within the chunk (0 = slot not prefilling this tick).  The
    chunk's K/V land in the pages holding positions [pos, pos+lens) through
    the block table (inside the tile kernel on the Pallas path; a masked
    scatter on XLA), and every chunk query attends prior pages plus the
    chunk causally."""
    b, c, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _qkv(params, x, cfg)  # (b, c, ...)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    posmat = posb[:, None] + jnp.arange(c, dtype=jnp.int32)
    q = apply_rope(q, posmat, cfg.rope_theta, rope_fraction)
    k = apply_rope(k, posmat, cfg.rope_theta, rope_fraction)
    backend = cfg.kernel_backend if cfg.kernel_backend != "auto" else None
    if cfg.kv_dtype is not None:
        out, kp, vp, ksp, vsp = ops.prefill_attention_quant(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), cache["k_pages"], cache["v_pages"],
            cache["k_scale_pages"], cache["v_scale_pages"],
            tables, posb, jnp.asarray(lens, jnp.int32), fmt=cfg.kv_dtype,
            window=window, logit_soft_cap=cfg.logit_soft_cap, backend=backend,
        )
        out = out.transpose(0, 2, 1, 3).reshape(b, c, h * hd)
        proj = jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"])
        return proj, {"k_pages": kp, "v_pages": vp,
                      "k_scale_pages": ksp, "v_scale_pages": vsp}
    out, kp, vp = ops.prefill_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), cache["k_pages"], cache["v_pages"],
        tables, posb, jnp.asarray(lens, jnp.int32), window=window,
        logit_soft_cap=cfg.logit_soft_cap, backend=backend,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, c, h * hd)
    proj = jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"])
    return proj, {"k_pages": kp, "v_pages": vp}


def attention_prefill(params, x, cfg: ModelConfig, cache, pos, lens,
                      window=None, rope_fraction=1.0):
    """Chunk-wide prefill against the contiguous cache (ring buffers for
    sliding-window layers).  Same contract as :func:`attention_prefill_paged`
    with the prior context read from the per-slot strip: attention runs
    against the strip *before* the chunk overwrites any ring entries, so
    queries early in the chunk still see context the chunk's own tail would
    evict."""
    b, c, _ = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    q, k, v = _qkv(params, x, cfg)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    lens = jnp.asarray(lens, jnp.int32)
    posmat = posb[:, None] + jnp.arange(c, dtype=jnp.int32)
    q = apply_rope(q, posmat, cfg.rope_theta, rope_fraction)
    k = apply_rope(k, posmat, cfg.rope_theta, rope_fraction)
    size = cache["k"].shape[2]
    r = jnp.arange(size, dtype=jnp.int32)[None, :]  # (1, S)
    if window:
        # ring entry r holds the latest position p < pos with p % size == r
        sm1 = posb[:, None] - 1
        p = sm1 - ((sm1 - r) % size)
        ctx_pos = jnp.where((posb[:, None] > 0) & (p >= 0), p, -1)
    else:
        ctx_pos = jnp.where(r < posb[:, None], r, -1)
    out = ref.prefill_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), cache["k"], cache["v"], ctx_pos, posmat,
        lens, window=window, logit_soft_cap=cfg.logit_soft_cap,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, c, h * hd)
    proj = jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"])
    # Write the chunk into the strip/ring as a gather-select over cache
    # entries (no scatter): entry r takes chunk token c(r) when live.  For
    # rings c(r) is the *latest* chunk index mapping to r, so a chunk longer
    # than the ring correctly keeps only its last `size` tokens.
    rel = jnp.arange(size, dtype=jnp.int32)[None, :] - posb[:, None]  # (B,S)
    if window:
        base = rel % size  # ring: c == base (mod size)
        cidx = base + ((lens[:, None] - 1 - base) // size) * size
    else:
        cidx = rel
    live = (cidx >= 0) & (cidx < lens[:, None])
    cg = jnp.clip(cidx, 0, c - 1)[:, None, :, None]  # (B,1,S,1)
    cdt = cache["k"].dtype
    kt = k.transpose(0, 2, 1, 3).astype(cdt)  # (B, Hkv, C, hd)
    vt = v.transpose(0, 2, 1, 3).astype(cdt)
    sel = live[:, None, :, None]
    knew = jnp.where(sel, jnp.take_along_axis(kt, cg, axis=2), cache["k"])
    vnew = jnp.where(sel, jnp.take_along_axis(vt, cg, axis=2), cache["v"])
    return proj, {"k": knew, "v": vnew}


def attention_decode(params, x, cfg: ModelConfig, cache, pos, window=None,
                     rope_fraction=1.0):
    """One-token decode.  ``pos`` is the absolute position — a scalar (lockstep
    batch) or an (B,) vector (continuous batching: every slot at its own
    position).  The cache is contiguous, or a ring buffer when ``window``."""
    b = x.shape[0]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _qkv(params, x, cfg)  # (b, 1, ...)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    posv = posb[:, None]
    q = apply_rope(q, posv, cfg.rope_theta, rope_fraction)
    k = apply_rope(k, posv, cfg.rope_theta, rope_fraction)
    size = cache["k"].shape[2]
    slot = (posb % size) if window else jnp.minimum(posb, size - 1)

    def upd(c, u, s):  # per-batch-row dynamic update at its own slot
        return jax.lax.dynamic_update_slice(c, u, (0, s, 0))

    knew = jax.vmap(upd)(cache["k"], k.transpose(0, 2, 1, 3), slot)
    vnew = jax.vmap(upd)(cache["v"], v.transpose(0, 2, 1, 3), slot)
    kv_len = jnp.minimum(posb + 1, size)
    qt = q.transpose(0, 2, 1, 3)
    out = ref.attention(
        qt, knew, vnew, causal=False, kv_len=kv_len,
        logit_soft_cap=cfg.logit_soft_cap,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    proj = jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["wo"])
    return proj, {"k": knew, "v": vnew}


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = _split(key, 6)
    qd = h * (m.qk_nope_head_dim + m.qk_rope_head_dim)
    p = {
        "w_dkv": _dense_init(ks[0], d, m.kv_lora_rank, cfg.dtype),
        "w_kpe": _dense_init(ks[1], d, m.qk_rope_head_dim, cfg.dtype),
        "w_uk": _dense_init(ks[2], m.kv_lora_rank, h * m.qk_nope_head_dim, cfg.dtype),
        "w_uv": _dense_init(ks[3], m.kv_lora_rank, h * m.v_head_dim, cfg.dtype),
        "w_o": _dense_init(ks[4], h * m.v_head_dim, d, cfg.dtype),
        "w_q": _dense_init(ks[5], d, qd, cfg.dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), cfg.dtype),
    }
    return p


def mla_full(params, x, cfg: ModelConfig, positions):
    """Training/prefill MLA: expand the latent into per-head K/V."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q = jnp.einsum("bsd,de->bse", x, params["w_q"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_pe = apply_rope(q_pe, positions, cfg.rope_theta)
    c_kv = rmsnorm(jnp.einsum("bsd,de->bse", x, params["w_dkv"]), params["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(
        jnp.einsum("bsd,de->bse", x, params["w_kpe"]), positions, cfg.rope_theta
    )
    k_nope = jnp.einsum("bsr,re->bse", c_kv, params["w_uk"]).reshape(
        b, s, h, m.qk_nope_head_dim
    )
    v = jnp.einsum("bsr,re->bse", c_kv, params["w_uv"]).reshape(b, s, h, m.v_head_dim)
    k_pe_h = jnp.broadcast_to(k_pe[:, :, None, :], (b, s, h, m.qk_rope_head_dim))
    qfull = jnp.concatenate([q_nope, q_pe], axis=-1).transpose(0, 2, 1, 3)
    kfull = jnp.concatenate([k_nope, k_pe_h], axis=-1).transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    sm = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    out = ops.attention(
        qfull, kfull, vt, causal=True, sm_scale=sm,
        backend=cfg.kernel_backend if cfg.kernel_backend != "auto" else None,
    )
    out = out.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    return jnp.einsum("bse,ed->bsd", out.astype(x.dtype), params["w_o"])


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    _require_fp_cache(cfg, "contiguous latent")
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, 1, m.kv_lora_rank), cfg.dtype),
        "k_pe": jnp.zeros((batch, max_len, 1, m.qk_rope_head_dim), cfg.dtype),
    }


def init_mla_paged_cache(cfg: ModelConfig, num_blocks: int, page_size: int):
    """Latent page pools for one layer: the latent is shared by every query
    head, so pages carry no head axis — ``(num_blocks, page_size, rank)``
    plus the rope part.  The per-token footprint is ``rank + rope_dim``
    instead of ``2 * heads * head_dim``: latent paging keeps MLA's KV
    compression through the block pool.

    With ``cfg.kv_dtype`` set the latent and rope pools store packed int8
    plus per-row scale pools, same contract as :func:`init_paged_kv_cache`."""
    m = cfg.mla
    if cfg.kv_dtype is not None:
        pack = ref.KV_PACK[cfg.kv_dtype]
        return {
            "ckv_pages": jnp.zeros(
                (num_blocks, page_size, m.kv_lora_rank // pack), jnp.int8),
            "kpe_pages": jnp.zeros(
                (num_blocks, page_size, m.qk_rope_head_dim // pack), jnp.int8),
            "ckv_scale_pages": jnp.zeros((num_blocks, page_size, 1), cfg.dtype),
            "kpe_scale_pages": jnp.zeros((num_blocks, page_size, 1), cfg.dtype),
        }
    return {
        "ckv_pages": jnp.zeros((num_blocks, page_size, m.kv_lora_rank), cfg.dtype),
        "kpe_pages": jnp.zeros((num_blocks, page_size, m.qk_rope_head_dim), cfg.dtype),
    }


def _mla_absorbed_q(params, q_nope, cfg: ModelConfig):
    """Absorb W_uk into the queries: latent-space scoring (Fig. 18)."""
    m = cfg.mla
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, cfg.num_heads, m.qk_nope_head_dim)
    return jnp.einsum(
        "...hn,rhn->...hr", q_nope.astype(jnp.float32), w_uk.astype(jnp.float32)
    )


def _mla_out_proj(params, out_lat, x_dtype, cfg: ModelConfig):
    """Expand latent outputs through W_uv and project with W_o."""
    m = cfg.mla
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, cfg.num_heads, m.v_head_dim)
    out = jnp.einsum(
        "...hr,rhv->...hv", out_lat.astype(jnp.float32), w_uv.astype(jnp.float32)
    )
    out = out.reshape(*out.shape[:-2], cfg.num_heads * m.v_head_dim).astype(x_dtype)
    return jnp.einsum("...e,ed->...d", out, params["w_o"])


def mla_decode(params, x, cfg: ModelConfig, cache, pos, window=None):
    """Latent-cache decode: absorb W_uk into q and attend in latent space —
    the FlashMLA serving path (paper Fig. 18), backed by our MLA kernel."""
    m = cfg.mla
    b = x.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q_nope, q_pe, c_kv, k_pe = _mla_decode_qkv(params, x, cfg, posb[:, None])

    def upd(c, u, s):  # per-row write at its own position
        return jax.lax.dynamic_update_slice(c, u, (s, 0, 0))

    cache_ckv = jax.vmap(upd)(cache["c_kv"], c_kv[:, None, None, :], posb)
    cache_kpe = jax.vmap(upd)(cache["k_pe"], k_pe[:, :, None, :], posb)
    q_lat = _mla_absorbed_q(params, q_nope, cfg)
    sm = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    # attend over the latent cache (mask positions beyond pos via kv_len)
    out_lat = ref.mla_masked(
        q_lat.astype(cfg.dtype), q_pe.astype(cfg.dtype),
        cache_ckv[:, :, 0], cache_kpe[:, :, 0], pos + 1, sm,
        window=window, logit_soft_cap=cfg.logit_soft_cap,
    )
    proj = _mla_out_proj(params, out_lat, x.dtype, cfg)[:, None]
    return proj, {"c_kv": cache_ckv, "k_pe": cache_kpe}


def _mla_decode_qkv(params, x, cfg: ModelConfig, posv):
    """Shared single-token MLA projections: absorbed latent queries, rotated
    rope queries, and the token's latent/rope cache entries."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    q = jnp.einsum("bsd,de->bse", x, params["w_q"]).reshape(
        b, h, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_pe = apply_rope(
        q_pe.reshape(b, 1, h, m.qk_rope_head_dim), posv, cfg.rope_theta
    ).reshape(b, h, m.qk_rope_head_dim)
    c_kv = rmsnorm(
        jnp.einsum("bd,de->be", x[:, 0], params["w_dkv"]), params["kv_norm"], cfg.norm_eps
    )
    k_pe = apply_rope(
        jnp.einsum("bd,de->be", x[:, 0], params["w_kpe"]).reshape(b, 1, -1),
        posv,
        cfg.rope_theta,
    )
    return q_nope, q_pe, c_kv, k_pe


def mla_decode_paged(params, x, cfg: ModelConfig, cache, pos, tables,
                     window=None):
    """One-token MLA decode against the **latent page pools** — the paged
    twin of :func:`mla_decode`.  The token's latent/rope entries are
    scattered into the page holding position ``pos`` through the block
    table, then the absorbed queries attend the gathered pages with a
    ragged length mask (ops.mla_paged: the paged MLA tile kernel, or its
    oracle on XLA hosts)."""
    m = cfg.mla
    b = x.shape[0]
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    q_nope, q_pe, c_kv, k_pe = _mla_decode_qkv(params, x, cfg, posb[:, None])
    page_size = cache["ckv_pages"].shape[1]
    logical = posb // page_size
    offset = posb % page_size
    phys = jnp.take_along_axis(tables, logical[:, None], axis=1)[:, 0]
    q_lat = _mla_absorbed_q(params, q_nope, cfg)
    sm = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    backend = cfg.kernel_backend if cfg.kernel_backend != "auto" else None
    if cfg.kv_dtype is not None:
        sdt = cache["ckv_scale_pages"].dtype
        cq, cs = ref.quantize_rows(c_kv, cfg.kv_dtype)
        pq, ps = ref.quantize_rows(k_pe[:, 0], cfg.kv_dtype)
        ckv_pages = cache["ckv_pages"].at[phys, offset].set(cq)
        kpe_pages = cache["kpe_pages"].at[phys, offset].set(pq)
        ckv_scales = cache["ckv_scale_pages"].at[phys, offset].set(cs.astype(sdt))
        kpe_scales = cache["kpe_scale_pages"].at[phys, offset].set(ps.astype(sdt))
        out_lat = ops.mla_paged_quant(
            q_lat.astype(cfg.dtype), q_pe.astype(cfg.dtype), ckv_pages,
            kpe_pages, ckv_scales, kpe_scales, tables, posb + 1,
            fmt=cfg.kv_dtype, sm_scale=sm, window=window,
            logit_soft_cap=cfg.logit_soft_cap, backend=backend,
        )
        proj = _mla_out_proj(params, out_lat, x.dtype, cfg)[:, None]
        return proj, {"ckv_pages": ckv_pages, "kpe_pages": kpe_pages,
                      "ckv_scale_pages": ckv_scales,
                      "kpe_scale_pages": kpe_scales}
    cdt = cache["ckv_pages"].dtype
    ckv_pages = cache["ckv_pages"].at[phys, offset].set(c_kv.astype(cdt))
    kpe_pages = cache["kpe_pages"].at[phys, offset].set(k_pe[:, 0].astype(cdt))
    out_lat = ops.mla_paged(
        q_lat.astype(cfg.dtype), q_pe.astype(cfg.dtype), ckv_pages, kpe_pages,
        tables, posb + 1, sm_scale=sm, window=window,
        logit_soft_cap=cfg.logit_soft_cap, backend=backend,
    )
    proj = _mla_out_proj(params, out_lat, x.dtype, cfg)[:, None]
    return proj, {"ckv_pages": ckv_pages, "kpe_pages": kpe_pages}


def _mla_prefill_qkv(params, x, cfg: ModelConfig, posmat):
    """Shared chunk-wide MLA projections for the prefill paths."""
    m = cfg.mla
    b, c, _ = x.shape
    h = cfg.num_heads
    q = jnp.einsum("bsd,de->bse", x, params["w_q"]).reshape(
        b, c, h, m.qk_nope_head_dim + m.qk_rope_head_dim
    )
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_pe = apply_rope(q_pe, posmat, cfg.rope_theta)
    c_kv = rmsnorm(
        jnp.einsum("bsd,de->bse", x, params["w_dkv"]), params["kv_norm"], cfg.norm_eps
    )
    k_pe = apply_rope(
        jnp.einsum("bsd,de->bse", x, params["w_kpe"]), posmat, cfg.rope_theta
    )
    q_lat = _mla_absorbed_q(params, q_nope, cfg)  # (b, c, h, r)
    sm = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    # (b, h, c, ·) for the kernels/oracles
    return (q_lat.transpose(0, 2, 1, 3), q_pe.transpose(0, 2, 1, 3),
            c_kv, k_pe, sm)


def mla_prefill_paged(params, x, cfg: ModelConfig, cache, pos, tables, lens,
                      window=None):
    """Chunk-wide MLA prefill against the latent page pools.  Same contract
    as :func:`attention_prefill_paged` — the chunk's latents land in the
    pages holding positions [pos, pos+lens) through the block table (inside
    the tile kernel on the Pallas path; a masked scatter on XLA), and every
    chunk query attends prior pages plus the chunk causally, all in latent
    space."""
    b, c, _ = x.shape
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    posmat = posb[:, None] + jnp.arange(c, dtype=jnp.int32)
    q_lat, q_pe, c_kv, k_pe, sm = _mla_prefill_qkv(params, x, cfg, posmat)
    backend = cfg.kernel_backend if cfg.kernel_backend != "auto" else None
    if cfg.kv_dtype is not None:
        out_lat, ckv_pages, kpe_pages, ckv_scales, kpe_scales = (
            ops.mla_prefill_quant(
                q_lat.astype(cfg.dtype), q_pe.astype(cfg.dtype), c_kv, k_pe,
                cache["ckv_pages"], cache["kpe_pages"],
                cache["ckv_scale_pages"], cache["kpe_scale_pages"],
                tables, posb, jnp.asarray(lens, jnp.int32), fmt=cfg.kv_dtype,
                sm_scale=sm, window=window,
                logit_soft_cap=cfg.logit_soft_cap, backend=backend,
            )
        )
        proj = _mla_out_proj(params, out_lat.transpose(0, 2, 1, 3), x.dtype, cfg)
        return proj, {"ckv_pages": ckv_pages, "kpe_pages": kpe_pages,
                      "ckv_scale_pages": ckv_scales,
                      "kpe_scale_pages": kpe_scales}
    out_lat, ckv_pages, kpe_pages = ops.mla_prefill(
        q_lat.astype(cfg.dtype), q_pe.astype(cfg.dtype), c_kv, k_pe,
        cache["ckv_pages"], cache["kpe_pages"], tables, posb,
        jnp.asarray(lens, jnp.int32), sm_scale=sm, window=window,
        logit_soft_cap=cfg.logit_soft_cap, backend=backend,
    )
    proj = _mla_out_proj(params, out_lat.transpose(0, 2, 1, 3), x.dtype, cfg)
    return proj, {"ckv_pages": ckv_pages, "kpe_pages": kpe_pages}


def mla_prefill(params, x, cfg: ModelConfig, cache, pos, lens, window=None):
    """Chunk-wide MLA prefill against the contiguous latent strips — the
    latent twin of :func:`attention_prefill`.  The strip stays full-length
    (no ring variant): a sliding ``window`` only masks scores.  Prior
    context comes from the per-slot strip; the chunk is written back as a
    gather-select (no scatter)."""
    b, c, _ = x.shape
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    lens = jnp.asarray(lens, jnp.int32)
    posmat = posb[:, None] + jnp.arange(c, dtype=jnp.int32)
    q_lat, q_pe, c_kv, k_pe, sm = _mla_prefill_qkv(params, x, cfg, posmat)
    size = cache["c_kv"].shape[1]
    r = jnp.arange(size, dtype=jnp.int32)[None, :]  # (1, S)
    ctx_pos = jnp.where(r < posb[:, None], r, -1)
    out_lat = ref.mla_prefill(
        q_lat.astype(cfg.dtype), q_pe.astype(cfg.dtype), c_kv, k_pe,
        cache["c_kv"][:, :, 0], cache["k_pe"][:, :, 0], ctx_pos, posmat,
        lens, sm_scale=sm, window=window, logit_soft_cap=cfg.logit_soft_cap,
    )
    proj = _mla_out_proj(params, out_lat.transpose(0, 2, 1, 3), x.dtype, cfg)
    # write the chunk into the strip as a gather-select over cache entries
    rel = r - posb[:, None]  # (B, S)
    live = (rel >= 0) & (rel < lens[:, None])
    cg = jnp.clip(rel, 0, c - 1)[:, :, None]  # (B, S, 1)
    cdt = cache["c_kv"].dtype
    sel = live[:, :, None, None]
    ckv_new = jnp.where(
        sel,
        jnp.take_along_axis(c_kv.astype(cdt), cg, axis=1)[:, :, None, :],
        cache["c_kv"],
    )
    kpe_new = jnp.where(
        sel,
        jnp.take_along_axis(k_pe.astype(cdt), cg, axis=1)[:, :, None, :],
        cache["k_pe"],
    )
    return proj, {"c_kv": ckv_new, "k_pe": kpe_new}


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff=None) -> Params:
    d_ff = d_ff or cfg.d_ff
    ks = _split(key, 3)
    if cfg.act in ("silu", "geglu"):
        return {
            "w_gate": _dense_init(ks[0], cfg.d_model, d_ff, cfg.dtype),
            "w_up": _dense_init(ks[1], cfg.d_model, d_ff, cfg.dtype),
            "w_down": _dense_init(ks[2], d_ff, cfg.d_model, cfg.dtype),
        }
    return {
        "w_up": _dense_init(ks[0], cfg.d_model, d_ff, cfg.dtype),
        "w_down": _dense_init(ks[1], d_ff, cfg.d_model, cfg.dtype),
    }


def mlp(params: Params, x, cfg: ModelConfig):
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        u = jnp.einsum("...d,df->...f", x, params["w_up"])
        act = jax.nn.gelu(g) if cfg.act == "geglu" else jax.nn.silu(g)
        h = act * u
    else:
        h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, params["w_up"]))
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


# ---------------------------------------------------------------------------
# MoE (GShard-style capacity dispatch; EP- or TP-shardable expert weights)
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> Params:
    mo = cfg.moe
    d, fe, e = cfg.d_model, mo.d_ff_expert, mo.num_experts
    ks = _split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": _dense_init(ks[0], d, e, "float32"),
        "w_gate": (jax.random.normal(ks[1], (e, d, fe), jnp.float32) * scale).astype(cfg.dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, fe), jnp.float32) * scale).astype(cfg.dtype),
        "w_down": (jax.random.normal(ks[3], (e, fe, d), jnp.float32) / math.sqrt(fe)).astype(cfg.dtype),
    }
    if mo.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=mo.num_shared_experts * fe)
    return p


def _moe_groups(t: int, batch: int) -> int:
    """Dispatch-group count: groups align with the data-parallel shards so
    every scatter/gather is shard-local (GShard grouping).  Must divide t."""
    for g in (16, 8, 4, 2):
        if t % g == 0 and t // g >= 1:
            return g
    return 1


def moe(params: Params, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).

    Capacity-based top-k routing with **grouped scatter/gather dispatch**
    (GShard grouping): tokens are split into G groups (aligned with the
    data shards), each group scatters into its own (E, cap_g) expert
    buffers via a vmapped (batched) scatter — so the SPMD partitioner sees
    a scatter with a leading batch dim and never rewrites it into a
    cross-shard one-hot contraction.  Expert buffers (G, E, cap_g, D) shard
    G over data and E over `model` (EP) when E divides; dispatch cost stays
    O(T·k·D) and all shapes are static."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = mo.num_experts, mo.experts_per_token
    G = _moe_groups(t, b)
    tg = t // G
    cap = max(1, int(mo.capacity_factor * tg * k / e))
    xg = x.reshape(G, tg, d)
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, tg, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # position of each (token, slot) within its expert queue, per group
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # (G, tg, k, e)
    flat = onehot.reshape(G, tg * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(pos_in_expert * flat, axis=-1)  # (G, tg*k)
    keep = (pos < cap).astype(x.dtype)
    slot = jnp.clip(pos, 0, cap - 1)
    eidx = gate_idx.reshape(G, tg * k)

    # batched scatter: every group's tokens land in its own expert buffers
    updates = (
        xg[:, :, None, :] * keep.reshape(G, tg, k)[..., None]
    ).reshape(G, tg * k, d)

    def scatter_one(ei, sl, upd):
        buf = jnp.zeros((e, cap, d), x.dtype)
        return buf.at[ei, sl].add(upd, mode="drop")

    expert_in = jax.vmap(scatter_one)(eidx, slot, updates)  # (G, e, cap, d)
    expert_in = _hint("moe_expert", expert_in)
    g_ = jnp.einsum("gecd,edf->gecf", expert_in, params["w_gate"])
    u = jnp.einsum("gecd,edf->gecf", expert_in, params["w_up"])
    h = jax.nn.silu(g_) * u
    expert_out = _hint(
        "moe_expert", jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    )

    def gather_one(buf, ei, sl):
        return buf[ei, sl]  # (tg*k, d)

    gathered = jax.vmap(gather_one)(expert_out, eidx, slot)
    wts = (gate_vals.reshape(G, tg * k) * keep)[..., None].astype(gathered.dtype)
    out = jnp.sum((gathered * wts).reshape(G, tg, k, d), axis=2)
    out = out.reshape(t, d)
    if "shared" in params:
        out = out + mlp(params["shared"], x.reshape(t, d), cfg)
    # load-balance auxiliary loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1)
    )
    density_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_prob) * e * mo.router_aux_weight
    return out.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) layer
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig) -> Params:
    # separate projections (not one fused in_proj) so each shards cleanly:
    # z/x column-parallel over d_inner, B/C/dt small (replicated or sharded)
    sm = cfg.ssm
    d = cfg.d_model
    di = sm.d_inner(d)
    nh = sm.num_heads(d)
    conv_dim = di + 2 * sm.state_dim
    ks = _split(key, 7)
    return {
        "w_z": _dense_init(ks[0], d, di, cfg.dtype),
        "w_x": _dense_init(ks[1], d, di, cfg.dtype),
        "w_B": _dense_init(ks[2], d, sm.state_dim, cfg.dtype),
        "w_C": _dense_init(ks[3], d, sm.state_dim, cfg.dtype),
        "w_dt": _dense_init(ks[4], d, nh, cfg.dtype),
        "conv_w": (jax.random.normal(ks[5], (sm.conv_width, conv_dim), jnp.float32) * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "a_log": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), cfg.dtype),
        "out_proj": _dense_init(ks[6], di, d, cfg.dtype),
    }


def _mamba_proj(params, x):
    z = jnp.einsum("...d,de->...e", x, params["w_z"])
    xin = jnp.einsum("...d,de->...e", x, params["w_x"])
    B = jnp.einsum("...d,de->...e", x, params["w_B"])
    C = jnp.einsum("...d,de->...e", x, params["w_C"])
    dt = jnp.einsum("...d,de->...e", x, params["w_dt"])
    return z, xin, B, C, dt


def _causal_conv(x, w, b):
    """x: (B, S, C); depthwise causal conv, width W."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + b)


def mamba2_full(params: Params, x, cfg: ModelConfig):
    sm = cfg.ssm
    b, s, d = x.shape
    di = sm.d_inner(d)
    nh = sm.num_heads(d)
    z, xin, B, C, dt = _mamba_proj(params, x)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_out = _causal_conv(conv_in, params["conv_w"], params["conv_b"])
    xin, B, C = jnp.split(conv_out, [di, di + sm.state_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b,s,nh)
    # fold heads into batch for the SSD kernels
    xh = xin.reshape(b, s, nh, sm.head_dim).transpose(0, 2, 1, 3).reshape(b * nh, s, sm.head_dim)
    Bh = jnp.broadcast_to(B[:, None], (b, nh, s, sm.state_dim)).reshape(
        b * nh, s, sm.state_dim
    )
    Ch = jnp.broadcast_to(C[:, None], (b, nh, s, sm.state_dim)).reshape(
        b * nh, s, sm.state_dim
    )
    dth = dt.transpose(0, 2, 1).reshape(b * nh, s)
    a_log = jnp.broadcast_to(params["a_log"][None], (b, nh)).reshape(b * nh)
    chunk = min(sm.chunk, s)
    if s % chunk:
        chunk = math.gcd(s, chunk) or 1
    y = _ssd_batched(Ch, Bh, xh * dth[..., None].astype(xh.dtype), dth, a_log, chunk, cfg)
    y = y.reshape(b, nh, s, sm.head_dim)
    y = y + params["d_skip"][None, :, None, None] * xh.reshape(b, nh, s, sm.head_dim)
    y = y.transpose(0, 2, 1, 3).reshape(b, s, di)
    y = rmsnorm(y * jax.nn.silu(z), params["norm_w"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out_proj"])


def _ssd_batched(c, bm, x, dt, a_log, chunk, cfg: ModelConfig):
    """SSD with per-batch a_log (heads folded into batch)."""
    be = cfg.kernel_backend if cfg.kernel_backend != "auto" else None
    bsz, s, n = c.shape
    p = x.shape[-1]
    nc = s // chunk
    rs = lambda t: t.reshape(bsz, nc, chunk, *t.shape[2:])
    da = dt * (-jnp.exp(a_log))[:, None]
    da_cum = jnp.cumsum(da.reshape(bsz, nc, chunk), axis=-1)
    states = ops.chunk_state(rs(bm), rs(x), da_cum, backend=be)
    incoming = ref.state_recurrence(states, da_cum[..., -1])
    y = ops.chunk_scan(rs(c), rs(bm), rs(x), da_cum, incoming, backend=be)
    return y.reshape(bsz, s, p).astype(x.dtype)


def init_mamba2_cache(cfg: ModelConfig, batch: int):
    sm = cfg.ssm
    d = cfg.d_model
    nh = sm.num_heads(d)
    conv_dim = sm.d_inner(d) + 2 * sm.state_dim
    return {
        "ssm": jnp.zeros((batch, nh, sm.state_dim, sm.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, sm.conv_width - 1, conv_dim), cfg.dtype),
    }


def mamba2_decode(params: Params, x, cfg: ModelConfig, cache):
    """Single-token SSM recurrence: h = exp(dt*A) h + dt * B^T x ; y = C h."""
    sm = cfg.ssm
    b, _, d = x.shape
    di = sm.d_inner(d)
    nh = sm.num_heads(d)
    z, xin, B, C, dt = (p[:, 0] for p in _mamba_proj(params, x))
    conv_in = jnp.concatenate([xin, B, C], axis=-1)  # (b, conv_dim)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)
    w = params["conv_w"]
    conv_out = jax.nn.silu(
        jnp.sum(window * w[None], axis=1) + params["conv_b"]
    )
    xin, B, C = jnp.split(conv_out, [di, di + sm.state_dim], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (b, nh)
    xh = xin.reshape(b, nh, sm.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * (-jnp.exp(params["a_log"]))[None])  # (b, nh)
    upd = jnp.einsum("bn,bhp->bhnp", B.astype(jnp.float32), xh * dt[..., None])
    h = cache["ssm"] * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", C.astype(jnp.float32), h)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = rmsnorm(y * jax.nn.silu(z[:, None]).astype(jnp.float32), params["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), params["out_proj"])
    return out, {"ssm": h, "conv": window[:, 1:]}
