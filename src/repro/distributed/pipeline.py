"""Pipeline parallelism: GPipe-style microbatch schedule over a `stage` mesh
axis via shard_map + collective_permute.

At the assigned model sizes (1.5–26B on 256 chips), TP×DP covers memory and
compute comfortably, so PP is not enabled by default (DESIGN.md §5) — but a
1000+-node deployment adds a stage axis.  This wrapper shows the axis
composes with the rest of the stack: each stage holds a contiguous slice of
layers; activations rotate stage→stage+1 each tick; the standard GPipe
schedule runs M microbatches in M + P - 1 ticks.

`bubble_fraction` quantifies the schedule's idle time — the number the
1F1B/interleaved variants improve on.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def bubble_fraction(num_microbatches: int, num_stages: int) -> float:
    """GPipe bubble: (P-1) / (M + P - 1)."""
    m, p = num_microbatches, num_stages
    return (p - 1) / (m + p - 1)


def pipeline_forward(
    layer_params,  # pytree stacked on leading axis = num_stages*layers_per
    x,  # (M, micro_batch, ...) microbatched input
    block_fn: Callable,  # fn(params_slice, x) -> x, applied per stage
    mesh: Mesh,
    stage_axis: str = "stage",
):
    """Run the stacked layers as `num_stages` pipeline stages over
    microbatches, using shard_map + ppermute (the canonical JAX PP pattern).

    ``layer_params`` leaves must have leading dim divisible by the stage
    count; ``x`` must have leading dim = num_microbatches.
    """
    num_stages = mesh.shape[stage_axis]
    m = x.shape[0]

    def split_stages(p):
        return p.reshape(num_stages, p.shape[0] // num_stages, *p.shape[1:])

    staged = jax.tree.map(split_stages, layer_params)

    @functools.partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(P(stage_axis), P(None)),
        out_specs=P(None),
    )
    def run(stage_params, xs):
        # stage_params: (1, layers_per, ...) — this stage's slice
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        idx = jax.lax.axis_index(stage_axis)
        ticks = m + num_stages - 1
        # pvary: the carries become stage-varying after the first ppermute,
        # so the initial values must be marked stage-varying too.
        buf = jax.lax.pvary(jnp.zeros_like(xs[0]), stage_axis)
        outs = jax.lax.pvary(jnp.zeros_like(xs), stage_axis)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            feed = xs[jnp.clip(t, 0, m - 1)]
            buf = jnp.where(idx == 0, jnp.where(t < m, feed, buf), buf)
            # every stage applies its layers
            def apply_stage(b):
                def layer(h, p):
                    return block_fn(p, h), None
                h, _ = jax.lax.scan(layer, b, stage_params)
                return h
            buf = apply_stage(buf)
            # last stage emits microbatch t-(P-1)
            out_t = t - (num_stages - 1)
            emit = jnp.logical_and(idx == num_stages - 1, out_t >= 0)
            outs = jnp.where(
                emit,
                outs.at[jnp.clip(out_t, 0, m - 1)].set(buf),
                outs,
            )
            # rotate: stage i sends to stage i+1
            buf = jax.lax.ppermute(
                buf, stage_axis,
                [(i, (i + 1) % num_stages) for i in range(num_stages)],
            )
            return (buf, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(ticks))
        # only the last stage holds real outputs; psum-select them
        outs = jnp.where(idx == num_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, stage_axis)

    return run(staged, x)
