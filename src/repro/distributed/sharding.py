"""Sharding rules: param PartitionSpecs by pytree path, ZeRO-1 state specs,
and activation constraints (DP / TP / SP / EP on the (pod, data, model) mesh).

Rules (Megatron-style TP on `model`, pure DP over `pod`×`data`):

====================================  =======================================
param                                 spec
====================================  =======================================
embedding (V, D)                      (model, None)        vocab-sharded
unembed   (D, V)                      (None, model)
attn wq/wk/wv (D, H*hd)               (None, model)        column-parallel
attn wo (H*hd, D)                     (model, None)        row-parallel
mlp w_gate/w_up (D, F)                (None, model)
mlp w_down (F, D)                     (model, None)
moe experts (E, D, F)                 (model, None, None)  EP when E%model==0
                                      (None, None, model)  else TP-in-expert
mamba w_z/w_x (D, Di)                 (None, model)
mamba out_proj (Di, D)                (model, None)
norms / scalars / small projections   replicated
====================================  =======================================

ZeRO-1: optimizer state (fp32 masters + moments) additionally shards its
largest replicated axis over the data(+pod) axes when divisible.

Activations: batch over (pod, data); the residual stream between scanned
layers is additionally sequence-sharded over `model` (Megatron sequence
parallelism) so per-layer remat residuals shrink by the TP degree.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _divisible(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def param_spec(path: str, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh) -> P:
    """Sharding rule for a single parameter (path = '/'-joined pytree keys).

    Stacked (scanned) layer params carry a leading L axis -> the rule applies
    to the trailing dims and the layer axis stays unsharded.
    """
    tp = mesh_axis_size(mesh, "model")
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    def spec(*trailing):
        lead = (None,) * (len(shape) - len(trailing))
        # drop shardings that don't divide
        fixed = []
        for dim, ax in zip(shape[len(shape) - len(trailing):], trailing):
            if ax is None:
                fixed.append(None)
            else:
                fixed.append(ax if _divisible(dim, tp) else None)
        return P(*lead, *fixed)

    if name == "embedding":
        return spec("model", None)
    if name == "unembed":
        return spec(None, "model")
    if name in ("enc_pos", "dec_pos"):
        return P(*(None,) * len(shape))
    if name in ("wq", "wk", "wv", "w_q", "w_kpe", "w_dkv", "w_uk", "w_uv"):
        return spec(None, "model")
    if name in ("wo", "w_o"):
        return spec("model", None)
    if name in ("bq", "bk", "bv"):
        return spec("model")
    if name in ("w_gate", "w_up") and parent != "moe":
        return spec(None, "model")
    if name == "w_down" and parent != "moe":
        return spec("model", None)
    if parent == "moe" or (cfg.moe and name in ("w_gate", "w_up", "w_down") and len(shape) >= 3):
        # expert weights (.., E, D, F) / (.., E, F, D)
        e = shape[-3]
        if name == "router":
            return P(*(None,) * len(shape))
        if _divisible(e, tp):
            return spec("model", None, None)  # EP
        # TP inside the expert FFN
        if name in ("w_gate", "w_up"):
            return spec(None, None, "model")
        return spec(None, "model", None)
    if name == "router":
        return P(*(None,) * len(shape))
    if name in ("w_z", "w_x"):
        return spec(None, "model")
    if name == "out_proj":
        return spec("model", None)
    if name in ("w_B", "w_C", "w_dt"):
        return spec(None, "model")
    # norms, conv, scalars, biases: replicate
    return P(*(None,) * len(shape))


def param_specs(params_shapes, cfg: ModelConfig, mesh: Mesh):
    """Pytree of PartitionSpec matching a pytree of ShapeDtypeStruct/arrays."""

    def rule(path, leaf):
        return param_spec(_path_str(path), tuple(leaf.shape), cfg, mesh)

    return jax.tree_util.tree_map_with_path(rule, params_shapes)


def zero1_specs(opt_shapes, params_specs, mesh: Mesh):
    """ZeRO-1: shard fp32 masters/moments over the data(+pod) axes on the
    first axis that is unsharded and divisible."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh_axis_size(mesh, a) for a in dp])) if dp else 1

    def rule(spec: P, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return P()
        spec_t = tuple(spec) + (None,) * (len(leaf.shape) - len(tuple(spec)))
        out = list(spec_t)
        for i, (dim, ax) in enumerate(zip(leaf.shape, spec_t)):
            if ax is None and _divisible(dim, dp_size):
                out[i] = dp if len(dp) > 1 else dp[0]
                break
        return P(*out)

    def map_state(state_tree):
        return jax.tree.map(rule, params_specs, state_tree)

    return {
        "master": map_state(opt_shapes["master"]),
        "m": map_state(opt_shapes["m"]),
        "v": map_state(opt_shapes["v"]),
        "step": P(),
    }


# ---------------------------------------------------------------------------
# Activation constraints
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, batch: int) -> P:
    """Shard the batch dim over (pod, data) when divisible, else replicate."""
    dp = dp_axes(mesh)
    size = int(np.prod([mesh_axis_size(mesh, a) for a in dp])) if dp else 1
    if dp and _divisible(batch, size):
        return P(dp if len(dp) > 1 else dp[0])
    # try data alone (e.g. batch 32 on (2,16,16): 32 % 32 == 0 though)
    if "data" in mesh.axis_names and _divisible(batch, mesh_axis_size(mesh, "data")):
        return P("data")
    return P(None)


def tokens_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    b = batch_spec(mesh, batch)
    return P(*tuple(b), *(None,) * extra_dims)


def residual_spec(mesh: Mesh, batch: int, seq: int) -> P:
    """(B, S, D) residual-stream constraint: batch over dp, sequence over
    `model` (sequence parallelism) when divisible."""
    b = batch_spec(mesh, batch)
    seq_ax = "model" if _divisible(seq, mesh_axis_size(mesh, "model")) else None
    return P(*tuple(b), seq_ax, None)


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
