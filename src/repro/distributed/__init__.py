from . import sharding
