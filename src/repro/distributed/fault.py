"""Fault tolerance: failure injection, checkpoint/restart, straggler
detection, elastic re-meshing.

At 1000+ nodes the failure model is: some host dies mid-step (preemption,
ECC, ICI link flap).  The recovery contract here is the standard one —
synchronous SPMD training restarts the failed step from the last complete
checkpoint; stragglers are detected by deadline and surfaced to the
scheduler; elastic events re-mesh the same checkpoint onto a smaller/larger
data axis (pure ZeRO-1 state is resharded at restore time).

On this single-host container, failures and stragglers are *injected* so the
recovery paths are actually exercised by tests (tests/test_fault.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


class SimulatedNodeFailure(RuntimeError):
    """Injected stand-in for a lost host / device."""


@dataclasses.dataclass
class FaultConfig:
    failure_prob: float = 0.0  # per-step probability of injected failure
    straggler_prob: float = 0.0  # per-step probability of injected delay
    straggler_delay_s: float = 0.2
    deadline_factor: float = 3.0  # median multiplier before flagging
    seed: int = 0


class StragglerMonitor:
    """Deadline-based straggler detection over step wall times.

    A step slower than ``deadline_factor`` × median is flagged; the runner's
    policy (re-dispatch on real clusters, log here) is pluggable.
    """

    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.window = window
        self.times: List[float] = []
        self.flagged: List[int] = []

    def observe(self, step: int, seconds: float) -> bool:
        straggled = False
        if len(self.times) >= 5:
            med = float(np.median(self.times[-self.window:]))
            if seconds > self.factor * med:
                self.flagged.append(step)
                straggled = True
        self.times.append(seconds)
        return straggled


class FaultInjector:
    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.injected_failures = 0
        self.injected_stragglers = 0

    def before_step(self, step: int):
        if self.rng.random() < self.cfg.straggler_prob:
            self.injected_stragglers += 1
            time.sleep(self.cfg.straggler_delay_s)
        if self.rng.random() < self.cfg.failure_prob:
            self.injected_failures += 1
            raise SimulatedNodeFailure(f"injected failure at step {step}")


def run_with_recovery(
    train_step: Callable,
    state,
    loader_factory: Callable[[int], Any],
    steps: int,
    ckpt_manager,
    shardings=None,
    fault: Optional[FaultConfig] = None,
    max_restarts: int = 10,
) -> Dict[str, Any]:
    """The fault-tolerant training driver.

    ``loader_factory(step)`` must return a deterministic-resume iterator
    starting at ``step``.  On (injected) failure: restore the latest
    checkpoint, rebuild the loader at that step, continue.  Returns run
    metadata (restarts, straggler log, final state).
    """
    injector = FaultInjector(fault or FaultConfig())
    monitor = StragglerMonitor(
        factor=(fault or FaultConfig()).deadline_factor
    )
    step = 0
    restarts = 0
    ckpt_manager.maybe_save(state, 0, force=True)
    loader = loader_factory(0)
    metrics = None
    while step < steps:
        try:
            t0 = time.time()
            injector.before_step(step)
            batch = next(loader)
            state, metrics = train_step(state, batch)
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            monitor.observe(step, time.time() - t0)
            step += 1
            ckpt_manager.maybe_save(state, step)
        except SimulatedNodeFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            last = ckpt_manager.latest()
            state = ckpt_manager.restore(state, shardings=shardings, step=last)
            step = last
            if hasattr(loader, "close"):
                loader.close()
            loader = loader_factory(step)
    ckpt_manager.maybe_save(state, steps, force=True)
    if hasattr(loader, "close"):
        loader.close()
    return {
        "state": state,
        "steps": step,
        "restarts": restarts,
        "stragglers_flagged": monitor.flagged,
        "injected": {
            "failures": injector.injected_failures,
            "stragglers": injector.injected_stragglers,
        },
        "last_metrics": metrics,
    }


# ---------------------------------------------------------------------------
# Elastic re-meshing
# ---------------------------------------------------------------------------


def elastic_remesh(host_state, new_mesh, state_specs):
    """Re-place a (host) state pytree onto a different mesh.

    Because ZeRO-1 state sharding is *derived* from the mesh (zero1_specs),
    growing/shrinking the data axis is just a restore with the new mesh's
    NamedShardings — no tensor layout surgery.  ``state_specs`` must be the
    specs computed against ``new_mesh``.
    """
    from jax.sharding import NamedSharding

    def place(x, spec):
        return jax.device_put(np.asarray(x), NamedSharding(new_mesh, spec))

    return jax.tree.map(place, host_state, state_specs)
