"""Cost-model-driven configuration search (paper §6 future direction).

Because TileLang exposes thread mapping, memory access and compute behavior
explicitly, a static cost model is enough to rank configurations without
running them — exactly the property the paper argues for.  We exploit it:
``lower.compile`` records a :class:`KernelCost` (FLOPs, HBM bytes, VMEM
footprint, grid) and the inference pass records padding waste and MXU
utilization; :func:`autotune` combines them into a roofline-style score and
returns the best-scoring feasible config.

This is *structural* tuning (no hardware timing needed): the same mechanism
the dry-run roofline uses, applied at kernel granularity.  Scores are cached
per (program-name, shapes, config) so kernel libraries with dynamic shape
sets amortize the search — the TPU analogue of the paper's "dynamic parameter
simplification" for kernel libraries.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .errors import ScheduleError, TileError
from .lower import CompiledKernel, compile as tl_compile
from .schedule import Schedule

# TPU v5e hardware constants (also used by repro.roofline).
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12  # MXU int8 path is 2x bf16
HBM_BW = 819e9


def peak_flops_for(dtype: str) -> float:
    return PEAK_FLOPS_INT8 if "int8" in dtype or "int4" in dtype else PEAK_FLOPS_BF16


@dataclasses.dataclass
class Candidate:
    config: Dict[str, Any]
    score: float  # estimated seconds (lower is better)
    compute_s: float
    memory_s: float
    mxu_util: float
    pad_waste: float
    feasible: bool
    reason: str = ""


_CACHE: Dict[Tuple, "Candidate"] = {}


def score_kernel(kernel: CompiledKernel) -> Tuple[float, float, float, float]:
    """Roofline-style score: max(compute, memory) with efficiency derates.

    * compute is derated by the worst MXU tile utilization (M/N pad to 128,
      K to the sublane granule) and credited the int8 2x path when the GEMM
      operands are int8.
    * memory is RAW HBM traffic — VMEM padding is a *capacity* effect
      (planned by plan_vmem), not wire traffic, so it does not derate
      bandwidth.
    """
    cost = kernel.info.cost
    inf = kernel.info.inference
    mxu = 1.0
    peak = PEAK_FLOPS_BF16
    if inf.gemms:
        mxu = min(g.mxu_utilization for g in inf.gemms)
    # operand dtype of the gemms decides the MXU rate (int8 path = 2x)
    if inf.gemms and all(g.a_dtype in ("int8", "uint8") for g in inf.gemms):
        peak = PEAK_FLOPS_INT8
    compute_s = cost.compute_seconds(peak) / max(mxu, 1e-3)
    memory_s = cost.memory_seconds(HBM_BW)
    # pipeline overlap: with >=2 stages compute and memory overlap; otherwise add
    overlap = kernel.info.num_stages >= 2
    total = max(compute_s, memory_s) if overlap else compute_s + memory_s
    return total, compute_s, memory_s, mxu


def autotune(
    build: Callable[..., Any],
    configs: Iterable[Dict[str, Any]],
    schedule: Optional[Schedule] = None,
    cache_key: Optional[Tuple] = None,
    return_all: bool = False,
):
    """Pick the best config for a program factory.

    ``build(**config)`` must return a TileProgram.  Infeasible configs (VMEM
    over budget, lowering errors) are skipped but recorded.
    """
    schedule = schedule or Schedule()
    results: List[Candidate] = []
    best: Optional[Tuple[Candidate, Any]] = None
    for config in configs:
        key = None
        if cache_key is not None:
            key = (cache_key, tuple(sorted(config.items())))
            if key in _CACHE:
                cand = _CACHE[key]
                results.append(cand)
                if cand.feasible and (best is None or cand.score < best[0].score):
                    best = (cand, None)  # rebuild lazily below
                continue
        try:
            program = build(**config)
            kernel = tl_compile(program, schedule=schedule)
            total, cs, ms, mxu = score_kernel(kernel)
            waste = max(kernel.info.inference.waste.values(), default=0.0)
            cand = Candidate(config, total, cs, ms, mxu, waste, True)
        except (ScheduleError, TileError) as e:
            cand = Candidate(config, float("inf"), 0, 0, 0, 0, False, str(e))
            kernel = None
        results.append(cand)
        if key is not None:
            _CACHE[key] = cand
        if cand.feasible and (best is None or cand.score < best[0].score):
            best = (cand, kernel)
    if best is None:
        msgs = "; ".join(c.reason[:80] for c in results[:4])
        raise ScheduleError(f"autotune: no feasible config ({msgs})")
    cand, kernel = best
    if kernel is None:  # cache hit path: rebuild the winner once
        program = build(**cand.config)
        kernel = tl_compile(program, schedule=schedule)
    if return_all:
        return kernel, cand, results
    return kernel, cand


def grid_configs(**axes: Sequence[Any]) -> List[Dict[str, Any]]:
    """Cartesian product of named axis values -> list of config dicts."""
    names = list(axes)
    out = []
    for vals in itertools.product(*(axes[n] for n in names)):
        out.append(dict(zip(names, vals)))
    return out
