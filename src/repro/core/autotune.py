"""Cost-model-driven configuration search (paper §6 future direction).

Because TileLang exposes thread mapping, memory access and compute behavior
explicitly, a static cost model is enough to rank configurations without
running them — exactly the property the paper argues for.  We exploit it:
the pass pipeline (repro.core.lowering) records a :class:`KernelCost`
(FLOPs, HBM bytes, VMEM footprint, grid) and the inference pass records
padding waste and MXU utilization; :func:`autotune` combines them into a
roofline-style score and returns the best-scoring feasible config.

Candidates are scored from the cached **analysis artifact**
(``lowering.analyze``) alone — no backend code is emitted while searching;
only the winning config is actually compiled.  Scores are additionally
cached per (program-name, shapes, config) so kernel libraries with dynamic
shape sets amortize the search — the TPU analogue of the paper's "dynamic
parameter simplification" for kernel libraries.

This is *structural* tuning (no hardware timing needed): the same mechanism
the dry-run roofline uses, applied at kernel granularity.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from .compiler import compile as tl_compile
from .errors import ScheduleError, TileError
from .lowering import CompiledKernel, analyze, schedule_key
from .schedule import Schedule

# TPU v5e hardware constants (also used by repro.roofline).
PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12  # MXU int8 path is 2x bf16
HBM_BW = 819e9


def peak_flops_for(dtype: str) -> float:
    return PEAK_FLOPS_INT8 if "int8" in dtype or "int4" in dtype else PEAK_FLOPS_BF16


@dataclasses.dataclass
class Candidate:
    config: Dict[str, Any]
    score: float  # estimated seconds (lower is better)
    compute_s: float
    memory_s: float
    mxu_util: float
    pad_waste: float
    feasible: bool
    reason: str = ""


# Scored candidates, LRU-bounded (same discipline as the serving engine's
# step-fn cache): config sweeps over many shape buckets must not pin a
# Candidate per visited config for process lifetime.
_CACHE: "collections.OrderedDict[Tuple, Candidate]" = collections.OrderedDict()
_CACHE_MAX = 512


def _cache_get(key):
    cand = _CACHE.get(key)
    if cand is not None:
        _CACHE.move_to_end(key)
    return cand


def _cache_put(key, cand) -> None:
    _CACHE[key] = cand
    _CACHE.move_to_end(key)
    while len(_CACHE) > _CACHE_MAX:
        _CACHE.popitem(last=False)


def _score(cost, inference, num_stages) -> Tuple[float, float, float, float]:
    """Roofline-style score: max(compute, memory) with efficiency derates.

    * compute is derated by the worst MXU tile utilization (M/N pad to 128,
      K to the sublane granule) and credited the int8 2x path when the GEMM
      operands are int8.
    * memory is RAW HBM traffic — VMEM padding is a *capacity* effect
      (planned by plan_vmem), not wire traffic, so it does not derate
      bandwidth.
    """
    mxu = 1.0
    peak = PEAK_FLOPS_BF16
    if inference.gemms:
        mxu = min(g.mxu_utilization for g in inference.gemms)
    # operand dtype of the gemms decides the MXU rate (int8 path = 2x)
    if inference.gemms and all(g.a_dtype in ("int8", "uint8") for g in inference.gemms):
        peak = PEAK_FLOPS_INT8
    compute_s = cost.compute_seconds(peak) / max(mxu, 1e-3)
    memory_s = cost.memory_seconds(HBM_BW)
    # pipeline overlap: with >=2 stages compute and memory overlap; otherwise add
    overlap = num_stages >= 2
    total = max(compute_s, memory_s) if overlap else compute_s + memory_s
    return total, compute_s, memory_s, mxu


def score_kernel(kernel: CompiledKernel) -> Tuple[float, float, float, float]:
    """Score an already-compiled kernel (delegates to the shared model)."""
    info = kernel.info
    return _score(info.cost, info.inference, info.num_stages)


def score_module(module) -> Tuple[float, float, float, float]:
    """Score a :class:`LoweredModule` analysis artifact — no emission."""
    return _score(module.cost, module.inference, module.num_stages)


def autotune(
    build: Callable[..., Any],
    configs: Iterable[Dict[str, Any]],
    schedule: Optional[Schedule] = None,
    cache_key: Optional[Tuple] = None,
    return_all: bool = False,
):
    """Pick the best config for a program factory.

    ``build(**config)`` must return a TileProgram.  Infeasible configs (VMEM
    over budget, lowering errors) are skipped but recorded.  Scoring runs on
    the cached pipeline analysis; only the winner is compiled.
    """
    schedule = schedule or Schedule()
    results: List[Candidate] = []
    for config in configs:
        key = None
        if cache_key is not None:
            # schedule_key included: the same config can be feasible under
            # one schedule and not another (stages, vmem limit, interpret).
            key = (cache_key, schedule_key(schedule), tuple(sorted(config.items())))
            hit = _cache_get(key)
            if hit is not None:
                results.append(hit)
                continue
        try:
            program = build(**config)
            module = analyze(program, schedule)
            if module.vmem is not None and not module.vmem.ok:
                raise ScheduleError(
                    f"VMEM budget exceeded —\n{module.vmem.summary()}"
                )
            total, cs, ms, mxu = _score(module.cost, module.inference, module.num_stages)
            waste = max(module.inference.waste.values(), default=0.0)
            cand = Candidate(config, total, cs, ms, mxu, waste, True)
        except (ScheduleError, TileError) as e:
            cand = Candidate(config, float("inf"), 0, 0, 0, 0, False, str(e))
        results.append(cand)
        if key is not None:
            _cache_put(key, cand)
    # Compile winners best-first — analysis is cached, so this only runs
    # backend emission.  A config can still fail *there* (some checks are
    # backend-specific, e.g. the Pallas written-and-read window rule); such
    # a candidate is demoted to infeasible and the next-best one is tried.
    # Demotion replaces the results entry with a copy: Candidate objects may
    # be aliased into _CACHE and into lists returned from earlier calls.
    kernel = winner = None
    for cand in sorted((c for c in results if c.feasible), key=lambda c: c.score):
        try:
            program = build(**cand.config)
            kernel = tl_compile(program, schedule=schedule)
            winner = cand
            break
        except (ScheduleError, TileError) as e:
            demoted = dataclasses.replace(
                cand, feasible=False, score=float("inf"), reason=str(e)
            )
            results[results.index(cand)] = demoted
            if cache_key is not None:
                # persist the demotion so later calls don't redo the
                # failing emission before falling back
                _cache_put(
                    (cache_key, schedule_key(schedule),
                     tuple(sorted(cand.config.items()))),
                    demoted,
                )
    if kernel is None:
        msgs = "; ".join(c.reason[:80] for c in results[:4])
        raise ScheduleError(f"autotune: no feasible config ({msgs})")
    if return_all:
        return kernel, winner, results
    return kernel, winner


def grid_configs(**axes: Sequence[Any]) -> List[Dict[str, Any]]:
    """Cartesian product of named axis values -> list of config dicts."""
    names = list(axes)
    out = []
    for vals in itertools.product(*(axes[n] for n in names)):
        out.append(dict(zip(names, vals)))
    return out
