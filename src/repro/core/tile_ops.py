"""Tile operator IR nodes (paper §3.2, Fig. 4).

Every tile operator implements the paper's two interfaces:

* ``infer_layout(layout_map, level)`` — contribute layout constraints at a
  given priority level (GEMM is strictest; elementwise conforms last).
* lowering — here split into ``lower_ref`` (trace-interpreter reference) and
  per-op handling in :mod:`repro.core.lower` for the Pallas path.

Ops are *pure descriptions*; they never touch device state at trace time.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .buffer import FRAGMENT, GLOBAL, SHARED, AxisSel, Region, TileBuffer
from .errors import LoweringError, TraceError
from .expr import ConstExpr, Expr, VarExpr, static_eval

# Layout-inference priority levels (paper §4.2: strict ops bind layouts first)
LEVEL_STRICT = 0  # tensor-core/MXU GEMM
LEVEL_COMMON = 1  # copy / reduce
LEVEL_FLEX = 2  # elementwise / fill


# ---------------------------------------------------------------------------
# Resolved regions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResolvedRegion:
    """A Region with concrete extents: per-axis (start expr, size, collapsed)."""

    buffer: TileBuffer
    starts: Tuple[Expr, ...]
    sizes: Tuple[int, ...]
    collapsed: Tuple[bool, ...]  # axis dropped in the logical tile view

    @property
    def tile_shape(self) -> Tuple[int, ...]:
        return tuple(s for s, c in zip(self.sizes, self.collapsed) if not c)

    def __repr__(self):
        parts = []
        for st, sz, col in zip(self.starts, self.sizes, self.collapsed):
            parts.append(f"{st}+:{sz}" + ("↓" if col else ""))
        return f"{self.buffer.name}[{', '.join(parts)}]"


def as_region(x) -> Region:
    if isinstance(x, Region):
        return x
    if isinstance(x, TileBuffer):
        return x.full_region()
    raise TraceError(f"Expected a buffer or region, got {type(x)}")


def resolve_copy_regions(src: Region, dst: Region) -> Tuple[ResolvedRegion, ResolvedRegion]:
    """Infer extents for ``T.copy`` operands (TileLang semantics).

    Scalar ("corner") selections either *collapse* an axis (when the peer has
    fewer axes) or denote a tile *corner* whose extent comes from the peer.
    """
    s_res = _resolve_against(src, dst)
    d_res = _resolve_against(dst, src)
    if s_res.tile_shape != d_res.tile_shape:
        raise TraceError(
            f"copy: tile shapes differ {s_res.tile_shape} vs {d_res.tile_shape} "
            f"({s_res} -> {d_res})"
        )
    return s_res, d_res


def _peer_tile_shape(peer: Region) -> Optional[Tuple[int, ...]]:
    """Tile shape of the peer if determinable without our help."""
    sizes = []
    for sel in peer.sels:
        if sel.kind in ("full", "slice"):
            sizes.append(sel.size)
        elif sel.kind == "corner":
            return None  # peer needs us to resolve
    return tuple(sizes)


def _resolve_against(r: Region, peer: Region) -> ResolvedRegion:
    n_scalar = sum(1 for s in r.sels if s.kind == "corner")
    n_sized = len(r.sels) - n_scalar
    peer_shape = _peer_tile_shape(peer)

    starts: List[Expr] = []
    sizes: List[int] = []
    collapsed: List[bool] = []

    if peer_shape is not None and n_sized == len(peer_shape):
        # All scalar sels collapse; sized sels must match the peer tile.
        it = iter(peer_shape)
        for axis, sel in enumerate(r.sels):
            if sel.kind == "corner":
                starts.append(sel.start)
                sizes.append(1)
                collapsed.append(True)
            else:
                expect = next(it)
                if sel.size != expect:
                    raise TraceError(
                        f"copy: extent mismatch on {r.buffer.name} axis {axis}: "
                        f"{sel.size} vs peer {expect}"
                    )
                starts.append(sel.start)
                sizes.append(sel.size)
                collapsed.append(False)
    elif peer_shape is not None and len(r.sels) >= len(peer_shape):
        # Right-align: the trailing len(peer) axes resolve positionally
        # (corner -> take peer extent); all leading axes must be scalar and
        # collapse.  This covers e.g. Q[bz, by, bx*bm, 0] -> (block_M, dim).
        lead = len(r.sels) - len(peer_shape)
        for axis in range(lead):
            sel = r.sels[axis]
            if sel.kind != "corner":
                raise TraceError(
                    f"copy: cannot align {r.buffer.name} axis {axis} (sized) "
                    f"with lower-rank peer {peer.buffer.name}"
                )
            starts.append(sel.start)
            sizes.append(1)
            collapsed.append(True)
        for off, (sel, psz) in enumerate(zip(r.sels[lead:], peer_shape)):
            starts.append(sel.start)
            if sel.kind == "corner":
                sizes.append(int(psz))
                collapsed.append(False)
            else:
                if sel.size != psz:
                    raise TraceError(
                        f"copy: extent mismatch on {r.buffer.name} axis "
                        f"{lead + off}: {sel.size} vs peer {psz}"
                    )
                sizes.append(sel.size)
                collapsed.append(False)
    elif peer_shape is None and n_scalar == 0:
        # We are fully sized; peer will resolve against us.
        for sel in r.sels:
            starts.append(sel.start)
            sizes.append(sel.size)
            collapsed.append(False)
    else:
        raise TraceError(
            f"copy: cannot infer extents for {r.buffer.name} "
            f"({len(r.sels)} axes, {n_scalar} scalar) against peer "
            f"{peer.buffer.name} ({len(peer.sels)} axes)"
        )
    # Bounds sanity for static corners
    for axis, (st, sz) in enumerate(zip(starts, sizes)):
        sv = static_eval(st)
        if sv is not None and sv + sz > r.buffer.shape[axis]:
            raise TraceError(
                f"copy: region [{sv}, {sv + sz}) exceeds {r.buffer.name} axis "
                f"{axis} extent {r.buffer.shape[axis]}"
            )
    return ResolvedRegion(r.buffer, tuple(starts), tuple(sizes), tuple(collapsed))


# ---------------------------------------------------------------------------
# Op base
# ---------------------------------------------------------------------------


class TileOp:
    """Base tile operator."""

    def buffers_read(self) -> List[TileBuffer]:
        return []

    def buffers_written(self) -> List[TileBuffer]:
        return []

    def infer_layout(self, layout_map: Dict[str, Any], level: int) -> None:
        """Contribute layout constraints at ``level`` (see infer.py)."""

    @property
    def priority(self) -> int:
        return LEVEL_FLEX


@dataclasses.dataclass
class CopyOp(TileOp):
    """``T.copy`` — parallel data movement between any two scopes."""

    src: ResolvedRegion
    dst: ResolvedRegion

    def buffers_read(self):
        return [self.src.buffer]

    def buffers_written(self):
        return [self.dst.buffer]

    @property
    def priority(self):
        return LEVEL_COMMON

    @property
    def kind(self) -> str:
        return f"{self.src.buffer.scope}->{self.dst.buffer.scope}"

    def __repr__(self):
        return f"Copy({self.src} -> {self.dst})"


@dataclasses.dataclass
class GemmOp(TileOp):
    """``T.gemm`` — tile matmul, MXU-tensorized on the TPU target.

    ``accumulate`` is always true (TileLang semantics: C += A@B; use
    T.clear to reset).  ``policy`` is advisory (warp policy on GPUs; on TPU it
    selects the MXU blocking preference recorded for the cost model).
    """

    a: TileBuffer
    b: TileBuffer
    c: TileBuffer
    transpose_a: bool = False
    transpose_b: bool = False
    policy: Optional[str] = None
    # m/n/k extents of the tile contraction, resolved at trace time:
    m: int = 0
    n: int = 0
    k: int = 0

    def buffers_read(self):
        return [self.a, self.b, self.c]

    def buffers_written(self):
        return [self.c]

    @property
    def priority(self):
        return LEVEL_STRICT

    def __repr__(self):
        ta = "T" if self.transpose_a else ""
        tb = "T" if self.transpose_b else ""
        return (
            f"Gemm({self.a.name}{ta} @ {self.b.name}{tb} -> {self.c.name} "
            f"[{self.m}x{self.n}x{self.k}])"
        )


@dataclasses.dataclass
class FillOp(TileOp):
    """``T.fill`` / ``T.clear``."""

    buffer: TileBuffer
    value: Expr

    def buffers_written(self):
        return [self.buffer]

    def __repr__(self):
        return f"Fill({self.buffer.name} = {self.value})"


@dataclasses.dataclass
class ReduceOp(TileOp):
    """``T.reduce_{sum,max,min,...}`` over one axis of a tile."""

    kind: str  # sum|max|min|prod|absmax
    src: TileBuffer
    dst: TileBuffer
    axis: int
    clear: bool = True  # False: combine with dst's current contents

    def buffers_read(self):
        return [self.src] + ([] if self.clear else [self.dst])

    def buffers_written(self):
        return [self.dst]

    @property
    def priority(self):
        return LEVEL_COMMON

    def __repr__(self):
        return f"Reduce[{self.kind}]({self.src.name} axis={self.axis} -> {self.dst.name})"


@dataclasses.dataclass
class CumsumOp(TileOp):
    """``T.cumsum`` along an axis (linear-attention intra-chunk scans)."""

    src: TileBuffer
    dst: TileBuffer
    axis: int
    reverse: bool = False

    def buffers_read(self):
        return [self.src]

    def buffers_written(self):
        return [self.dst]


@dataclasses.dataclass
class ParallelOp(TileOp):
    """``T.Parallel`` elementwise body: a list of stores over an iteration box.

    Each store is ``(buffer, idx_exprs, value_expr)``.  Thread binding /
    vectorization for this op is *inferred*, never written by the user
    (paper §4.2, Fig. 8).
    """

    axes: Tuple[VarExpr, ...]
    extents: Tuple[int, ...]
    stores: List[Tuple[TileBuffer, Tuple[Expr, ...], Expr]] = dataclasses.field(
        default_factory=list
    )

    def buffers_read(self):
        from .expr import loads_in

        out = []
        for _, idx, val in self.stores:
            for e in (*idx, val):
                for ld in loads_in(e):
                    out.append(ld.buffer)
        return out

    def buffers_written(self):
        return [b for b, _, _ in self.stores]

    def __repr__(self):
        axes = ", ".join(f"{a.name}<{e}>" for a, e in zip(self.axes, self.extents))
        return f"Parallel[{axes}]({len(self.stores)} stores)"


@dataclasses.dataclass
class PipelinedOp(TileOp):
    """``T.Pipelined`` loop: the software-pipeline region (paper §4.4).

    On the TPU lowering this becomes an ``arbitrary`` grid axis whose
    global->shared copies turn into BlockSpec-managed double-buffered DMA —
    the Pallas-native analogue of cp.async / TMA rings.  ``num_stages`` and
    explicit ``order``/``stage`` hints are honored as scheduling metadata
    (multi-buffering depth) and budget-checked by the VMEM planner.
    """

    var: VarExpr
    extent: int
    num_stages: int
    body: List[TileOp] = dataclasses.field(default_factory=list)
    order: Optional[Sequence[int]] = None
    stage: Optional[Sequence[int]] = None

    def buffers_read(self):
        out = []
        for op in self.body:
            out.extend(op.buffers_read())
        return out

    def buffers_written(self):
        out = []
        for op in self.body:
            out.extend(op.buffers_written())
        return out

    def __repr__(self):
        return (
            f"Pipelined({self.var.name} < {self.extent}, stages={self.num_stages}, "
            f"{len(self.body)} ops)"
        )


@dataclasses.dataclass
class SerialOp(TileOp):
    """``T.serial`` / ``T.unroll`` — an in-kernel loop, unrolled at lowering."""

    var: VarExpr
    extent: int
    unroll: bool
    body: List[TileOp] = dataclasses.field(default_factory=list)

    def buffers_read(self):
        out = []
        for op in self.body:
            out.extend(op.buffers_read())
        return out

    def buffers_written(self):
        out = []
        for op in self.body:
            out.extend(op.buffers_written())
        return out


@dataclasses.dataclass
class AtomicOp(TileOp):
    """``T.atomic_{add,max,min}`` — no HBM atomics exist on TPU.

    The lowering rewrites this to an owned-accumulation pattern: the
    destination region must be exclusively owned by the current grid cell
    (verified from the index map), turning the atomic into a plain
    read-modify-write; otherwise lowering fails with guidance to reduce over
    an ``arbitrary`` grid axis or a JAX-level collective (DESIGN.md §2).
    """

    kind: str
    dst: ResolvedRegion
    src: TileBuffer

    def buffers_read(self):
        return [self.src, self.dst.buffer]

    def buffers_written(self):
        return [self.dst.buffer]


@dataclasses.dataclass
class CustomOp(TileOp):
    """``T.call_tile_lib`` — Tile Library escape hatch (paper §4.3).

    The GPU paper injects C++/PTX via ``T.import_source``/``T.call_extern``/
    ``T.ptx``; the TPU analogue is registering a JAX-traceable tile function
    that consumes/produces whole tiles (it may itself wrap another Pallas
    call or an MXU-specific pattern).
    """

    fn: Callable[..., Any]
    inputs: Tuple[TileBuffer, ...]
    output: TileBuffer
    name: str = "custom"

    def buffers_read(self):
        return list(self.inputs)

    def buffers_written(self):
        return [self.output]
