"""Schedule space & VMEM planning (paper §4: everything that is *not* dataflow).

The four scheduling axes of the paper map onto the TPU target as:

=====================  =====================================================
paper axis             realization here
=====================  =====================================================
thread binding         vector-lane layout inference (infer.py) — no threads
memory layout          Layout/Fragment padding + alignment (layout.py/infer)
tensorization          T.gemm -> MXU dot_general; custom ops via CustomOp
pipeline               T.Pipelined -> `arbitrary` grid axis, multi-buffered
                       BlockSpec DMA (num_stages budgeted here)
=====================  =====================================================

``Schedule`` collects the knobs a caller (or the autotuner) can set without
touching the dataflow; ``plan_vmem`` validates the resulting on-chip
footprint against the hardware budget *before* any lowering happens.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .buffer import FRAGMENT, GLOBAL, SHARED, TileBuffer, dtype_bits
from .errors import ScheduleError
from .layout import LANE, round_up, sublane

# TPU v5e on-chip budget (bytes).  ~128 MiB VMEM; keep headroom for Mosaic's
# own spills, semaphores and the grid pipeline's internal buffers.
VMEM_BYTES = 128 * 1024 * 1024
VMEM_HEADROOM = 0.85


@dataclasses.dataclass
class Schedule:
    """User/autotuner-controllable scheduling knobs for one program."""

    interpret: bool = False  # run Pallas in interpreter (CPU validation)
    num_stages: Optional[int] = None  # override T.Pipelined's stage count
    grid_swizzle: Optional[int] = None  # override T.use_swizzle
    dimension_semantics: Optional[Tuple[str, ...]] = None  # rarely needed
    vmem_limit: int = int(VMEM_BYTES * VMEM_HEADROOM)
    # Advisory: collected by lower.py for the cost model / roofline.
    notes: Dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class BufferPlan:
    name: str
    scope: str
    logical_shape: Tuple[int, ...]
    physical_shape: Tuple[int, ...]  # padded to (sublane, lane) tiling
    copies: int  # multi-buffering factor
    bytes: int

    @property
    def waste(self) -> float:
        import numpy as np

        log = int(np.prod(self.logical_shape)) or 1
        phys = int(np.prod(self.physical_shape))
        return 1.0 - log / phys


@dataclasses.dataclass
class VmemPlan:
    buffers: List[BufferPlan]
    total_bytes: int
    limit: int

    @property
    def ok(self) -> bool:
        return self.total_bytes <= self.limit

    def summary(self) -> str:
        lines = [f"VMEM plan: {self.total_bytes/2**20:.2f} MiB / {self.limit/2**20:.1f} MiB"]
        for b in self.buffers:
            lines.append(
                f"  {b.name:<16} {b.scope:<8} {str(b.logical_shape):<18} -> "
                f"{str(b.physical_shape):<18} x{b.copies} = {b.bytes/2**10:8.1f} KiB"
                + (f"  (pad waste {b.waste:.0%})" if b.waste > 0 else "")
            )
        return "\n".join(lines)


def physical_tile_shape(shape: Tuple[int, ...], dtype: str) -> Tuple[int, ...]:
    """Pad the last two dims to the Mosaic VMEM tiling ((sublane, lane))."""
    if not shape:
        return shape
    s = list(shape)
    s[-1] = round_up(s[-1], LANE)
    if len(s) >= 2:
        s[-2] = round_up(s[-2], sublane(dtype))
    else:
        # 1-D arrays occupy a (1, lane)-tiled row per sublane group
        pass
    return tuple(s)


def plan_vmem(
    program,
    schedule: Schedule,
    pipelined_inputs: Dict[str, int],
    check: bool = True,
) -> VmemPlan:
    """Compute the on-chip footprint of a traced program.

    ``pipelined_inputs`` maps buffer name -> multi-buffering depth for shared
    buffers fed by global copies inside a T.Pipelined loop (the grid
    pipeline double/multi-buffers those windows).

    ``check=False`` returns the (possibly over-budget) plan instead of
    raising — the pass pipeline uses this so the budget stays a *backend*
    feasibility concern (the reference interpreter has no VMEM).
    """
    plans: List[BufferPlan] = []
    total = 0
    for buf in program.allocs:
        phys = physical_tile_shape(buf.shape, buf.dtype)
        copies = pipelined_inputs.get(buf.name, 1)
        if schedule.num_stages is not None and buf.name in pipelined_inputs:
            copies = max(2, schedule.num_stages)
        import numpy as np

        nbytes = int(np.prod(phys)) * dtype_bits(buf.dtype) // 8 * copies
        plans.append(
            BufferPlan(buf.name, buf.scope, buf.shape, phys, copies, nbytes)
        )
        total += nbytes
    plan = VmemPlan(plans, total, schedule.vmem_limit)
    if check and not plan.ok:
        raise ScheduleError(
            f"{program.name}: VMEM budget exceeded —\n{plan.summary()}\n"
            "Reduce block shapes or num_stages."
        )
    return plan


# ---------------------------------------------------------------------------
# Grid swizzling (T.use_swizzle): reorder the sequential grid walk.
# ---------------------------------------------------------------------------


def swizzle_decode(flat, g0: int, g1: int, factor: int):
    """Decode a flattened 2-D grid step into (i0, i1) with panel rasterization.

    Walks ``factor`` consecutive i0 values per i1 before advancing i1 —
    consecutive grid steps then reuse the same operand-1 block, which the
    Pallas pipeline detects (identical block index => copy skipped).  This is
    the TPU analogue of the L2-locality thread-block swizzle: the "cache"
    being exploited is the VMEM window itself.

    Works on ints and traced int32 scalars alike.
    """
    panel = factor * g1
    group = flat // panel
    rem = flat % panel
    if isinstance(flat, int):
        # Last (possibly ragged) panel: clamp the panel height.
        rows = min(factor, g0 - group * factor)
        i0 = group * factor + rem % rows
        i1 = rem // rows
        return i0, i1
    # Traced path: require g0 % factor == 0 (checked by caller).
    i0 = group * factor + rem % factor
    i1 = rem // factor
    return i0, i1


def validate_swizzle(g0: int, g1: int, factor: int):
    if factor <= 0:
        raise ScheduleError(f"swizzle factor must be positive, got {factor}")
    if g0 % factor != 0:
        raise ScheduleError(
            f"use_swizzle({factor}): leading grid extent {g0} must be a "
            f"multiple of the factor on the TPU lowering"
        )
