"""Compatibility shim — the monolithic lowering moved (DESIGN.md §1).

This module used to hold the whole compiler; it is now split into

* :mod:`repro.core.lowering`  — the pass pipeline producing a
  :class:`~repro.core.lowering.LoweredModule` analysis artifact
  (``split_phases``, ``collect_windows``, layout inference, ``plan_grid``,
  ``plan_vmem``, cost estimation), memoized per (program fingerprint,
  schedule).
* :mod:`repro.core.backends`  — the pluggable backend registry; ``pallas``
  and ``reference`` are built in, third parties add targets with
  :func:`repro.core.backends.register_backend`.
* :mod:`repro.core.compiler`  — the ``compile()`` entry point dispatching
  through the registry, with kernel-level caching.

Importing the old names from here keeps working; new code should import
from the packages above.
"""
from .backends import available_backends, get_backend, register_backend  # noqa: F401
from .compiler import clear_compile_cache, compile  # noqa: F401
from .lowering import (  # noqa: F401
    LOOP,
    POST,
    PRE,
    CompiledKernel,
    KernelCost,
    LoweredInfo,
    LoweredModule,
    Phases,
    Window,
    analyze,
    collect_windows,
    estimate_cost,
    make_index_map,
    split_phases,
)
from .lowering.indexing import no_loads as _no_loads  # noqa: F401
from .lowering.windows import _is_onchip, _merge_out_window, _same_starts  # noqa: F401

# Pre-split private names kept for callers that reached into the module.
_estimate_cost = estimate_cost

__all__ = [
    "compile",
    "CompiledKernel",
    "KernelCost",
    "LoweredInfo",
    "LoweredModule",
    "Phases",
    "Window",
    "PRE",
    "LOOP",
    "POST",
    "split_phases",
    "collect_windows",
    "make_index_map",
    "analyze",
    "available_backends",
    "get_backend",
    "register_backend",
    "clear_compile_cache",
]
