"""Lowering: TileProgram -> Pallas TPU kernel (and a reference interpreter).

The central translation (DESIGN.md §2): a ``T.Pipelined`` loop over K with
global->shared ``T.copy`` ops becomes the **Pallas grid pipeline** — the
copies turn into BlockSpec-managed windows whose index maps depend on the
reduction grid axis, so the hardware DMA double-buffers them and overlaps
with compute exactly like cp.async/TMA rings on GPUs.  Fragment buffers
become VMEM scratch accumulators persisting across the ``arbitrary`` axis.

Two backends:

* ``pallas``    — emits ``pl.pallas_call`` (TPU target; ``interpret=True``
                  executes the same kernel body on CPU for validation).
* ``reference`` — a direct trace interpreter over jnp arrays (tiny shapes
                  only); an independent oracle for the lowering itself.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .buffer import FRAGMENT, GLOBAL, SHARED, TileBuffer, dtype_bits
from .errors import LoweringError
from .expr import (
    BinExpr,
    ConstExpr,
    Expr,
    VarExpr,
    evaluate,
    linear_decompose,
    static_eval,
)
from .infer import InferenceResult, infer_layouts
from .program import TileProgram
from .schedule import Schedule, VmemPlan, plan_vmem, swizzle_decode, validate_swizzle
from .tile_ops import (
    AtomicOp,
    CopyOp,
    CumsumOp,
    CustomOp,
    FillOp,
    GemmOp,
    ParallelOp,
    PipelinedOp,
    ReduceOp,
    ResolvedRegion,
    SerialOp,
    TileOp,
)

PRE, LOOP, POST = "pre", "loop", "post"


# ---------------------------------------------------------------------------
# Cost info recorded at lowering time (feeds autotune + benchmarks + roofline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KernelCost:
    flops: int
    hbm_bytes: int
    grid: Tuple[int, ...]
    vmem_bytes: int

    def compute_seconds(self, peak_flops: float = 197e12) -> float:
        return self.flops / peak_flops

    def memory_seconds(self, hbm_bw: float = 819e9) -> float:
        return self.hbm_bytes / hbm_bw

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)

    def bound(self, peak_flops: float = 197e12, hbm_bw: float = 819e9) -> str:
        return (
            "compute" if self.compute_seconds(peak_flops) >= self.memory_seconds(hbm_bw)
            else "memory"
        )


# ---------------------------------------------------------------------------
# Phase classification
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Phases:
    pre: List[TileOp]
    pipeline: Optional[PipelinedOp]
    post: List[TileOp]


def split_phases(program: TileProgram) -> Phases:
    pre: List[TileOp] = []
    pipe: Optional[PipelinedOp] = None
    post: List[TileOp] = []
    for op in program.ops:
        if isinstance(op, PipelinedOp):
            if pipe is not None:
                raise LoweringError(
                    f"{program.name}: multiple T.Pipelined loops at kernel top "
                    "level; fuse them or split the kernel (one grid pipeline "
                    "per Pallas kernel)."
                )
            pipe = op
        elif pipe is None:
            pre.append(op)
        else:
            post.append(op)
    return Phases(pre, pipe, post)


# ---------------------------------------------------------------------------
# Window extraction (copies that become BlockSpecs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Window:
    """One BlockSpec-managed operand window."""

    param: TileBuffer  # the global buffer
    onchip: Optional[TileBuffer]  # dst for inputs; src for outputs (may be None for atomics)
    region: ResolvedRegion  # region on the global side
    phase: str
    is_output: bool
    aliased: bool = False  # in-out (atomic RMW)

    @property
    def block_shape(self) -> Tuple[int, ...]:
        return tuple(self.region.sizes)


def _is_onchip(buf: TileBuffer) -> bool:
    return buf.scope in (SHARED, FRAGMENT)


def collect_windows(program: TileProgram, phases: Phases):
    """Find all global<->onchip copies; returns (in_windows, out_windows,
    window_backed: dst name -> window idx, store_ops)."""
    in_windows: List[Window] = []
    out_windows: List[Window] = []
    fed_by: Dict[str, Window] = {}
    stores: List[Tuple[TileOp, str, Window]] = []  # (op, phase, out window)

    def scan(ops: List[TileOp], phase: str):
        for op in ops:
            if isinstance(op, SerialOp):
                scan(op.body, phase)
            elif isinstance(op, CopyOp):
                s, d = op.src.buffer, op.dst.buffer
                if s.scope == GLOBAL and _is_onchip(d):
                    if d.name in fed_by:
                        raise LoweringError(
                            f"{program.name}: buffer {d.name} fed by two "
                            "global copies; each shared tile must have one "
                            "producer copy."
                        )
                    if any(c for c in op.dst.collapsed) or op.dst.tile_shape != tuple(
                        op.dst.buffer.shape
                    ):
                        raise LoweringError(
                            f"{program.name}: global->onchip copy must fill the "
                            f"whole destination tile ({op})"
                        )
                    w = Window(s, d, op.src, phase, is_output=False)
                    in_windows.append(w)
                    fed_by[d.name] = w
                elif _is_onchip(s) and d.scope == GLOBAL:
                    w = _merge_out_window(out_windows, Window(d, s, op.dst, phase, True))
                    stores.append((op, phase, w))
                elif s.scope == GLOBAL and d.scope == GLOBAL:
                    raise LoweringError(
                        f"{program.name}: global->global copy; stage through "
                        "a shared tile."
                    )
            elif isinstance(op, AtomicOp):
                if op.dst.buffer.scope != GLOBAL:
                    continue
                w = _merge_out_window(
                    out_windows, Window(op.dst.buffer, None, op.dst, phase, True, aliased=True)
                )
                w.aliased = True
                stores.append((op, phase, w))

    scan(phases.pre, PRE)
    if phases.pipeline is not None:
        scan(phases.pipeline.body, LOOP)
    scan(phases.post, POST)
    return in_windows, out_windows, fed_by, stores


def _merge_out_window(out_windows: List[Window], w: Window) -> Window:
    for existing in out_windows:
        if existing.param is w.param:
            if existing.block_shape != w.block_shape or not _same_starts(
                existing.region, w.region
            ):
                raise LoweringError(
                    f"two stores to {w.param.name} with different windows; "
                    "unify the destination regions."
                )
            return existing
    out_windows.append(w)
    return w


def _same_starts(a: ResolvedRegion, b: ResolvedRegion) -> bool:
    return [repr(s) for s in a.starts] == [repr(s) for s in b.starts]


# ---------------------------------------------------------------------------
# Index-map derivation
# ---------------------------------------------------------------------------


def make_index_map(
    region: ResolvedRegion,
    env_builder: Callable[..., Dict[str, Any]],
):
    """Build a Pallas ``index_map(*grid_ids) -> block indices``.

    Affine starts with size-divisible coefficients fold statically; otherwise
    we fall back to a runtime floordiv (correct when the region is aligned —
    the TileLang contract for unmasked copies).
    """
    starts, sizes = region.starts, region.sizes

    def fold(e: Expr, size: int):
        if size == 1:
            return ("expr", e)
        dec = linear_decompose(e)
        if dec is not None and all(v % size == 0 for v in dec.values()):
            folded = {k: v // size for k, v in dec.items()}
            return ("affine", folded)
        return ("div", e)

    plans = [fold(e, s) for e, s in zip(starts, sizes)]

    def index_map(*grid_ids):
        env = env_builder(*grid_ids)

        def ev(e: Expr):
            return evaluate(e, env, load_fn=_no_loads)

        out = []
        for (kind, payload), size in zip(plans, sizes):
            if kind == "expr":
                out.append(ev(payload))
            elif kind == "affine":
                acc = payload.get("", 0)
                for name, coeff in payload.items():
                    if name == "":
                        continue
                    if coeff:
                        acc = acc + coeff * env[name]
                out.append(acc)
            else:
                out.append(ev(payload) // size)
        return tuple(out)

    return index_map


def _no_loads(buffer, idx_values, idx_exprs):
    raise LoweringError("Buffer loads are not allowed in index expressions")


# ---------------------------------------------------------------------------
# Compiled kernel object
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LoweredInfo:
    grid: Tuple[int, ...]
    dimension_semantics: Tuple[str, ...]
    vmem: VmemPlan
    inference: InferenceResult
    cost: KernelCost
    num_stages: int
    n_windows_in: int
    n_windows_out: int


class CompiledKernel:
    """Callable wrapper: ``kernel(*input_arrays) -> output(s)``.

    Inputs are the program's read-only global params (in declaration order)
    followed by any in-out (atomic) params; outputs are the written globals
    in declaration order.
    """

    def __init__(self, program: TileProgram, fn: Callable, info: LoweredInfo,
                 arg_params: List[TileBuffer], out_params: List[TileBuffer]):
        self.program = program
        self._fn = fn
        self.info = info
        self.arg_params = arg_params
        self.out_params = out_params
        self.__name__ = program.name

    def __call__(self, *arrays):
        if len(arrays) != len(self.arg_params):
            raise LoweringError(
                f"{self.program.name}: expected {len(self.arg_params)} arrays "
                f"({[p.name for p in self.arg_params]}), got {len(arrays)}"
            )
        for arr, p in zip(arrays, self.arg_params):
            if tuple(arr.shape) != p.shape:
                raise LoweringError(
                    f"{self.program.name}: arg {p.name} shape {arr.shape} != "
                    f"declared {p.shape}"
                )
        out = self._fn(*arrays)
        return out


# ---------------------------------------------------------------------------
# The Pallas lowering
# ---------------------------------------------------------------------------


def compile(  # noqa: A001 — mirrors tilelang.compile
    program: TileProgram,
    schedule: Optional[Schedule] = None,
    backend: str = "pallas",
) -> CompiledKernel:
    schedule = schedule or Schedule()
    if backend == "reference":
        return _compile_reference(program, schedule)
    if backend != "pallas":
        raise LoweringError(f"Unknown backend {backend!r}")
    return _compile_pallas(program, schedule)


def _grid_layout(program: TileProgram, phases: Phases, schedule: Schedule):
    """Returns (grid, env_builder, kdim, dimension_semantics).

    Kernel axes are reversed so the first-declared axis (``bx``) is the
    fastest-varying parallel dimension (CUDA blockIdx.x convention), and the
    pipelined axis is innermost overall so accumulators stay resident.
    """
    kernel_axes = program.grid_axes  # declaration order
    n = len(kernel_axes)
    swz = schedule.grid_swizzle
    if swz is None:
        swz = program.annotations.swizzle

    pipe = phases.pipeline
    kext = pipe.extent if pipe is not None else None
    kname = pipe.var.name if pipe is not None else None

    if swz is not None and n == 2:
        (v0, e0), (v1, e1) = kernel_axes
        # pallas-minor ordering: v1 (by) slower, v0 (bx) faster in raster;
        # flatten to one axis and decode with panel swizzling.  Clamp the
        # panel height to a divisor of the row extent (traced decode needs
        # uniform panels).
        factor = min(swz, e1)
        if e1 % factor != 0:
            factor = math.gcd(e1, factor) or 1
        validate_swizzle(e1, e0, factor)
        grid = (e1 * e0,) + ((kext,) if kext else ())
        sem = ("arbitrary",) * len(grid)

        def env_builder(*gids):
            flat = gids[0]
            i1, i0 = swizzle_decode(flat, e1, e0, factor)
            env = {v1.name: i1, v0.name: i0}
            if kname is not None:
                env[kname] = gids[1]
            return env

        kdim = 1 if kext else None
        return grid, env_builder, kdim, sem

    grid = tuple(e for _, e in reversed(kernel_axes)) + ((kext,) if kext else ())
    sem = ("parallel",) * n + (("arbitrary",) if kext else ())

    def env_builder(*gids):
        env = {}
        for i, (v, _) in enumerate(kernel_axes):
            env[v.name] = gids[n - 1 - i]
        if kname is not None:
            env[kname] = gids[n]
        return env

    kdim = n if kext else None
    return grid, env_builder, kdim, sem


def _compile_pallas(program: TileProgram, schedule: Schedule) -> CompiledKernel:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    inference = infer_layouts(program)
    phases = split_phases(program)
    in_windows, out_windows, fed_by, _stores = collect_windows(program, phases)
    grid, env_builder, kdim, dim_sem = _grid_layout(program, phases, schedule)
    if schedule.dimension_semantics is not None:
        dim_sem = schedule.dimension_semantics

    pipe = phases.pipeline
    num_stages = (
        schedule.num_stages
        if schedule.num_stages is not None
        else (pipe.num_stages if pipe is not None else 1)
    )

    # ---- VMEM plan -------------------------------------------------------
    pipelined_inputs = {
        w.onchip.name: max(2, num_stages)
        for w in in_windows
        if w.phase == LOOP and w.onchip is not None
    }
    vmem = plan_vmem(program, schedule, pipelined_inputs)

    # ---- scratch: every onchip buffer not window-backed ---------------------
    scratch_bufs: List[TileBuffer] = [
        b for b in program.allocs if b.name not in fed_by
    ]
    scratch_pos = {b.name: i for i, b in enumerate(scratch_bufs)}

    # ---- params/ordering ---------------------------------------------------
    written = {id(p) for p in program.written_globals()}
    aliased_params = [w.param for w in out_windows if w.aliased]
    arg_params = [p for p in program.params if id(p) not in written]
    arg_params += [p for p in aliased_params]  # in-out params passed as inputs
    out_params = [p for p in program.params if id(p) in written]

    # operand list: one per input window (+ aliased outputs appended last)
    window_param_idx: List[int] = []
    param_pos = {id(p): i for i, p in enumerate(arg_params)}
    for w in in_windows:
        if id(w.param) not in param_pos:
            # a written global read back through a window — unsupported
            raise LoweringError(
                f"{program.name}: {w.param.name} is both written and read "
                "through separate windows; use T.atomic or split kernels."
            )
        window_param_idx.append(param_pos[id(w.param)])
    alias_operand_idx: Dict[int, int] = {}
    n_in_ops = len(in_windows)
    for j, w in enumerate(out_windows):
        if w.aliased:
            alias_operand_idx[n_in_ops + len(alias_operand_idx)] = j

    # ---- specs ----------------------------------------------------------------
    in_specs = [
        pl.BlockSpec(w.block_shape, make_index_map(w.region, env_builder))
        for w in in_windows
    ]
    alias_in_specs = [
        pl.BlockSpec(w.block_shape, make_index_map(w.region, env_builder))
        for w in out_windows
        if w.aliased
    ]
    out_specs = [
        pl.BlockSpec(w.block_shape, make_index_map(w.region, env_builder))
        for w in out_windows
    ]
    out_shape = [
        jax.ShapeDtypeStruct(w.param.shape, jnp.dtype(w.param.dtype))
        for w in out_windows
    ]
    scratch_shapes = [
        pltpu.VMEM(b.shape, jnp.dtype(b.dtype)) for b in scratch_bufs
    ]
    input_output_aliases = {
        n_in_ops + i: j for i, j in enumerate(alias_operand_idx.values())
    }

    window_of: Dict[str, int] = {
        w.onchip.name: i for i, w in enumerate(in_windows) if w.onchip is not None
    }
    out_window_of: Dict[int, int] = {id(w.param): j for j, w in enumerate(out_windows)}

    kext = pipe.extent if pipe is not None else None

    # ---- kernel body ------------------------------------------------------
    def body(*refs):
        n_in_total = n_in_ops + len(alias_in_specs)
        in_refs = refs[:n_in_total]
        out_refs = refs[n_in_total : n_in_total + len(out_windows)]
        scr_refs = refs[n_in_total + len(out_windows) :]

        grid_ids = tuple(pl.program_id(d) for d in range(len(grid)))
        env_scalars = env_builder(*grid_ids)
        kval = grid_ids[kdim] if kdim is not None else None

        values: Dict[str, Any] = {}
        dirty: set = set()

        def squeeze(arr, region: ResolvedRegion):
            keep = tuple(
                i for i, c in enumerate(region.collapsed) if not c
            )
            if len(keep) == arr.ndim:
                return arr
            return arr.reshape(tuple(arr.shape[i] for i in keep))

        def get(buf: TileBuffer):
            if buf.name in values:
                return values[buf.name]
            if buf.name in window_of:
                w = in_windows[window_of[buf.name]]
                val = squeeze(in_refs[window_of[buf.name]][...], w.region)
                val = val.astype(jnp.dtype(buf.dtype))
                values[buf.name] = val
                return val
            pos = scratch_pos[buf.name]
            val = scr_refs[pos][...]
            values[buf.name] = val
            return val

        def put(buf: TileBuffer, val):
            if buf.name in window_of:
                raise LoweringError(
                    f"{program.name}: write to window-backed tile {buf.name}"
                )
            val = val.astype(jnp.dtype(buf.dtype))
            val = jnp.broadcast_to(val, buf.shape)
            values[buf.name] = val
            if buf.name in scratch_pos:
                dirty.add(buf.name)

        def gput(buf: TileBuffer, new, phase: str):
            """Phase-guarded value update.

            PRE ops must only take effect at k==0 and POST ops at k==last —
            the body re-executes every grid step, and unguarded PRE/POST
            writes would corrupt accumulators carried across the reduction
            axis.  Guards are functional selects (Mosaic-friendly), not
            control flow."""
            g = guard(phase)
            if g is None:
                put(buf, new)
                return
            new = jnp.broadcast_to(
                jnp.asarray(new).astype(jnp.dtype(buf.dtype)), buf.shape
            )
            put(buf, jnp.where(g, new, get(buf).astype(new.dtype)))

        def scalar_env():
            return dict(env_scalars)

        def eval_expr(e: Expr, extra: Dict[str, Any], load_fn):
            env = scalar_env()
            env.update(extra)
            return evaluate(e, env, load_fn)

        def guard(phase: str):
            """Functional guard for value ops outside the loop phase."""
            if kval is None:
                return None
            if phase == PRE:
                return kval == 0
            if phase == POST:
                return kval == kext - 1
            return None

        def run_fill(op: FillOp, phase: str, extra):
            fillval = eval_expr(op.value, extra, _no_loads)
            tile = jnp.full(op.buffer.shape, fillval, dtype=jnp.dtype(op.buffer.dtype))
            gput(op.buffer, tile, phase)

        def region_value(region: ResolvedRegion, extra):
            """Read a region of an on-chip buffer as a tile value."""
            base = get(region.buffer)
            starts = [eval_expr(s, extra, _no_loads) for s in region.starts]
            if all(isinstance(s, (int, np.integer)) and s == 0 for s in starts) and tuple(
                region.sizes
            ) == tuple(region.buffer.shape):
                val = base
            else:
                import jax.lax as lax

                val = lax.dynamic_slice(base, [jnp.asarray(s, jnp.int32) for s in starts], region.sizes)
            return squeeze(val, region)

        def run_copy(op: CopyOp, phase: str, extra):
            s, d = op.src.buffer, op.dst.buffer
            if s.scope == GLOBAL and _is_onchip(d):
                val = get(d)  # window read; already cast
                values[d.name] = val
                return
            if _is_onchip(s) and d.scope == GLOBAL:
                j = out_window_of[id(d)]
                w = out_windows[j]
                val = region_value(op.src, extra).astype(jnp.dtype(d.dtype))
                block = val.reshape(w.block_shape)
                g = guard(phase)
                if g is None:
                    out_refs[j][...] = block
                else:
                    @pl.when(g)
                    def _():
                        out_refs[j][...] = block
                return
            # on-chip -> on-chip
            val = region_value(op.src, extra)
            if tuple(op.dst.tile_shape) == tuple(d.shape) and not any(op.dst.collapsed):
                gput(d, val, phase)
            else:
                import jax.lax as lax

                starts = [eval_expr(x, extra, _no_loads) for x in op.dst.starts]
                cur = get(d)
                upd = val.reshape(tuple(op.dst.sizes)).astype(cur.dtype)
                gput(
                    d,
                    lax.dynamic_update_slice(
                        cur, upd, [jnp.asarray(x, jnp.int32) for x in starts]
                    ),
                    phase,
                )

        def run_gemm(op: GemmOp, phase: str, extra):
            a, b = get(op.a), get(op.b)
            if op.transpose_a:
                a = a.T if a.ndim == 2 else jnp.swapaxes(a, -1, -2)
            if op.transpose_b:
                b = b.T if b.ndim == 2 else jnp.swapaxes(b, -1, -2)
            acc = get(op.c)
            prod = jax.lax.dot_general(
                a,
                b,
                dimension_numbers=(((a.ndim - 1,), (b.ndim - 2,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            gput(op.c, acc + prod.astype(acc.dtype), phase)

        def run_reduce(op: ReduceOp, phase: str, extra):
            src = get(op.src)
            if op.kind == "absmax":
                val = jnp.max(jnp.abs(src), axis=op.axis)
            elif op.kind == "sum":
                val = jnp.sum(src, axis=op.axis)
            elif op.kind == "max":
                val = jnp.max(src, axis=op.axis)
            elif op.kind == "min":
                val = jnp.min(src, axis=op.axis)
            elif op.kind == "prod":
                val = jnp.prod(src, axis=op.axis)
            else:
                raise LoweringError(f"Unknown reduce kind {op.kind}")
            if not op.clear:
                cur = get(op.dst)
                comb = {
                    "sum": jnp.add,
                    "max": jnp.maximum,
                    "min": jnp.minimum,
                    "prod": jnp.multiply,
                    "absmax": jnp.maximum,
                }[op.kind]
                val = comb(cur, val.astype(cur.dtype))
            gput(op.dst, val, phase)

        def run_cumsum(op: CumsumOp, phase: str, extra):
            src = get(op.src)
            if op.reverse:
                src = jnp.flip(src, axis=op.axis)
            val = jnp.cumsum(src, axis=op.axis)
            if op.reverse:
                val = jnp.flip(val, axis=op.axis)
            gput(op.dst, val, phase)

        def run_parallel(op: ParallelOp, phase: str, extra):
            nax = len(op.axes)
            axis_names = [a.name for a in op.axes]
            iotas = {}
            for i, (v, e) in enumerate(zip(op.axes, op.extents)):
                shape = [1] * nax
                shape[i] = e
                iotas[v.name] = jax.lax.broadcasted_iota(jnp.int32, tuple(shape), i)

            def structured_load(buffer, idx_exprs):
                """TPU-friendly load patterns over the parallel box.

                * all-direct indices -> the whole tile (pure vector op)
                * ``ax // c`` on an axis -> jnp.repeat along that axis (the
                  vectorized sub-byte unpack idiom; the TPU analogue of PTX
                  lop3 byte-extraction in the paper's dequant kernels)
                Returns None when the pattern doesn't apply.
                """
                if len(idx_exprs) != buffer.ndim or len(idx_exprs) != nax:
                    return None
                plan = []
                for i, e in enumerate(idx_exprs):
                    if (
                        isinstance(e, VarExpr)
                        and e.name == axis_names[i]
                        and buffer.shape[i] == op.extents[i]
                    ):
                        plan.append(("id", 1))
                    elif (
                        isinstance(e, BinExpr)
                        and e.op == "floordiv"
                        and isinstance(e.lhs, VarExpr)
                        and e.lhs.name == axis_names[i]
                        and isinstance(e.rhs, ConstExpr)
                        and buffer.shape[i] * int(e.rhs.value) == op.extents[i]
                    ):
                        plan.append(("repeat", int(e.rhs.value)))
                    else:
                        return None
                val = get(buffer)
                for ax, (kind, c) in enumerate(plan):
                    if kind == "repeat":
                        val = jnp.repeat(val, c, axis=ax)
                return val

            def load_fn(buffer, idx_values, idx_exprs):
                fast = structured_load(buffer, idx_exprs)
                if fast is not None:
                    return fast
                base = get(buffer)
                idx = tuple(jnp.asarray(v) for v in idx_values)
                return base[idx]

            for buf, idx_exprs, val_expr in op.stores:
                senv = scalar_env()
                senv.update(extra)
                senv.update(iotas)
                val = evaluate(val_expr, senv, load_fn)
                direct = (
                    len(idx_exprs) == nax
                    and all(
                        isinstance(e, VarExpr) and e.name == axis_names[i]
                        for i, e in enumerate(idx_exprs)
                    )
                    and tuple(buf.shape) == op.extents
                )
                if direct:
                    new = jnp.broadcast_to(val, op.extents)
                else:
                    cur0 = get(buf)
                    idx_vals = tuple(
                        jnp.asarray(evaluate(e, senv, load_fn)) for e in idx_exprs
                    )
                    new = cur0.at[idx_vals].set(jnp.asarray(val).astype(cur0.dtype))
                gput(buf, new, phase)

        def run_custom(op: CustomOp, phase: str, extra):
            vals = [get(b) for b in op.inputs]
            out = op.fn(*vals)
            if tuple(out.shape) != tuple(op.output.shape):
                raise LoweringError(
                    f"custom op {op.name}: produced {out.shape}, expected "
                    f"{op.output.shape}"
                )
            gput(op.output, out, phase)

        def run_atomic(op: AtomicOp, phase: str, extra):
            j = out_window_of[id(op.dst.buffer)]
            val = get(op.src).astype(jnp.dtype(op.dst.buffer.dtype))
            block = val.reshape(out_windows[j].block_shape)
            comb = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op.kind]
            g = guard(phase)
            if g is None:
                out_refs[j][...] = comb(out_refs[j][...], block)
            else:
                @pl.when(g)
                def _():
                    out_refs[j][...] = comb(out_refs[j][...], block)

        def run_ops(ops: List[TileOp], phase: str, extra):
            for op in ops:
                if isinstance(op, CopyOp):
                    run_copy(op, phase, extra)
                elif isinstance(op, GemmOp):
                    run_gemm(op, phase, extra)
                elif isinstance(op, FillOp):
                    run_fill(op, phase, extra)
                elif isinstance(op, ReduceOp):
                    run_reduce(op, phase, extra)
                elif isinstance(op, CumsumOp):
                    run_cumsum(op, phase, extra)
                elif isinstance(op, ParallelOp):
                    run_parallel(op, phase, extra)
                elif isinstance(op, CustomOp):
                    run_custom(op, phase, extra)
                elif isinstance(op, AtomicOp):
                    run_atomic(op, phase, extra)
                elif isinstance(op, SerialOp):
                    for i in range(op.extent):
                        e2 = dict(extra)
                        e2[op.var.name] = i
                        run_ops(op.body, phase, e2)
                elif isinstance(op, PipelinedOp):
                    raise LoweringError("nested T.Pipelined is unsupported")
                else:
                    raise LoweringError(f"Unhandled op {op!r}")

        run_ops(phases.pre, PRE, {})
        if pipe is not None:
            run_ops(pipe.body, LOOP, {})
        run_ops(phases.post, POST, {})

        # write back dirty scratch accumulators
        for name in dirty:
            scr_refs[scratch_pos[name]][...] = values[name].astype(
                scr_refs[scratch_pos[name]].dtype
            )

    # ---- cost accounting -----------------------------------------------------
    cost = _estimate_cost(program, phases, grid, in_windows, out_windows, vmem)

    compiler_params = pltpu.CompilerParams(dimension_semantics=dim_sem)
    call = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs + alias_in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        input_output_aliases=input_output_aliases,
        interpret=schedule.interpret,
        compiler_params=compiler_params,
        name=program.name,
    )

    n_aliased = len(alias_in_specs)

    def fn(*arrays):
        operands = [arrays[i] for i in window_param_idx]
        operands += list(arrays[len(arrays) - n_aliased :]) if n_aliased else []
        res = call(*operands)
        return res[0] if len(out_windows) == 1 else tuple(res)

    info = LoweredInfo(
        grid=grid,
        dimension_semantics=tuple(dim_sem),
        vmem=vmem,
        inference=inference,
        cost=cost,
        num_stages=num_stages,
        n_windows_in=len(in_windows),
        n_windows_out=len(out_windows),
    )
    return CompiledKernel(program, fn, info, arg_params, out_params)


def _estimate_cost(program, phases, grid, in_windows, out_windows, vmem) -> KernelCost:
    total_steps = int(np.prod(grid))
    pipe = phases.pipeline
    cells = total_steps // (pipe.extent if pipe is not None else 1)

    flops = 0

    def op_flops(op: TileOp) -> int:
        if isinstance(op, GemmOp):
            return 2 * op.m * op.n * op.k
        if isinstance(op, ParallelOp):
            return int(np.prod(op.extents)) * max(1, len(op.stores)) * 2
        if isinstance(op, (ReduceOp,)):
            return op.src.size
        if isinstance(op, CumsumOp):
            return op.src.size
        if isinstance(op, SerialOp):
            return op.extent * sum(op_flops(o) for o in op.body)
        return 0

    for op in phases.pre + phases.post:
        flops += cells * op_flops(op)
    if pipe is not None:
        for op in pipe.body:
            flops += total_steps * op_flops(op)

    hbm = 0
    for w in in_windows:
        steps = total_steps if w.phase == LOOP else cells
        hbm += steps * int(np.prod(w.block_shape)) * dtype_bits(w.param.dtype) // 8
    for w in out_windows:
        steps = total_steps if w.phase == LOOP else cells
        hbm += steps * int(np.prod(w.block_shape)) * dtype_bits(w.param.dtype) // 8

    return KernelCost(flops=flops, hbm_bytes=hbm, grid=tuple(grid), vmem_bytes=vmem.total_bytes)


# ---------------------------------------------------------------------------
# Reference interpreter backend (tiny shapes; independent oracle)
# ---------------------------------------------------------------------------


def _compile_reference(program: TileProgram, schedule: Schedule) -> CompiledKernel:
    import itertools

    import jax
    import jax.numpy as jnp

    inference = infer_layouts(program)
    phases = split_phases(program)
    in_windows, out_windows, fed_by, _ = collect_windows(program, phases)
    pipe = phases.pipeline

    written = {id(p) for p in program.written_globals()}
    aliased = [w.param for w in out_windows if w.aliased]
    arg_params = [p for p in program.params if id(p) not in written] + aliased
    out_params = [p for p in program.params if id(p) in written]

    kernel_axes = program.grid_axes

    def fn(*arrays):
        globals_: Dict[str, Any] = {}
        for p, a in zip(arg_params, arrays):
            globals_[p.name] = jnp.asarray(a)
        for p in out_params:
            if p.name not in globals_:
                globals_[p.name] = jnp.zeros(p.shape, jnp.dtype(p.dtype))

        for cell in itertools.product(*[range(e) for _, e in kernel_axes]):
            env0 = {v.name: idx for (v, _), idx in zip(kernel_axes, cell)}
            tiles: Dict[str, Any] = {}

            def run(ops, extra):
                for op in ops:
                    _ref_op(op, globals_, tiles, {**env0, **extra}, jnp)

            run(phases.pre, {})
            if pipe is not None:
                for k in range(pipe.extent):
                    run(pipe.body, {pipe.var.name: k})
            run(phases.post, {})
        outs = [globals_[p.name] for p in out_params]
        return outs[0] if len(outs) == 1 else tuple(outs)

    info = LoweredInfo(
        grid=tuple(e for _, e in kernel_axes),
        dimension_semantics=("reference",),
        vmem=plan_vmem(program, schedule, {}),
        inference=inference,
        cost=_estimate_cost(
            program,
            phases,
            tuple(e for _, e in kernel_axes) + ((pipe.extent,) if pipe else ()),
            in_windows,
            out_windows,
            plan_vmem(program, schedule, {}),
        ),
        num_stages=1,
        n_windows_in=len(in_windows),
        n_windows_out=len(out_windows),
    )
    return CompiledKernel(program, fn, info, arg_params, out_params)


def _ref_op(op: TileOp, globals_: Dict, tiles: Dict, env: Dict, jnp):
    import jax

    def ev(e: Expr, extra=None, load_fn=_no_loads):
        en = dict(env)
        if extra:
            en.update(extra)
        return evaluate(e, en, load_fn)

    def get(buf: TileBuffer):
        if buf.scope == GLOBAL:
            return globals_[buf.name]
        if buf.name not in tiles:
            tiles[buf.name] = jnp.zeros(buf.shape, jnp.dtype(buf.dtype))
        return tiles[buf.name]

    def put(buf: TileBuffer, val):
        val = jnp.broadcast_to(val, buf.shape).astype(jnp.dtype(buf.dtype))
        if buf.scope == GLOBAL:
            globals_[buf.name] = val
        else:
            tiles[buf.name] = val

    def region_read(region: ResolvedRegion):
        base = get(region.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in region.starts]
        val = jax.lax.dynamic_slice(base, starts, region.sizes)
        keep = tuple(i for i, c in enumerate(region.collapsed) if not c)
        return val.reshape(tuple(region.sizes[i] for i in keep))

    def region_write(region: ResolvedRegion, val):
        base = get(region.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in region.starts]
        upd = val.reshape(region.sizes).astype(base.dtype)
        out = jax.lax.dynamic_update_slice(base, upd, starts)
        if region.buffer.scope == GLOBAL:
            globals_[region.buffer.name] = out
        else:
            tiles[region.buffer.name] = out

    if isinstance(op, CopyOp):
        region_write(op.dst, region_read(op.src).astype(jnp.dtype(op.dst.buffer.dtype)))
    elif isinstance(op, FillOp):
        put(op.buffer, jnp.full(op.buffer.shape, ev(op.value), jnp.dtype(op.buffer.dtype)))
    elif isinstance(op, GemmOp):
        a, b = get(op.a), get(op.b)
        if op.transpose_a:
            a = jnp.swapaxes(a, -1, -2)
        if op.transpose_b:
            b = jnp.swapaxes(b, -1, -2)
        acc = get(op.c)
        prod = jax.lax.dot_general(
            a, b, (((a.ndim - 1,), (b.ndim - 2,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        put(op.c, acc + prod.astype(acc.dtype))
    elif isinstance(op, ReduceOp):
        src = get(op.src)
        fns = {
            "sum": jnp.sum,
            "max": jnp.max,
            "min": jnp.min,
            "prod": jnp.prod,
            "absmax": lambda x, axis: jnp.max(jnp.abs(x), axis=axis),
        }
        val = fns[op.kind](src, axis=op.axis)
        if not op.clear:
            comb = {
                "sum": jnp.add,
                "max": jnp.maximum,
                "min": jnp.minimum,
                "prod": jnp.multiply,
                "absmax": jnp.maximum,
            }[op.kind]
            val = comb(get(op.dst), val.astype(get(op.dst).dtype))
        put(op.dst, val)
    elif isinstance(op, CumsumOp):
        src = get(op.src)
        if op.reverse:
            src = jnp.flip(src, axis=op.axis)
        val = jnp.cumsum(src, axis=op.axis)
        if op.reverse:
            val = jnp.flip(val, axis=op.axis)
        put(op.dst, val)
    elif isinstance(op, ParallelOp):
        import jax.lax as lax

        nax = len(op.axes)
        iotas = {}
        for i, (v, e) in enumerate(zip(op.axes, op.extents)):
            shape = [1] * nax
            shape[i] = e
            iotas[v.name] = lax.broadcasted_iota(jnp.int32, tuple(shape), i)

        def load_fn(buffer, idx_values, idx_exprs):
            base = get(buffer)
            return base[tuple(jnp.asarray(v) for v in idx_values)]

        for buf, idx_exprs, val_expr in op.stores:
            val = ev(val_expr, extra=iotas, load_fn=load_fn)
            idx_vals = tuple(jnp.asarray(ev(e, extra=iotas, load_fn=load_fn)) for e in idx_exprs)
            direct = (
                len(idx_exprs) == nax
                and all(
                    isinstance(e, VarExpr) and e.name == op.axes[i].name
                    for i, e in enumerate(idx_exprs)
                )
                and tuple(buf.shape) == op.extents
            )
            if direct:
                put(buf, jnp.broadcast_to(val, op.extents))
            else:
                cur = get(buf)
                put(buf, cur.at[idx_vals].set(jnp.asarray(val).astype(cur.dtype)))
    elif isinstance(op, CustomOp):
        put(op.output, op.fn(*[get(b) for b in op.inputs]))
    elif isinstance(op, AtomicOp):
        base = get(op.dst.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in op.dst.starts]
        cur = jax.lax.dynamic_slice(base, starts, op.dst.sizes)
        val = get(op.src).reshape(op.dst.sizes).astype(cur.dtype)
        comb = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op.kind]
        globals_[op.dst.buffer.name] = jax.lax.dynamic_update_slice(
            base, comb(cur, val), starts
        )
    elif isinstance(op, SerialOp):
        for i in range(op.extent):
            for o in op.body:
                _ref_op(o, globals_, tiles, {**env, op.var.name: i}, jnp)
    else:
        raise LoweringError(f"reference: unhandled op {op!r}")
