"""Error types for the tile language."""


class TileError(Exception):
    """Base error for all tile-language failures."""


class TraceError(TileError):
    """Raised when the Python-embedded frontend is used outside a kernel
    context or with malformed arguments."""


class LoweringError(TileError):
    """Raised when a traced program cannot be lowered to the requested
    backend (e.g. unsupported op pattern for the Pallas path)."""


class LayoutError(TileError):
    """Raised by the layout-inference pass on conflicting constraints."""


class ScheduleError(TileError):
    """Raised for invalid schedule parameters (vmem budget, stages...)."""
