"""Error types for the tile language."""

from typing import Optional


class TileError(Exception):
    """Base error for all tile-language failures.

    ``context`` carries where the failure happened — typically the program
    name and the pipeline pass that raised (attached by ``run_pipeline``) —
    so a mid-pipeline error names its kernel instead of surfacing as a bare
    message three layers up.
    """

    def __init__(self, *args, context: Optional[str] = None):
        super().__init__(*args)
        self.context = context

    def __str__(self) -> str:
        base = super().__str__()
        if self.context:
            return f"{base} [{self.context}]"
        return base


class TraceError(TileError):
    """Raised when the Python-embedded frontend is used outside a kernel
    context or with malformed arguments."""


class LoweringError(TileError):
    """Raised when a traced program cannot be lowered to the requested
    backend (e.g. unsupported op pattern for the Pallas path)."""


class LayoutError(TileError):
    """Raised by the layout-inference pass on conflicting constraints."""


class ScheduleError(TileError):
    """Raised for invalid schedule parameters (vmem budget, stages...)."""


class VerifyError(LoweringError):
    """Raised by the static verifier pass (lowering/verify.py): a window
    provably escapes its buffer, two grid cells provably write overlapping
    output regions, or the in-out alias wiring is inconsistent."""


class SanitizeError(TileError):
    """Raised by the reference interpreter on unsanitary kernel behavior:
    out-of-bounds region starts or scalar-load indices (checked always —
    Python's negative-index wrap-around must never silently read the end of
    a buffer), plus duplicate cross-cell writes, uninitialized-output reads
    and non-finite outputs under sanitize mode."""


class GuardError(TileError):
    """A runtime obligation failed at dispatch time (kernels/ops.py guard):
    a block table directed a kernel at an out-of-range, reserved, or
    duplicated writable page.  ``violations`` is a list of ``(row, kind,
    message)`` tuples so a batch dispatcher can fail exactly the offending
    rows and keep the rest."""

    def __init__(self, violations, context: Optional[str] = None):
        self.violations = list(violations)
        msg = "; ".join(
            f"row {r}: {kind}: {m}" for r, kind, m in self.violations
        )
        super().__init__(f"dispatch guard: {msg}", context=context)
