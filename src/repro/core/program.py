"""The TileLang-style Python-embedded frontend (``import ... as T``).

A kernel is an ordinary Python function whose parameters are annotated with
:class:`Tensor` placeholders.  Decorating it with :func:`prim_func` executes
the body once with symbolic values ("tracing"), producing a
:class:`TileProgram` — a grid, explicit buffer allocations, and a tree of
tile operators.  The program is then compiled by :func:`repro.core.compile`
(see lower.py) to a Pallas TPU kernel or a pure-jnp reference.

Dataflow vs scheduling (the paper's thesis) shows up directly here: the body
only ever states *what moves where* (T.copy/T.gemm/T.reduce over explicitly
placed buffers); *how* it runs (grid pipelining, layouts, vectorization,
swizzles) is carried by annotations (T.Pipelined/T.annotate_layout/
T.use_swizzle) and otherwise inferred.
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .buffer import (
    FRAGMENT,
    GLOBAL,
    SCALAR,
    SHARED,
    Region,
    TileBuffer,
    canonical_dtype,
)
from .errors import TraceError
from .expr import (
    BinExpr,
    CastExpr,
    ConstExpr,
    Expr,
    UnaryExpr,
    VarExpr,
    WhereExpr,
    wrap,
)
from .tile_ops import (
    AtomicOp,
    CopyOp,
    CumsumOp,
    CustomOp,
    FillOp,
    GemmOp,
    ParallelOp,
    PipelinedOp,
    ReduceOp,
    SerialOp,
    TileOp,
    as_region,
    resolve_copy_regions,
)

_name_counter = itertools.count()


# ---------------------------------------------------------------------------
# Builder state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Annotations:
    layouts: Dict[str, Any] = dataclasses.field(default_factory=dict)
    swizzle: Optional[int] = None
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


class ProgramBuilder:
    def __init__(self, name: str):
        self.name = name
        self.grid_axes: List[Tuple[VarExpr, int]] = []
        self.threads: Optional[int] = None
        self.allocs: List[TileBuffer] = []
        self.annotations = Annotations()
        self._op_stack: List[List[TileOp]] = [[]]
        self._parallel_stack: List[ParallelOp] = []
        self.kernel_entered = False

    # -- op recording -----------------------------------------------------
    @property
    def ops(self) -> List[TileOp]:
        return self._op_stack[0]

    def record(self, op: TileOp):
        self._op_stack[-1].append(op)

    def push_ops(self, lst: List[TileOp]):
        self._op_stack.append(lst)

    def pop_ops(self):
        self._op_stack.pop()


_BUILDERS: List[ProgramBuilder] = []


def _builder() -> ProgramBuilder:
    if not _BUILDERS:
        raise TraceError(
            "Tile-language primitive used outside a @T.prim_func body."
        )
    return _BUILDERS[-1]


def current_parallel_context() -> Optional["_ParallelRecorder"]:
    if not _BUILDERS:
        return None
    b = _BUILDERS[-1]
    return b._parallel_stack[-1] if b._parallel_stack else None  # type: ignore[return-value]


# ---------------------------------------------------------------------------
# Signature placeholders
# ---------------------------------------------------------------------------


class Tensor:
    """Annotation for a global (HBM) tensor parameter: ``A: T.Tensor(shape, dtype)``."""

    def __init__(self, shape: Sequence[Union[int, Any]], dtype: str = "float32"):
        self.shape = tuple(int(s) for s in shape)
        self.dtype = canonical_dtype(dtype)

    def __repr__(self):
        return f"T.Tensor({self.shape}, {self.dtype!r})"


Buffer = Tensor  # alias familiar from TVM-style frontends


class ScalarTensor(Tensor):
    """Annotation for a scalar-prefetch parameter: a small integer tensor
    (block tables, sequence lengths) whose *elements* may appear in index
    expressions — including the starts of global->shared ``T.copy`` regions,
    which is how a kernel gathers non-contiguous tiles (paged KV pages).

    On the Pallas backend these become ``PrefetchScalarGridSpec`` scalar
    operands living in SMEM; the reference interpreter reads them as plain
    arrays.  Only integer dtypes are allowed.
    """

    def __init__(self, shape: Sequence[Union[int, Any]], dtype: str = "int32"):
        super().__init__(shape, dtype)
        if not self.dtype.startswith(("int", "uint")):
            raise TraceError(
                f"T.ScalarTensor must have an integer dtype, got {self.dtype!r}"
            )


# ---------------------------------------------------------------------------
# The traced program
# ---------------------------------------------------------------------------


class TileProgram:
    def __init__(
        self,
        name: str,
        params: List[TileBuffer],
        grid_axes: List[Tuple[VarExpr, int]],
        threads: Optional[int],
        ops: List[TileOp],
        allocs: List[TileBuffer],
        annotations: Annotations,
        source_lines: int = 0,
    ):
        self.name = name
        self.params = params
        self.grid_axes = grid_axes
        self.threads = threads
        self.ops = ops
        self.allocs = allocs
        self.annotations = annotations
        self.source_lines = source_lines
        self._validate()

    # -- dataflow classification -------------------------------------------
    def _walk(self, ops=None):
        for op in self.ops if ops is None else ops:
            yield op
            if isinstance(op, (PipelinedOp, SerialOp)):
                yield from self._walk(op.body)

    def written_globals(self) -> List[TileBuffer]:
        seen, out = set(), []
        for op in self._walk():
            for b in op.buffers_written():
                if b.scope == GLOBAL and id(b) not in seen:
                    seen.add(id(b))
                    out.append(b)
        return out

    def read_globals(self) -> List[TileBuffer]:
        seen, out = set(), []
        for op in self._walk():
            for b in op.buffers_read():
                if b.scope == GLOBAL and id(b) not in seen:
                    seen.add(id(b))
                    out.append(b)
        return out

    def input_params(self) -> List[TileBuffer]:
        written = {id(b) for b in self.written_globals()}
        return [p for p in self.params if id(p) not in written]

    def output_params(self) -> List[TileBuffer]:
        written = {id(b) for b in self.written_globals()}
        return [p for p in self.params if id(p) in written]

    def pipelined_ops(self) -> List[PipelinedOp]:
        return [op for op in self._walk() if isinstance(op, PipelinedOp)]

    def scalar_params(self) -> List[TileBuffer]:
        """Scalar-prefetch params (T.ScalarTensor), in declaration order."""
        return [p for p in self.params if p.scope == SCALAR]

    def scalar_reads(self) -> List[TileBuffer]:
        """Scalar-prefetch buffers read anywhere (index exprs or bodies)."""
        from .expr import loads_in
        from .tile_ops import AtomicOp, CopyOp, FillOp, ParallelOp

        seen, out = set(), []

        def note(e):
            for ld in loads_in(e):
                b = ld.buffer
                if b.scope == SCALAR and id(b) not in seen:
                    seen.add(id(b))
                    out.append(b)

        for op in self._walk():
            if isinstance(op, CopyOp):
                for e in (*op.src.starts, *op.dst.starts):
                    note(e)
            elif isinstance(op, FillOp):
                note(op.value)
            elif isinstance(op, AtomicOp):
                for e in op.dst.starts:
                    note(e)
            elif isinstance(op, ParallelOp):
                for _, idx, val in op.stores:
                    for e in (*idx, val):
                        note(e)
        return out

    def _validate(self):
        if not self.grid_axes:
            raise TraceError(f"{self.name}: no T.Kernel context was entered.")
        reads = {id(b) for b in self.read_globals()}
        reads |= {id(b) for b in self.scalar_reads()}
        writes = {id(b) for b in self.written_globals()}
        for p in self.params:
            if id(p) not in reads and id(p) not in writes:
                # unused params are allowed (kernel libraries) but flagged
                self.annotations.extra.setdefault("unused_params", []).append(p.name)

    def __repr__(self):
        g = "x".join(str(e) for _, e in self.grid_axes)
        return f"TileProgram({self.name}, grid={g}, {len(self.ops)} top ops)"


# ---------------------------------------------------------------------------
# prim_func decorator
# ---------------------------------------------------------------------------


def prim_func(fn: Callable) -> TileProgram:
    """Trace ``fn`` into a TileProgram.

    Parameters must be annotated with :class:`Tensor` instances.  The body is
    executed exactly once with symbolic values.
    """
    sig = inspect.signature(fn)
    params: List[TileBuffer] = []
    kwargs = {}
    for pname, p in sig.parameters.items():
        ann = p.annotation
        if not isinstance(ann, Tensor):
            raise TraceError(
                f"{fn.__name__}: parameter {pname!r} must be annotated with "
                f"T.Tensor(shape, dtype); got {ann!r}"
            )
        scope = SCALAR if isinstance(ann, ScalarTensor) else GLOBAL
        buf = TileBuffer(ann.shape, ann.dtype, scope, name=pname)
        params.append(buf)
        kwargs[pname] = buf

    builder = ProgramBuilder(fn.__name__)
    _BUILDERS.append(builder)
    try:
        fn(**kwargs)
    finally:
        _BUILDERS.pop()

    try:
        src = inspect.getsource(fn)
        nlines = len([l for l in src.splitlines() if l.strip() and not l.strip().startswith("#")])
    except (OSError, TypeError):
        nlines = 0

    return TileProgram(
        fn.__name__,
        params,
        builder.grid_axes,
        builder.threads,
        builder.ops,
        builder.allocs,
        builder.annotations,
        source_lines=nlines,
    )


# ---------------------------------------------------------------------------
# Kernel context and loops
# ---------------------------------------------------------------------------


class Kernel:
    """``with T.Kernel(n0, n1, ..., threads=...) as (b0, b1, ...):``

    Declares the launch grid.  On the TPU lowering each grid cell is one
    sequential step of the Pallas grid (axis semantics `parallel`); ``threads``
    is accepted for source compatibility and recorded as metadata (TPU has no
    user-visible threads — see DESIGN.md §2).
    """

    def __init__(self, *dims: int, threads: Optional[int] = None):
        if not dims:
            raise TraceError("T.Kernel needs at least one grid dimension")
        self.dims = [int(d) for d in dims]
        if any(d <= 0 for d in self.dims):
            raise TraceError(f"Grid dims must be positive, got {self.dims}")
        self.threads = threads

    def __enter__(self):
        b = _builder()
        if b.kernel_entered:
            raise TraceError("Only one T.Kernel context per program is supported")
        b.kernel_entered = True
        b.threads = self.threads
        names = "xyzuvw"
        vars_ = []
        for i, d in enumerate(self.dims):
            v = VarExpr(f"b{names[i]}", extent=d)
            b.grid_axes.append((v, d))
            vars_.append(v)
        return vars_[0] if len(vars_) == 1 else tuple(vars_)

    def __exit__(self, exc_type, exc, tb):
        return False


class _LoopIter:
    """Common machinery for Pipelined/serial/unroll loop tracing: yields one
    symbolic index, body ops are collected into the loop op."""

    def __init__(self, op, var: VarExpr):
        self.op = op
        self.var = var

    def __iter__(self):
        b = _builder()
        b.record(self.op)
        b.push_ops(self.op.body)
        try:
            yield self.var
        finally:
            b.pop_ops()


def Pipelined(
    extent: int,
    num_stages: int = 2,
    order: Optional[Sequence[int]] = None,
    stage: Optional[Sequence[int]] = None,
) -> _LoopIter:
    """Software-pipelined loop (paper §4.4).

    ``num_stages`` is the multi-buffering depth; ``order``/``stage`` allow an
    explicitly user-defined pipeline as in the paper.  The TPU lowering turns
    this loop into an ``arbitrary`` grid axis so that its global->shared
    copies become BlockSpec-managed double-buffered DMAs overlapped with
    compute.
    """
    extent = int(extent)
    if extent <= 0:
        raise TraceError(f"T.Pipelined extent must be positive, got {extent}")
    if num_stages < 1:
        raise TraceError("num_stages must be >= 1")
    var = VarExpr(f"k{next(_name_counter)}", extent=extent)
    return _LoopIter(PipelinedOp(var, extent, num_stages, [], order, stage), var)


def serial(extent: int) -> _LoopIter:
    var = VarExpr(f"s{next(_name_counter)}", extent=int(extent))
    return _LoopIter(SerialOp(var, int(extent), unroll=False, body=[]), var)


def unroll(extent: int) -> _LoopIter:
    var = VarExpr(f"u{next(_name_counter)}", extent=int(extent))
    return _LoopIter(SerialOp(var, int(extent), unroll=True, body=[]), var)


class _ParallelRecorder:
    def __init__(self, op: ParallelOp):
        self.op = op

    def record_store(self, buffer: TileBuffer, idx: Tuple[Expr, ...], value: Expr):
        if buffer.scope == GLOBAL:
            raise TraceError(
                f"Elementwise store to global buffer {buffer.name}; stage "
                "through shared/fragment and T.copy instead."
            )
        if buffer.scope == SCALAR:
            raise TraceError(
                f"Scalar-prefetch buffer {buffer.name} is read-only."
            )
        self.op.stores.append((buffer, idx, value))


class Parallel:
    """``for i, j in T.Parallel(e0, e1):`` — elementwise iteration space.

    The body may only read/write shared+fragment buffers with scalar
    expressions; thread binding and vectorization are inferred (Fig. 8).
    """

    def __init__(self, *extents: int):
        if not extents:
            raise TraceError("T.Parallel needs at least one extent")
        self.extents = tuple(int(e) for e in extents)

    def __iter__(self):
        b = _builder()
        axes = tuple(
            VarExpr(f"p{next(_name_counter)}", extent=e) for e in self.extents
        )
        op = ParallelOp(axes, self.extents, [])
        b.record(op)
        rec = _ParallelRecorder(op)
        b._parallel_stack.append(rec)
        try:
            yield axes[0] if len(axes) == 1 else axes
        finally:
            b._parallel_stack.pop()


# ---------------------------------------------------------------------------
# Allocation
# ---------------------------------------------------------------------------


def _alloc(shape, dtype, scope, name=None) -> TileBuffer:
    b = _builder()
    if isinstance(shape, int):
        shape = (shape,)
    buf = TileBuffer(tuple(shape), dtype, scope, name=name)
    b.allocs.append(buf)
    return buf


def alloc_shared(shape, dtype: str = "float32", name: Optional[str] = None) -> TileBuffer:
    """Allocate a tile in fast on-chip memory (TPU: a VMEM window)."""
    return _alloc(shape, dtype, SHARED, name)


def alloc_fragment(shape, dtype: str = "float32", name: Optional[str] = None) -> TileBuffer:
    """Allocate a block-level accumulator (TPU: VMEM scratch kept hot in
    VREGs by Mosaic; the Fragment layout describes the (vreg_tile, lane)
    partitioning — see layout.py)."""
    return _alloc(shape, dtype, FRAGMENT, name)


alloc_local = alloc_fragment


# ---------------------------------------------------------------------------
# Dataflow operators
# ---------------------------------------------------------------------------


def copy(src, dst):
    s, d = resolve_copy_regions(as_region(src), as_region(dst))
    _builder().record(CopyOp(s, d))


def gemm(
    a: TileBuffer,
    b: TileBuffer,
    c: TileBuffer,
    transpose_A: bool = False,
    transpose_B: bool = False,
    policy: Optional[str] = None,
    clear_accum: bool = False,
):
    for x, nm in ((a, "A"), (b, "B"), (c, "C")):
        if not isinstance(x, TileBuffer):
            raise TraceError(f"T.gemm operand {nm} must be a whole tile buffer")
        if x.scope == GLOBAL:
            raise TraceError(
                f"T.gemm operand {nm} ({x.name}) is global; stage through "
                "shared/fragment first (dataflow must be explicit)."
            )
    am, ak = (a.shape[-2], a.shape[-1]) if not transpose_A else (a.shape[-1], a.shape[-2])
    bk, bn = (b.shape[-2], b.shape[-1]) if not transpose_B else (b.shape[-1], b.shape[-2])
    if ak != bk:
        raise TraceError(f"T.gemm: contraction mismatch K={ak} vs {bk}")
    if (c.shape[-2], c.shape[-1]) != (am, bn):
        raise TraceError(
            f"T.gemm: accumulator shape {c.shape} != ({am}, {bn})"
        )
    if clear_accum:
        _builder().record(FillOp(c, ConstExpr(0.0, "float32")))
    _builder().record(
        GemmOp(a, b, c, transpose_A, transpose_B, policy, m=am, n=bn, k=ak)
    )


def fill(buffer: TileBuffer, value):
    _builder().record(FillOp(buffer, wrap(value)))


def clear(buffer: TileBuffer):
    fill(buffer, 0.0 if buffer.dtype.startswith(("float", "bf")) else 0)


def _reduce(kind, src, dst, dim, clear):
    if not isinstance(src, TileBuffer) or not isinstance(dst, TileBuffer):
        raise TraceError("T.reduce operands must be whole buffers")
    if dim < 0:
        dim += src.ndim
    expect = tuple(s for i, s in enumerate(src.shape) if i != dim)
    if tuple(dst.shape) != expect and not (expect == () and dst.size == 1):
        raise TraceError(
            f"T.reduce_{kind}: dst shape {dst.shape} != {expect} "
            f"(src {src.shape} minus axis {dim})"
        )
    _builder().record(ReduceOp(kind, src, dst, dim, clear))


def reduce_sum(src, dst, dim: int = -1, clear: bool = True):
    _reduce("sum", src, dst, dim, clear)


def reduce_max(src, dst, dim: int = -1, clear: bool = True):
    _reduce("max", src, dst, dim, clear)


def reduce_min(src, dst, dim: int = -1, clear: bool = True):
    _reduce("min", src, dst, dim, clear)


def reduce_absmax(src, dst, dim: int = -1, clear: bool = True):
    _reduce("absmax", src, dst, dim, clear)


def cumsum(src, dst, dim: int = -1, reverse: bool = False):
    if dim < 0:
        dim += src.ndim
    _builder().record(CumsumOp(src, dst, dim, reverse))


def atomic_add(dst, src):
    d = as_region(dst)
    from .tile_ops import _resolve_against

    dres = _resolve_against(d, as_region(src))
    _builder().record(AtomicOp("add", dres, src))


def call_tile_lib(fn: Callable, output: TileBuffer, *inputs: TileBuffer, name=None):
    """Tile-library escape hatch (TPU analogue of T.call_extern/T.ptx)."""
    _builder().record(CustomOp(fn, tuple(inputs), output, name or fn.__name__))


# ---------------------------------------------------------------------------
# Scheduling annotations
# ---------------------------------------------------------------------------


def annotate_layout(mapping: Dict[TileBuffer, Any]):
    b = _builder()
    for buf, layout in mapping.items():
        b.annotations.layouts[buf.name] = layout


def use_swizzle(factor: int = 8):
    """Rasterization swizzle over the parallel grid — on TPU this reorders
    the sequential grid walk for HBM-reuse (analogue of L2 swizzle)."""
    _builder().annotations.swizzle = int(factor)


def import_source(*_args, **_kw):
    """GPU-only source injection; recorded as a no-op for source compat."""
    _builder().annotations.extra.setdefault("import_source", True)


# ---------------------------------------------------------------------------
# Scalar math / expression helpers
# ---------------------------------------------------------------------------


def _unary(op):
    def f(x):
        return UnaryExpr(op, wrap(x))

    return f


exp = _unary("exp")
exp2 = _unary("exp2")
log = _unary("log")
log2 = _unary("log2")
sqrt = _unary("sqrt")
rsqrt = _unary("rsqrt")
abs = _unary("abs")  # noqa: A001 - mirrors T.abs
tanh = _unary("tanh")
sigmoid = _unary("sigmoid")
floor = _unary("floor")
ceil = _unary("ceil")


def maximum(a, b):
    return BinExpr("max", wrap(a), wrap(b))


def minimum(a, b):
    return BinExpr("min", wrap(a), wrap(b))


def if_then_else(cond, a, b):
    return WhereExpr(wrap(cond), wrap(a), wrap(b))


def cast(x, dtype: str):
    return CastExpr(wrap(x), canonical_dtype(dtype))


def float32(x):
    return cast(x, "float32")


def float16(x):
    return cast(x, "float16")


def bfloat16(x):
    return cast(x, "bfloat16")


def int32(x):
    return cast(x, "int32")


def infinity(dtype: str = "float32"):
    return ConstExpr(float("inf"), canonical_dtype(dtype))


def ceildiv(a, b):
    if isinstance(a, int) and isinstance(b, int):
        return -(-a // b)
    return (wrap(a) + (wrap(b) - 1)) // b
