"""Priority-ordered layout & binding inference (paper §4.1–4.2).

The paper's scheme: maintain a LayoutMap over all buffers; process tile
operators from the *strictest* layout requirements down to the most flexible,
letting strict ops (tensor-core GEMM) pin layouts that flexible ops
(elementwise) must then conform to.

TPU adaptation: "thread binding" becomes *vector-lane binding* — the mapping
of logical tile elements onto (vreg_tile, lane) coordinates, plus the padded
physical VMEM shape Mosaic will materialize.  The same top-down priority
walk applies:

  level 0 (STRICT)  GemmOp  — MXU 128×128 alignment, vreg fragments for
                     operands/accumulator
  level 1 (COMMON)  Copy/Reduce — conforming padded layouts, DMA-friendly
                     minor-dim contiguity
  level 2 (FLEX)    Parallel/Fill — whatever is still unbound; vectorization
                     width and replication inferred per Fig. 7/8

The result feeds: the VMEM planner (padded footprints), the cost model
(padding waste, MXU utilization), and tests that assert the Fig. 7
replication semantics.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from .buffer import FRAGMENT, GLOBAL, SCALAR, SHARED, TileBuffer
from .errors import LayoutError
from .expr import VarExpr, linear_decompose
from .layout import (
    LANE,
    MXU,
    Fragment,
    Layout,
    padded,
    round_up,
    sublane,
    vreg_fragment,
)
from .tile_ops import (
    LEVEL_COMMON,
    LEVEL_FLEX,
    LEVEL_STRICT,
    CopyOp,
    CustomOp,
    FillOp,
    GemmOp,
    ParallelOp,
    PipelinedOp,
    ReduceOp,
    SerialOp,
    TileOp,
)


@dataclasses.dataclass
class GemmReport:
    op: str
    m: int
    n: int
    k: int
    mxu_m: int
    mxu_n: int
    mxu_k: int
    a_dtype: str = "float32"

    @property
    def mxu_utilization(self) -> float:
        """Fraction of MXU issue slots doing useful work for this tile."""
        return (self.m / self.mxu_m) * (self.n / self.mxu_n) * (self.k / self.mxu_k)


@dataclasses.dataclass
class ParallelBinding:
    """Inferred binding for one T.Parallel op (paper Fig. 7/8)."""

    axes: Tuple[str, ...]
    extents: Tuple[int, ...]
    vector_width: int  # lanes engaged on the innermost axis
    # buffer -> replication count (elements held in >1 partition)
    replication: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class InferenceResult:
    layouts: Dict[str, Layout]
    gemms: List[GemmReport]
    parallels: List[ParallelBinding]
    waste: Dict[str, float]

    def summary(self) -> str:
        lines = ["layout inference:"]
        for name, lay in self.layouts.items():
            w = self.waste.get(name, 0.0)
            lines.append(f"  {name:<18} {lay!r}" + (f"  waste={w:.0%}" if w else ""))
        for g in self.gemms:
            lines.append(
                f"  gemm {g.op}: {g.m}x{g.n}x{g.k} on MXU "
                f"{g.mxu_m}x{g.mxu_n}x{g.mxu_k} util={g.mxu_utilization:.0%}"
            )
        for p in self.parallels:
            rep = {k: v for k, v in p.replication.items() if v > 1}
            lines.append(
                f"  parallel {p.axes}: vec={p.vector_width}"
                + (f" replicated={rep}" if rep else "")
            )
        return "\n".join(lines)


def _walk(ops):
    for op in ops:
        yield op
        if isinstance(op, (PipelinedOp, SerialOp)):
            yield from _walk(op.body)


def _padded_layout(buf: TileBuffer) -> Layout:
    """Physical VMEM layout: identity coordinates inside a (sublane, lane)-
    aligned box (the non-bijective padding layout of paper Fig. 5c)."""
    if buf.ndim == 0:
        raise LayoutError(f"Scalar buffer {buf.name} not supported")
    pad_to = list(buf.shape)
    pad_to[-1] = round_up(pad_to[-1], LANE)
    if buf.ndim >= 2:
        pad_to[-2] = round_up(pad_to[-2], sublane(buf.dtype))
    return padded(buf.shape, pad_to)


def _fragment_layout(buf: TileBuffer) -> Fragment:
    """Vreg fragment over the last two dims (leading dims repeat tiles)."""
    if buf.ndim == 1:
        frag = vreg_fragment((1, buf.shape[-1]), buf.dtype)
        return frag
    frag = vreg_fragment((buf.shape[-2], buf.shape[-1]), buf.dtype)
    for d in range(buf.ndim - 3, -1, -1):
        frag = frag.repeat(buf.shape[d], axis=0)
    return frag


def infer_layouts(program) -> InferenceResult:
    layouts: Dict[str, Layout] = {}
    gemms: List[GemmReport] = []
    parallels: List[ParallelBinding] = []

    # User annotations always win (T.annotate_layout).
    user = dict(program.annotations.layouts)

    def assign(buf: TileBuffer, make):
        # GLOBAL operands live in HBM; SCALAR operands live in SMEM and are
        # read element-wise — neither gets a VMEM tile layout.
        if buf.scope in (GLOBAL, SCALAR) or buf.name in layouts:
            return
        if buf.name in user:
            layouts[buf.name] = user[buf.name]
            return
        layouts[buf.name] = make(buf)

    ops = list(_walk(program.ops))

    # ---- level 0: GEMM (strict) ------------------------------------------
    for op in ops:
        if not isinstance(op, GemmOp):
            continue
        for buf in (op.a, op.b):
            assign(buf, _padded_layout if buf.scope == SHARED else _fragment_layout)
        assign(op.c, _fragment_layout)
        # MXU alignment: the systolic array wants M and N in multiples of
        # 128; the contraction dim K streams through and only pads to the
        # sublane granule of the operand dtype.
        gemms.append(
            GemmReport(
                op=f"{op.a.name}@{op.b.name}",
                m=op.m,
                n=op.n,
                k=op.k,
                mxu_m=round_up(op.m, MXU[0]),
                mxu_n=round_up(op.n, MXU[1]),
                mxu_k=round_up(op.k, sublane(op.a.dtype)),
                a_dtype=op.a.dtype,
            )
        )

    # ---- level 1: copy / reduce (common) -----------------------------------
    for op in ops:
        if isinstance(op, CopyOp):
            for buf in (op.src.buffer, op.dst.buffer):
                assign(buf, _padded_layout if buf.scope == SHARED else _fragment_layout)
        elif isinstance(op, ReduceOp):
            assign(op.src, _fragment_layout if op.src.scope == FRAGMENT else _padded_layout)
            assign(op.dst, _fragment_layout if op.dst.scope == FRAGMENT else _padded_layout)

    # ---- level 2: elementwise / fill (flex) ---------------------------------
    for op in ops:
        if isinstance(op, FillOp):
            assign(op.buffer, _padded_layout if op.buffer.scope == SHARED else _fragment_layout)
        elif isinstance(op, CustomOp):
            for buf in (*op.inputs, op.output):
                assign(buf, _padded_layout if buf.scope == SHARED else _fragment_layout)
        elif isinstance(op, ParallelOp):
            for buf in (*op.buffers_read(), *op.buffers_written()):
                assign(buf, _padded_layout if buf.scope == SHARED else _fragment_layout)
            parallels.append(_infer_parallel_binding(op))

    # ---- waste accounting ----------------------------------------------------
    waste: Dict[str, float] = {}
    by_name = {b.name: b for b in program.allocs}
    for name, lay in layouts.items():
        buf = by_name.get(name)
        if buf is None:
            continue
        phys = int(np.prod(lay.out_shape())) if not isinstance(lay, Fragment) else None
        if phys is None:
            # fragments: partition*local slots
            shp = lay.out_shape()
            phys = int(np.prod(shp))
        log = buf.size
        waste[name] = max(0.0, 1.0 - log / max(phys, 1))

    return InferenceResult(layouts, gemms, parallels, waste)


def _infer_parallel_binding(op: ParallelOp) -> ParallelBinding:
    """Replication & vectorization inference for one elementwise op.

    A buffer whose index expressions do not mention some parallel axis is
    *replicated* across that axis (paper Fig. 7: the bias row needed by every
    thread column).  The innermost axis determines the vector width: if the
    buffer accesses are affine with unit stride in that axis we can engage
    full 128-lane vectors.
    """
    from .expr import free_vars, loads_in

    axis_names = tuple(a.name for a in op.axes)
    replication: Dict[str, int] = {}
    unit_stride = True
    inner = axis_names[-1]

    def visit_access(buf: TileBuffer, idx_exprs):
        used = set()
        for e in idx_exprs:
            used |= free_vars(e)
        rep = 1
        for nm, ext in zip(axis_names, op.extents):
            if nm not in used:
                rep *= ext
        prev = replication.get(buf.name, 1)
        replication[buf.name] = max(prev, rep)
        # unit-stride check on the innermost axis in the minor index
        if idx_exprs:
            dec = linear_decompose(idx_exprs[-1])
            nonlocal unit_stride
            if dec is None or dec.get(inner, 0) not in (0, 1):
                unit_stride = False

    for buf, idx, val in op.stores:
        visit_access(buf, idx)
        for ld in loads_in(val):
            visit_access(ld.buffer, ld.indices)

    vec = min(op.extents[-1], LANE) if unit_stride else 1
    return ParallelBinding(axis_names, tuple(op.extents), vec, replication)
