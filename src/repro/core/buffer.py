"""Tile buffers and memory scopes.

TileLang's hallmark is *explicit placement* of buffers in the memory
hierarchy.  On the TPU target the scopes map as (see DESIGN.md §2):

=================  =======================  ==================================
TileLang scope     GPU realization          TPU realization (this package)
=================  =======================  ==================================
``global``         HBM/DRAM                 HBM (pallas_call operands)
``shared``         SMEM (per-block SRAM)    VMEM window (BlockSpec-managed) or
                                            VMEM scratch when locally produced
``fragment``       register file per block  VMEM scratch accumulator; Mosaic
                                            keeps the hot tile in VREGs
=================  =======================  ==================================

Indexing a buffer returns either a :class:`Region` (corner/slice selection,
used as ``T.copy`` operands) or, inside a ``T.Parallel`` elementwise body, a
:class:`LoadExpr` scalar node.  Assignment inside ``T.Parallel`` records an
elementwise-store op on the current kernel context.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Sequence, Tuple

import numpy as np

from .errors import TraceError
from .expr import ConstExpr, Expr, LoadExpr, static_eval, wrap

GLOBAL = "global"
SHARED = "shared"
FRAGMENT = "fragment"
# Scalar-prefetch params (T.ScalarTensor): small integer tensors placed in
# SMEM ahead of the grid walk so *index expressions* — BlockSpec index maps
# included — may read them.  This is how data-dependent gathers (paged
# attention block tables) stay inside the declarative window model.
SCALAR = "scalar"

_SCOPES = (GLOBAL, SHARED, FRAGMENT, SCALAR)

_counter = itertools.count()

_DTYPE_BITS = {
    "float32": 32,
    "bfloat16": 16,
    "float16": 16,
    "float64": 64,
    "int8": 8,
    "uint8": 8,
    "int16": 16,
    "int32": 32,
    "uint32": 32,
    "int64": 64,
    "bool": 8,
    "float8_e4m3fn": 8,
    "float8_e5m2": 8,
}

_DTYPE_ALIASES = {
    "fp32": "float32",
    "f32": "float32",
    "bf16": "bfloat16",
    "fp16": "float16",
    "f16": "float16",
    "fp64": "float64",
    "i8": "int8",
    "u8": "uint8",
    "i32": "int32",
    "i64": "int64",
}


def canonical_dtype(dtype: str) -> str:
    d = _DTYPE_ALIASES.get(dtype, dtype)
    if d not in _DTYPE_BITS:
        raise TraceError(f"Unsupported tile dtype {dtype!r}")
    return d


def dtype_bits(dtype: str) -> int:
    return _DTYPE_BITS[canonical_dtype(dtype)]


@dataclasses.dataclass(frozen=True)
class AxisSel:
    """Selection along one buffer axis.

    ``kind`` is one of:
      * ``"corner"``  — scalar start index; extent taken from the peer buffer
      * ``"collapse"``— scalar index selecting a single element (axis dropped)
      * ``"slice"``   — explicit [start, start+size) window
      * ``"full"``    — the whole axis
    """

    kind: str
    start: Expr
    size: Optional[int] = None  # static size for "slice"/"full"


class Region:
    """A rectangular sub-region of a buffer, as produced by indexing."""

    def __init__(self, buffer: "TileBuffer", sels: Tuple[AxisSel, ...]):
        self.buffer = buffer
        self.sels = sels

    def __repr__(self):
        return f"Region({self.buffer.name}, {self.sels})"


class TileBuffer:
    """A shaped, typed buffer living in one of the three memory scopes."""

    def __init__(
        self,
        shape: Sequence[int],
        dtype: str,
        scope: str,
        name: Optional[str] = None,
    ):
        if scope not in _SCOPES:
            raise TraceError(f"Unknown buffer scope {scope!r}")
        self.shape: Tuple[int, ...] = tuple(int(s) for s in shape)
        if any(s <= 0 for s in self.shape):
            raise TraceError(f"Buffer shape must be positive, got {self.shape}")
        self.dtype = canonical_dtype(dtype)
        self.scope = scope
        self.name = name or f"{scope[0]}buf{next(_counter)}"

    # ------------------------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))

    @property
    def nbytes(self) -> int:
        return self.size * dtype_bits(self.dtype) // 8

    def __repr__(self):
        return f"TileBuffer({self.name}: {self.scope} {self.dtype}{list(self.shape)})"

    # ------------------------------------------------------------------
    # Indexing.  Two modes:
    #   * inside a T.Parallel body -> scalar LoadExpr / elementwise store
    #   * otherwise                -> Region (T.copy operand)
    # ------------------------------------------------------------------
    def _normalize_idx(self, idx) -> Tuple:
        if not isinstance(idx, tuple):
            idx = (idx,)
        if len(idx) > self.ndim:
            raise TraceError(
                f"{self.name}: {len(idx)} indices for {self.ndim}-d buffer"
            )
        # pad with full-axis selections
        idx = idx + (slice(None),) * (self.ndim - len(idx))
        return idx

    def __getitem__(self, idx):
        from . import program  # circular-safe: resolved at call time

        idx = self._normalize_idx(idx)
        if self.scope == SCALAR:
            # Scalar-prefetch buffers are read element-wise wherever an index
            # expression is legal: copy-region starts (-> data-dependent
            # BlockSpec index maps) and T.Parallel bodies alike.
            exprs = []
            for i in idx:
                if isinstance(i, slice):
                    raise TraceError(
                        f"{self.name}: scalar-prefetch buffers must be indexed "
                        "element-wise (no slices)."
                    )
                exprs.append(wrap(i))
            if len(exprs) != self.ndim:
                raise TraceError(
                    f"{self.name}: scalar-prefetch load needs all {self.ndim} "
                    f"indices, got {len(exprs)}"
                )
            return LoadExpr(self, tuple(exprs))
        ctx = program.current_parallel_context()
        if ctx is not None and self.scope != GLOBAL:
            # Elementwise scalar load
            exprs = []
            for axis, i in enumerate(idx):
                if isinstance(i, slice):
                    if i.start is None and i.stop is None:
                        raise TraceError(
                            f"{self.name}: slices are not allowed in elementwise "
                            "bodies; index every axis with scalar expressions."
                        )
                    raise TraceError("Partial slices unsupported in T.Parallel body")
                exprs.append(wrap(i))
            return LoadExpr(self, tuple(exprs))
        # Region mode
        sels = []
        for axis, i in enumerate(idx):
            if isinstance(i, slice):
                if i.step not in (None, 1):
                    raise TraceError("Strided slices are not supported")
                if i.start is None and i.stop is None:
                    sels.append(
                        AxisSel("full", ConstExpr(0), self.shape[axis])
                    )
                else:
                    start = wrap(i.start if i.start is not None else 0)
                    if i.stop is None:
                        raise TraceError("Open-ended slices unsupported")
                    stop = wrap(i.stop)
                    size = _static_extent(start, stop)
                    sels.append(AxisSel("slice", start, size))
            else:
                # scalar: corner vs collapse resolved later against the peer
                sels.append(AxisSel("corner", wrap(i), None))
        return Region(self, tuple(sels))

    def __setitem__(self, idx, value):
        from . import program

        ctx = program.current_parallel_context()
        if ctx is None:
            raise TraceError(
                f"Assignment to {self.name}[...] outside a T.Parallel body; "
                "use T.copy / T.fill for region writes."
            )
        idx = self._normalize_idx(idx)
        exprs = []
        for i in idx:
            if isinstance(i, slice):
                raise TraceError("Slices unsupported on the LHS of elementwise stores")
            exprs.append(wrap(i))
        ctx.record_store(self, tuple(exprs), wrap(value))

    # convenience: whole-buffer region
    def full_region(self) -> Region:
        return Region(
            self,
            tuple(AxisSel("full", ConstExpr(0), s) for s in self.shape),
        )


def _static_extent(start: Expr, stop: Expr) -> int:
    """Extent of ``stop - start``; must be statically known."""
    from .expr import BinExpr

    diff = BinExpr("sub", stop, start)
    val = static_eval(diff)
    if val is None:
        # Common symbolic pattern: k*c : (k+1)*c  -> extent c.
        val = _symbolic_extent(start, stop)
    if val is None:
        raise TraceError(
            f"Slice extent must be static; got [{start} : {stop}]"
        )
    if val <= 0:
        raise TraceError(f"Slice extent must be positive, got {val}")
    return int(val)


def _symbolic_extent(start: Expr, stop: Expr) -> Optional[int]:
    """Recognize ``e*c : (e+1)*c`` and ``e : e+c`` patterns."""
    from .expr import BinExpr, linear_decompose

    ds, dp = linear_decompose(start), linear_decompose(stop)
    if ds is None or dp is None:
        return None
    names = set(ds) | set(dp)
    diff = {}
    for n in names:
        diff[n] = dp.get(n, 0) - ds.get(n, 0)
    if any(v != 0 for k, v in diff.items() if k != ""):
        return None
    return diff.get("", None)
