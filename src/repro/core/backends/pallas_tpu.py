"""Pallas-TPU backend: ``LoweredModule -> pl.pallas_call`` (DESIGN.md §2, §4).

The central translation: a ``T.Pipelined`` loop over K with global->shared
``T.copy`` ops becomes the **Pallas grid pipeline** — the copies turn into
BlockSpec-managed windows whose index maps depend on the reduction grid
axis, so the hardware DMA double-buffers them and overlaps with compute
exactly like cp.async/TMA rings on GPUs.  Fragment buffers become VMEM
scratch accumulators persisting across the ``arbitrary`` axis.

With ``schedule.interpret=True`` the same kernel body executes on CPU for
validation; on a TPU host it is the Mosaic-compiled kernel.
"""
from __future__ import annotations

from typing import Any, Dict, List

import numpy as np

from ..buffer import GLOBAL, SCALAR, TileBuffer
from ..errors import LoweringError, ScheduleError, VerifyError
from ..expr import BinExpr, ConstExpr, Expr, VarExpr, evaluate
from ..lowering.indexing import make_index_map, no_loads
from ..lowering.verify import alias_wiring
from ..lowering.module import CompiledKernel, LoweredModule
from ..lowering.phases import LOOP, POST, PRE
from ..lowering.windows import _is_onchip
from ..tile_ops import (
    AtomicOp,
    CopyOp,
    CumsumOp,
    CustomOp,
    FillOp,
    GemmOp,
    ParallelOp,
    PipelinedOp,
    ReduceOp,
    ResolvedRegion,
    SerialOp,
    TileOp,
)
from . import register_backend


def _compiler_params_cls(pltpu):
    """JAX moved ``TPUCompilerParams`` -> ``CompilerParams`` across releases;
    accept whichever name the installed version exposes."""
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise LoweringError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams nor "
            "TPUCompilerParams; unsupported JAX version"
        )
    return cls


@register_backend("pallas")
def emit_pallas(module: LoweredModule) -> CompiledKernel:
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl
    from jax.experimental.pallas import tpu as pltpu

    program = module.program
    schedule = module.schedule
    if module.vmem is not None and not module.vmem.ok:
        raise ScheduleError(
            f"{program.name}: VMEM budget exceeded —\n{module.vmem.summary()}\n"
            "Reduce block shapes or num_stages."
        )
    phases = module.phases
    in_windows, out_windows = module.in_windows, module.out_windows
    plan = module.grid_plan
    grid, env_builder, kdim = plan.grid, plan.env_builder, plan.kdim
    dim_sem = plan.dimension_semantics
    pipe = phases.pipeline
    scratch_bufs, scratch_pos = module.scratch_bufs, module.scratch_pos
    arg_params, out_params = module.arg_params, module.out_params
    window_of, out_window_of = module.window_of, module.out_window_of

    # ---- operand list: one per input window (+ aliased outputs last) -----
    window_param_idx: List[int] = []
    for w, idx in zip(in_windows, module.window_param_idx):
        if idx is None:
            # a written global read back through a window — unsupported
            raise LoweringError(
                f"{program.name}: {w.param.name} is both written and read "
                "through separate windows; use T.atomic or split kernels."
            )
        window_param_idx.append(idx)
    aliased_js = [j for j, w in enumerate(out_windows) if w.aliased]
    n_in_ops = len(in_windows)

    # ---- scalar-prefetch operands ----------------------------------------
    # T.ScalarTensor params ride ahead of the grid walk in SMEM
    # (PrefetchScalarGridSpec); every index map then receives their refs as
    # trailing args so window starts may load them (block-table gathers).
    # Output windows go through the same index-map derivation, so stores may
    # be table-directed too (the chunked-prefill kernel writing K/V pages);
    # combined with an in-out alias the unwritten pages keep their contents.
    scalar_params = module.scalar_params
    n_scalars = len(scalar_params)
    scalar_pos = {p.name: i for i, p in enumerate(scalar_params)}
    arg_pos = {id(p): i for i, p in enumerate(arg_params)}
    scalar_arg_idx = [arg_pos[id(p)] for p in scalar_params]

    def _index_map(region):
        return make_index_map(region, env_builder, scalar_params or None)

    # ---- specs -----------------------------------------------------------
    in_specs = [
        pl.BlockSpec(w.block_shape, _index_map(w.region)) for w in in_windows
    ]
    alias_in_specs = [
        pl.BlockSpec(
            out_windows[j].block_shape,
            _index_map(out_windows[j].region),
        )
        for j in aliased_js
    ]
    out_specs = [
        pl.BlockSpec(w.block_shape, _index_map(w.region)) for w in out_windows
    ]
    out_shape = [
        jax.ShapeDtypeStruct(w.param.shape, jnp.dtype(w.param.dtype))
        for w in out_windows
    ]
    scratch_shapes = [
        pltpu.VMEM(b.shape, jnp.dtype(b.dtype)) for b in scratch_bufs
    ]
    # alias operand indices are positional over *all* pallas_call inputs —
    # scalar-prefetch operands included.  Cross-check against the verifier's
    # canonical wiring: a drift between the operand list assembled here and
    # the windows' aliased marks would silently alias the wrong buffers.
    input_output_aliases = {
        n_scalars + n_in_ops + i: j for i, j in enumerate(aliased_js)
    }
    expected_aliases = alias_wiring(module)
    if input_output_aliases != expected_aliases:
        raise VerifyError(
            f"{program.name}: input_output_aliases {input_output_aliases} "
            f"disagrees with the verifier wiring {expected_aliases}"
        )

    kext = pipe.extent if pipe is not None else None

    # ---- kernel body ------------------------------------------------------
    def body(*refs):
        scalar_refs = refs[:n_scalars]
        refs = refs[n_scalars:]
        n_in_total = n_in_ops + len(alias_in_specs)
        in_refs = refs[:n_in_total]
        out_refs = refs[n_in_total : n_in_total + len(out_windows)]
        scr_refs = refs[n_in_total + len(out_windows) :]

        grid_ids = tuple(pl.program_id(d) for d in range(len(grid)))
        env_scalars = env_builder(*grid_ids)
        kval = grid_ids[kdim] if kdim is not None else None

        values: Dict[str, Any] = {}
        dirty: set = set()

        def squeeze(arr, region: ResolvedRegion):
            keep = tuple(
                i for i, c in enumerate(region.collapsed) if not c
            )
            if len(keep) == arr.ndim:
                return arr
            return arr.reshape(tuple(arr.shape[i] for i in keep))

        def get(buf: TileBuffer):
            if buf.name in values:
                return values[buf.name]
            if buf.scope == SCALAR:
                val = scalar_refs[scalar_pos[buf.name]][...]
                values[buf.name] = val
                return val
            if buf.name in window_of:
                w = in_windows[window_of[buf.name]]
                val = squeeze(in_refs[window_of[buf.name]][...], w.region)
                val = val.astype(jnp.dtype(buf.dtype))
                values[buf.name] = val
                return val
            pos = scratch_pos[buf.name]
            val = scr_refs[pos][...]
            values[buf.name] = val
            return val

        def put(buf: TileBuffer, val):
            if buf.name in window_of:
                raise LoweringError(
                    f"{program.name}: write to window-backed tile {buf.name}"
                )
            val = val.astype(jnp.dtype(buf.dtype))
            val = jnp.broadcast_to(val, buf.shape)
            values[buf.name] = val
            if buf.name in scratch_pos:
                dirty.add(buf.name)

        def gput(buf: TileBuffer, new, phase: str):
            """Phase-guarded value update.

            PRE ops must only take effect at k==0 and POST ops at k==last —
            the body re-executes every grid step, and unguarded PRE/POST
            writes would corrupt accumulators carried across the reduction
            axis.  Guards are functional selects (Mosaic-friendly), not
            control flow."""
            g = guard(phase)
            if g is None:
                put(buf, new)
                return
            new = jnp.broadcast_to(
                jnp.asarray(new).astype(jnp.dtype(buf.dtype)), buf.shape
            )
            put(buf, jnp.where(g, new, get(buf).astype(new.dtype)))

        def scalar_env():
            return dict(env_scalars)

        def eval_expr(e: Expr, extra: Dict[str, Any], load_fn):
            env = scalar_env()
            env.update(extra)
            return evaluate(e, env, load_fn)

        def guard(phase: str):
            """Functional guard for value ops outside the loop phase."""
            if kval is None:
                return None
            if phase == PRE:
                return kval == 0
            if phase == POST:
                return kval == kext - 1
            return None

        def run_fill(op: FillOp, phase: str, extra):
            fillval = eval_expr(op.value, extra, no_loads)
            tile = jnp.full(op.buffer.shape, fillval, dtype=jnp.dtype(op.buffer.dtype))
            gput(op.buffer, tile, phase)

        def region_value(region: ResolvedRegion, extra):
            """Read a region of an on-chip buffer as a tile value."""
            base = get(region.buffer)
            starts = [eval_expr(s, extra, no_loads) for s in region.starts]
            if all(isinstance(s, (int, np.integer)) and s == 0 for s in starts) and tuple(
                region.sizes
            ) == tuple(region.buffer.shape):
                val = base
            else:
                import jax.lax as lax

                val = lax.dynamic_slice(base, [jnp.asarray(s, jnp.int32) for s in starts], region.sizes)
            return squeeze(val, region)

        def run_copy(op: CopyOp, phase: str, extra):
            s, d = op.src.buffer, op.dst.buffer
            if s.scope == GLOBAL and _is_onchip(d):
                val = get(d)  # window read; already cast
                values[d.name] = val
                return
            if _is_onchip(s) and d.scope == GLOBAL:
                j = out_window_of[id(d)]
                w = out_windows[j]
                val = region_value(op.src, extra).astype(jnp.dtype(d.dtype))
                block = val.reshape(w.block_shape)
                g = guard(phase)
                if g is None:
                    out_refs[j][...] = block
                else:
                    @pl.when(g)
                    def _():
                        out_refs[j][...] = block
                return
            # on-chip -> on-chip
            val = region_value(op.src, extra)
            if tuple(op.dst.tile_shape) == tuple(d.shape) and not any(op.dst.collapsed):
                gput(d, val, phase)
            else:
                import jax.lax as lax

                starts = [eval_expr(x, extra, no_loads) for x in op.dst.starts]
                cur = get(d)
                upd = val.reshape(tuple(op.dst.sizes)).astype(cur.dtype)
                gput(
                    d,
                    lax.dynamic_update_slice(
                        cur, upd, [jnp.asarray(x, jnp.int32) for x in starts]
                    ),
                    phase,
                )

        def run_gemm(op: GemmOp, phase: str, extra):
            a, b = get(op.a), get(op.b)
            if op.transpose_a:
                a = a.T if a.ndim == 2 else jnp.swapaxes(a, -1, -2)
            if op.transpose_b:
                b = b.T if b.ndim == 2 else jnp.swapaxes(b, -1, -2)
            acc = get(op.c)
            prod = jax.lax.dot_general(
                a,
                b,
                dimension_numbers=(((a.ndim - 1,), (b.ndim - 2,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
            gput(op.c, acc + prod.astype(acc.dtype), phase)

        def run_reduce(op: ReduceOp, phase: str, extra):
            src = get(op.src)
            if op.kind == "absmax":
                val = jnp.max(jnp.abs(src), axis=op.axis)
            elif op.kind == "sum":
                val = jnp.sum(src, axis=op.axis)
            elif op.kind == "max":
                val = jnp.max(src, axis=op.axis)
            elif op.kind == "min":
                val = jnp.min(src, axis=op.axis)
            elif op.kind == "prod":
                val = jnp.prod(src, axis=op.axis)
            else:
                raise LoweringError(f"Unknown reduce kind {op.kind}")
            if not op.clear:
                cur = get(op.dst)
                comb = {
                    "sum": jnp.add,
                    "max": jnp.maximum,
                    "min": jnp.minimum,
                    "prod": jnp.multiply,
                    "absmax": jnp.maximum,
                }[op.kind]
                val = comb(cur, val.astype(cur.dtype))
            gput(op.dst, val, phase)

        def run_cumsum(op: CumsumOp, phase: str, extra):
            src = get(op.src)
            if op.reverse:
                src = jnp.flip(src, axis=op.axis)
            val = jnp.cumsum(src, axis=op.axis)
            if op.reverse:
                val = jnp.flip(val, axis=op.axis)
            gput(op.dst, val, phase)

        def run_parallel(op: ParallelOp, phase: str, extra):
            nax = len(op.axes)
            axis_names = [a.name for a in op.axes]
            iotas = {}
            for i, (v, e) in enumerate(zip(op.axes, op.extents)):
                shape = [1] * nax
                shape[i] = e
                iotas[v.name] = jax.lax.broadcasted_iota(jnp.int32, tuple(shape), i)

            def structured_load(buffer, idx_exprs):
                """TPU-friendly load patterns over the parallel box.

                * all-direct indices -> the whole tile (pure vector op)
                * ``ax // c`` on an axis -> jnp.repeat along that axis (the
                  vectorized sub-byte unpack idiom; the TPU analogue of PTX
                  lop3 byte-extraction in the paper's dequant kernels)
                Returns None when the pattern doesn't apply.
                """
                if len(idx_exprs) != buffer.ndim or len(idx_exprs) != nax:
                    return None
                plan = []
                for i, e in enumerate(idx_exprs):
                    if (
                        isinstance(e, VarExpr)
                        and e.name == axis_names[i]
                        and buffer.shape[i] == op.extents[i]
                    ):
                        plan.append(("id", 1))
                    elif (
                        isinstance(e, BinExpr)
                        and e.op == "floordiv"
                        and isinstance(e.lhs, VarExpr)
                        and e.lhs.name == axis_names[i]
                        and isinstance(e.rhs, ConstExpr)
                        and buffer.shape[i] * int(e.rhs.value) == op.extents[i]
                    ):
                        plan.append(("repeat", int(e.rhs.value)))
                    else:
                        return None
                val = get(buffer)
                for ax, (kind, c) in enumerate(plan):
                    if kind == "repeat":
                        val = jnp.repeat(val, c, axis=ax)
                return val

            def load_fn(buffer, idx_values, idx_exprs):
                fast = structured_load(buffer, idx_exprs)
                if fast is not None:
                    return fast
                base = get(buffer)
                idx = tuple(jnp.asarray(v) for v in idx_values)
                return base[idx]

            for buf, idx_exprs, val_expr in op.stores:
                senv = scalar_env()
                senv.update(extra)
                senv.update(iotas)
                val = evaluate(val_expr, senv, load_fn)
                direct = (
                    len(idx_exprs) == nax
                    and all(
                        isinstance(e, VarExpr) and e.name == axis_names[i]
                        for i, e in enumerate(idx_exprs)
                    )
                    and tuple(buf.shape) == op.extents
                )
                if direct:
                    new = jnp.broadcast_to(val, op.extents)
                else:
                    cur0 = get(buf)
                    idx_vals = tuple(
                        jnp.asarray(evaluate(e, senv, load_fn)) for e in idx_exprs
                    )
                    new = cur0.at[idx_vals].set(jnp.asarray(val).astype(cur0.dtype))
                gput(buf, new, phase)

        def run_custom(op: CustomOp, phase: str, extra):
            vals = [get(b) for b in op.inputs]
            out = op.fn(*vals)
            if tuple(out.shape) != tuple(op.output.shape):
                raise LoweringError(
                    f"custom op {op.name}: produced {out.shape}, expected "
                    f"{op.output.shape}"
                )
            gput(op.output, out, phase)

        def run_atomic(op: AtomicOp, phase: str, extra):
            j = out_window_of[id(op.dst.buffer)]
            val = get(op.src).astype(jnp.dtype(op.dst.buffer.dtype))
            block = val.reshape(out_windows[j].block_shape)
            comb = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op.kind]
            g = guard(phase)
            if g is None:
                out_refs[j][...] = comb(out_refs[j][...], block)
            else:
                @pl.when(g)
                def _():
                    out_refs[j][...] = comb(out_refs[j][...], block)

        def run_ops(ops: List[TileOp], phase: str, extra):
            for op in ops:
                if isinstance(op, CopyOp):
                    run_copy(op, phase, extra)
                elif isinstance(op, GemmOp):
                    run_gemm(op, phase, extra)
                elif isinstance(op, FillOp):
                    run_fill(op, phase, extra)
                elif isinstance(op, ReduceOp):
                    run_reduce(op, phase, extra)
                elif isinstance(op, CumsumOp):
                    run_cumsum(op, phase, extra)
                elif isinstance(op, ParallelOp):
                    run_parallel(op, phase, extra)
                elif isinstance(op, CustomOp):
                    run_custom(op, phase, extra)
                elif isinstance(op, AtomicOp):
                    run_atomic(op, phase, extra)
                elif isinstance(op, SerialOp):
                    for i in range(op.extent):
                        e2 = dict(extra)
                        e2[op.var.name] = i
                        run_ops(op.body, phase, e2)
                elif isinstance(op, PipelinedOp):
                    raise LoweringError("nested T.Pipelined is unsupported")
                else:
                    raise LoweringError(f"Unhandled op {op!r}")

        run_ops(phases.pre, PRE, {})
        if pipe is not None:
            run_ops(pipe.body, LOOP, {})
        run_ops(phases.post, POST, {})

        # write back dirty scratch accumulators
        for name in dirty:
            scr_refs[scratch_pos[name]][...] = values[name].astype(
                scr_refs[scratch_pos[name]].dtype
            )

    compiler_params = _compiler_params_cls(pltpu)(dimension_semantics=dim_sem)
    if n_scalars:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=n_scalars,
            grid=grid,
            in_specs=in_specs + alias_in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch_shapes,
        )
        call = pl.pallas_call(
            body,
            grid_spec=grid_spec,
            out_shape=out_shape,
            input_output_aliases=input_output_aliases,
            interpret=schedule.interpret,
            compiler_params=compiler_params,
            name=program.name,
        )
    else:
        call = pl.pallas_call(
            body,
            grid=grid,
            in_specs=in_specs + alias_in_specs,
            out_specs=out_specs,
            out_shape=out_shape,
            scratch_shapes=scratch_shapes,
            input_output_aliases=input_output_aliases,
            interpret=schedule.interpret,
            compiler_params=compiler_params,
            name=program.name,
        )

    n_aliased = len(alias_in_specs)
    # pallas_call returns one array per out *window* (store order); the
    # CompiledKernel contract is out *param* (declaration) order — the same
    # order the reference backend produces.
    out_perm = [
        next(j for j, w in enumerate(out_windows) if w.param is p)
        for p in out_params
    ]

    def fn(*arrays):
        # scalar-prefetch operands lead (PrefetchScalarGridSpec convention),
        # then one array per input window, then aliased in-out operands.
        operands = [arrays[i] for i in scalar_arg_idx]
        operands += [arrays[i] for i in window_param_idx]
        operands += list(arrays[len(arrays) - n_aliased :]) if n_aliased else []
        res = call(*operands)
        if len(out_windows) == 1:
            return res[0]
        return tuple(res[j] for j in out_perm)

    return CompiledKernel(
        program, fn, module.info(), arg_params, out_params, backend="pallas"
    )
