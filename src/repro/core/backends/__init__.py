"""Pluggable backend registry (DESIGN.md §4).

A *backend* is a function ``emit(module: LoweredModule) -> CompiledKernel``
registered under a target name.  ``repro.core.compile(..., target=...)``
dispatches through this registry, so adding a target is:

    from repro.core.backends import register_backend

    @register_backend("my_target")
    def emit_my_target(module):
        ...
        return CompiledKernel(module.program, fn, module.info(), ...)

Built-ins: ``pallas`` (Pallas-TPU; ``schedule.interpret=True`` runs the same
kernel body on CPU) and ``reference`` (trace interpreter over jnp arrays —
tiny shapes only, the independent oracle for the lowering itself).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from ..errors import LoweringError
from ..lowering.module import CompiledKernel, LoweredModule

BackendFn = Callable[[LoweredModule], CompiledKernel]

_REGISTRY: Dict[str, BackendFn] = {}

# Alternate spellings accepted by compile(target=...).
_ALIASES = {
    "pallas_tpu": "pallas",
    "tpu": "pallas",
    "interp": "reference",
    "ref": "reference",
}


def register_backend(name: str, emit: Optional[BackendFn] = None):
    """Register ``emit`` under ``name``; usable directly or as a decorator."""
    if name in _ALIASES:
        raise LoweringError(
            f"backend name {name!r} is reserved as an alias of "
            f"{_ALIASES[name]!r}; register under a different name"
        )

    def _register(fn: BackendFn) -> BackendFn:
        _REGISTRY[name] = fn
        return fn

    if emit is not None:
        return _register(emit)
    return _register


def canonical_target(name: str) -> str:
    """Resolve alias spellings so caches key on one name per backend."""
    return _ALIASES.get(name, name)


def get_backend(name: str) -> BackendFn:
    fn = _REGISTRY.get(canonical_target(name))
    if fn is None:
        raise LoweringError(
            f"Unknown backend {name!r}; available: {sorted(_REGISTRY)}"
        )
    return fn


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# Built-in backends self-register on import.
from . import pallas_tpu as _pallas_tpu  # noqa: E402,F401
from . import reference as _reference  # noqa: E402,F401

__all__ = [
    "BackendFn",
    "register_backend",
    "get_backend",
    "available_backends",
]
