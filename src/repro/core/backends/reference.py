"""Reference interpreter backend: an independent oracle for the lowering.

Walks every grid cell sequentially and interprets the traced ops over jnp
arrays — no Pallas, no BlockSpecs, no pipelining.  Tiny shapes only; its
entire value is being *structurally unrelated* to the Pallas emission so the
parity suite can cross-check them (DESIGN.md §4.2).

Two registered targets share the interpreter:

* ``reference`` — the oracle.  Concrete region starts and scalar-load
  indices are always bounds-checked: Python/NumPy negative-index wrap-around
  silently reads from the *end* of a buffer, and ``dynamic_slice`` silently
  clamps, so a corrupt block-table entry would otherwise produce plausible
  garbage instead of an error.
* ``sanitize`` — the oracle under instrumentation (DESIGN.md §5.8): pure
  outputs are poison-filled and tracked per element, duplicate writes from
  distinct grid cells, reads of never-written output regions, non-finite
  values escaping into outputs (with the op that introduced them), and
  vectorized-store bounds are all reported as :class:`SanitizeError`.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import numpy as np

from ..buffer import GLOBAL, SCALAR, TileBuffer
from ..errors import LoweringError, SanitizeError
from ..expr import Expr, VarExpr, evaluate, loads_in
from ..lowering.indexing import no_loads
from ..lowering.module import CompiledKernel, LoweredInfo, LoweredModule
from ..tile_ops import (
    AtomicOp,
    CopyOp,
    CumsumOp,
    CustomOp,
    FillOp,
    GemmOp,
    ParallelOp,
    ReduceOp,
    ResolvedRegion,
    SerialOp,
    TileOp,
)
from . import register_backend


def _as_int(v) -> Optional[int]:
    """Concrete Python int, or None when the value is a tracer."""
    try:
        return int(v)
    except Exception:
        return None


def _check_region_starts(buffer: TileBuffer, starts, sizes, what: str):
    """Loud out-of-bounds error for concrete starts (always on): negative
    starts would wrap, over-large ones would be clamped — both silent."""
    for ax, (s, sz) in enumerate(zip(starts, sizes)):
        c = _as_int(s)
        if c is None:
            continue
        if c < 0 or c + sz > buffer.shape[ax]:
            raise SanitizeError(
                f"{what} out of bounds: {buffer.name} axis {ax} start {c} "
                f"block {sz} exceeds extent {buffer.shape[ax]}"
            )


def _check_scalar_index(buffer: TileBuffer, idx_values):
    for ax, v in enumerate(idx_values):
        c = _as_int(v)
        if c is None:
            continue
        if c < 0 or c >= buffer.shape[ax]:
            raise SanitizeError(
                f"scalar load out of bounds: {buffer.name} axis {ax} "
                f"index {c} not in [0, {buffer.shape[ax]})"
            )


class _Sanitizer:
    """Per-invocation instrumentation state for the ``sanitize`` target.

    ``writer[name]`` maps every element of a written global to the grid
    cell that last wrote it (-1 = never written).  Duplicate writes are
    judged at *cell* granularity: one cell may rewrite its own region
    (pipelined accumulation), two different cells may not — except the
    serving page-0 convention, where table-directed stores park dead rows
    on reserved page 0 (a sanctioned garbage sink).
    """

    def __init__(self, module: LoweredModule):
        self.module = module
        self.cell = -1
        self.writer: Dict[str, np.ndarray] = {}
        self.pure: set = set()
        self.taint: Dict[str, str] = {}
        aliased = {w.param.name for w in module.out_windows if w.aliased}
        for p in module.out_params:
            self.writer[p.name] = np.full(p.shape, -1, np.int64)
            if p.name not in aliased:
                self.pure.add(p.name)

    # -- helpers -----------------------------------------------------------
    def _slices(self, starts, sizes):
        out = []
        for s, sz in zip(starts, sizes):
            c = _as_int(s)
            if c is None:
                return None
            out.append(slice(c, c + sz))
        return tuple(out)

    @staticmethod
    def _page0_sink(region: ResolvedRegion, starts) -> bool:
        """A table-directed store whose dynamic axis landed on 0: the
        serving stack points every dead row at reserved page 0, so
        cross-cell duplicates there are sanctioned."""
        for ax, e in enumerate(region.starts):
            if any(ld.buffer.scope == SCALAR for ld in loads_in(e)):
                if _as_int(starts[ax]) == 0:
                    return True
        return False

    # -- events ------------------------------------------------------------
    def on_region_write(self, region: ResolvedRegion, starts, op: TileOp):
        mask = self.writer.get(region.buffer.name)
        if mask is None:
            return
        if self._page0_sink(region, starts):
            return
        sl = self._slices(starts, region.sizes)
        if sl is None:
            return
        prev = mask[sl]
        clash = prev[(prev >= 0) & (prev != self.cell)]
        if clash.size:
            raise SanitizeError(
                f"duplicate write: cells {int(clash[0])} and {self.cell} "
                f"both write {region.buffer.name}{[s for s in sl]} "
                f"({op.__class__.__name__}) — a lost write on parallel grids"
            )
        mask[sl] = self.cell

    def on_full_write(self, buf: TileBuffer):
        mask = self.writer.get(buf.name)
        if mask is None:
            return
        prev = mask
        clash = prev[(prev >= 0) & (prev != self.cell)]
        if clash.size:
            raise SanitizeError(
                f"duplicate write: cells {int(clash[0])} and {self.cell} "
                f"both write all of {buf.name}"
            )
        mask[...] = self.cell

    def on_scatter_write(self, buf: TileBuffer, idx_vals):
        mask = self.writer.get(buf.name)
        if mask is None:
            return
        try:
            idx = tuple(np.asarray(v) for v in idx_vals)
        except Exception:
            return  # traced indices: nothing concrete to mark
        prev = mask[idx]
        clash = prev[(prev >= 0) & (prev != self.cell)]
        if clash.size:
            raise SanitizeError(
                f"duplicate write: cells {int(clash[0])} and {self.cell} "
                f"both scatter into {buf.name}"
            )
        mask[idx] = self.cell

    def on_region_read(self, region: ResolvedRegion, starts):
        if region.buffer.name not in self.pure:
            return
        mask = self.writer[region.buffer.name]
        sl = self._slices(starts, region.sizes)
        if sl is None:
            return
        if (mask[sl] < 0).any():
            raise SanitizeError(
                f"read of uninitialized output region "
                f"{region.buffer.name}{[s for s in sl]} (never written)"
            )

    def note_value(self, buf: TileBuffer, val, op: TileOp, jnp):
        if buf.name not in self.writer or buf.name in self.taint:
            return
        if not jnp.issubdtype(jnp.asarray(val).dtype, jnp.floating):
            return
        if not bool(jnp.all(jnp.isfinite(val))):
            self.taint[buf.name] = (
                f"{op.__class__.__name__} at cell {self.cell}"
            )

    def check_parallel_indices(self, buf: TileBuffer, idx_vals, jnp):
        for ax, v in enumerate(idx_vals):
            arr = jnp.asarray(v)
            lo, hi = _as_int(jnp.min(arr)), _as_int(jnp.max(arr))
            if lo is None or hi is None:
                continue
            if lo < 0 or hi >= buf.shape[ax]:
                raise SanitizeError(
                    f"vectorized store out of bounds: {buf.name} axis {ax} "
                    f"indices span [{lo}, {hi}], extent {buf.shape[ax]}"
                )

    # -- verdict -----------------------------------------------------------
    def finalize(self, globals_: Dict[str, Any], jnp):
        for name in sorted(self.writer):
            val = globals_[name]
            if name in self.pure and (self.writer[name] < 0).any():
                n = int((self.writer[name] < 0).sum())
                raise SanitizeError(
                    f"output {name}: {n} element(s) never written "
                    "(poisoned values would escape to the caller)"
                )
            if jnp.issubdtype(jnp.asarray(val).dtype, jnp.floating):
                if not bool(jnp.all(jnp.isfinite(val))):
                    origin = self.taint.get(name, "unknown op")
                    raise SanitizeError(
                        f"output {name} contains non-finite values "
                        f"(first introduced by {origin})"
                    )


def _poison(shape, dtype, jnp):
    if jnp.issubdtype(jnp.dtype(dtype), jnp.floating):
        return jnp.full(shape, jnp.nan, jnp.dtype(dtype))
    return jnp.full(shape, jnp.iinfo(jnp.dtype(dtype)).min, jnp.dtype(dtype))


def _emit(module: LoweredModule, sanitize: bool) -> CompiledKernel:
    import itertools

    import jax.numpy as jnp

    program = module.program
    phases = module.phases
    pipe = phases.pipeline
    arg_params, out_params = module.arg_params, module.out_params
    kernel_axes = program.grid_axes

    def fn(*arrays):
        globals_: Dict[str, Any] = {}
        for p, a in zip(arg_params, arrays):
            globals_[p.name] = jnp.asarray(a)
        san = _Sanitizer(module) if sanitize else None
        for p in out_params:
            # In-out (aliased) params are already seeded from arg_params —
            # regions no grid cell writes must keep the caller's contents
            # (paged-KV pool semantics); pure outputs start at zero (or at
            # poison under the sanitizer, so an unwritten element can never
            # masquerade as a legitimate zero).
            if p.name not in globals_:
                globals_[p.name] = (
                    _poison(p.shape, p.dtype, jnp)
                    if sanitize
                    else jnp.zeros(p.shape, jnp.dtype(p.dtype))
                )

        for cell_id, cell in enumerate(
            itertools.product(*[range(e) for _, e in kernel_axes])
        ):
            if san is not None:
                san.cell = cell_id
            env0 = {v.name: idx for (v, _), idx in zip(kernel_axes, cell)}
            tiles: Dict[str, Any] = {}

            def run(ops, extra):
                for op in ops:
                    _ref_op(op, globals_, tiles, {**env0, **extra}, jnp, san)

            run(phases.pre, {})
            if pipe is not None:
                for k in range(pipe.extent):
                    run(pipe.body, {pipe.var.name: k})
            run(phases.post, {})
        if san is not None:
            san.finalize(globals_, jnp)
        outs = [globals_[p.name] for p in out_params]
        return outs[0] if len(outs) == 1 else tuple(outs)

    backend = "sanitize" if sanitize else "reference"
    info = LoweredInfo(
        grid=tuple(e for _, e in kernel_axes),
        dimension_semantics=(backend,),
        vmem=module.vmem,
        inference=module.inference,
        cost=module.cost,
        num_stages=1,
        n_windows_in=len(module.in_windows),
        n_windows_out=len(module.out_windows),
    )
    return CompiledKernel(
        program, fn, info, arg_params, out_params, backend=backend
    )


@register_backend("reference")
def emit_reference(module: LoweredModule) -> CompiledKernel:
    return _emit(module, sanitize=False)


@register_backend("sanitize")
def emit_sanitize(module: LoweredModule) -> CompiledKernel:
    return _emit(module, sanitize=True)


def _ref_op(
    op: TileOp,
    globals_: Dict,
    tiles: Dict,
    env: Dict,
    jnp,
    san: Optional[_Sanitizer] = None,
):
    import jax

    def scalar_load(buffer, idx_values, idx_exprs):
        """Index-expression loads: only scalar-prefetch params are legal."""
        if buffer.scope != SCALAR:
            return no_loads(buffer, idx_values, idx_exprs)
        _check_scalar_index(buffer, idx_values)
        base = globals_[buffer.name]
        return base[tuple(jnp.asarray(v) for v in idx_values)]

    def ev(e: Expr, extra=None, load_fn=None):
        en = dict(env)
        if extra:
            en.update(extra)
        return evaluate(e, en, load_fn if load_fn is not None else scalar_load)

    def get(buf: TileBuffer):
        if buf.scope in (GLOBAL, SCALAR):
            return globals_[buf.name]
        if buf.name not in tiles:
            tiles[buf.name] = jnp.zeros(buf.shape, jnp.dtype(buf.dtype))
        return tiles[buf.name]

    def put(buf: TileBuffer, val):
        val = jnp.broadcast_to(val, buf.shape).astype(jnp.dtype(buf.dtype))
        if buf.scope == GLOBAL:
            if san is not None:
                san.on_full_write(buf)
                san.note_value(buf, val, op, jnp)
            globals_[buf.name] = val
        else:
            tiles[buf.name] = val

    def region_read(region: ResolvedRegion):
        base = get(region.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in region.starts]
        _check_region_starts(region.buffer, starts, region.sizes, "region read")
        if san is not None and region.buffer.scope == GLOBAL:
            san.on_region_read(region, starts)
        val = jax.lax.dynamic_slice(base, starts, region.sizes)
        keep = tuple(i for i, c in enumerate(region.collapsed) if not c)
        return val.reshape(tuple(region.sizes[i] for i in keep))

    def region_write(region: ResolvedRegion, val):
        base = get(region.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in region.starts]
        _check_region_starts(region.buffer, starts, region.sizes, "region write")
        upd = val.reshape(region.sizes).astype(base.dtype)
        if san is not None and region.buffer.scope == GLOBAL:
            san.on_region_write(region, starts, op)
            san.note_value(region.buffer, upd, op, jnp)
        out = jax.lax.dynamic_update_slice(base, upd, starts)
        if region.buffer.scope == GLOBAL:
            globals_[region.buffer.name] = out
        else:
            tiles[region.buffer.name] = out

    if isinstance(op, CopyOp):
        region_write(op.dst, region_read(op.src).astype(jnp.dtype(op.dst.buffer.dtype)))
    elif isinstance(op, FillOp):
        put(op.buffer, jnp.full(op.buffer.shape, ev(op.value), jnp.dtype(op.buffer.dtype)))
    elif isinstance(op, GemmOp):
        a, b = get(op.a), get(op.b)
        if op.transpose_a:
            a = jnp.swapaxes(a, -1, -2)
        if op.transpose_b:
            b = jnp.swapaxes(b, -1, -2)
        acc = get(op.c)
        prod = jax.lax.dot_general(
            a, b, (((a.ndim - 1,), (b.ndim - 2,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        put(op.c, acc + prod.astype(acc.dtype))
    elif isinstance(op, ReduceOp):
        src = get(op.src)
        fns = {
            "sum": jnp.sum,
            "max": jnp.max,
            "min": jnp.min,
            "prod": jnp.prod,
            "absmax": lambda x, axis: jnp.max(jnp.abs(x), axis=axis),
        }
        val = fns[op.kind](src, axis=op.axis)
        if not op.clear:
            comb = {
                "sum": jnp.add,
                "max": jnp.maximum,
                "min": jnp.minimum,
                "prod": jnp.multiply,
                "absmax": jnp.maximum,
            }[op.kind]
            val = comb(get(op.dst), val.astype(get(op.dst).dtype))
        put(op.dst, val)
    elif isinstance(op, CumsumOp):
        src = get(op.src)
        if op.reverse:
            src = jnp.flip(src, axis=op.axis)
        val = jnp.cumsum(src, axis=op.axis)
        if op.reverse:
            val = jnp.flip(val, axis=op.axis)
        put(op.dst, val)
    elif isinstance(op, ParallelOp):
        import jax.lax as lax

        nax = len(op.axes)
        iotas = {}
        for i, (v, e) in enumerate(zip(op.axes, op.extents)):
            shape = [1] * nax
            shape[i] = e
            iotas[v.name] = lax.broadcasted_iota(jnp.int32, tuple(shape), i)

        def load_fn(buffer, idx_values, idx_exprs):
            base = get(buffer)
            return base[tuple(jnp.asarray(v) for v in idx_values)]

        for buf, idx_exprs, val_expr in op.stores:
            val = ev(val_expr, extra=iotas, load_fn=load_fn)
            idx_vals = tuple(jnp.asarray(ev(e, extra=iotas, load_fn=load_fn)) for e in idx_exprs)
            direct = (
                len(idx_exprs) == nax
                and all(
                    isinstance(e, VarExpr) and e.name == op.axes[i].name
                    for i, e in enumerate(idx_exprs)
                )
                and tuple(buf.shape) == op.extents
            )
            if direct:
                put(buf, jnp.broadcast_to(val, op.extents))
            else:
                if san is not None:
                    san.check_parallel_indices(buf, idx_vals, jnp)
                cur = get(buf)
                new = cur.at[idx_vals].set(jnp.asarray(val).astype(cur.dtype))
                if buf.scope == GLOBAL:
                    if san is not None:
                        san.on_scatter_write(buf, idx_vals)
                        san.note_value(buf, new, op, jnp)
                    globals_[buf.name] = new
                else:
                    tiles[buf.name] = new
    elif isinstance(op, CustomOp):
        put(op.output, op.fn(*[get(b) for b in op.inputs]))
    elif isinstance(op, AtomicOp):
        base = get(op.dst.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in op.dst.starts]
        _check_region_starts(op.dst.buffer, starts, op.dst.sizes, "atomic update")
        cur = jax.lax.dynamic_slice(base, starts, op.dst.sizes)
        val = get(op.src).reshape(op.dst.sizes).astype(cur.dtype)
        comb = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op.kind]
        globals_[op.dst.buffer.name] = jax.lax.dynamic_update_slice(
            base, comb(cur, val), starts
        )
    elif isinstance(op, SerialOp):
        for i in range(op.extent):
            for o in op.body:
                _ref_op(o, globals_, tiles, {**env, op.var.name: i}, jnp, san)
    else:
        raise LoweringError(f"reference: unhandled op {op!r}")
