"""Reference interpreter backend: an independent oracle for the lowering.

Walks every grid cell sequentially and interprets the traced ops over jnp
arrays — no Pallas, no BlockSpecs, no pipelining.  Tiny shapes only; its
entire value is being *structurally unrelated* to the Pallas emission so the
parity suite can cross-check them (DESIGN.md §4.2).
"""
from __future__ import annotations

from typing import Any, Dict

from ..buffer import GLOBAL, SCALAR, TileBuffer
from ..errors import LoweringError
from ..expr import Expr, VarExpr, evaluate
from ..lowering.indexing import no_loads
from ..lowering.module import CompiledKernel, LoweredInfo, LoweredModule
from ..tile_ops import (
    AtomicOp,
    CopyOp,
    CumsumOp,
    CustomOp,
    FillOp,
    GemmOp,
    ParallelOp,
    ReduceOp,
    ResolvedRegion,
    SerialOp,
    TileOp,
)
from . import register_backend


@register_backend("reference")
def emit_reference(module: LoweredModule) -> CompiledKernel:
    import itertools

    import jax.numpy as jnp

    program = module.program
    phases = module.phases
    pipe = phases.pipeline
    arg_params, out_params = module.arg_params, module.out_params
    kernel_axes = program.grid_axes

    def fn(*arrays):
        globals_: Dict[str, Any] = {}
        for p, a in zip(arg_params, arrays):
            globals_[p.name] = jnp.asarray(a)
        for p in out_params:
            # In-out (aliased) params are already seeded from arg_params —
            # regions no grid cell writes must keep the caller's contents
            # (paged-KV pool semantics); pure outputs start at zero.
            if p.name not in globals_:
                globals_[p.name] = jnp.zeros(p.shape, jnp.dtype(p.dtype))

        for cell in itertools.product(*[range(e) for _, e in kernel_axes]):
            env0 = {v.name: idx for (v, _), idx in zip(kernel_axes, cell)}
            tiles: Dict[str, Any] = {}

            def run(ops, extra):
                for op in ops:
                    _ref_op(op, globals_, tiles, {**env0, **extra}, jnp)

            run(phases.pre, {})
            if pipe is not None:
                for k in range(pipe.extent):
                    run(pipe.body, {pipe.var.name: k})
            run(phases.post, {})
        outs = [globals_[p.name] for p in out_params]
        return outs[0] if len(outs) == 1 else tuple(outs)

    info = LoweredInfo(
        grid=tuple(e for _, e in kernel_axes),
        dimension_semantics=("reference",),
        vmem=module.vmem,
        inference=module.inference,
        cost=module.cost,
        num_stages=1,
        n_windows_in=len(module.in_windows),
        n_windows_out=len(module.out_windows),
    )
    return CompiledKernel(
        program, fn, info, arg_params, out_params, backend="reference"
    )


def _ref_op(op: TileOp, globals_: Dict, tiles: Dict, env: Dict, jnp):
    import jax

    def scalar_load(buffer, idx_values, idx_exprs):
        """Index-expression loads: only scalar-prefetch params are legal."""
        if buffer.scope != SCALAR:
            return no_loads(buffer, idx_values, idx_exprs)
        base = globals_[buffer.name]
        return base[tuple(jnp.asarray(v) for v in idx_values)]

    def ev(e: Expr, extra=None, load_fn=None):
        en = dict(env)
        if extra:
            en.update(extra)
        return evaluate(e, en, load_fn if load_fn is not None else scalar_load)

    def get(buf: TileBuffer):
        if buf.scope in (GLOBAL, SCALAR):
            return globals_[buf.name]
        if buf.name not in tiles:
            tiles[buf.name] = jnp.zeros(buf.shape, jnp.dtype(buf.dtype))
        return tiles[buf.name]

    def put(buf: TileBuffer, val):
        val = jnp.broadcast_to(val, buf.shape).astype(jnp.dtype(buf.dtype))
        if buf.scope == GLOBAL:
            globals_[buf.name] = val
        else:
            tiles[buf.name] = val

    def region_read(region: ResolvedRegion):
        base = get(region.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in region.starts]
        val = jax.lax.dynamic_slice(base, starts, region.sizes)
        keep = tuple(i for i, c in enumerate(region.collapsed) if not c)
        return val.reshape(tuple(region.sizes[i] for i in keep))

    def region_write(region: ResolvedRegion, val):
        base = get(region.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in region.starts]
        upd = val.reshape(region.sizes).astype(base.dtype)
        out = jax.lax.dynamic_update_slice(base, upd, starts)
        if region.buffer.scope == GLOBAL:
            globals_[region.buffer.name] = out
        else:
            tiles[region.buffer.name] = out

    if isinstance(op, CopyOp):
        region_write(op.dst, region_read(op.src).astype(jnp.dtype(op.dst.buffer.dtype)))
    elif isinstance(op, FillOp):
        put(op.buffer, jnp.full(op.buffer.shape, ev(op.value), jnp.dtype(op.buffer.dtype)))
    elif isinstance(op, GemmOp):
        a, b = get(op.a), get(op.b)
        if op.transpose_a:
            a = jnp.swapaxes(a, -1, -2)
        if op.transpose_b:
            b = jnp.swapaxes(b, -1, -2)
        acc = get(op.c)
        prod = jax.lax.dot_general(
            a, b, (((a.ndim - 1,), (b.ndim - 2,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        put(op.c, acc + prod.astype(acc.dtype))
    elif isinstance(op, ReduceOp):
        src = get(op.src)
        fns = {
            "sum": jnp.sum,
            "max": jnp.max,
            "min": jnp.min,
            "prod": jnp.prod,
            "absmax": lambda x, axis: jnp.max(jnp.abs(x), axis=axis),
        }
        val = fns[op.kind](src, axis=op.axis)
        if not op.clear:
            comb = {
                "sum": jnp.add,
                "max": jnp.maximum,
                "min": jnp.minimum,
                "prod": jnp.multiply,
                "absmax": jnp.maximum,
            }[op.kind]
            val = comb(get(op.dst), val.astype(get(op.dst).dtype))
        put(op.dst, val)
    elif isinstance(op, CumsumOp):
        src = get(op.src)
        if op.reverse:
            src = jnp.flip(src, axis=op.axis)
        val = jnp.cumsum(src, axis=op.axis)
        if op.reverse:
            val = jnp.flip(val, axis=op.axis)
        put(op.dst, val)
    elif isinstance(op, ParallelOp):
        import jax.lax as lax

        nax = len(op.axes)
        iotas = {}
        for i, (v, e) in enumerate(zip(op.axes, op.extents)):
            shape = [1] * nax
            shape[i] = e
            iotas[v.name] = lax.broadcasted_iota(jnp.int32, tuple(shape), i)

        def load_fn(buffer, idx_values, idx_exprs):
            base = get(buffer)
            return base[tuple(jnp.asarray(v) for v in idx_values)]

        for buf, idx_exprs, val_expr in op.stores:
            val = ev(val_expr, extra=iotas, load_fn=load_fn)
            idx_vals = tuple(jnp.asarray(ev(e, extra=iotas, load_fn=load_fn)) for e in idx_exprs)
            direct = (
                len(idx_exprs) == nax
                and all(
                    isinstance(e, VarExpr) and e.name == op.axes[i].name
                    for i, e in enumerate(idx_exprs)
                )
                and tuple(buf.shape) == op.extents
            )
            if direct:
                put(buf, jnp.broadcast_to(val, op.extents))
            else:
                cur = get(buf)
                put(buf, cur.at[idx_vals].set(jnp.asarray(val).astype(cur.dtype)))
    elif isinstance(op, CustomOp):
        put(op.output, op.fn(*[get(b) for b in op.inputs]))
    elif isinstance(op, AtomicOp):
        base = get(op.dst.buffer)
        starts = [jnp.asarray(ev(s), jnp.int32) for s in op.dst.starts]
        cur = jax.lax.dynamic_slice(base, starts, op.dst.sizes)
        val = get(op.src).reshape(op.dst.sizes).astype(cur.dtype)
        comb = {"add": jnp.add, "max": jnp.maximum, "min": jnp.minimum}[op.kind]
        globals_[op.dst.buffer.name] = jax.lax.dynamic_update_slice(
            base, comb(cur, val), starts
        )
    elif isinstance(op, SerialOp):
        for i in range(op.extent):
            for o in op.body:
                _ref_op(o, globals_, tiles, {**env, op.var.name: i}, jnp)
    else:
        raise LoweringError(f"reference: unhandled op {op!r}")
