# repro.core — TileLang-on-TPU: the paper's primary contribution.
#
# A Python-embedded tile DSL (program.py) whose dataflow operators
# (tile_ops.py) are decoupled from scheduling (schedule.py), with
# priority-ordered layout inference (infer.py, layout.py) and a lowering to
# Pallas TPU kernels / a reference interpreter (lower.py).  autotune.py adds
# the cost-model config search.  See DESIGN.md §2 for the GPU->TPU mapping.

from . import program as lang  # the "T" namespace:  from repro.core import lang as T
from .autotune import autotune, grid_configs
from .buffer import FRAGMENT, GLOBAL, SHARED, Region, TileBuffer
from .errors import (
    LayoutError,
    LoweringError,
    ScheduleError,
    TileError,
    TraceError,
)
from .infer import InferenceResult, infer_layouts
from .layout import Fragment, IterVar, Layout, padded, row_major, swizzle_2d, tiled_2d, vreg_fragment
from .lower import CompiledKernel, KernelCost, compile
from .program import TileProgram, Tensor, prim_func
from .schedule import Schedule, plan_vmem

__all__ = [
    "lang",
    "autotune",
    "grid_configs",
    "FRAGMENT",
    "GLOBAL",
    "SHARED",
    "Region",
    "TileBuffer",
    "TileError",
    "TraceError",
    "LoweringError",
    "LayoutError",
    "ScheduleError",
    "InferenceResult",
    "infer_layouts",
    "Fragment",
    "IterVar",
    "Layout",
    "padded",
    "row_major",
    "swizzle_2d",
    "tiled_2d",
    "vreg_fragment",
    "CompiledKernel",
    "KernelCost",
    "compile",
    "TileProgram",
    "Tensor",
    "prim_func",
    "Schedule",
    "plan_vmem",
]
