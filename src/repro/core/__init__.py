# repro.core — TileLang-on-TPU: the paper's primary contribution.
#
# A Python-embedded tile DSL (program.py) whose dataflow operators
# (tile_ops.py) are decoupled from scheduling (schedule.py), with
# priority-ordered layout inference (infer.py, layout.py), a pass-based
# lowering pipeline (lowering/) producing a LoweredModule analysis artifact,
# and a pluggable backend registry (backends/: Pallas-TPU + a reference
# interpreter).  autotune.py adds the cost-model config search over cached
# analyses.  See DESIGN.md §2 for the GPU->TPU mapping and §3–§4 for the
# pipeline/backend architecture.

from . import program as lang  # the "T" namespace:  from repro.core import lang as T
from .autotune import autotune, grid_configs
from .backends import available_backends, get_backend, register_backend
from .buffer import FRAGMENT, GLOBAL, SCALAR, SHARED, Region, TileBuffer
from .compiler import clear_compile_cache, compile
from .errors import (
    LayoutError,
    LoweringError,
    ScheduleError,
    TileError,
    TraceError,
)
from .infer import InferenceResult, infer_layouts
from .layout import Fragment, IterVar, Layout, padded, row_major, swizzle_2d, tiled_2d, vreg_fragment
from .lowering import (
    CompiledKernel,
    KernelCost,
    LoweredInfo,
    LoweredModule,
    analyze,
    program_fingerprint,
)
from .program import ScalarTensor, TileProgram, Tensor, prim_func
from .schedule import Schedule, plan_vmem

__all__ = [
    "lang",
    "autotune",
    "grid_configs",
    "FRAGMENT",
    "GLOBAL",
    "SCALAR",
    "SHARED",
    "Region",
    "TileBuffer",
    "TileError",
    "TraceError",
    "LoweringError",
    "LayoutError",
    "ScheduleError",
    "InferenceResult",
    "infer_layouts",
    "Fragment",
    "IterVar",
    "Layout",
    "padded",
    "row_major",
    "swizzle_2d",
    "tiled_2d",
    "vreg_fragment",
    "CompiledKernel",
    "KernelCost",
    "LoweredInfo",
    "LoweredModule",
    "analyze",
    "program_fingerprint",
    "compile",
    "clear_compile_cache",
    "available_backends",
    "get_backend",
    "register_backend",
    "TileProgram",
    "Tensor",
    "ScalarTensor",
    "prim_func",
    "Schedule",
    "plan_vmem",
]
