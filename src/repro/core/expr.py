"""Symbolic scalar/index expression trees for the tile language.

The Python-embedded frontend (program.py) executes user kernels once with
symbolic objects; every arithmetic interaction builds one of the ``Expr``
nodes below.  Two evaluators consume them:

* ``evaluate`` — vectorized evaluation against an environment mapping
  variable names to (broadcastable) jnp arrays or Python ints.  Used by both
  the pure-jnp reference lowering and the Pallas kernel-body lowering, and by
  ``BlockSpec`` index maps (where the environment holds ``pl.program_id``
  values).
* ``static_eval`` — partial evaluation to a Python int when every leaf is a
  constant (used for shape/divisibility checks at trace time).

Expressions are deliberately small and closed: constants, variables, binary
arithmetic, unary math, comparisons, select, buffer loads and dtype casts.
This is the same role TVM's ``PrimExpr`` plays under TileLang.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

from .errors import TraceError

# ---------------------------------------------------------------------------
# Node definitions
# ---------------------------------------------------------------------------


class Expr:
    """Base class: supports Python arithmetic to build trees."""

    dtype: Optional[str] = None  # optional dtype hint ("float32", ...)

    # -- arithmetic ---------------------------------------------------------
    def __add__(self, o):
        return BinExpr("add", self, wrap(o))

    def __radd__(self, o):
        return BinExpr("add", wrap(o), self)

    def __sub__(self, o):
        return BinExpr("sub", self, wrap(o))

    def __rsub__(self, o):
        return BinExpr("sub", wrap(o), self)

    def __mul__(self, o):
        return BinExpr("mul", self, wrap(o))

    def __rmul__(self, o):
        return BinExpr("mul", wrap(o), self)

    def __truediv__(self, o):
        return BinExpr("div", self, wrap(o))

    def __rtruediv__(self, o):
        return BinExpr("div", wrap(o), self)

    def __floordiv__(self, o):
        return BinExpr("floordiv", self, wrap(o))

    def __rfloordiv__(self, o):
        return BinExpr("floordiv", wrap(o), self)

    def __mod__(self, o):
        return BinExpr("mod", self, wrap(o))

    def __rmod__(self, o):
        return BinExpr("mod", wrap(o), self)

    def __neg__(self):
        return UnaryExpr("neg", self)

    def __pow__(self, o):
        return BinExpr("pow", self, wrap(o))

    # -- bitwise (dequantization kernels) ------------------------------------
    def __rshift__(self, o):
        return BinExpr("shr", self, wrap(o))

    def __lshift__(self, o):
        return BinExpr("shl", self, wrap(o))

    def __and__(self, o):
        return BinExpr("bitand", self, wrap(o))

    def __or__(self, o):
        return BinExpr("bitor", self, wrap(o))

    def __xor__(self, o):
        return BinExpr("bitxor", self, wrap(o))

    # -- comparisons ----------------------------------------------------------
    def __lt__(self, o):
        return BinExpr("lt", self, wrap(o))

    def __le__(self, o):
        return BinExpr("le", self, wrap(o))

    def __gt__(self, o):
        return BinExpr("gt", self, wrap(o))

    def __ge__(self, o):
        return BinExpr("ge", self, wrap(o))

    def eq(self, o):  # cannot override __eq__ safely (hashing)
        return BinExpr("eq", self, wrap(o))

    def ne(self, o):
        return BinExpr("ne", self, wrap(o))

    def astype(self, dtype: str) -> "Expr":
        return CastExpr(self, dtype)

    # -- trace hygiene --------------------------------------------------------
    def __bool__(self):
        raise TraceError(
            "A symbolic tile expression was used in Python control flow "
            "(if/while). Use T.if_then_else / masks instead."
        )

    def __iter__(self):
        raise TraceError("Tile expressions are not iterable.")

    def __hash__(self):  # identity hash; nodes are immutable-by-convention
        return id(self)


@dataclasses.dataclass(eq=False)
class ConstExpr(Expr):
    value: Any
    dtype: Optional[str] = None

    def __repr__(self):
        return f"{self.value}"


@dataclasses.dataclass(eq=False)
class VarExpr(Expr):
    """A named symbolic variable: grid index, loop index, parallel index."""

    name: str
    extent: Optional[int] = None  # range [0, extent) when known

    def __repr__(self):
        return self.name


@dataclasses.dataclass(eq=False)
class BinExpr(Expr):
    op: str
    lhs: Expr
    rhs: Expr

    def __repr__(self):
        return f"({self.lhs} {self.op} {self.rhs})"


@dataclasses.dataclass(eq=False)
class UnaryExpr(Expr):
    op: str
    operand: Expr

    def __repr__(self):
        return f"{self.op}({self.operand})"


@dataclasses.dataclass(eq=False)
class CastExpr(Expr):
    operand: Expr
    target_dtype: str

    def __repr__(self):
        return f"cast<{self.target_dtype}>({self.operand})"


@dataclasses.dataclass(eq=False)
class WhereExpr(Expr):
    cond: Expr
    then: Expr
    otherwise: Expr

    def __repr__(self):
        return f"where({self.cond}, {self.then}, {self.otherwise})"


@dataclasses.dataclass(eq=False)
class LoadExpr(Expr):
    """Read of ``buffer[idx...]`` inside an elementwise (T.Parallel) body."""

    buffer: Any  # TileBuffer; Any to avoid circular import
    indices: Tuple[Expr, ...]

    def __repr__(self):
        idx = ", ".join(map(repr, self.indices))
        return f"{self.buffer.name}[{idx}]"


def wrap(v) -> Expr:
    if isinstance(v, Expr):
        return v
    if isinstance(v, bool):
        return ConstExpr(v, "bool")
    if isinstance(v, int):
        return ConstExpr(v, "int32")
    if isinstance(v, float):
        return ConstExpr(v, "float32")
    raise TraceError(f"Cannot use value of type {type(v)} in a tile expression.")


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

_BIN_IMPL: Dict[str, Callable[[Any, Any], Any]] = {}
_UNARY_IMPL: Dict[str, Callable[[Any], Any]] = {}


def _lazy_impls():
    """jnp imports kept lazy so expr.py stays importable without jax."""
    global _BIN_IMPL, _UNARY_IMPL
    if _BIN_IMPL:
        return
    import jax.numpy as jnp

    _BIN_IMPL.update(
        add=lambda a, b: a + b,
        sub=lambda a, b: a - b,
        mul=lambda a, b: a * b,
        div=lambda a, b: a / b,
        floordiv=lambda a, b: a // b,
        mod=lambda a, b: a % b,
        pow=lambda a, b: a**b,
        shr=lambda a, b: a >> b,
        shl=lambda a, b: a << b,
        bitand=lambda a, b: a & b,
        bitor=lambda a, b: a | b,
        bitxor=lambda a, b: a ^ b,
        lt=lambda a, b: a < b,
        le=lambda a, b: a <= b,
        gt=lambda a, b: a > b,
        ge=lambda a, b: a >= b,
        eq=lambda a, b: a == b,
        ne=lambda a, b: a != b,
        max=jnp.maximum,
        min=jnp.minimum,
    )
    _UNARY_IMPL.update(
        neg=lambda a: -a,
        exp=jnp.exp,
        exp2=jnp.exp2,
        log=jnp.log,
        log2=jnp.log2,
        abs=jnp.abs,
        sqrt=jnp.sqrt,
        rsqrt=lambda a: 1.0 / jnp.sqrt(a),
        sigmoid=lambda a: 1.0 / (1.0 + jnp.exp(-a)),
        tanh=jnp.tanh,
        floor=jnp.floor,
        ceil=jnp.ceil,
    )


def evaluate(e: Expr, env: Dict[str, Any], load_fn: Callable) -> Any:
    """Vectorized evaluation.

    ``env`` maps variable names to values (ints, tracers or arrays shaped to
    broadcast over the surrounding iteration space).  ``load_fn(buffer,
    idx_values, idx_exprs)`` materializes a ``LoadExpr`` — the two lowerings
    supply different implementations (plain array indexing for the reference
    path, Ref reads for the Pallas path).
    """
    _lazy_impls()
    import jax.numpy as jnp

    def rec(node: Expr):
        if isinstance(node, ConstExpr):
            return node.value
        if isinstance(node, VarExpr):
            if node.name not in env:
                raise TraceError(f"Unbound variable {node.name!r} during evaluation.")
            return env[node.name]
        if isinstance(node, BinExpr):
            return _BIN_IMPL[node.op](rec(node.lhs), rec(node.rhs))
        if isinstance(node, UnaryExpr):
            return _UNARY_IMPL[node.op](rec(node.operand))
        if isinstance(node, CastExpr):
            val = rec(node.operand)
            return jnp.asarray(val).astype(node.target_dtype)
        if isinstance(node, WhereExpr):
            return jnp.where(rec(node.cond), rec(node.then), rec(node.otherwise))
        if isinstance(node, LoadExpr):
            idx_values = tuple(rec(i) for i in node.indices)
            return load_fn(node.buffer, idx_values, node.indices)
        raise TraceError(f"Unknown expression node {node!r}")

    return rec(e)


def static_eval(e: Expr) -> Optional[int]:
    """Constant-fold to a Python number, or ``None`` if symbolic."""
    if isinstance(e, ConstExpr):
        return e.value
    if isinstance(e, BinExpr):
        a, b = static_eval(e.lhs), static_eval(e.rhs)
        if a is None or b is None:
            return None
        _PY = {
            "add": lambda x, y: x + y,
            "sub": lambda x, y: x - y,
            "mul": lambda x, y: x * y,
            "floordiv": lambda x, y: x // y,
            "mod": lambda x, y: x % y,
        }
        fn = _PY.get(e.op)
        return None if fn is None else fn(a, b)
    if isinstance(e, UnaryExpr) and e.op == "neg":
        a = static_eval(e.operand)
        return None if a is None else -a
    return None


def free_vars(e: Expr) -> set:
    """Names of all variables referenced by ``e`` (including inside loads)."""
    out: set = set()

    def rec(node: Expr):
        if isinstance(node, VarExpr):
            out.add(node.name)
        elif isinstance(node, BinExpr):
            rec(node.lhs)
            rec(node.rhs)
        elif isinstance(node, (UnaryExpr,)):
            rec(node.operand)
        elif isinstance(node, CastExpr):
            rec(node.operand)
        elif isinstance(node, WhereExpr):
            rec(node.cond)
            rec(node.then)
            rec(node.otherwise)
        elif isinstance(node, LoadExpr):
            for i in node.indices:
                rec(i)

    rec(e)
    return out


def loads_in(e: Expr) -> list:
    """All LoadExpr nodes in ``e`` (pre-order)."""
    out: list = []

    def rec(node: Expr):
        if isinstance(node, LoadExpr):
            out.append(node)
            for i in node.indices:
                rec(i)
        elif isinstance(node, BinExpr):
            rec(node.lhs)
            rec(node.rhs)
        elif isinstance(node, UnaryExpr):
            rec(node.operand)
        elif isinstance(node, CastExpr):
            rec(node.operand)
        elif isinstance(node, WhereExpr):
            rec(node.cond)
            rec(node.then)
            rec(node.otherwise)

    rec(e)
    return out


# ---------------------------------------------------------------------------
# Affine analysis helpers (used by BlockSpec index-map derivation)
# ---------------------------------------------------------------------------


def linear_decompose(e: Expr) -> Optional[Dict[str, int]]:
    """Decompose ``e`` as ``sum_i coeff_i * var_i + const`` if possible.

    Returns ``{var_name: coeff, "": const}`` or ``None`` when non-affine.
    """
    if isinstance(e, ConstExpr):
        if isinstance(e.value, bool) or not isinstance(e.value, int):
            return None
        return {"": e.value}
    if isinstance(e, VarExpr):
        return {e.name: 1, "": 0}
    if isinstance(e, UnaryExpr) and e.op == "neg":
        sub = linear_decompose(e.operand)
        if sub is None:
            return None
        return {k: -v for k, v in sub.items()}
    if isinstance(e, BinExpr):
        if e.op in ("add", "sub"):
            a, b = linear_decompose(e.lhs), linear_decompose(e.rhs)
            if a is None or b is None:
                return None
            sign = 1 if e.op == "add" else -1
            out = dict(a)
            out.setdefault("", 0)
            for k, v in b.items():
                out[k] = out.get(k, 0) + sign * v
            return out
        if e.op == "mul":
            a, b = linear_decompose(e.lhs), linear_decompose(e.rhs)
            if a is None or b is None:
                return None
            a_const = set(a) <= {""}
            b_const = set(b) <= {""}
            if not (a_const or b_const):
                return None
            const = a[""] if a_const else b[""]
            other = b if a_const else a
            return {k: v * const for k, v in other.items()}
    return None
