"""Layout & Fragment algebra (paper §4.1, TPU-adapted).

TileLang models index translation with a composable ``Layout`` abstraction: a
function ``f : K^n -> K^m`` from logical indices to memory coordinates,
expressed algebraically over ``IterVar``-like symbolic variables.  ``Fragment``
extends it to ``f : K^n -> K^2`` mapping a logical element to *(thread,
local_register)* on GPUs.

On TPU there are no user-visible threads; the physical partitioning that
Fragment describes is the mapping of a logical tile onto **(vreg_tile,
lane)** coordinates — the sublane×lane grid of the VPU's vector registers
((8,128) f32 / (16,128) bf16 / (32,128) int8) and the 128×128 MXU systolic
tiles.  The algebra is unchanged (same ``repeat`` / ``repeat_on_thread`` /
``replicate`` combinators as the paper's Fig. 6); only the interpretation of
the first output coordinate differs (vreg-tile id instead of thread id).

The inference pass (infer.py) consumes Layouts to decide padded block shapes
and to check MXU/VREG alignment; the scheduler (schedule.py) uses a Layout
transform over grid coordinates to realize ``T.use_swizzle``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from .errors import LayoutError
from .expr import (
    BinExpr,
    ConstExpr,
    Expr,
    VarExpr,
    linear_decompose,
    static_eval,
    wrap,
)

# ---------------------------------------------------------------------------
# VREG / MXU geometry for the TPU target (v5e).  The second-minor ("sublane")
# extent depends on element width; the minor ("lane") extent is always 128.
# ---------------------------------------------------------------------------
LANE = 128
MXU = (128, 128)


def sublane(dtype: str) -> int:
    from .buffer import dtype_bits

    bits = dtype_bits(dtype)
    return {32: 8, 16: 16, 8: 32, 64: 4}.get(bits, 8)


def vreg_tile(dtype: str) -> Tuple[int, int]:
    """Native vector-register tile for ``dtype``: (sublane, lane)."""
    return (sublane(dtype), LANE)


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class IterVar:
    """An iteration variable with a known extent (paper: IterVar with range)."""

    var: VarExpr
    extent: int

    @staticmethod
    def make(name: str, extent: int) -> "IterVar":
        return IterVar(VarExpr(name, extent=int(extent)), int(extent))


class Layout:
    """An algebraic index map ``f : K^n -> K^m``.

    ``iter_vars`` bind the n input dimensions; ``forward_index`` is a tuple of
    m expressions over those variables.
    """

    def __init__(self, iter_vars: Sequence[IterVar], forward_index: Sequence[Expr]):
        self.iter_vars: Tuple[IterVar, ...] = tuple(iter_vars)
        self.forward_index: Tuple[Expr, ...] = tuple(forward_index)

    # -- basic properties ----------------------------------------------------
    @property
    def in_shape(self) -> Tuple[int, ...]:
        return tuple(iv.extent for iv in self.iter_vars)

    @property
    def in_rank(self) -> int:
        return len(self.iter_vars)

    @property
    def out_rank(self) -> int:
        return len(self.forward_index)

    def out_shape(self) -> Tuple[int, ...]:
        """Bounding extents of each output coordinate (affine bound analysis).

        For affine expressions we evaluate the max over the input box exactly
        from coefficient signs; non-affine expressions fall back to corner
        sampling of the input box.
        """
        shape = []
        for e in self.forward_index:
            dec = linear_decompose(e)
            if dec is not None:
                hi = dec.get("", 0)
                for iv in self.iter_vars:
                    c = dec.get(iv.var.name, 0)
                    if c > 0:
                        hi += c * (iv.extent - 1)
                shape.append(hi + 1)
            else:
                shape.append(self._sample_max(e) + 1)
        return tuple(int(s) for s in shape)

    def _sample_max(self, e: Expr) -> int:
        import itertools as _it

        best = 0
        corners = [(0, iv.extent - 1) for iv in self.iter_vars]
        for pt in _it.product(*corners):
            env = {iv.var.name: v for iv, v in zip(self.iter_vars, pt)}
            val = _substitute_eval(e, env)
            if val is None:
                raise LayoutError(f"Cannot bound non-affine layout expr {e!r}")
            best = max(best, int(val))
        return best

    # -- application -----------------------------------------------------------
    def __call__(self, *indices):
        """Apply the map to indices (ints, Exprs, or jnp values)."""
        if len(indices) != self.in_rank:
            raise LayoutError(
                f"Layout expects {self.in_rank} indices, got {len(indices)}"
            )
        env = {iv.var.name: idx for iv, idx in zip(self.iter_vars, indices)}
        return tuple(_substitute(e, env) for e in self.forward_index)

    def map_concrete(self, *indices: int) -> Tuple[int, ...]:
        env = {iv.var.name: int(i) for iv, i in zip(self.iter_vars, indices)}
        out = []
        for e in self.forward_index:
            v = _substitute_eval(e, env)
            if v is None:
                raise LayoutError(f"Layout expr {e!r} not evaluable at {indices}")
            out.append(int(v))
        return tuple(out)

    # -- composition (paper: "composable and stackable") -----------------------
    def compose(self, inner: "Layout") -> "Layout":
        """``self ∘ inner``: first apply ``inner``, feed its outputs to ``self``."""
        if inner.out_rank != self.in_rank:
            raise LayoutError(
                f"Cannot compose: inner produces {inner.out_rank} coords, outer "
                f"consumes {self.in_rank}"
            )
        env = {
            iv.var.name: e
            for iv, e in zip(self.iter_vars, inner.forward_index)
        }
        fwd = tuple(_substitute(e, env) for e in self.forward_index)
        return type(self)(inner.iter_vars, fwd)

    def __repr__(self):
        ivs = ", ".join(f"{iv.var.name}<{iv.extent}>" for iv in self.iter_vars)
        fwd = ", ".join(map(repr, self.forward_index))
        return f"{type(self).__name__}([{ivs}] -> ({fwd}))"

    # -- bijectivity check (padding layouts are non-bijective; Fig. 5c) -------
    def is_bijective(self) -> bool:
        import numpy as np

        in_size = 1
        for iv in self.iter_vars:
            in_size *= iv.extent
        if in_size > 1 << 16:  # only check small layouts exactly
            raise LayoutError("Bijectivity check too large; use structural info")
        seen = set()
        import itertools as _it

        for pt in _it.product(*(range(iv.extent) for iv in self.iter_vars)):
            out = self.map_concrete(*pt)
            if out in seen:
                return False
            seen.add(out)
        out_size = 1
        for s in self.out_shape():
            out_size *= s
        return len(seen) == out_size


# -- substitution helpers ----------------------------------------------------


def _substitute(e: Expr, env: Dict[str, object]):
    """Substitute variables; returns an Expr when env values are Exprs, or a
    numeric value when everything folds."""
    from .expr import CastExpr, LoadExpr, UnaryExpr, WhereExpr

    def rec(node):
        if isinstance(node, ConstExpr):
            return node
        if isinstance(node, VarExpr):
            if node.name in env:
                v = env[node.name]
                return v if isinstance(v, Expr) else wrap(v)
            return node
        if isinstance(node, BinExpr):
            return BinExpr(node.op, rec(node.lhs), rec(node.rhs))
        if isinstance(node, UnaryExpr):
            return UnaryExpr(node.op, rec(node.operand))
        if isinstance(node, CastExpr):
            return CastExpr(rec(node.operand), node.target_dtype)
        if isinstance(node, WhereExpr):
            return WhereExpr(rec(node.cond), rec(node.then), rec(node.otherwise))
        if isinstance(node, LoadExpr):
            return LoadExpr(node.buffer, tuple(rec(i) for i in node.indices))
        raise LayoutError(f"Unknown node {node!r}")

    out = rec(e)
    sv = static_eval(out)
    return sv if sv is not None else out


def _substitute_eval(e: Expr, env: Dict[str, int]) -> Optional[int]:
    out = _substitute(e, env)
    if isinstance(out, Expr):
        return static_eval(out)
    return out


# ---------------------------------------------------------------------------
# Common layout constructors
# ---------------------------------------------------------------------------


def row_major(shape: Sequence[int]) -> Layout:
    """Standard C-order linearization ``(i0,..,ik) -> i0*s0 + ... + ik``."""
    ivs = [IterVar.make(f"i{d}", s) for d, s in enumerate(shape)]
    stride = 1
    strides = []
    for s in reversed(shape):
        strides.append(stride)
        stride *= int(s)
    strides = list(reversed(strides))
    expr: Expr = ConstExpr(0)
    for iv, st in zip(ivs, strides):
        expr = expr + iv.var * st
    return Layout(ivs, (expr,))


def strided(shape: Sequence[int], strides: Sequence[int]) -> Layout:
    ivs = [IterVar.make(f"i{d}", s) for d, s in enumerate(shape)]
    expr: Expr = ConstExpr(0)
    for iv, st in zip(ivs, strides):
        expr = expr + iv.var * int(st)
    return Layout(ivs, (expr,))


def padded(shape: Sequence[int], pad_to: Sequence[int]) -> Layout:
    """Non-bijective padding layout (paper Fig. 5c): logical (i,j) land in a
    padded physical box. On TPU this is how non-(sublane,lane)-aligned tiles
    are physically stored in VMEM."""
    if len(shape) != len(pad_to):
        raise LayoutError("padded: rank mismatch")
    ivs = [IterVar.make(f"i{d}", s) for d, s in enumerate(shape)]
    fwd = tuple(iv.var + 0 for iv in ivs)  # identity coords in a padded box
    lay = Layout(ivs, fwd)
    lay._padded_shape = tuple(int(p) for p in pad_to)  # type: ignore[attr-defined]
    orig_out_shape = lay.out_shape

    def out_shape():
        return lay._padded_shape  # type: ignore[attr-defined]

    lay.out_shape = out_shape  # type: ignore[assignment]
    del orig_out_shape
    return lay


def tiled_2d(shape: Tuple[int, int], tile: Tuple[int, int]) -> Layout:
    """(i, j) -> (i//ti, j//tj, i%ti, j%tj): blocked storage, the layout the
    Mosaic compiler gives VMEM arrays ((8,128) native tiling)."""
    (M, N), (ti, tj) = shape, tile
    i, j = IterVar.make("i", M), IterVar.make("j", N)
    fwd = (i.var // ti, j.var // tj, i.var % ti, j.var % tj)
    return Layout([i, j], fwd)


def swizzle_2d(shape: Tuple[int, int], bank_words: int = 0) -> Layout:
    """XOR-swizzled row-major layout.

    On GPUs this kills shared-memory bank conflicts.  VMEM has no banked
    access hazards, so on TPU this layout is used only for *grid* traversal
    reordering (schedule.grid_swizzle) — kept here because the paper's
    ``T.annotate_layout``/``make_swizzle_layout`` are part of the core
    algebra and kernels may still request it explicitly.
    """
    M, N = shape
    i, j = IterVar.make("i", M), IterVar.make("j", N)
    fwd = (i.var, (j.var ^ (i.var % max(1, N))) % N if bank_words == 0 else (j.var ^ (i.var // bank_words)) % N)
    return Layout([i, j], fwd)


# ---------------------------------------------------------------------------
# Fragment: f : K^n -> (partition, local)
# ---------------------------------------------------------------------------


class Fragment(Layout):
    """A Layout whose two outputs are *(partition, local_index)*.

    GPU reading: partition = thread id within the block, local = register slot.
    TPU reading: partition = vreg-tile id within the VMEM tile, local = lane
    slot inside that vreg tile.  ``replication`` counts how many partitions
    hold a copy of the same logical element (paper Fig. 7 — bias broadcast).
    """

    def __init__(self, iter_vars, forward_index, replication: int = 1):
        if len(tuple(forward_index)) != 2:
            raise LayoutError("Fragment must produce exactly (partition, local)")
        super().__init__(iter_vars, forward_index)
        self.replication = int(replication)

    # -- the paper's four extension primitives (Fig. 6) ------------------------
    def repeat(self, n: int, axis: int = 0) -> "Fragment":
        """Tile the fragment n× along a logical axis; new elements land in the
        *same partitions* with new local slots (single warp consuming more
        rows; Fig. 6c top)."""
        ivs, subst, new_var = self._extend_axis(n, axis)
        part, local = (
            _substitute(self.forward_index[0], subst),
            _substitute(self.forward_index[1], subst),
        )
        locals_per = self._local_extent()
        local = wrap(local) + new_var * locals_per
        return Fragment(ivs, (wrap(part), local), self.replication)

    def repeat_on_thread(self, n: int, axis: int = 0) -> "Fragment":
        """Tile n× along an axis onto *new partitions* (more warps; local slots
        unchanged)."""
        ivs, subst, new_var = self._extend_axis(n, axis)
        part, local = (
            _substitute(self.forward_index[0], subst),
            _substitute(self.forward_index[1], subst),
        )
        parts_per = self._partition_extent()
        part = wrap(part) + new_var * parts_per
        return Fragment(ivs, (part, wrap(local)), self.replication)

    def replicate(self, n: int) -> "Fragment":
        """Replicate the whole fragment across n partition groups: every
        logical element now lives in n partitions (broadcast operands)."""
        rep = IterVar.make(f"_rep{len(self.iter_vars)}", n)
        parts_per = self._partition_extent()
        part = wrap(self.forward_index[0]) + rep.var * parts_per
        return Fragment(
            tuple(self.iter_vars) + (rep,),
            (part, self.forward_index[1]),
            self.replication * n,
        )

    def condense(self) -> "Fragment":
        """Drop replication (inverse of replicate); keeps partition group 0."""
        if self.replication == 1:
            return self
        ivs = self.iter_vars[:-1]
        env = {self.iter_vars[-1].var.name: 0}
        fwd = tuple(wrap(_substitute(e, env)) for e in self.forward_index)
        return Fragment(ivs, fwd, 1)

    # -- helpers ---------------------------------------------------------------
    def _extend_axis(self, n, axis):
        if axis >= self.in_rank:
            raise LayoutError(f"repeat axis {axis} out of range")
        old = self.iter_vars[axis]
        new_outer = IterVar.make(f"_o{axis}_{n}", n)
        merged = IterVar.make(old.var.name, old.extent * n)
        # merged index m decomposes as m = new_outer*old.extent + old
        subst = {old.var.name: merged.var % old.extent}
        ivs = list(self.iter_vars)
        ivs[axis] = merged
        new_var = merged.var // old.extent
        return tuple(ivs), subst, new_var

    def _partition_extent(self) -> int:
        return int(self.out_shape()[0])

    def _local_extent(self) -> int:
        return int(self.out_shape()[1])

    def threads(self) -> int:  # paper naming
        return self._partition_extent()

    def locals_per_thread(self) -> int:
        return self._local_extent()


def vreg_fragment(shape: Tuple[int, int], dtype: str) -> Fragment:
    """Base TPU fragment: map a logical 2-D tile onto (vreg_tile, lane_slot).

    This is the TPU analogue of the paper's ``mma_ldmatrix`` base layout for
    m16k16 fragments: the native unit the hardware consumes.  A (sub, 128)
    vreg tile holds ``sub*128`` elements; tiles are raster-ordered over the
    logical tile.
    """
    sub = sublane(dtype)
    M, N = shape
    pm, pn = round_up(M, sub), round_up(N, LANE)
    tiles_n = pn // LANE
    i, j = IterVar.make("i", M), IterVar.make("j", N)
    tile_id = (i.var // sub) * tiles_n + (j.var // LANE)
    slot = (i.var % sub) * LANE + (j.var % LANE)
    return Fragment([i, j], (tile_id, slot))


def mxu_fragment(dtype: str) -> Fragment:
    """Fragment for one full MXU matmul tile (128×128)."""
    return vreg_fragment(MXU, dtype)
