"""Window extraction pass: copies that become BlockSpec-managed operands.

Every ``global -> onchip`` copy becomes an input window and every
``onchip -> global`` copy (or global atomic) an output window.  Windows are
target-neutral: the Pallas backend turns them into ``pl.BlockSpec``s, the
reference backend into dynamic slices.

A param that is *both* read through input windows and written through a
**table-directed** output window (the paged-KV pool of the chunked-prefill
kernel: prior pages gathered through the block table, the chunk's pages
written back through it) is marked ``aliased`` — the backends then treat
it as an in-out operand (``input_output_aliases`` on Pallas), so pages no
grid cell writes keep their previous contents.  The kernel contract is
that the read and write page sets of one launch are disjoint; the lowering
cannot verify this for data-dependent tables, so the aliasing is granted
only when the store's starts actually load a scalar-prefetch buffer —
statically-indexed read+write of one param remains a Pallas lowering
error, as before.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from ..buffer import FRAGMENT, GLOBAL, SHARED, TileBuffer
from ..errors import LoweringError
from ..tile_ops import AtomicOp, CopyOp, ResolvedRegion, SerialOp, TileOp
from .phases import LOOP, POST, PRE, Phases


@dataclasses.dataclass
class Window:
    """One BlockSpec-managed operand window."""

    param: TileBuffer  # the global buffer
    onchip: Optional[TileBuffer]  # dst for inputs; src for outputs (may be None for atomics)
    region: ResolvedRegion  # region on the global side
    phase: str
    is_output: bool
    aliased: bool = False  # in-out (atomic RMW)

    @property
    def block_shape(self) -> Tuple[int, ...]:
        return tuple(self.region.sizes)


def _is_onchip(buf: TileBuffer) -> bool:
    return buf.scope in (SHARED, FRAGMENT)


def collect_windows(program, phases: Phases):
    """Find all global<->onchip copies; returns (in_windows, out_windows,
    window_backed: dst name -> window idx, store_ops)."""
    in_windows: List[Window] = []
    out_windows: List[Window] = []
    fed_by: Dict[str, Window] = {}
    stores: List[Tuple[TileOp, str, Window]] = []  # (op, phase, out window)

    def scan(ops: List[TileOp], phase: str):
        for op in ops:
            if isinstance(op, SerialOp):
                scan(op.body, phase)
            elif isinstance(op, CopyOp):
                s, d = op.src.buffer, op.dst.buffer
                if s.scope == GLOBAL and _is_onchip(d):
                    if d.name in fed_by:
                        raise LoweringError(
                            f"{program.name}: buffer {d.name} fed by two "
                            "global copies; each shared tile must have one "
                            "producer copy."
                        )
                    if any(c for c in op.dst.collapsed) or op.dst.tile_shape != tuple(
                        op.dst.buffer.shape
                    ):
                        raise LoweringError(
                            f"{program.name}: global->onchip copy must fill the "
                            f"whole destination tile ({op})"
                        )
                    w = Window(s, d, op.src, phase, is_output=False)
                    in_windows.append(w)
                    fed_by[d.name] = w
                elif _is_onchip(s) and d.scope == GLOBAL:
                    w = _merge_out_window(out_windows, Window(d, s, op.dst, phase, True))
                    stores.append((op, phase, w))
                elif s.scope == GLOBAL and d.scope == GLOBAL:
                    raise LoweringError(
                        f"{program.name}: global->global copy; stage through "
                        "a shared tile."
                    )
            elif isinstance(op, AtomicOp):
                if op.dst.buffer.scope != GLOBAL:
                    continue
                w = _merge_out_window(
                    out_windows, Window(op.dst.buffer, None, op.dst, phase, True, aliased=True)
                )
                w.aliased = True
                stores.append((op, phase, w))

    scan(phases.pre, PRE)
    if phases.pipeline is not None:
        scan(phases.pipeline.body, LOOP)
    scan(phases.post, POST)
    # A written param that is also fed to input windows becomes an in-out
    # operand — but only when the store's placement is data-dependent
    # (scalar-load starts, the paged write path): there the caller owns the
    # disjointness contract and unwritten regions must survive the call.
    # Statically-indexed read+write of one param stays rejected by the
    # Pallas backend (the overlap is the user error the old guard caught).
    read_params = {id(w.param) for w in in_windows}
    for w in out_windows:
        if id(w.param) in read_params and _scalar_dependent(w.region):
            w.aliased = True
    return in_windows, out_windows, fed_by, stores


def _scalar_dependent(region: ResolvedRegion) -> bool:
    from ..buffer import SCALAR
    from ..expr import loads_in

    return any(
        ld.buffer.scope == SCALAR for s in region.starts for ld in loads_in(s)
    )


def _merge_out_window(out_windows: List[Window], w: Window) -> Window:
    for existing in out_windows:
        if existing.param is w.param:
            if existing.block_shape != w.block_shape or not _same_starts(
                existing.region, w.region
            ):
                raise LoweringError(
                    f"two stores to {w.param.name} with different windows; "
                    "unify the destination regions."
                )
            return existing
    out_windows.append(w)
    return w


def _same_starts(a: ResolvedRegion, b: ResolvedRegion) -> bool:
    return [repr(s) for s in a.starts] == [repr(s) for s in b.starts]
