"""Stable structural fingerprints for programs and schedules.

Two independently traced programs with identical structure must hash the
same even though tracing mints fresh buffer/variable names (``sbuf17``,
``k3``...), so the serializer renames buffers and loop variables to their
position in a canonical traversal.  The fingerprint keys the analysis and
compile caches: ``(program_fingerprint, schedule_key, target)`` identifies a
compiled kernel exactly (DESIGN.md §3.3).

``CustomOp`` bodies are opaque Python callables; they contribute
``(name, id(fn))`` so two programs sharing the *same* function object can
share a cache entry but freshly minted closures never alias each other.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List

from ..buffer import TileBuffer
from ..expr import (
    BinExpr,
    CastExpr,
    ConstExpr,
    Expr,
    LoadExpr,
    UnaryExpr,
    VarExpr,
    WhereExpr,
)
from ..schedule import Schedule
from ..tile_ops import (
    AtomicOp,
    CopyOp,
    CumsumOp,
    CustomOp,
    FillOp,
    GemmOp,
    ParallelOp,
    PipelinedOp,
    ReduceOp,
    ResolvedRegion,
    SerialOp,
    TileOp,
)


class _Canon:
    """Stable id assignment for buffers and trace variables."""

    def __init__(self):
        self.bufs: Dict[int, str] = {}
        self.vars: Dict[str, str] = {}

    def buf(self, b: TileBuffer) -> str:
        key = id(b)
        if key not in self.bufs:
            self.bufs[key] = f"%b{len(self.bufs)}"
        return self.bufs[key]

    def var(self, name: str) -> str:
        if name not in self.vars:
            self.vars[name] = f"%v{len(self.vars)}"
        return self.vars[name]


def _ser_buf_decl(b: TileBuffer, c: _Canon) -> str:
    return f"{c.buf(b)}:{b.scope}:{b.dtype}:{b.shape}"


def _ser_expr(e: Expr, c: _Canon) -> str:
    if isinstance(e, ConstExpr):
        return f"c({e.value!r},{e.dtype})"
    if isinstance(e, VarExpr):
        return f"v({c.var(e.name)},{e.extent})"
    if isinstance(e, BinExpr):
        return f"b({e.op},{_ser_expr(e.lhs, c)},{_ser_expr(e.rhs, c)})"
    if isinstance(e, UnaryExpr):
        return f"u({e.op},{_ser_expr(e.operand, c)})"
    if isinstance(e, CastExpr):
        return f"cast({_ser_expr(e.operand, c)},{e.target_dtype})"
    if isinstance(e, WhereExpr):
        return (
            f"w({_ser_expr(e.cond, c)},{_ser_expr(e.then, c)},"
            f"{_ser_expr(e.otherwise, c)})"
        )
    if isinstance(e, LoadExpr):
        idx = ",".join(_ser_expr(i, c) for i in e.indices)
        return f"ld({c.buf(e.buffer)},[{idx}])"
    return f"expr({e!r})"


def _ser_region(r: ResolvedRegion, c: _Canon) -> str:
    starts = ",".join(_ser_expr(s, c) for s in r.starts)
    return f"{c.buf(r.buffer)}[{starts};{r.sizes};{r.collapsed}]"


def _ser_op(op: TileOp, c: _Canon, out: List[str]) -> None:
    if isinstance(op, CopyOp):
        out.append(f"copy({_ser_region(op.src, c)}->{_ser_region(op.dst, c)})")
    elif isinstance(op, GemmOp):
        out.append(
            f"gemm({c.buf(op.a)},{c.buf(op.b)},{c.buf(op.c)},"
            f"{op.transpose_a},{op.transpose_b},{op.m},{op.n},{op.k})"
        )
    elif isinstance(op, FillOp):
        out.append(f"fill({c.buf(op.buffer)},{_ser_expr(op.value, c)})")
    elif isinstance(op, ReduceOp):
        out.append(
            f"reduce({op.kind},{c.buf(op.src)},{c.buf(op.dst)},{op.axis},{op.clear})"
        )
    elif isinstance(op, CumsumOp):
        out.append(
            f"cumsum({c.buf(op.src)},{c.buf(op.dst)},{op.axis},{op.reverse})"
        )
    elif isinstance(op, ParallelOp):
        axes = ",".join(c.var(a.name) for a in op.axes)
        out.append(f"parallel[{axes};{op.extents}](")
        for buf, idx, val in op.stores:
            sidx = ",".join(_ser_expr(i, c) for i in idx)
            out.append(f"  st({c.buf(buf)},[{sidx}],{_ser_expr(val, c)})")
        out.append(")")
    elif isinstance(op, PipelinedOp):
        out.append(
            f"pipelined({c.var(op.var.name)},{op.extent},{op.num_stages},"
            f"{op.order},{op.stage}]("
        )
        for o in op.body:
            _ser_op(o, c, out)
        out.append(")")
    elif isinstance(op, SerialOp):
        out.append(f"serial({c.var(op.var.name)},{op.extent},{op.unroll}](")
        for o in op.body:
            _ser_op(o, c, out)
        out.append(")")
    elif isinstance(op, AtomicOp):
        out.append(f"atomic({op.kind},{_ser_region(op.dst, c)},{c.buf(op.src)})")
    elif isinstance(op, CustomOp):
        out.append(
            f"custom({op.name},{id(op.fn)},"
            f"{[c.buf(b) for b in op.inputs]},{c.buf(op.output)})"
        )
    else:
        out.append(f"op({op!r})")


def program_fingerprint(program) -> str:
    """Hex digest identifying the program's structure (not its trace names)."""
    c = _Canon()
    parts: List[str] = [program.name]
    for p in program.params:
        parts.append("param " + _ser_buf_decl(p, c))
    for v, e in program.grid_axes:
        parts.append(f"axis {c.var(v.name)}:{e}")
    for b in program.allocs:
        parts.append("alloc " + _ser_buf_decl(b, c))
    for op in program.ops:
        _ser_op(op, c, parts)
    ann = program.annotations
    parts.append(f"swizzle={ann.swizzle}")
    for name, layout in sorted(ann.layouts.items()):
        parts.append(f"layout {name}={layout!r}")
    blob = "\n".join(parts).encode()
    return hashlib.sha256(blob).hexdigest()


def schedule_key(schedule: Schedule) -> tuple:
    """Hashable key over the schedule fields that affect lowering output
    (``notes`` is advisory metadata and deliberately excluded)."""
    return (
        schedule.interpret,
        schedule.num_stages,
        schedule.grid_swizzle,
        tuple(schedule.dimension_semantics)
        if schedule.dimension_semantics is not None
        else None,
        schedule.vmem_limit,
    )
