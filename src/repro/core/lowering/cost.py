"""Cost-estimation pass (feeds autotune + benchmarks + roofline)."""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from ..buffer import dtype_bits
from ..tile_ops import CumsumOp, GemmOp, ParallelOp, ReduceOp, SerialOp, TileOp
from .phases import LOOP, Phases
from .windows import Window


@dataclasses.dataclass
class KernelCost:
    flops: int
    hbm_bytes: int
    grid: Tuple[int, ...]
    vmem_bytes: int

    def compute_seconds(self, peak_flops: float = 197e12) -> float:
        return self.flops / peak_flops

    def memory_seconds(self, hbm_bw: float = 819e9) -> float:
        return self.hbm_bytes / hbm_bw

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.hbm_bytes, 1)

    def bound(self, peak_flops: float = 197e12, hbm_bw: float = 819e9) -> str:
        return (
            "compute" if self.compute_seconds(peak_flops) >= self.memory_seconds(hbm_bw)
            else "memory"
        )


def estimate_cost(
    program,
    phases: Phases,
    grid: Tuple[int, ...],
    in_windows: List[Window],
    out_windows: List[Window],
    vmem,
) -> KernelCost:
    total_steps = int(np.prod(grid))
    pipe = phases.pipeline
    cells = total_steps // (pipe.extent if pipe is not None else 1)

    flops = 0

    def op_flops(op: TileOp) -> int:
        if isinstance(op, GemmOp):
            return 2 * op.m * op.n * op.k
        if isinstance(op, ParallelOp):
            return int(np.prod(op.extents)) * max(1, len(op.stores)) * 2
        if isinstance(op, (ReduceOp,)):
            return op.src.size
        if isinstance(op, CumsumOp):
            return op.src.size
        if isinstance(op, SerialOp):
            return op.extent * sum(op_flops(o) for o in op.body)
        return 0

    for op in phases.pre + phases.post:
        flops += cells * op_flops(op)
    if pipe is not None:
        for op in pipe.body:
            flops += total_steps * op_flops(op)

    hbm = 0
    for w in in_windows:
        steps = total_steps if w.phase == LOOP else cells
        hbm += steps * int(np.prod(w.block_shape)) * dtype_bits(w.param.dtype) // 8
    for w in out_windows:
        steps = total_steps if w.phase == LOOP else cells
        hbm += steps * int(np.prod(w.block_shape)) * dtype_bits(w.param.dtype) // 8

    return KernelCost(flops=flops, hbm_bytes=hbm, grid=tuple(grid), vmem_bytes=vmem.total_bytes)
