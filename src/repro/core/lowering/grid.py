"""Grid planning pass: kernel axes -> launch grid + scalar environment.

Kernel axes are reversed so the first-declared axis (``bx``) is the
fastest-varying parallel dimension (CUDA blockIdx.x convention), and the
pipelined axis is innermost overall so accumulators stay resident.  An
active ``T.use_swizzle`` flattens a 2-D parallel grid into one panel-raster
axis (see schedule.swizzle_decode).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

from ..schedule import Schedule, swizzle_decode, validate_swizzle
from .phases import Phases


@dataclasses.dataclass
class GridPlan:
    grid: Tuple[int, ...]
    env_builder: Callable[..., Dict[str, Any]]
    kdim: Optional[int]  # grid position of the pipelined ("arbitrary") axis
    dimension_semantics: Tuple[str, ...]


def plan_grid(program, phases: Phases, schedule: Schedule) -> GridPlan:
    kernel_axes = program.grid_axes  # declaration order
    n = len(kernel_axes)
    swz = schedule.grid_swizzle
    if swz is None:
        swz = program.annotations.swizzle

    pipe = phases.pipeline
    kext = pipe.extent if pipe is not None else None
    kname = pipe.var.name if pipe is not None else None

    if swz is not None and n == 2:
        (v0, e0), (v1, e1) = kernel_axes
        # pallas-minor ordering: v1 (by) slower, v0 (bx) faster in raster;
        # flatten to one axis and decode with panel swizzling.  Clamp the
        # panel height to a divisor of the row extent (traced decode needs
        # uniform panels).
        factor = min(swz, e1)
        if e1 % factor != 0:
            factor = math.gcd(e1, factor) or 1
        validate_swizzle(e1, e0, factor)
        grid = (e1 * e0,) + ((kext,) if kext else ())
        sem = ("arbitrary",) * len(grid)

        def env_builder(*gids):
            flat = gids[0]
            i1, i0 = swizzle_decode(flat, e1, e0, factor)
            env = {v1.name: i1, v0.name: i0}
            if kname is not None:
                env[kname] = gids[1]
            return env

        kdim = 1 if kext else None
        return _with_override(grid, env_builder, kdim, sem, schedule)

    grid = tuple(e for _, e in reversed(kernel_axes)) + ((kext,) if kext else ())
    sem = ("parallel",) * n + (("arbitrary",) if kext else ())

    def env_builder(*gids):
        env = {}
        for i, (v, _) in enumerate(kernel_axes):
            env[v.name] = gids[n - 1 - i]
        if kname is not None:
            env[kname] = gids[n]
        return env

    kdim = n if kext else None
    return _with_override(grid, env_builder, kdim, sem, schedule)


def _with_override(grid, env_builder, kdim, sem, schedule: Schedule) -> GridPlan:
    if schedule.dimension_semantics is not None:
        sem = tuple(schedule.dimension_semantics)
    return GridPlan(grid, env_builder, kdim, sem)
