"""Index-map derivation shared by the grid plan and the Pallas backend."""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional

from ..buffer import SCALAR
from ..errors import LoweringError
from ..expr import Expr, evaluate, linear_decompose


def make_index_map(
    region,
    env_builder: Callable[..., Dict[str, Any]],
    scalar_params: Optional[List] = None,
):
    """Build a Pallas ``index_map(*grid_ids) -> block indices``.

    Affine starts with size-divisible coefficients fold statically; otherwise
    we fall back to a runtime floordiv (correct when the region is aligned —
    the TileLang contract for unmasked copies).

    ``scalar_params`` (when non-empty) is the declaration-ordered list of
    scalar-prefetch buffers: the index map then accepts their SMEM refs as
    trailing arguments (the ``PrefetchScalarGridSpec`` convention) and
    resolves ``LoadExpr`` starts against them — the data-dependent gather of
    paged attention block tables.  The same derivation serves input *and*
    output windows: a store whose starts load a block table becomes a
    table-directed output BlockSpec (the chunked-prefill kernel writing the
    chunk's K/V pages), paired with an in-out alias so unwritten pages keep
    their previous contents.
    """
    starts, sizes = region.starts, region.sizes
    scalar_names = [p.name for p in (scalar_params or [])]

    def fold(e: Expr, size: int):
        if size == 1:
            return ("expr", e)
        dec = linear_decompose(e)
        if dec is not None and all(v % size == 0 for v in dec.values()):
            folded = {k: v // size for k, v in dec.items()}
            return ("affine", folded)
        return ("div", e)

    plans = [fold(e, s) for e, s in zip(starts, sizes)]

    def index_map(*args):
        if scalar_names:
            n = len(scalar_names)
            grid_ids, scalar_refs = args[:-n], args[-n:]
            by_name = dict(zip(scalar_names, scalar_refs))

            def load_fn(buffer, idx_values, idx_exprs):
                ref = by_name.get(buffer.name)
                if ref is None or buffer.scope != SCALAR:
                    raise LoweringError(
                        f"index expression loads {buffer.name}, which is not "
                        "a scalar-prefetch param"
                    )
                return ref[tuple(idx_values)]

        else:
            grid_ids = args
            load_fn = no_loads
        env = env_builder(*grid_ids)

        def ev(e: Expr):
            return evaluate(e, env, load_fn=load_fn)

        out = []
        for (kind, payload), size in zip(plans, sizes):
            if kind == "expr":
                out.append(ev(payload))
            elif kind == "affine":
                acc = payload.get("", 0)
                for name, coeff in payload.items():
                    if name == "":
                        continue
                    if coeff:
                        acc = acc + coeff * env[name]
                out.append(acc)
            else:
                out.append(ev(payload) // size)
        return tuple(out)

    return index_map


def no_loads(buffer, idx_values, idx_exprs):
    raise LoweringError("Buffer loads are not allowed in index expressions")
