"""Index-map derivation shared by the grid plan and the Pallas backend."""
from __future__ import annotations

from typing import Any, Callable, Dict

from ..errors import LoweringError
from ..expr import Expr, evaluate, linear_decompose
from ..tile_ops import ResolvedRegion


def make_index_map(
    region: ResolvedRegion,
    env_builder: Callable[..., Dict[str, Any]],
):
    """Build a Pallas ``index_map(*grid_ids) -> block indices``.

    Affine starts with size-divisible coefficients fold statically; otherwise
    we fall back to a runtime floordiv (correct when the region is aligned —
    the TileLang contract for unmasked copies).
    """
    starts, sizes = region.starts, region.sizes

    def fold(e: Expr, size: int):
        if size == 1:
            return ("expr", e)
        dec = linear_decompose(e)
        if dec is not None and all(v % size == 0 for v in dec.values()):
            folded = {k: v // size for k, v in dec.items()}
            return ("affine", folded)
        return ("div", e)

    plans = [fold(e, s) for e, s in zip(starts, sizes)]

    def index_map(*grid_ids):
        env = env_builder(*grid_ids)

        def ev(e: Expr):
            return evaluate(e, env, load_fn=no_loads)

        out = []
        for (kind, payload), size in zip(plans, sizes):
            if kind == "expr":
                out.append(ev(payload))
            elif kind == "affine":
                acc = payload.get("", 0)
                for name, coeff in payload.items():
                    if name == "":
                        continue
                    if coeff:
                        acc = acc + coeff * env[name]
                out.append(acc)
            else:
                out.append(ev(payload) // size)
        return tuple(out)

    return index_map


def no_loads(buffer, idx_values, idx_exprs):
    raise LoweringError("Buffer loads are not allowed in index expressions")
