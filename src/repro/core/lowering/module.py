"""The ``LoweredModule`` analysis artifact and the compiled-kernel wrapper.

A ``LoweredModule`` is everything the pass pipeline knows about one
``(TileProgram, Schedule)`` pair — phases, windows, grid plan, VMEM plan,
parameter ordering, layout inference and cost — with **no target code**.
Backends (repro.core.backends) consume it to emit a :class:`CompiledKernel`;
the autotuner scores it directly without ever emitting code (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..buffer import TileBuffer
from ..errors import LoweringError
from ..infer import InferenceResult
from ..schedule import Schedule, VmemPlan
from .cost import KernelCost
from .grid import GridPlan
from .phases import Phases
from .windows import Window


@dataclasses.dataclass
class LoweredInfo:
    """Backend-independent summary attached to every compiled kernel."""

    grid: Tuple[int, ...]
    dimension_semantics: Tuple[str, ...]
    vmem: VmemPlan
    inference: InferenceResult
    cost: KernelCost
    num_stages: int
    n_windows_in: int
    n_windows_out: int


@dataclasses.dataclass
class LoweredModule:
    """Single analysis artifact produced by the pass pipeline.

    Fields are filled pass by pass (in PIPELINE order); ``None`` means the
    corresponding pass has not run yet.  The artifact is cached per
    (program fingerprint, schedule key) and may therefore be shared between
    structurally identical programs — backends must only depend on the
    structure, never on Python object identity of the originating trace.
    """

    program: Any
    schedule: Schedule
    # -- split_phases ------------------------------------------------------
    phases: Optional[Phases] = None
    # -- infer_layouts -----------------------------------------------------
    inference: Optional[InferenceResult] = None
    # -- collect_windows ---------------------------------------------------
    in_windows: List[Window] = dataclasses.field(default_factory=list)
    out_windows: List[Window] = dataclasses.field(default_factory=list)
    fed_by: Dict[str, Window] = dataclasses.field(default_factory=dict)
    stores: List[Tuple] = dataclasses.field(default_factory=list)
    # -- plan_grid ---------------------------------------------------------
    grid_plan: Optional[GridPlan] = None
    # -- plan_stages -------------------------------------------------------
    num_stages: int = 1
    # -- plan_vmem ---------------------------------------------------------
    vmem: Optional[VmemPlan] = None
    # -- plan_params -------------------------------------------------------
    scratch_bufs: List[TileBuffer] = dataclasses.field(default_factory=list)
    scratch_pos: Dict[str, int] = dataclasses.field(default_factory=dict)
    arg_params: List[TileBuffer] = dataclasses.field(default_factory=list)
    out_params: List[TileBuffer] = dataclasses.field(default_factory=list)
    # scalar-prefetch params (declaration order); a subset of arg_params
    scalar_params: List[TileBuffer] = dataclasses.field(default_factory=list)
    # operand index into arg_params per input window; None when the window
    # reads a written global (only the Pallas backend rejects that).
    window_param_idx: List[Optional[int]] = dataclasses.field(default_factory=list)
    window_of: Dict[str, int] = dataclasses.field(default_factory=dict)
    out_window_of: Dict[int, int] = dataclasses.field(default_factory=dict)
    # -- estimate_cost -----------------------------------------------------
    cost: Optional[KernelCost] = None
    # -- verify ------------------------------------------------------------
    # runtime obligations (verify.Obligation): checks the static verifier
    # could not prove because they depend on runtime scalars (table-directed
    # windows); the dispatch guard in kernels/ops.py discharges them.
    obligations: List[Any] = dataclasses.field(default_factory=list)

    # ---------------------------------------------------------------------
    @property
    def grid(self) -> Tuple[int, ...]:
        return self.grid_plan.grid if self.grid_plan is not None else ()

    @property
    def dimension_semantics(self) -> Tuple[str, ...]:
        return (
            tuple(self.grid_plan.dimension_semantics)
            if self.grid_plan is not None
            else ()
        )

    def info(self) -> LoweredInfo:
        return LoweredInfo(
            grid=self.grid,
            dimension_semantics=self.dimension_semantics,
            vmem=self.vmem,
            inference=self.inference,
            cost=self.cost,
            num_stages=self.num_stages,
            n_windows_in=len(self.in_windows),
            n_windows_out=len(self.out_windows),
        )

    def summary(self) -> str:
        lines = [
            f"LoweredModule({self.program.name})",
            f"  grid={self.grid} semantics={self.dimension_semantics}",
            f"  windows: {len(self.in_windows)} in / {len(self.out_windows)} out, "
            f"scratch={len(self.scratch_bufs)}, stages={self.num_stages}",
        ]
        if self.cost is not None:
            lines.append(
                f"  cost: {self.cost.flops/1e9:.2f} GFLOP, "
                f"{self.cost.hbm_bytes/2**20:.1f} MiB HBM, "
                f"AI={self.cost.arithmetic_intensity:.1f} ({self.cost.bound()}-bound)"
            )
        if self.vmem is not None:
            lines.append("  " + self.vmem.summary().replace("\n", "\n  "))
        return "\n".join(lines)


class CompiledKernel:
    """Callable wrapper: ``kernel(*input_arrays) -> output(s)``.

    Inputs are the program's read-only global params (in declaration order)
    followed by any in-out (atomic) params; outputs are the written globals
    in declaration order.
    """

    def __init__(self, program, fn: Callable, info: LoweredInfo,
                 arg_params: List[TileBuffer], out_params: List[TileBuffer],
                 backend: str = "?"):
        self.program = program
        self._fn = fn
        self.info = info
        self.arg_params = arg_params
        self.out_params = out_params
        self.backend = backend
        self.__name__ = program.name

    def __call__(self, *arrays):
        if len(arrays) != len(self.arg_params):
            raise LoweringError(
                f"{self.program.name}: expected {len(self.arg_params)} arrays "
                f"({[p.name for p in self.arg_params]}), got {len(arrays)}"
            )
        for arr, p in zip(arrays, self.arg_params):
            if tuple(arr.shape) != p.shape:
                raise LoweringError(
                    f"{self.program.name}: arg {p.name} shape {arr.shape} != "
                    f"declared {p.shape}"
                )
        out = self._fn(*arrays)
        return out
