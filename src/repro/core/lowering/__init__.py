"""The pass-based lowering pipeline (DESIGN.md §1, §3).

``TileProgram -> LoweredModule`` is a sequence of explicit, individually
testable passes (see :mod:`.pipeline` for the ordered list):

    split_phases -> infer_layouts -> collect_windows -> plan_grid
    -> plan_stages -> plan_vmem -> plan_params -> estimate_cost

Each pass fills a slice of the :class:`LoweredModule` analysis artifact and
never emits target code; code emission lives in :mod:`repro.core.backends`,
which consume the finished artifact.  ``analyze`` memoizes the whole pipeline
on ``(program fingerprint, schedule key)`` so the autotuner and kernel
libraries score candidates without re-running the passes.
"""
from .cost import KernelCost, estimate_cost
from .fingerprint import program_fingerprint, schedule_key
from .grid import GridPlan, plan_grid
from .indexing import make_index_map, no_loads
from .module import CompiledKernel, LoweredInfo, LoweredModule
from .phases import LOOP, POST, PRE, Phases, split_phases
from .pipeline import PIPELINE, analyze, clear_analysis_cache, run_pipeline
from .windows import Window, collect_windows

__all__ = [
    "KernelCost",
    "estimate_cost",
    "program_fingerprint",
    "schedule_key",
    "GridPlan",
    "plan_grid",
    "make_index_map",
    "no_loads",
    "CompiledKernel",
    "LoweredInfo",
    "LoweredModule",
    "PRE",
    "LOOP",
    "POST",
    "Phases",
    "split_phases",
    "PIPELINE",
    "analyze",
    "clear_analysis_cache",
    "run_pipeline",
    "Window",
    "collect_windows",
]
