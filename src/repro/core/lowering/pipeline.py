"""The ordered pass pipeline and the analysis cache (DESIGN.md §3).

``run_pipeline`` executes every pass over a fresh :class:`LoweredModule`;
``analyze`` memoizes the result on ``(program fingerprint, schedule key)`` so
autotuning over N candidate schedules of the same dataflow — or serving
traffic that compiles the same kernel per request — re-runs nothing.

Each pass is a plain ``fn(module) -> None`` mutating its own slice of the
artifact, which keeps them individually testable: build a module with
``LoweredModule(program, schedule)``, run a prefix of PIPELINE, inspect.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

from ..errors import TileError
from ..infer import infer_layouts
from ..schedule import Schedule, plan_vmem
from .cost import estimate_cost
from .fingerprint import program_fingerprint, schedule_key
from .grid import plan_grid
from .module import LoweredModule
from .phases import LOOP, split_phases
from .verify import pass_verify
from .windows import collect_windows


# ---------------------------------------------------------------------------
# Passes
# ---------------------------------------------------------------------------


def pass_split_phases(m: LoweredModule) -> None:
    m.phases = split_phases(m.program)


def pass_infer_layouts(m: LoweredModule) -> None:
    m.inference = infer_layouts(m.program)


def pass_collect_windows(m: LoweredModule) -> None:
    m.in_windows, m.out_windows, m.fed_by, m.stores = collect_windows(
        m.program, m.phases
    )
    m.window_of = {
        w.onchip.name: i for i, w in enumerate(m.in_windows) if w.onchip is not None
    }
    m.out_window_of = {id(w.param): j for j, w in enumerate(m.out_windows)}


def pass_plan_grid(m: LoweredModule) -> None:
    m.grid_plan = plan_grid(m.program, m.phases, m.schedule)


def pass_plan_stages(m: LoweredModule) -> None:
    pipe = m.phases.pipeline
    m.num_stages = (
        m.schedule.num_stages
        if m.schedule.num_stages is not None
        else (pipe.num_stages if pipe is not None else 1)
    )


def pass_plan_vmem(m: LoweredModule) -> None:
    pipelined_inputs = {
        w.onchip.name: max(2, m.num_stages)
        for w in m.in_windows
        if w.phase == LOOP and w.onchip is not None
    }
    # check=False: analysis records the footprint; whether an over-budget
    # plan is fatal is the backend's call (the reference interpreter and
    # third-party targets may not have a 128 MiB VMEM at all).
    m.vmem = plan_vmem(m.program, m.schedule, pipelined_inputs, check=False)


def pass_plan_params(m: LoweredModule) -> None:
    """Parameter / operand ordering shared by every backend.

    ``window_param_idx[i]`` is the position in ``arg_params`` feeding input
    window i, or ``None`` when the window reads a *written* global — legal
    for the reference interpreter, rejected by the Pallas backend."""
    program = m.program
    m.scratch_bufs = [b for b in program.allocs if b.name not in m.fed_by]
    m.scratch_pos = {b.name: i for i, b in enumerate(m.scratch_bufs)}

    written = {id(p) for p in program.written_globals()}
    aliased_params = [w.param for w in m.out_windows if w.aliased]
    m.arg_params = [p for p in program.params if id(p) not in written]
    m.arg_params += list(aliased_params)  # in-out params passed as inputs
    m.out_params = [p for p in program.params if id(p) in written]

    param_pos = {id(p): i for i, p in enumerate(m.arg_params)}
    m.window_param_idx = [param_pos.get(id(w.param)) for w in m.in_windows]
    m.scalar_params = program.scalar_params()


def pass_estimate_cost(m: LoweredModule) -> None:
    m.cost = estimate_cost(
        m.program, m.phases, m.grid, m.in_windows, m.out_windows, m.vmem
    )


PIPELINE: List[Tuple[str, Callable[[LoweredModule], None]]] = [
    ("split_phases", pass_split_phases),
    ("infer_layouts", pass_infer_layouts),
    ("collect_windows", pass_collect_windows),
    ("plan_grid", pass_plan_grid),
    ("plan_stages", pass_plan_stages),
    ("plan_vmem", pass_plan_vmem),
    ("plan_params", pass_plan_params),
    ("verify", pass_verify),
    ("estimate_cost", pass_estimate_cost),
]


# ---------------------------------------------------------------------------
# Driver + analysis cache
# ---------------------------------------------------------------------------

_ANALYSIS_CACHE: Dict[Tuple[str, tuple], LoweredModule] = {}


def run_pipeline(program, schedule: Schedule) -> LoweredModule:
    """Run every pass; no caching (unit tests / debugging).

    A TileError escaping a pass is tagged with the program name and the
    failing pass (``TileError.context``) so a mid-pipeline failure names
    its kernel instead of surfacing as a bare message three layers up.
    """
    m = LoweredModule(program, schedule)
    for name, p in PIPELINE:
        try:
            p(m)
        except TileError as e:
            if e.context is None:
                e.context = f"program {program.name!r}, pass {name!r}"
            raise
    return m


def analyze(program, schedule: Schedule = None, use_cache: bool = True) -> LoweredModule:
    """Cached ``TileProgram -> LoweredModule``.

    The cache key is structural, so re-traced copies of the same kernel
    (fresh buffer names, fresh factory call) hit the same entry."""
    schedule = schedule or Schedule()
    if not use_cache:
        return run_pipeline(program, schedule)
    key = (program_fingerprint(program), schedule_key(schedule))
    mod = _ANALYSIS_CACHE.get(key)
    if mod is None:
        mod = run_pipeline(program, schedule)
        _ANALYSIS_CACHE[key] = mod
    return mod


def clear_analysis_cache() -> None:
    _ANALYSIS_CACHE.clear()
