"""Phase classification pass (DESIGN.md §3.1).

A kernel body has at most one top-level ``T.Pipelined`` loop; everything
before it runs once per grid cell at k==0 (PRE), everything after at k==last
(POST).  The phase tag decides both window placement (LOOP windows advance
with the reduction axis) and the functional guards the Pallas backend wraps
around PRE/POST value updates.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

from ..errors import LoweringError
from ..tile_ops import PipelinedOp, TileOp

PRE, LOOP, POST = "pre", "loop", "post"


@dataclasses.dataclass
class Phases:
    pre: List[TileOp]
    pipeline: Optional[PipelinedOp]
    post: List[TileOp]


def split_phases(program) -> Phases:
    pre: List[TileOp] = []
    pipe: Optional[PipelinedOp] = None
    post: List[TileOp] = []
    for op in program.ops:
        if isinstance(op, PipelinedOp):
            if pipe is not None:
                raise LoweringError(
                    f"{program.name}: multiple T.Pipelined loops at kernel top "
                    "level; fuse them or split the kernel (one grid pipeline "
                    "per Pallas kernel)."
                )
            pipe = op
        elif pipe is None:
            pre.append(op)
        else:
            post.append(op)
    return Phases(pre, pipe, post)
