"""Static verification pass over a :class:`LoweredModule` (DESIGN.md §5.8).

TileLang's thesis — scheduling as annotations decoupled from dataflow —
means the dataflow of every lowered kernel is statically analyzable.  This
pass spends that analyzability on safety:

* **Window bounds.**  Every static BlockSpec start expression is interval-
  analyzed over the grid/loop variable extents; a window that can escape
  its declared buffer shape is a :class:`VerifyError` at lowering time.
* **Write races.**  Two grid cells whose output windows can overlap lose
  writes nondeterministically on a parallel grid (and silently, in order,
  on an ``arbitrary`` one).  A grid variable that never reaches any start
  expression of an output window is a proven race; variables that do reach
  one are proven disjoint where the affine structure allows (mixed-radix
  argument below).
* **Alias wiring.**  The ``aliased`` in-out marks decided by
  ``lowering/windows.py`` must match the operand wiring the Pallas backend
  builds for ``input_output_aliases``; :func:`alias_wiring` is the single
  source of truth both sides check against.

Checks that depend on *runtime* scalars — table-directed windows whose
starts load a scalar-prefetch buffer (paged-KV block tables) — cannot be
proved here.  They are not skipped: each becomes a structured
:class:`Obligation` attached to the module, and the dispatch guard in
``kernels/ops.py`` discharges them against the concrete tables before
every launch (entries in range, writable pages disjoint).

What is proved vs. deferred:

====================  =========================================
static start exprs    in-bounds proved here (interval analysis)
table-directed axis   ``table_in_range`` obligation -> dispatch guard
grid var not in any
  output start        write race, rejected here
affine output starts  disjointness proved here (mixed-radix)
table-directed store  ``table_writes_disjoint`` obligation -> guard
atomic (accumulate)   exempt: commutative by construction
====================  =========================================
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Set, Tuple

from ..buffer import SCALAR
from ..errors import VerifyError
from ..expr import (
    BinExpr,
    CastExpr,
    ConstExpr,
    Expr,
    LoadExpr,
    UnaryExpr,
    VarExpr,
    WhereExpr,
    free_vars,
    linear_decompose,
    loads_in,
)
from .module import LoweredModule
from .windows import Window

INF = math.inf


# ---------------------------------------------------------------------------
# Runtime obligations
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Obligation:
    """One check the dispatcher owes the kernel before launch.

    kind
        ``table_in_range`` — axis ``axis`` of ``param`` is positioned by
        entries of scalar buffer ``table``; every entry consumed by the
        launch must place the ``size``-wide window inside the buffer
        (for page pools: entry in ``[0, num_pages)``, with page 0 reserved
        by the serving convention).
        ``table_writes_disjoint`` — ``param`` is *written* through a
        table-directed window; the table rows of one launch must not map
        two grid cells onto the same page (duplicate writable entries).
    """

    kind: str  # "table_in_range" | "table_writes_disjoint"
    param: str  # global buffer the window manages
    tables: Tuple[str, ...]  # scalar-prefetch buffers the start loads
    axis: int  # buffer axis the tables position
    size: int  # window extent along that axis
    writable: bool  # True when the window is an output

    def describe(self) -> str:
        return (
            f"{self.kind}: {self.param}[axis {self.axis}, block {self.size}] "
            f"directed by {'+'.join(self.tables)}"
            + (" (writable)" if self.writable else "")
        )


# ---------------------------------------------------------------------------
# Interval analysis over start expressions
# ---------------------------------------------------------------------------


def _mul_bound(a: float, b: float) -> float:
    if (a in (INF, -INF) and b == 0) or (b in (INF, -INF) and a == 0):
        return 0.0
    return a * b


def interval(e: Expr) -> Tuple[float, float]:
    """Conservative ``[lo, hi]`` bounds of a *static* expression, using
    ``VarExpr.extent`` (every grid/loop/parallel var carries one).  Unknown
    constructs widen to ``(-inf, inf)``; loads must be handled by the
    caller (they make the expression dynamic, not wide)."""
    if isinstance(e, ConstExpr):
        v = float(e.value)
        return (v, v)
    if isinstance(e, VarExpr):
        if e.extent is not None and e.extent >= 1:
            return (0.0, float(e.extent - 1))
        return (-INF, INF)
    if isinstance(e, CastExpr):
        return interval(e.operand)
    if isinstance(e, WhereExpr):
        tl, th = interval(e.then)
        ol, oh = interval(e.otherwise)
        return (min(tl, ol), max(th, oh))
    if isinstance(e, UnaryExpr):
        lo, hi = interval(e.operand)
        if e.op == "neg":
            return (-hi, -lo)
        if e.op == "abs":
            if lo >= 0:
                return (lo, hi)
            return (0.0, max(abs(lo), abs(hi)))
        if e.op in ("floor", "ceil"):
            return (lo, hi)
        return (-INF, INF)
    if isinstance(e, BinExpr):
        if e.op in ("lt", "le", "gt", "ge", "eq", "ne"):
            return (0.0, 1.0)
        ll, lh = interval(e.lhs)
        rl, rh = interval(e.rhs)
        if e.op == "add":
            return (ll + rl, lh + rh)
        if e.op == "sub":
            return (ll - rh, lh - rl)
        if e.op == "mul":
            prods = [
                _mul_bound(ll, rl),
                _mul_bound(ll, rh),
                _mul_bound(lh, rl),
                _mul_bound(lh, rh),
            ]
            return (min(prods), max(prods))
        if e.op == "max":
            return (max(ll, rl), max(lh, rh))
        if e.op == "min":
            return (min(ll, rl), min(lh, rh))
        if e.op in ("floordiv", "mod") and rl == rh and rl > 0:
            b = rl
            if e.op == "floordiv":
                lo = -INF if ll == -INF else math.floor(ll / b)
                hi = INF if lh == INF else math.floor(lh / b)
                return (float(lo), float(hi))
            # Python mod with a positive divisor lands in [0, b)
            if ll >= 0 and lh < b:
                return (ll, lh)
            return (0.0, b - 1)
        return (-INF, INF)
    if isinstance(e, LoadExpr):
        # dynamic; callers split loads out before calling interval()
        return (-INF, INF)
    return (-INF, INF)


def _dynamic_tables(start: Expr) -> List[str]:
    """Scalar-prefetch buffers loaded by a start expression (the axis is
    table-directed when non-empty)."""
    return sorted(
        {ld.buffer.name for ld in loads_in(start) if ld.buffer.scope == SCALAR}
    )


# ---------------------------------------------------------------------------
# Alias wiring — single source of truth for in-out operand positions
# ---------------------------------------------------------------------------


def alias_wiring(m: LoweredModule) -> Dict[int, int]:
    """The ``input_output_aliases`` mapping the Pallas call must use:
    operand position (over scalar-prefetch + input-window + aliased-output
    operands, in that order) -> output index.  The backend builds its own
    wiring from its operand list and cross-checks it against this."""
    n_scalars = len(m.scalar_params)
    n_in_ops = len(m.in_windows)
    aliased_js = [j for j, w in enumerate(m.out_windows) if w.aliased]
    return {n_scalars + n_in_ops + i: j for i, j in enumerate(aliased_js)}


def check_alias_marks(m: LoweredModule) -> None:
    """Structural invariants tying window ``aliased`` marks to the operand
    plan (plan_params) — violated marks would desynchronize the backend's
    ``input_output_aliases`` from the arrays actually passed."""
    name = m.program.name
    aliased = [w for w in m.out_windows if w.aliased]
    # plan_params appends aliased out-params to the tail of arg_params, in
    # out_windows order; the Pallas operand assembly relies on exactly that.
    tail = m.arg_params[len(m.arg_params) - len(aliased):]
    if [id(w.param) for w in aliased] != [id(p) for p in tail]:
        raise VerifyError(
            f"{name}: aliased out-params are not the tail of arg_params; "
            "operand order no longer matches input_output_aliases"
        )
    for w in aliased:
        if sum(1 for p in m.arg_params if p is w.param) != 1:
            raise VerifyError(
                f"{name}: aliased param {w.param.name} appears "
                "more than once in arg_params"
            )
        if w.onchip is not None and not _any_table_axis(w):
            # aliasing for non-atomic stores is only granted when the write
            # placement is data-dependent (lowering/windows.py); a static
            # aliased store would overlap its own reads
            raise VerifyError(
                f"{name}: output window for {w.param.name} is aliased but "
                "statically indexed; aliasing requires a table-directed store"
            )
    for w in m.out_windows:
        if not w.aliased and any(p is w.param for p in m.arg_params):
            raise VerifyError(
                f"{name}: written param {w.param.name} also appears in "
                "arg_params without an alias mark"
            )


def _any_table_axis(w: Window) -> bool:
    return any(_dynamic_tables(s) for s in w.region.starts)


# ---------------------------------------------------------------------------
# The verifier pass
# ---------------------------------------------------------------------------


def _check_bounds(name: str, w: Window, obligations: List[Obligation]) -> None:
    shape = w.param.shape
    for axis, (start, size) in enumerate(zip(w.region.starts, w.region.sizes)):
        tables = _dynamic_tables(start)
        if tables:
            obligations.append(
                Obligation(
                    kind="table_in_range",
                    param=w.param.name,
                    tables=tuple(tables),
                    axis=axis,
                    size=size,
                    writable=w.is_output,
                )
            )
            continue
        if loads_in(start):
            raise VerifyError(
                f"{name}: window start of {w.param.name} axis {axis} loads a "
                "non-scalar buffer; index expressions may only load "
                "scalar-prefetch params"
            )
        lo, hi = interval(start)
        # The index-map fold (lowering/indexing.py) realizes the start as
        # either the expression itself (size-1 / size-divisible affine) or
        # ``(e // size) * size`` (runtime-div fallback).  Both realizations
        # lie in [floor(lo/size)*size, hi], so ``lo >= 0`` and
        # ``hi + size <= extent`` bound every fold soundly.
        if lo < 0 or hi + size > shape[axis]:
            raise VerifyError(
                f"{name}: window of {w.param.name} can escape axis {axis}: "
                f"start in [{lo:g}, {hi:g}], block {size}, extent "
                f"{shape[axis]} ({start!r})"
            )


def _radix_injective(groups: List[Tuple[int, int]], block: int) -> bool:
    """True when ``sum coeff_i * v_i`` (each ``v_i`` in ``[0, extent_i)``)
    maps distinct tuples at least ``block`` apart — i.e. the windows the
    cells select along this axis cannot overlap.

    Mixed-radix argument: sort by |coeff| ascending with uniform sign; if
    ``|c_1| >= block`` and each ``|c_{i+1}| >= |c_i| * extent_i``, the
    smallest nonzero difference between two assignments is ``|c_1|``.
    """
    if not groups:
        return False
    coeffs = [c for c, _ in groups]
    if 0 in coeffs:
        return False
    if not (all(c > 0 for c in coeffs) or all(c < 0 for c in coeffs)):
        return False
    ordered = sorted(((abs(c), e) for c, e in groups))
    if ordered[0][0] < block:
        return False
    for (c0, e0), (c1, _e1) in zip(ordered, ordered[1:]):
        if c1 < c0 * e0:
            return False
    return True


def _check_races(
    name: str,
    w: Window,
    cell_vars: Dict[str, int],
    obligations: List[Obligation],
) -> None:
    """Every variable that distinguishes grid cells must provably steer
    this output window to a distinct region (or be covered by a runtime
    obligation on a table-directed axis)."""
    if w.onchip is None:
        return  # atomic accumulate: commutative, any overlap is the point
    covered: Set[str] = set()
    proven: Set[str] = set()
    dyn_tables: List[Tuple[int, Tuple[str, ...]]] = []
    decomps: List[Tuple[int, int, Optional[Dict[str, int]]]] = []
    for axis, (start, size) in enumerate(zip(w.region.starts, w.region.sizes)):
        tables = _dynamic_tables(start)
        if tables:
            dyn_tables.append((axis, tuple(tables)))
            # the table owns disjointness for every var feeding its lookup
            covered |= free_vars(start)
            continue
        covered |= free_vars(start)
        decomps.append((axis, size, linear_decompose(start)))
    for axis, size, dec in decomps:
        if dec is None:
            continue
        group = [
            (coeff, cell_vars[v])
            for v, coeff in dec.items()
            if v in cell_vars and coeff != 0
        ]
        named = [v for v, c in dec.items() if v in cell_vars and c != 0]
        extra = [
            v for v, c in dec.items() if v and c != 0 and v not in cell_vars
        ]
        if extra:
            # a non-cell variable (e.g. a serial loop) also moves this axis;
            # the radix argument over cell vars alone is no longer airtight
            continue
        if _radix_injective(group, size):
            proven |= set(named)
    for axis, tables in dyn_tables:
        obligations.append(
            Obligation(
                kind="table_writes_disjoint",
                param=w.param.name,
                tables=tables,
                axis=axis,
                size=w.region.sizes[axis],
                writable=True,
            )
        )
    missing = [v for v in cell_vars if v not in covered]
    if missing:
        raise VerifyError(
            f"{name}: write race on {w.param.name}: grid var(s) "
            f"{', '.join(sorted(missing))} never reach the output window "
            f"{w.region!r} — two grid cells write the same region"
        )
    # vars that reach the window but defeat the affine proof are accepted
    # (documented limitation: we reject proven races, we don't demand a
    # disjointness proof for every non-affine pattern)
    del proven


def verify_module(m: LoweredModule) -> List[Obligation]:
    """Run all static checks; returns the runtime obligations."""
    name = m.program.name
    obligations: List[Obligation] = []
    for w in list(m.in_windows) + list(m.out_windows):
        _check_bounds(name, w, obligations)
    pipe_var = (
        m.phases.pipeline.var.name if m.phases.pipeline is not None else None
    )
    # grid cells = parallel kernel axes; the pipelined axis revisits the
    # *same* cell (accumulator semantics), so it is exempt from race checks
    cell_vars = {
        v.name: int(e)
        for v, e in m.program.grid_axes
        if e > 1 and v.name != pipe_var
    }
    for w in m.out_windows:
        _check_races(name, w, cell_vars, obligations)
    check_alias_marks(m)
    # one obligation per distinct check, even when several windows merge
    seen = set()
    unique: List[Obligation] = []
    for ob in obligations:
        if ob not in seen:
            seen.add(ob)
            unique.append(ob)
    return unique


def pass_verify(m: LoweredModule) -> None:
    m.obligations = verify_module(m)
