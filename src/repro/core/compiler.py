"""``repro.core.compile``: pipeline analysis + backend dispatch + caching.

    kernel = compile(program, schedule, target="pallas")     # or "reference"

The analysis half (``lowering.analyze``) is memoized on the program's
structural fingerprint and the schedule, and the emitted kernel is memoized
again per target — so autotuners, kernel libraries and the serving engine
can call ``compile`` per request and pay nothing after the first hit
(DESIGN.md §3.3).  Third-party targets plug in through
``repro.core.backends.register_backend``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from .backends import available_backends, canonical_target, get_backend
from .errors import LoweringError
from .lowering import (
    CompiledKernel,
    LoweredModule,
    analyze,
    clear_analysis_cache,
    program_fingerprint,
    schedule_key,
)
from .schedule import Schedule

DEFAULT_TARGET = "pallas"

_KERNEL_CACHE: Dict[Tuple[str, tuple, str], CompiledKernel] = {}


def compile(  # noqa: A001 — mirrors tilelang.compile
    program,
    schedule: Optional[Schedule] = None,
    target: Optional[str] = None,
    backend: Optional[str] = None,
    use_cache: bool = True,
) -> CompiledKernel:
    """Compile a TileProgram for ``target`` (by registry name).

    ``backend=`` is an accepted alias of ``target=`` (the pre-registry
    keyword); passing both with different values is an error.
    """
    if backend is not None:
        if target is not None and canonical_target(target) != canonical_target(backend):
            raise LoweringError(
                f"compile: conflicting target={target!r} and backend={backend!r}"
            )
        target = backend
    target = canonical_target(target or DEFAULT_TARGET)
    schedule = schedule or Schedule()

    if not use_cache:
        return get_backend(target)(analyze(program, schedule, use_cache=False))

    key = (program_fingerprint(program), schedule_key(schedule), target)
    kernel = _KERNEL_CACHE.get(key)
    if kernel is None:
        module = analyze(program, schedule)
        kernel = get_backend(target)(module)
        _KERNEL_CACHE[key] = kernel
    return kernel


def clear_compile_cache() -> None:
    """Drop both the kernel cache and the underlying analysis cache."""
    _KERNEL_CACHE.clear()
    clear_analysis_cache()


__all__ = [
    "compile",
    "clear_compile_cache",
    "available_backends",
    "DEFAULT_TARGET",
]
