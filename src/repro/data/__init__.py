from .pipeline import DataConfig, SyntheticTokens, TokenFileDataset, make_loader

__all__ = ["DataConfig", "SyntheticTokens", "TokenFileDataset", "make_loader"]
