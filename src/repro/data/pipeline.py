"""Token data pipeline: synthetic + sharded binary file reader, with
deterministic resume and background prefetch.

Design points for the 1000+-node posture:

* **host sharding** — each host reads only its slice (``host_id``/
  ``num_hosts``); the global batch is assembled by the runtime from
  per-host shards (standard multi-host jax input layout).
* **deterministic resume** — batch ``i`` is a pure function of (seed, i),
  so restoring step ``k`` replays the exact stream without saved iterator
  state.
* **prefetch** — a small background thread keeps ``prefetch`` batches ready.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Iterator, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class DataConfig:
    batch: int  # per-host batch
    seq: int
    vocab_size: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    prefetch: int = 2


class SyntheticTokens:
    """Zipf-ish synthetic LM tokens; infinite, deterministic per (seed, idx)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # zipf-like unnormalized weights over a capped alphabet for speed
        self.alphabet = min(cfg.vocab_size, 32768)

    def batch_at(self, index: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + index) * cfg.num_hosts + cfg.host_id
        )
        # cheap zipf via pareto-quantized draw
        u = rng.random((cfg.batch, cfg.seq + 1))
        toks = np.minimum(
            (self.alphabet * (u ** 2.5)).astype(np.int32), cfg.vocab_size - 1
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


class TokenFileDataset:
    """Reader over sharded flat binary token files (.bin of uint16/uint32).

    Files are memory-mapped; sample ``i`` is a deterministic window, so the
    stream is resumable and identical across restarts.
    """

    def __init__(self, cfg: DataConfig, paths: Sequence[str], dtype=np.uint16):
        self.cfg = cfg
        self.maps = [np.memmap(p, dtype=dtype, mode="r") for p in paths]
        self.sizes = [len(m) - cfg.seq - 1 for m in self.maps]
        if any(s <= 0 for s in self.sizes):
            raise ValueError("shard shorter than one sample")
        self.total = sum(self.sizes)

    def batch_at(self, index: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + index) * cfg.num_hosts + cfg.host_id
        )
        toks = np.empty((cfg.batch, cfg.seq + 1), np.int32)
        for b in range(cfg.batch):
            off = int(rng.integers(0, self.total))
            for m, size in zip(self.maps, self.sizes):
                if off < size:
                    toks[b] = np.asarray(m[off : off + cfg.seq + 1], np.int32)
                    break
                off -= size
        toks = np.minimum(toks, cfg.vocab_size - 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


def make_loader(dataset, start_step: int = 0) -> Iterator[dict]:
    """Background-prefetched iterator starting at ``start_step``."""
    cfg = dataset.cfg
    q: queue.Queue = queue.Queue(maxsize=max(cfg.prefetch, 1))
    stop = threading.Event()

    def worker():
        i = start_step
        while not stop.is_set():
            batch = dataset.batch_at(i)
            while not stop.is_set():
                try:
                    q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            i += 1

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()

    return _Iter()
