"""Batched serving engine: continuous batching over a paged KV cache.

The engine owns a fixed number of decode *slots* (static shapes — the jit'd
step never retraces).  Requests are admitted into free slots, prefilled,
and generate until EOS / max_tokens, at which point the slot is recycled
for the next queued request.

Prefill comes in two modes (``ServeConfig.prefill``):

* ``"chunked"`` (default, Sarathi-style) — each engine tick spends a fixed
  **token budget**: every generating slot consumes one budget token for its
  decode step, and the leftover budget feeds prompt *chunks* (up to
  ``prefill_chunk`` tokens, oldest-admitted request first) through one
  chunk-wide forward pass (``lm.prefill_step`` — the prefill_attention
  kernel path).  A 1k-token prompt then costs ~``1k / prefill_chunk``
  ticks instead of 1k full decode steps, while decode latency stays
  bounded: no tick ever exceeds ``token_budget`` tokens.  Covers the
  attention families (GQA via prefill_attention, MLA via mla_prefill);
  falls back to replay only for architectures without chunk-parallel cache
  writes (SSM / hybrid recurrent state).
* ``"replay"`` — the legacy baseline: prompts stream one token per engine
  tick through the decode step.

KV memory comes in two layouts behind one ``decode_step`` interface
(``ServeConfig.cache``):

* ``"paged"`` (default) — vLLM-style block pool: KV lives in fixed-size
  pages; each slot owns a block table (serving/paged_cache.py).  The
  scheduler is real: **admission** requires enough free blocks for the
  request's resident tokens, **preemption** evicts the lowest-priority
  (then youngest) request back to the queue when the pool is exhausted
  (recompute-style resume: its prompt *and* generated tokens replay through
  prefill), and completion **recycles blocks immediately** at EOS.

Paged mode additionally runs a **prefix cache** (``ServeConfig.
prefix_cache``, on by default for the attention families): full pages of
prompt tokens are indexed in a radix tree over token ids
(paged_cache.PrefixCache) when a request finishes prefilling, and a new
request whose prompt prefixes a cached chain *attaches* those pages at
admission — positions advance past them with **no kernel dispatch at
all**, so a warm-prefix request's TTFT collapses to the divergent tail
(~one chunk under chunked prefill).  Pages are refcounted; a slot that
must write into a shared page goes through copy-on-write
(``ensure_writable`` + ``lm.copy_pages``) before the step runs, and every
repoint marks the device block table dirty.  Cached pages nobody
references are reclaimed LRU-first when admission, growth or grow-ahead
grants run short — a hot pool degrades to the uncached engine rather than
refusing admission.  SSM/hybrid families gate the cache off: skipped
positions would skip recurrent-state updates.
* ``"contiguous"`` — the legacy per-slot ``max_len`` strip (ring buffers
  for sliding-window layers); preallocates ``slots × max_len`` regardless
  of real prompt lengths.  Kept as the comparison baseline.

Both layouts cover every attention family: GQA/MQA page their KV heads,
MLA pages its shared latent+rope cache (DESIGN.md §5.4).  Pure-SSM archs
have no attention KV state to page — asking for ``cache="paged"`` there is
a loud ``ValueError``, never a silent layout downgrade.

Both layouts produce identical outputs for identical requests — asserted in
tests/test_serving.py.

The decode hot loop is **device-resident** (``ServeConfig.sync_every``):

* Sampling is folded into the jit'd step (``sampling.sample_step``) — the
  engine uploads token feeds and downloads sampled token *ids*; logits
  never cross the device boundary.  The PRNG key is a device carry with a
  greedy fast path that never splits it.
* The jit'd steps **donate** the cache (``donate_argnums``): XLA updates
  the KV pages/strips in place instead of copying the full cache every
  tick.  The device block-table tensor is cached on the engine and
  re-uploaded only when the scheduler actually mutates tables.
* With ``sync_every > 1``, up to that many decode ticks run in a single
  ``jax.lax.scan`` dispatch (``lm.decode_loop``): EOS and per-slot token
  limits become on-device stop masks, emitted tokens land in a device
  buffer drained once per dispatch, and the Python scheduler (admission,
  growth, preemption) runs only at sync boundaries.  Paged slots are
  pre-granted grow-ahead pages for the worst-case window, all-or-nothing;
  when the pool is too tight the engine falls back to per-tick stepping
  for that boundary, so scheduling fidelity is never traded for speed.

**Failure model** (DESIGN.md §5.7): every request ends in exactly one
terminal status (COMPLETED / TIMED_OUT / CANCELLED / FAILED / REJECTED)
through one exit path (``_terminate``) that releases its pages — requests
carry ``deadline_ticks``/``max_retries`` declaratively and expose
``cancel()``; ``drain()``/``shutdown()`` wind the engine down to an empty
pool.  A :class:`serving.faults.FaultInjector` can force pool exhaustion,
grant failure or logits poisoning at the real allocation/dispatch sites,
and ``ServeConfig.audit=True`` re-checks page conservation, refcount
consistency, radix reachability and slot hygiene after every tick.
``snapshot()``/``restore()`` persist the radix index plus its page
contents across engine restarts so warm-prefix TTFT survives a crash.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import GuardError
from repro.kernels.ops import guard_dispatch
from repro.models import lm
from repro.models.config import ModelConfig

from .faults import FaultInjector, audit_engine
from .paged_cache import (
    BlockPool,
    PoolExhausted,
    PrefixCache,
    SlotTables,
    blocks_for,
)
from .sampling import sample_step, spec_accept, spec_sample_step

# One jit'd decode step per (model configuration, sampling temperature),
# shared by every engine instance (and so by every request): constructing a
# fresh ``jax.jit`` wrapper per engine discards XLA's trace cache and
# recompiles the step for each new engine even when the config is
# identical.  Keyed on the config's dataclass repr (deterministic over
# field values); the closure captures a deep copy so later mutation of the
# caller's config object cannot change what a cached entry computes.
# LRU-bounded so config sweeps don't pin an XLA executable per visited
# config for process lifetime.  Both cache layouts share one entry: the
# layout lives in the cache pytree's treedef, so jax.jit keeps one trace
# per layout under the same wrapper.
#
# Every cached step **donates its cache argument** (``donate_argnums``):
# the caller's cache pytree is consumed — XLA writes the new KV in place
# instead of materializing a second full cache per tick — and the returned
# cache is the only live reference afterwards.  The engine upholds this by
# always replacing ``self.cache`` with the step's output.
_STEP_FNS: "collections.OrderedDict[tuple, object]" = collections.OrderedDict()
_STEP_FNS_MAX = 8


def _cached_fn(key, build):
    fn = _STEP_FNS.get(key)
    if fn is None:
        fn = build()
        _STEP_FNS[key] = fn
        while len(_STEP_FNS) > _STEP_FNS_MAX:
            _STEP_FNS.popitem(last=False)
    else:
        _STEP_FNS.move_to_end(key)
    return fn


def _decode_step_fn(cfg: ModelConfig, temperature: float):
    """Fused decode tick: model step + sampling in one jit'd program.
    Returns ``(tokens, bad, cache, key)`` — logits stay on device.
    ``poison`` is the fault injector's NaN overwrite mask (all-False in
    normal operation) and ``bad`` flags rows whose logits held no finite
    value — injected or genuine — so the engine can fail exactly the
    affected request instead of emitting garbage."""

    def build():
        snap = copy.deepcopy(cfg)

        def step(p, c, tok, pos, key, live, poison):
            logits, c = lm.decode_step(p, snap, c, tok, pos, live=live)
            logits = jnp.where(poison[:, None], jnp.nan, logits)
            bad = ~jnp.any(jnp.isfinite(logits), axis=-1)
            tok, key = sample_step(logits, key, temperature=temperature)
            return tok, bad, c, key

        return jax.jit(step, donate_argnums=(1,))

    return _cached_fn(("decode", repr(cfg), temperature), build)


def _prefill_step_fn(cfg: ModelConfig, temperature: float):
    """One jit'd chunk-wide prefill step per model config (the chunk width
    is a trace-time shape, so differing ``prefill_chunk`` values simply
    trace separate entries under the same wrapper).  Sampling is fused like
    the decode step: the returned tokens are what a chunk that completes
    its prompt emits.  ``poison``/``bad`` mirror the decode step."""

    def build():
        snap = copy.deepcopy(cfg)

        def step(p, c, toks, pos, lens, key, poison):
            logits, c = lm.prefill_step(p, snap, c, toks, pos, lens)
            logits = jnp.where(poison[:, None], jnp.nan, logits)
            bad = ~jnp.any(jnp.isfinite(logits), axis=-1)
            tok, key = sample_step(logits, key, temperature=temperature)
            return tok, bad, c, key

        return jax.jit(step, donate_argnums=(1,))

    return _cached_fn(("prefill", repr(cfg), temperature), build)


def _decode_loop_fn(cfg: ModelConfig, temperature: float, n_steps: int,
                    eos_id: int, max_len: int):
    """The multi-step window: ``n_steps`` fused decode ticks in one
    ``jax.lax.scan`` dispatch (``lm.decode_loop``), stop masks and emitted
    tokens on device."""

    def build():
        snap = copy.deepcopy(cfg)

        def sample_fn(logits, key, gate):
            return sample_step(logits, key, temperature=temperature,
                               gate=gate)

        def loop(p, c, feed, pos, key, live, remaining):
            return lm.decode_loop(
                p, snap, c, feed, pos, key, live, remaining,
                n_steps=n_steps, sample_fn=sample_fn, eos_id=eos_id,
                max_len=max_len,
            )

        return jax.jit(loop, donate_argnums=(1,))

    return _cached_fn(
        ("decode_loop", repr(cfg), temperature, n_steps, eos_id, max_len),
        build,
    )


def _spec_loop_fn(cfg: ModelConfig, temperature: float, proposer: str,
                  n_rounds: int, draft_len: int, eos_id: int, max_len: int):
    """The speculative window: ``n_rounds`` draft-verify rounds in one
    ``jax.lax.scan`` dispatch (``lm.spec_decode_loop``) — each round
    proposes ``draft_len`` tokens from the slot's own history, scores them
    in one chunk forward through the prefill kernels, and commits the
    accepted prefix as on-device masks."""

    def build():
        snap = copy.deepcopy(cfg)
        propose = lm.DRAFT_PROPOSERS[proposer]

        def sample_fn(logits, key, gate):
            return spec_sample_step(logits, key, temperature=temperature,
                                    gate=gate)

        def loop(p, c, feed, pos, key, live, remaining, history, poison):
            return lm.spec_decode_loop(
                p, snap, c, feed, pos, key, live, remaining, history,
                n_rounds=n_rounds, draft_len=draft_len, propose_fn=propose,
                sample_fn=sample_fn, accept_fn=spec_accept, eos_id=eos_id,
                max_len=max_len, poison=poison,
            )

        return jax.jit(loop, donate_argnums=(1,))

    return _cached_fn(
        ("spec_loop", repr(cfg), temperature, proposer, n_rounds, draft_len,
         eos_id, max_len),
        build,
    )


def _copy_pages_fn(cfg: ModelConfig):
    """jit'd copy-on-write page duplication (``lm.copy_pages``), donating
    the cache like every other step so XLA copies pages in place.  One
    wrapper per model config; distinct pair-count shapes trace separate
    entries under it (the engine pads pair lists to powers of two to bound
    the variants)."""

    def build():
        return jax.jit(lm.copy_pages, donate_argnums=(0,))

    return _cached_fn(("copy_pages", repr(cfg)), build)


def plan_prefill_chunks(
    budget: int,
    n_gen: int,
    pending: Sequence[Tuple[int, int, int]],  # (slot, admit_seq, remaining)
    chunk: int,
) -> Dict[int, int]:
    """Sarathi-style budget split: decode tokens are spent first (one per
    generating slot), the leftover feeds prompt chunks oldest-admitted
    first.  Grants are all-or-nothing per request — always ``min(chunk,
    remaining)``, never a room-limited partial — so every chunk *starts* at
    a multiple of ``chunk``: the page-alignment contract of the prefill
    kernel's table-directed page writes (a room-limited partial would shift
    every later chunk of that prompt off page boundaries).  Invariants
    (property-tested): ``n_gen + sum(result.values()) <= max(budget,
    n_gen)``, every grant equals ``min(chunk, remaining)``, and grants form
    an age-ordered prefix of ``pending`` (no head-of-line skipping)."""
    room = budget - n_gen
    out: Dict[int, int] = {}
    for slot, _seq, remaining in sorted(pending, key=lambda t: t[1]):
        n = min(chunk, remaining)
        if n <= 0:
            continue
        if n > room:
            break
        out[slot] = n
        room -= n
    return out


@dataclasses.dataclass
class ServeConfig:
    slots: int = 8  # decode batch width
    max_len: int = 1024  # per-request logical cache length
    max_new_tokens: int = 128
    eos_id: int = -1  # -1: never stops early
    temperature: float = 0.0
    seed: int = 0
    cache: str = "paged"  # "paged" | "contiguous"
    page_size: int = 16  # tokens per KV block (paged mode)
    # pool size in blocks; None = slots * ceil(max_len / page_size), i.e.
    # parity with the contiguous footprint.  Size it below that to actually
    # oversubscribe memory (that's the point of paging).
    num_blocks: Optional[int] = None
    # KV storage format for the page pools: None = model dtype; "int8"/"int4"
    # = packed per-token quantization with per-row scales (paged mode only).
    # Overrides ModelConfig.kv_dtype for this engine; the attention kernels
    # dequantize inline at gather, so quality degrades gracefully while
    # per-page bytes shrink ~2-4x (see BlockPool.page_bytes).
    kv_dtype: Optional[str] = None
    # -- prefill fast path ------------------------------------------------
    prefill: str = "chunked"  # "chunked" | "replay"
    # prompt tokens per chunk-wide forward pass; clamped at engine init to
    # token_budget - slots + 1 so a chunk always fits the leftover budget
    # (grants are all-or-nothing to keep chunk starts page-aligned)
    prefill_chunk: int = 16
    # per-tick token budget shared by the decode batch and prefill chunks;
    # None = slots + prefill_chunk (one full chunk rides along with a full
    # decode batch).  Effective budget is floored at `slots` so a full
    # generation batch always fits.
    token_budget: Optional[int] = None
    # -- prefix caching ---------------------------------------------------
    # index full prompt pages in a radix tree and attach cache-hit pages at
    # admission (refcounted sharing + copy-on-write).  Paged mode only;
    # gated off automatically for SSM/hybrid families, whose recurrent
    # state cannot skip positions.
    prefix_cache: bool = True
    # -- device-resident decode loop --------------------------------------
    # decode ticks per host dispatch: 1 = legacy per-tick stepping; N > 1
    # runs up to N ticks in one jax.lax.scan when every active slot is
    # generating (EOS / token limits become on-device stop masks, scheduling
    # happens only at sync boundaries).  Paged slots must win an
    # all-or-nothing grow-ahead page grant for the worst-case window, else
    # that boundary falls back to a per-tick step.
    sync_every: int = 1
    # -- speculative decoding ---------------------------------------------
    # draft proposer name (lm.DRAFT_PROPOSERS) or None = off.  "ngram" is
    # self-speculation: an on-device lookahead over each slot's own emitted
    # tokens — no second model, no new weights; the registry is the plug
    # point for a tiny draft model later.  A speculative round drafts
    # draft_len tokens, scores all of them plus the feed token in ONE chunk
    # forward through the prefill kernels (batched verify *is* chunked
    # prefill), and commits the accepted prefix on device — so it composes
    # multiplicatively with sync_every: one host dispatch covers up to
    # sync_every * (draft_len + 1) tokens.  Requires an arch with
    # supports_chunked_prefill (checked at engine init, where the model
    # config is known).  Greedy output is byte-identical to plain decode by
    # construction; temperature streams advance the PRNG key a fixed
    # draft_len + 2 splits per round regardless of acceptance length.
    spec_decode: Optional[str] = None
    draft_len: int = 4
    # -- fault tolerance --------------------------------------------------
    # run the invariant auditor (serving.faults.audit_engine) after every
    # tick: page conservation, refcount consistency, radix reachability,
    # no orphaned slots.  O(pool) per tick — chaos/debug machinery.
    audit: bool = False
    # base ticks a preemption victim waits before re-admission, doubling
    # per preemption (capped at 32x).  0 = legacy immediate re-admission.
    # Under a preemption storm, backoff lets the slots drain instead of
    # thrashing the same victims through recompute-resume every tick.
    retry_backoff: int = 0
    # discharge the kernels' runtime obligations (core.lowering.verify)
    # before every paged dispatch: block-table entries in range, no
    # duplicate writable pages, lengths within capacity.  A violation FAILs
    # exactly the offending request (graceful degradation) instead of
    # letting a corrupt table scribble on another request's pages.  On by
    # default; opt out (e.g. to benchmark raw dispatch cost) with
    # ``guards=False`` / ``--guards off``.
    guards: bool = True

    def __post_init__(self):
        # loud at construction, not a shape error three layers down
        for name in ("slots", "max_len", "max_new_tokens", "page_size",
                     "prefill_chunk", "draft_len"):
            v = getattr(self, name)
            if v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")
        if self.num_blocks is not None and self.num_blocks <= 0:
            raise ValueError(
                f"num_blocks must be positive, got {self.num_blocks}"
            )
        if self.token_budget is not None and self.token_budget < self.slots:
            raise ValueError(
                f"token_budget={self.token_budget} < slots={self.slots}: "
                "a full generation batch could never fit in one tick"
            )
        if self.kv_dtype not in (None, "int8", "int4"):
            raise ValueError(
                f"unknown kv_dtype {self.kv_dtype!r} "
                "(expected None, 'int8' or 'int4')"
            )
        if self.cache not in ("paged", "contiguous"):
            raise ValueError(f"unknown cache mode {self.cache!r}")
        if self.prefill not in ("chunked", "replay"):
            raise ValueError(f"unknown prefill mode {self.prefill!r}")
        if self.retry_backoff < 0:
            raise ValueError(
                f"retry_backoff must be >= 0, got {self.retry_backoff}"
            )
        if (self.spec_decode is not None
                and self.spec_decode not in lm.DRAFT_PROPOSERS):
            raise ValueError(
                f"unknown spec_decode proposer {self.spec_decode!r} "
                f"(registered: {sorted(lm.DRAFT_PROPOSERS)})"
            )


# Request lifecycle: QUEUED <-> RUNNING (preemption re-queues), ending in
# exactly one terminal status.  Reaching *any* terminal status releases
# every block the request held — the freed-page guarantee lives in the
# engine's single exit path (``_terminate``) and is checked live by the
# auditor (serving.faults).
QUEUED = "queued"
RUNNING = "running"
COMPLETED = "completed"  # EOS / token limit reached
TIMED_OUT = "timed_out"  # deadline_ticks expired before completion
CANCELLED = "cancelled"  # cancel() honored, or engine shutdown
FAILED = "failed"  # poisoned logits, retry budget, or outgrew the pool
REJECTED = "rejected"  # could never be served (admission fail-fast)
TERMINAL = (COMPLETED, TIMED_OUT, CANCELLED, FAILED, REJECTED)

# snapshot()/restore() wire format version (DESIGN.md §5.7)
SNAPSHOT_FORMAT = 1


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: Optional[int] = None
    priority: int = 0  # higher survives preemption longer
    # ticks from submission before the request times out wherever it is
    # (queued or mid-generation); None = no deadline.  Partial output is
    # preserved on the request when the deadline fires.
    deadline_ticks: Optional[int] = None
    # preemption re-admissions before the request fails instead of
    # retrying; None = retry forever (the legacy behavior)
    max_retries: Optional[int] = None
    # filled by the engine:
    status: str = QUEUED  # QUEUED <-> RUNNING -> one of TERMINAL
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0
    error: Optional[str] = None  # why a non-COMPLETED request ended
    submit_step: int = 0  # engine tick at submission
    first_token_step: Optional[int] = None  # tick that produced output[0]
    admit_step: Optional[int] = None  # tick of first admission into a slot
    cached_tokens: int = 0  # prompt tokens covered by prefix-cache hits
    _cancel: bool = dataclasses.field(default=False, repr=False)

    def cancel(self) -> None:
        """Request cancellation; honored at the next scheduler boundary
        (the engine frees the slot/queue entry and marks the request
        CANCELLED).  A no-op once the request is terminal."""
        if not self.done:
            self._cancel = True

    @property
    def ttft_ticks(self) -> Optional[int]:
        """Engine ticks from submission to the first generated token."""
        if self.first_token_step is None:
            return None
        return self.first_token_step - self.submit_step + 1

    @property
    def ttft_admit_ticks(self) -> Optional[int]:
        """Engine ticks from first admission to the first generated token —
        the queue-independent TTFT (what prefix caching shrinks: prefill
        work, not time spent waiting for a slot)."""
        if self.first_token_step is None or self.admit_step is None:
            return None
        return self.first_token_step - self.admit_step + 1


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                 injector: Optional[FaultInjector] = None):
        if serve_cfg.kv_dtype is not None and cfg.kv_dtype != serve_cfg.kv_dtype:
            # the storage format is a property of the cache pytree the step
            # functions trace over, so it lives on the model config (and so
            # inside the jit-cache keys) — the engine just forwards it
            cfg = dataclasses.replace(cfg, kv_dtype=serve_cfg.kv_dtype)
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        b = serve_cfg.slots
        mode = serve_cfg.cache
        if mode not in ("paged", "contiguous"):
            raise ValueError(f"unknown cache mode {mode!r}")
        if cfg.kv_dtype is not None and mode != "paged":
            raise ValueError(
                f"kv_dtype={cfg.kv_dtype!r} requires cache='paged'"
            )
        # no silent downgrades: every attention family pages (GQA/MQA
        # through KV pages, MLA through latent pages); an arch with no
        # attention KV state fails loudly inside lm.init_cache instead of
        # being quietly handed a different memory layout than requested
        self.cache_mode = mode

        if mode == "paged":
            ps = serve_cfg.page_size
            self.max_pages = blocks_for(serve_cfg.max_len, ps)
            nb = serve_cfg.num_blocks or b * self.max_pages
            # physical page 0 is reserved (padding/garbage page), so the
            # device pool holds nb + 1 pages and the allocator hands out
            # ids 1..nb.
            self.cache = lm.init_cache(
                cfg, b, serve_cfg.max_len, layout="paged", page_size=ps,
                num_blocks=nb + 1,
            )
            # bytes one physical page costs across every layer's pool leaves
            # (packed data + scale columns for quantized caches) — the unit
            # byte-budget sizing works in (paged_cache.blocks_for_bytes)
            page_bytes = self.cache.kv_bytes() // (nb + 1)
            self.pool = BlockPool(nb, ps, base=1, page_bytes=page_bytes)
            self.tables = SlotTables(self.pool, b, self.max_pages)
        else:
            self.pool = None
            self.tables = None
            self.cache = lm.init_cache(cfg, b, serve_cfg.max_len)

        # prefix cache: paged attention families only — skipping cached
        # positions is only sound when all per-position state lives in the
        # (shareable) KV pages; recurrent SSM/hybrid state must replay
        self.prefix: Optional[PrefixCache] = None
        if (
            mode == "paged"
            and serve_cfg.prefix_cache
            and lm.supports_chunked_prefill(cfg)
        ):
            self.prefix = PrefixCache(
                self.pool, salt=(cfg.name, serve_cfg.page_size)
            )
        self.pages_shared = 0  # cache-hit pages attached at admission
        self.pages_copied = 0  # copy-on-write page duplications
        self.pages_deduped = 0  # duplicate prefill pages absorbed at insert

        self.pos = np.zeros((b,), np.int32)  # next write position per slot
        self.slot_req: List[Optional[Request]] = [None] * b
        # chunked mode: "prefill" until the replay cursor reaches the end of
        # prompt+output, then "gen" (replay mode leaves these unused)
        self.slot_state: List[Optional[str]] = [None] * b
        self.queue: collections.deque[Request] = collections.deque()
        self._uid = itertools.count()
        self._admit_seq = itertools.count()
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._step = _decode_step_fn(cfg, serve_cfg.temperature)
        if serve_cfg.prefill not in ("chunked", "replay"):
            raise ValueError(f"unknown prefill mode {serve_cfg.prefill!r}")
        self.prefill_mode = (
            "chunked"
            if serve_cfg.prefill == "chunked" and lm.supports_chunked_prefill(cfg)
            else "replay"
        )
        self._prefill = (
            _prefill_step_fn(cfg, serve_cfg.temperature)
            if self.prefill_mode == "chunked" else None
        )
        self.sync_every = max(1, serve_cfg.sync_every)
        self._loop_fns: Dict[int, object] = {}  # window length -> jit'd loop
        # -- speculative decoding -----------------------------------------
        # gated on the model config (hence here, not __post_init__): the
        # verify pass routes through the chunked-prefill kernels, so an
        # arch that cannot chunk-prefill cannot verify drafts either
        if serve_cfg.spec_decode is not None and not lm.supports_chunked_prefill(cfg):
            raise ValueError(
                f"spec_decode={serve_cfg.spec_decode!r} requires a chunked-"
                f"prefill arch (GQA/MLA); {cfg.name} (attention="
                f"{cfg.attention}, family={cfg.family}) cannot run the "
                "verify pass"
            )
        self.spec_proposer = serve_cfg.spec_decode
        self._spec_loop_fns: Dict[int, object] = {}  # rounds -> jit'd loop
        self.spec_windows = 0  # speculative dispatches taken
        self.spec_rounds = 0  # draft-verify rounds drained (>=1 emit or bad)
        self.spec_proposed = 0  # draft tokens scored by verify
        self.spec_accepted = 0  # draft tokens accepted (excl. bonus token)
        self.spec_all_rejected = 0  # live slot-rounds accepting zero drafts
        self.spec_fallbacks = 0  # spec window declined -> plain window/tick
        # the device-side block-table tensor is cached across ticks and
        # re-uploaded only after the scheduler mutates tables (admission
        # growth, grow-ahead grants/trims, preemption, EOS recycling)
        self._tables_dirty = True
        self.table_uploads = 0  # perf counter: host->device table transfers
        self.decode_windows = 0  # multi-step dispatches taken
        self.window_fallbacks = 0  # grow-ahead denied -> per-tick boundary
        self.dispatches = 0  # step() calls that ran device work: a window
        # counts once however many ticks it covers — the deterministic
        # measure of host-round-trip amortization (the flaky-free companion
        # to wall-clock tok/s in the bench trajectory)
        # effective per-tick budget: a full generation batch always fits
        self.token_budget = max(
            serve_cfg.token_budget or (b + serve_cfg.prefill_chunk), b
        )
        # effective chunk: grants are all-or-nothing (chunk starts must stay
        # chunk-aligned — the kernel's page-write contract), so the chunk is
        # clamped to the worst-case leftover room (budget minus a full
        # generation batch less the prefilling slot itself).  Guarantees a
        # prefill slot always makes progress: room = budget - n_gen >=
        # budget - (slots-1) >= chunk.
        self.prefill_chunk = max(
            1, min(serve_cfg.prefill_chunk, self.token_budget - b + 1)
        )
        # per-tick spend, bounded like every other per-process accumulator
        # here (a heavy-traffic engine must not grow state per tick)
        self.tick_tokens: "collections.deque[int]" = collections.deque(
            maxlen=4096
        )
        self.completed: List[Request] = []
        self.steps_run = 0
        self.preemptions = 0
        # -- fault tolerance --------------------------------------------
        self.admission_open = True  # drain()/shutdown() close intake
        self.poisoned_rows = 0  # logits rows with no finite value seen
        self.audits_run = 0  # invariant audits executed (scfg.audit)
        self.guard_failures = 0  # requests FAILed by the dispatch guard
        self.table_corruptions = 0  # injected table_corrupt faults fired
        self._corrupt_mode = 0  # cycles injected-corruption flavors
        self.injector = injector
        if injector is not None:
            injector.bind_clock(lambda: self.steps_run)
            if self.pool is not None:
                self.pool.injector = injector

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens=None,
               priority: int = 0, deadline_ticks: Optional[int] = None,
               max_retries: Optional[int] = None) -> Request:
        req = Request(next(self._uid), list(prompt), max_new_tokens,
                      priority=priority, deadline_ticks=deadline_ticks,
                      max_retries=max_retries, submit_step=self.steps_run)
        self.queue.append(req)
        return req

    # -- scheduler ------------------------------------------------------
    def _resident_tokens(self, req: Request) -> int:
        """Tokens the request must hold to make forward progress: its full
        replay (prompt + already-generated) plus the next write."""
        return len(req.prompt) + len(req.output) + 1

    def _admit(self):
        """FIFO admission into free slots; paged mode additionally gates on
        free-block count, allocating the request's replay footprint up front
        (no head-of-line skipping — deterministic order).  The one sanctioned
        exception: preemption victims still in retry backoff step aside and
        let younger requests pass until their wait expires.  Closed entirely
        once ``drain()``/``shutdown()`` stops intake."""
        if not self.admission_open:
            return
        for s in range(self.scfg.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = None
            for cand in self.queue:
                if getattr(cand, "_not_before", 0) > self.steps_run:
                    continue  # backing off after a preemption storm
                req = cand
                break
            if req is None:
                break  # everyone queued is backing off
            if self.pool is not None:
                need = blocks_for(self._resident_tokens(req), self.pool.page_size)
                if need > min(self.pool.num_blocks, self.max_pages):
                    # can never fit — pool too small, or prompt beyond the
                    # per-slot table (max_len): fail fast instead of wedging
                    # the queue head forever (or crashing ensure_capacity).
                    self.queue.remove(req)
                    self._terminate(req, REJECTED, error=(
                        f"needs {need} KV blocks; pool holds "
                        f"{self.pool.num_blocks}, table holds {self.max_pages}"
                    ))
                    continue
                matched: List[int] = []
                if self.prefix is not None:
                    # cap the match so at least one replay token remains (the
                    # decode loop needs a real last token to feed) and so only
                    # prompt pages are ever consumed from the cache — resumed
                    # preemptees replay prompt + output, but output pages are
                    # never published to the index.
                    ps = self.pool.page_size
                    replay_len = len(req.prompt) + len(req.output)
                    cap = min(len(req.prompt), replay_len - 1) // ps
                    matched = self.prefix.match(req.prompt, cap)
                shortfall = (need - len(matched)) - self.pool.free
                if shortfall > 0 and self.prefix is not None:
                    self.prefix.evict(shortfall, protect=frozenset(matched))
                if self.pool.free < need - len(matched):
                    break
            else:
                matched = []
            self.queue.remove(req)
            self.slot_req[s] = req
            self.slot_state[s] = "prefill"
            req.status = RUNNING
            start = len(matched) * self.pool.page_size if matched else 0
            self.pos[s] = start
            req._cursor = start  # type: ignore[attr-defined]
            req._admit_seq = next(self._admit_seq)  # type: ignore[attr-defined]
            req._prefix_done = False  # type: ignore[attr-defined]
            if req.admit_step is None:
                req.admit_step = self.steps_run
            req.cached_tokens = start
            if self.tables is not None:
                if matched:
                    self.tables.attach(s, matched)
                    self.pages_shared += len(matched)
                    self._tables_dirty = True
                try:
                    if self.tables.ensure_capacity(
                        s, self._resident_tokens(req), req.uid
                    ):
                        self._tables_dirty = True
                except PoolExhausted:
                    # an injected alloc fault fired past the free-count
                    # gate: roll the whole admission back (matched pages
                    # return their references) and retry next tick
                    self.tables.release_slot(s)
                    self._tables_dirty = True
                    self.slot_req[s] = None
                    self.slot_state[s] = None
                    self.pos[s] = 0
                    req._cursor = 0  # type: ignore[attr-defined]
                    req.cached_tokens = 0
                    req.status = QUEUED
                    self.queue.appendleft(req)
                    break

    def _pick_victim(self, exclude) -> Optional[int]:
        """Preemption victim: lowest priority, then youngest admission.
        ``exclude`` is a slot or a collection of slots never picked (e.g.
        every slot in the dispatch currently being assembled)."""
        excluded = {exclude} if isinstance(exclude, int) else set(exclude)
        best = None
        for s in range(self.scfg.slots):
            if s in excluded or self.slot_req[s] is None:
                continue
            r = self.slot_req[s]
            key = (r.priority, -r._admit_seq)  # type: ignore[attr-defined]
            if best is None or key < best[0]:
                best = (key, s)
        return None if best is None else best[1]

    def _preempt(self, s: int):
        """Evict slot ``s``: blocks back to the pool, request to the front of
        the queue (recompute resume — prompt + generated tokens replay).
        A victim past its ``max_retries`` budget fails instead of retrying;
        with ``retry_backoff`` set, storm victims wait out an exponential
        backoff before re-admission."""
        req = self.slot_req[s]
        req.preemptions += 1
        self.preemptions += 1
        if req.max_retries is not None and req.preemptions > req.max_retries:
            self._terminate(req, FAILED, slot=s, error=(
                f"preempted {req.preemptions} times "
                f"(max_retries={req.max_retries})"
            ))
            return
        self.tables.release_slot(s)
        self._tables_dirty = True
        self.slot_req[s] = None
        self.slot_state[s] = None
        self.pos[s] = 0
        req._cursor = 0  # type: ignore[attr-defined]
        req.status = QUEUED
        if self.scfg.retry_backoff > 0:
            wait = self.scfg.retry_backoff * (
                1 << min(req.preemptions - 1, 5)
            )
            req._not_before = self.steps_run + wait  # type: ignore[attr-defined]
        self.queue.appendleft(req)

    def _reclaim(self, want: int) -> int:
        """Evict up to ``want`` unreferenced prefix-cache pages back to the
        pool. Cached-but-unused pages are the cheapest blocks to reclaim, so
        they always go before any live slot is preempted."""
        if self.prefix is None or want <= 0:
            return 0
        return self.prefix.evict(want)

    def _ensure_with_evict(self, s: int, target_tokens: int, owner) -> bool:
        """ensure_capacity with prefix-cache eviction as the pressure valve.
        Returns False only when eviction cannot free enough blocks."""
        while True:
            try:
                if self.tables.ensure_capacity(s, target_tokens, owner):
                    self._tables_dirty = True
                return True
            except PoolExhausted:
                need = blocks_for(target_tokens, self.pool.page_size) - self.tables.num_blocks(s)
                if self.prefix is None or self.prefix.evict(need - self.pool.free) == 0:
                    return False

    def _grow(self, s: int) -> bool:
        """Ensure slot ``s`` can write at ``pos[s]``; preempt on exhaustion.
        Returns False when ``s`` itself was evicted to make room."""
        req = self.slot_req[s]
        if blocks_for(int(self.pos[s]) + 1, self.pool.page_size) > self.pool.num_blocks:
            # outgrew the entire pool mid-generation; no preemption can help
            self._terminate(req, FAILED, slot=s,
                            error="request outgrew the KV block pool")
            return False
        while True:
            if self._ensure_with_evict(s, int(self.pos[s]) + 1, req.uid):
                return True
            victim = self._pick_victim(exclude=s)
            if victim is None:
                self._preempt(s)
                return False
            # don't evict someone strictly more important than s
            v = self.slot_req[victim]
            if (v.priority, -v._admit_seq) > (req.priority, -req._admit_seq):  # type: ignore[attr-defined]
                self._preempt(s)
                return False
            self._preempt(victim)

    def _terminate(self, req: Request, status: str,
                   slot: Optional[int] = None,
                   error: Optional[str] = None):
        """The single request exit path: every request ends exactly once,
        through here, with its slot's pages released — whatever the reason
        (COMPLETED / TIMED_OUT / CANCELLED / FAILED / REJECTED).  The
        freed-page guarantee the auditor checks lives here, not scattered
        per exit site."""
        if slot is not None:
            self.slot_req[slot] = None
            self.slot_state[slot] = None
            self.pos[slot] = 0
            if self.tables is not None:
                self.tables.release_slot(slot)  # blocks recycle immediately
                self._tables_dirty = True
        if error is not None:
            req.error = error
        req.status = status
        req.done = True
        self.completed.append(req)

    def _sweep_lifecycle(self):
        """Honor ``cancel()`` and ``deadline_ticks`` before dispatching: an
        expired or cancelled request exits through ``_terminate`` wherever
        it currently lives (queue or slot), freeing its pages on the spot.
        Partial output stays on the request."""
        now = self.steps_run
        for req in list(self.queue):
            verdict = self._lifecycle_verdict(req, now)
            if verdict is not None:
                self.queue.remove(req)
                self._terminate(req, verdict[0], error=verdict[1])
        for s in range(self.scfg.slots):
            req = self.slot_req[s]
            if req is None:
                continue
            verdict = self._lifecycle_verdict(req, now)
            if verdict is not None:
                self._terminate(req, verdict[0], slot=s, error=verdict[1])

    @staticmethod
    def _lifecycle_verdict(req: Request, now: int):
        if req._cancel:
            return (CANCELLED, "cancelled by caller")
        if (req.deadline_ticks is not None
                and now - req.submit_step >= req.deadline_ticks):
            return (TIMED_OUT,
                    f"deadline of {req.deadline_ticks} ticks exceeded")
        return None

    def _emit_token(self, s: int, req: Request, tok: int):
        """Record a generated token and apply the stop conditions."""
        req.output.append(tok)
        if req.first_token_step is None:
            req.first_token_step = self.steps_run
        limit = req.max_new_tokens or self.scfg.max_new_tokens
        if (
            tok == self.scfg.eos_id
            or len(req.output) >= limit
            or self.pos[s] >= self.scfg.max_len
        ):
            self._terminate(req, COMPLETED, slot=s)

    # ------------------------------------------------------------------
    def _fresh_cache(self):
        """The cache to feed the next jit'd step.  The device block-table
        tensor is cached across ticks (it rides inside ``self.cache`` as the
        ``tables`` leaf, threaded through every step) and re-uploaded only
        after a scheduler mutation — the per-tick upload the profile blamed
        for most of the paged-vs-contiguous gap."""
        if self.tables is not None and self._tables_dirty:
            self.cache = self.cache.with_tables(
                jnp.asarray(self.tables.tables())
            )
            self._tables_dirty = False
            self.table_uploads += 1
        return self.cache

    def _gen_ready(self, s: int) -> bool:
        """Slot ``s`` is in steady-state generation: its next feed is its
        last known token and every later feed is a model output — exactly
        the shape of work the device-resident loop can run without the
        host."""
        req = self.slot_req[s]
        if self.prefill_mode == "chunked" and self.slot_state[s] != "gen":
            return False
        return (
            req._cursor  # type: ignore[attr-defined]
            == len(req.prompt) + len(req.output) - 1
        )

    def step(self) -> int:
        """One engine tick (one host dispatch).  Replay mode: one batched
        decode step (slots still replaying their prompt feed the next
        replay token).  Chunked mode: one decode step for the generating
        slots plus prompt chunks for prefilling slots, together bounded by
        ``token_budget``.  With ``sync_every > 1`` and every active slot
        generating, one dispatch runs up to ``sync_every`` decode ticks on
        device.  Cancellations and deadlines are honored before the
        dispatch; with ``ServeConfig.audit`` the invariant auditor runs
        after it.  Returns #active slots."""
        self._sweep_lifecycle()
        n = self._step_inner()
        if self.scfg.audit:
            self.audits_run += 1
            audit_engine(self)
        return n

    def _step_inner(self) -> int:
        self._admit()
        if self.tables is not None:
            for s in range(self.scfg.slots):
                if self.slot_req[s] is not None:
                    self._grow(s)
            self._admit()  # preemption may have freed blocks for the queue head
        active = [s for s in range(self.scfg.slots) if self.slot_req[s] is not None]
        if not active:
            if self.queue and self.admission_open:
                # every queued request is waiting out a retry backoff: the
                # clock must still advance or backoffs (and deadlines)
                # would never expire
                self.steps_run += 1
            return 0
        self.dispatches += 1
        all_gen = all(self._gen_ready(s) for s in active)
        spec_ok = self.spec_proposer is not None and all_gen
        window_ok = self.sync_every > 1 and all_gen
        if (self.injector is not None and self.injector.pending("poison")):
            # poison faults land per-tick, where per-row detection runs;
            # the plain window has no mid-scan logits check (the spec
            # window checks verify logits, but through its own site)
            spec_ok = window_ok = False
        if spec_ok:
            done = self._step_spec_window(active)
            if done is not None:
                return done
            self.spec_fallbacks += 1  # no headroom / grant denied
        if window_ok:
            done = self._step_window(active)
            if done is not None:
                return done
            self.window_fallbacks += 1  # pool too tight for grow-ahead
        if self.prefill_mode == "chunked":
            return self._step_chunked(active)
        return self._step_replay(active)

    # -- device-resident multi-step window ------------------------------
    def _grant_window(self, active: List[int], spans: Dict[int, int]) -> bool:
        """All-or-nothing grow-ahead: every active slot gets pages covering
        its worst-case window write span (``spans[s]`` tokens past its
        current position, never past ``max_len``) — so a slot near its
        token limit doesn't inflate the ask with pages it can never touch.
        On any shortfall the grant rolls back *exactly* — every slot
        trimmed to its pre-grant block count and the table-dirty flag
        restored, so a failed grant costs no table re-upload — and the
        boundary falls back to per-tick stepping.  The grant itself never
        preempts, so a tight pool degrades throughput, not scheduling."""
        if self.injector is not None and self.injector.fire("grant"):
            return False  # injected mid-window grant failure
        pre = {s: self.tables.num_blocks(s) for s in active}
        dirty_before = self._tables_dirty
        for s in active:
            req = self.slot_req[s]
            target = min(int(self.pos[s]) + spans[s], self.scfg.max_len)
            if not self._ensure_with_evict(s, target, req.uid):
                ps = self.pool.page_size
                for t in active:
                    self.tables.trim(t, pre[t] * ps)
                self._tables_dirty = dirty_before
                return False
        return True

    def _prepare_window(self, active: List[int],
                        spans: Dict[int, int]) -> bool:
        """Shared paged-window preamble for the plain and speculative
        multi-step paths: grow-ahead grant, copy-on-write over the whole
        write span, and the dispatch guard over the granted tables.  On any
        failure the grow-ahead is returned (survivors trimmed to
        ``pos + 1``) and the caller falls back — per-tick stepping for the
        plain window, plain window for the speculative one.  ``spans[s]``
        is the slot's worst-case token span; the caller has already clamped
        it to ``max_len`` headroom."""
        if self.tables is None:
            return True
        if not self._grant_window(active, spans):
            return False
        pairs: List[Tuple[int, int]] = []
        try:
            for s in active:
                target = min(int(self.pos[s]) + spans[s], self.scfg.max_len)
                last = max(int(self.pos[s]), target - 1)
                self._cow_range(s, last, protect=frozenset(active),
                                out=pairs)
        except PoolExhausted:
            # a COW copy could not be satisfied even after eviction: apply
            # the copies already repointed (their tables reference the
            # fresh pages), give back the grow-ahead, and fall back — the
            # per-tick path's COW failure preempts
            self._apply_cow(pairs)
            for s in active:
                if self.tables.trim(s, int(self.pos[s]) + 1):
                    self._tables_dirty = True
            return False
        self._apply_cow(pairs)
        work = [(s, spans[s]) for s in active]
        if len(self._guard_work(work)) != len(work):
            # a guard violation FAILed the blamed slot(s): give back the
            # survivors' grow-ahead and fall back, where the next path's
            # own guard re-checks the trimmed dispatch
            for s in active:
                if self.slot_req[s] is not None:
                    if self.tables.trim(s, int(self.pos[s]) + 1):
                        self._tables_dirty = True
            return False
        return True

    def _step_window(self, active: List[int]) -> Optional[int]:
        """Up to ``sync_every`` decode ticks in one ``lax.scan`` dispatch.
        Feed, positions, PRNG key, stop flags and emitted tokens live on
        device (``lm.decode_loop``); the host uploads one feed vector and
        drains one token buffer.  Returns #active slots, or ``None`` when
        the paged pool cannot cover the worst-case window (caller falls
        back to a per-tick step)."""
        b = self.scfg.slots
        feed = np.zeros((b,), np.int32)
        live = np.zeros((b,), bool)
        rem = np.zeros((b,), np.int32)
        for s in active:
            req = self.slot_req[s]
            feed[s] = (req.prompt + req.output)[req._cursor]  # type: ignore[attr-defined]
            live[s] = True
            limit = req.max_new_tokens or self.scfg.max_new_tokens
            rem[s] = limit - len(req.output)
        # clamp the window to the slots' host-known tick spans — token
        # allowance AND max_len headroom — by halving (not to the exact
        # span: every distinct length is its own scan trace, so lengths are
        # bounded to ~log2(sync_every) variants).  Guaranteed-dead tail
        # iterations would burn full-batch decode steps and delay
        # boundary-time admission of queued work.
        n = self.sync_every
        max_span = max(
            min(int(rem[s]), self.scfg.max_len - int(self.pos[s]))
            for s in active
        )
        while n // 2 >= max_span:
            n //= 2
        spans = {s: min(n, int(rem[s]) + 1) for s in active}
        if not self._prepare_window(active, spans):
            return None
        loop = self._loop_fns.get(n)
        if loop is None:
            loop = self._loop_fns[n] = _decode_loop_fn(
                self.cfg, self.scfg.temperature, n, self.scfg.eos_id,
                self.scfg.max_len,
            )
        toks, emitted, self._key, self.cache = loop(
            self.params, self._fresh_cache(), jnp.asarray(feed),
            jnp.asarray(self.pos), self._key, jnp.asarray(live),
            jnp.asarray(rem),
        )
        self.decode_windows += 1
        toks = np.asarray(toks)
        emitted = np.asarray(emitted)
        # drain: replay each in-window tick through the same host-side
        # bookkeeping the per-tick path runs, so Request state, tick
        # accounting and EOS recycling stay byte-for-byte identical
        for t in range(n):
            row = emitted[t]
            if not row.any():
                break  # every slot stopped; later rows are all-False too
            for s in active:
                if not row[s]:
                    continue
                req = self.slot_req[s]
                self.pos[s] += 1
                req._cursor += 1  # type: ignore[attr-defined]
                self._emit_token(s, req, int(toks[t, s]))
            self.tick_tokens.append(int(row.sum()))
            self.steps_run += 1
        if self.tables is not None:
            # return unused grow-ahead pages so boundary-time admission /
            # preemption sees the same pool a per-tick engine would
            for s in active:
                if self.slot_req[s] is not None:
                    if self.tables.trim(s, int(self.pos[s]) + 1):
                        self._tables_dirty = True
        return len(active)

    # -- speculative draft-verify window --------------------------------
    def _step_spec_window(self, active: List[int]) -> Optional[int]:
        """Up to ``sync_every`` draft-verify rounds in one dispatch
        (``lm.spec_decode_loop``).  Each round's verify chunk writes
        ``draft_len + 1`` KV positions through the block tables, so the
        grow-ahead must cover the worst case ``n * (draft_len + 1)`` tokens
        per slot (capped by the slot's token allowance plus the round's
        unaccepted draft tail); rejected tails stay *logically* truncated
        behind the position carry and the grant's unused pages return via
        ``trim`` at the sync boundary — rollback never allocates, so it can
        never leak.  Returns #active slots, or ``None`` when a slot lacks
        ``max_len`` headroom for even one round or the grant/COW/guard
        preamble declines (caller falls back to the plain window, which is
        byte-identical by construction)."""
        scfg = self.scfg
        k = scfg.draft_len
        c = k + 1
        b = scfg.slots
        feed = np.zeros((b,), np.int32)
        live = np.zeros((b,), bool)
        rem = np.zeros((b,), np.int32)
        for s in active:
            req = self.slot_req[s]
            feed[s] = (req.prompt + req.output)[req._cursor]  # type: ignore[attr-defined]
            live[s] = True
            limit = req.max_new_tokens or scfg.max_new_tokens
            rem[s] = limit - len(req.output)

        # a slot's worst-case write span over n rounds: every verify chunk
        # lands c positions from the current pos, and a live round commits
        # at least one token, so the furthest write is bounded both by
        # n * c and by the token allowance plus one round's draft tail
        def span(s: int, n: int) -> int:
            return min(n * c, int(rem[s]) + k)

        # clamp rounds by halving (each distinct n is its own scan trace):
        # first to the emission spans, then until every slot's worst-case
        # chunk write fits under max_len — unlike the plain window, a
        # verify chunk writes ahead of what it commits, so headroom is a
        # hard precondition, not an optimization
        n = self.sync_every
        max_rounds = max(
            -(-min(int(rem[s]), scfg.max_len - int(self.pos[s])) // c)
            for s in active
        )
        while n // 2 >= max_rounds:
            n //= 2
        while n > 1 and any(
            int(self.pos[s]) + span(s, n) > scfg.max_len for s in active
        ):
            n //= 2
        if any(int(self.pos[s]) + span(s, n) > scfg.max_len for s in active):
            return None  # a slot within c of max_len: plain path finishes it
        spans = {s: span(s, n) for s in active}
        if not self._prepare_window(active, spans):
            return None

        hist = np.zeros((b, scfg.max_len), np.int32)
        for s in active:
            req = self.slot_req[s]
            toks = req.prompt + req.output
            hist[s, : len(toks)] = toks
        poison = self._poison_mask(active, site="spec_poison")

        loop = self._spec_loop_fns.get(n)
        if loop is None:
            loop = self._spec_loop_fns[n] = _spec_loop_fn(
                self.cfg, scfg.temperature, self.spec_proposer, n, k,
                scfg.eos_id, scfg.max_len,
            )
        toks, emitted, bad, self._key, self.cache = loop(
            self.params, self._fresh_cache(), jnp.asarray(feed),
            jnp.asarray(self.pos), self._key, jnp.asarray(live),
            jnp.asarray(rem), jnp.asarray(hist), jnp.asarray(poison),
        )
        self.spec_windows += 1
        toks = np.asarray(toks)
        emitted = np.asarray(emitted)
        bad = np.asarray(bad)
        # drain: replay each round through the same host-side bookkeeping
        # the per-tick path runs — the device emit masks already encode
        # acceptance, EOS, token limits and max_len, so _emit_token's stop
        # conditions fire on exactly the tokens the mask delivers
        for t in range(n):
            row = emitted[t]
            rbad = bad[t]
            if not row.any() and not rbad.any():
                break  # every slot stopped; later rounds are dead too
            self.spec_rounds += 1
            for s in active:
                req = self.slot_req[s]
                if req is None:
                    continue
                if rbad[s]:
                    self.poisoned_rows += 1
                    self._terminate(
                        req, FAILED, slot=s,
                        error="poisoned verify logits (no finite value)")
                    continue
                if not row[s].any():
                    continue
                acc = int(row[s].sum()) - 1  # drafts accepted this round
                self.spec_proposed += k
                self.spec_accepted += acc
                if acc == 0:
                    self.spec_all_rejected += 1
                for i in range(c):
                    if not row[s, i]:
                        continue
                    self.pos[s] += 1
                    req._cursor += 1  # type: ignore[attr-defined]
                    self._emit_token(s, req, int(toks[t, s, i]))
                    if req.done:
                        break
            self.tick_tokens.append(int(row.sum()))
            self.steps_run += 1
        if self.tables is not None:
            # rejected draft tails sit in pages past pos under the
            # grow-ahead grant; trim reclaims them with the unused grant
            for s in active:
                if self.slot_req[s] is not None:
                    if self.tables.trim(s, int(self.pos[s]) + 1):
                        self._tables_dirty = True
        return len(active)

    # -- prefix-cache bookkeeping ---------------------------------------
    def _register_prefix(self, s: int, req: Request):
        """Publish the slot's full prompt pages into the prefix index once
        prefill completes.  ``insert`` retains each new page; pages already
        cached come back as (idx, cached_page) pairs and the slot's table is
        repointed at the canonical copy so the duplicate recycles — the
        device copy of the table is re-uploaded before the next dispatch."""
        if self.prefix is None or getattr(req, "_prefix_done", False):
            return
        req._prefix_done = True  # type: ignore[attr-defined]
        ps = self.pool.page_size
        n_pages = min(len(req.prompt) // ps, self.tables.num_blocks(s))
        if n_pages <= 0:
            return
        pages = self.tables.blocks(s)[:n_pages]
        for idx, cached in self.prefix.insert(req.prompt[: n_pages * ps], pages):
            self.tables.repoint(s, idx, cached)
            self.pages_deduped += 1
            self._tables_dirty = True

    def _cow_range(self, s: int, last_pos: int,
                   protect: frozenset = frozenset(),
                   out: Optional[List[Tuple[int, int]]] = None,
                   ) -> List[Tuple[int, int]]:
        """Copy-on-write guard for the pages slot ``s`` may write this
        dispatch (positions ``pos[s]..last_pos``).  Shared pages (refcount
        > 1) are swapped for fresh private copies and the table repointed;
        returns the (src, dst) page pairs still needing a device-side copy
        (appended to ``out`` when given, so a caller that must recover from
        ``PoolExhausted`` still sees the pairs already repointed).

        Exhaustion during a copy tries, in order: prefix-cache eviction,
        then preempting a victim outside ``protect | {s}``; when neither
        frees a block ``PoolExhausted`` propagates and the caller decides
        (per-tick paths preempt ``s`` itself, the window path rolls back
        its grant and falls back to per-tick).

        In the normal flow the copy never fires: only *full* prompt pages
        are published to the index and matches are capped so the divergent
        tail starts page-aligned — a shared page is never written.  The
        guard exists so sharing stays safe by construction (tests pin it
        via manually attached partial pages), not by scheduler luck."""
        pairs = out if out is not None else []
        ps = self.pool.page_size
        req = self.slot_req[s]
        first = int(self.pos[s]) // ps
        last = min(last_pos // ps, self.tables.num_blocks(s) - 1)
        for pidx in range(first, last + 1):
            while True:
                try:
                    pair = self.tables.ensure_writable(s, pidx, req.uid)
                    break
                except PoolExhausted:
                    if self._reclaim(1):
                        continue
                    victim = self._pick_victim(exclude=protect | {s})
                    if victim is None:
                        raise
                    self._preempt(victim)
            if pair:
                pairs.append(pair)
        return pairs

    def _cow_or_preempt(self, work: List[Tuple[int, int]],
                        ) -> Tuple[List[int], List[Tuple[int, int]]]:
        """Run the COW gate for each ``(slot, last_pos)`` about to be
        dispatched.  A slot whose copy cannot be satisfied even after
        eviction and victim preemption is preempted itself and dropped
        from the dispatch — its partially-repointed pages roll back with
        its table, so the surviving slots' pairs stay valid.  Returns
        (surviving slots, device copy pairs)."""
        dispatch = frozenset(s for s, _ in work)
        survivors: List[int] = []
        pairs: List[Tuple[int, int]] = []
        for s, last in work:
            if self.slot_req[s] is None:
                continue  # became a victim earlier in this loop
            try:
                local = self._cow_range(s, last, protect=dispatch)
            except PoolExhausted:
                self._preempt(s)  # recompute resume replays it cleanly
                continue
            survivors.append(s)
            pairs += local
        return survivors, pairs

    def _poison_mask(self, rows: List[int],
                     site: str = "poison") -> np.ndarray:
        """(slots,) bool — rows the injector poisons this dispatch
        (``site``: "poison" for per-tick logits, "spec_poison" for the
        speculative window's verify logits).  A due fault targets
        ``fault.slot`` mod the dispatched rows, so a schedule stays
        meaningful whatever the slot occupancy is by then."""
        mask = np.zeros((self.scfg.slots,), bool)
        if self.injector is None or not rows:
            return mask
        while True:
            f = self.injector.fire(site)
            if f is None:
                break
            mask[rows[f.slot % len(rows)]] = True
        return mask

    def _fire_table_corrupt(self, work: List[Tuple[int, int]]):
        """Due ``table_corrupt`` faults overwrite one device-table entry of
        a dispatched slot — the page backing its write position, so the bad
        entry sits inside both the guarded live prefix and the write range.
        Corruption is physical: it fires whether or not guards are enabled
        (with guards off, the invariant auditor is what notices the row
        diverging from the block ledger).  Flavors cycle deterministically:
        out-of-range id, reserved page 0 in the live prefix, duplicate of
        another dispatched row's page."""
        if self.injector is None or self.tables is None or not work:
            return
        ps = self.pool.page_size
        out_of_range = self.pool.base + self.pool.num_blocks + 5
        while True:
            f = self.injector.fire("table_corrupt")
            if f is None:
                break
            s, n = work[f.slot % len(work)]
            j = max(0, -(-(int(self.pos[s]) + n) // ps) - 1)
            mode = self._corrupt_mode % 3
            self._corrupt_mode += 1
            if mode == 0:
                bad = out_of_range
            elif mode == 1:
                bad = 0  # reserved sink page inside the live prefix
            else:
                other = next((t for t, _ in work if t != s
                              and self.tables.num_blocks(t) > 0), None)
                bad = (self.tables.blocks(other)[0]
                       if other is not None else out_of_range)
            self.tables.poke(s, j, bad)
            self._tables_dirty = True
            self.table_corruptions += 1

    def _guard_work(self, work: List[Tuple[int, int]],
                    ) -> List[Tuple[int, int]]:
        """Discharge the kernels' runtime obligations for the ``(slot,
        n_tokens)`` pairs about to dispatch (core.lowering.verify emits
        them; this is where the engine pays): every live block-table entry
        in range, no duplicate writable pages, lengths within capacity.  A
        violating slot FAILs through ``_terminate`` — graceful degradation,
        never a kernel scribbling on another request's pages — and is
        dropped from the dispatch; the survivors proceed untouched."""
        if self.tables is None or not work:
            return work
        self._fire_table_corrupt(work)
        if not self.scfg.guards:
            return work
        rows = []
        for s, n in work:
            p = int(self.pos[s])
            rows.append((s, p + n, p, p + n))
        try:
            guard_dispatch(
                self.tables.tables(),
                self.pool.base + self.pool.num_blocks,
                self.pool.page_size, rows,
            )
        except GuardError as e:
            blamed = sorted({row for row, _, _ in e.violations})
            detail = {row: f"{kind}: {msg}"
                      for row, kind, msg in reversed(e.violations)}
            for s in blamed:
                req = self.slot_req[s]
                if req is None:
                    continue
                self.guard_failures += 1
                self._terminate(req, FAILED, slot=s,
                                error=f"dispatch guard: {detail[s]}")
            dead = set(blamed)
            return [(s, n) for s, n in work if s not in dead]
        return work

    def _apply_cow(self, pairs: List[Tuple[int, int]]):
        """Run the device-side page copies for COW repoints.  Pairs are
        padded to a power-of-two count to bound jit trace variants; padding
        copies page 0 onto itself (page 0 is reserved, never shared)."""
        if not pairs:
            return
        self.pages_copied += len(pairs)
        self._tables_dirty = True
        n = 1
        while n < len(pairs):
            n *= 2
        src = np.zeros((n,), np.int32)
        dst = np.zeros((n,), np.int32)
        for i, (a, b) in enumerate(pairs):
            src[i] = a
            dst[i] = b
        self.cache = _copy_pages_fn(self.cfg)(
            self.cache, jnp.asarray(src), jnp.asarray(dst)
        )

    # -- per-tick paths -------------------------------------------------
    def _step_replay(self, active: List[int]) -> int:
        if self.tables is not None:
            active, pairs = self._cow_or_preempt(
                [(s, int(self.pos[s])) for s in active]
            )
            self._apply_cow(pairs)
            active = [s for s, _ in self._guard_work([(s, 1) for s in active])]
            if not active:
                self.dispatches -= 1  # nothing actually dispatched
                return 0
        feed = np.zeros((self.scfg.slots,), np.int32)
        live = np.zeros((self.scfg.slots,), bool)
        full_len: Dict[int, int] = {}
        for s in active:
            req = self.slot_req[s]
            cur = req._cursor  # type: ignore[attr-defined]
            np_ = len(req.prompt)
            full_len[s] = np_ + len(req.output)
            feed[s] = (
                req.prompt[cur] if cur < np_ else req.output[cur - np_]
            )
            live[s] = True
        poison = self._poison_mask(active)
        next_tok, bad, self.cache, self._key = self._step(
            self.params, self._fresh_cache(), jnp.asarray(feed),
            jnp.asarray(self.pos), self._key, jnp.asarray(live),
            jnp.asarray(poison),
        )
        next_tok = np.asarray(next_tok)
        bad = np.asarray(bad)
        for s in active:
            req = self.slot_req[s]
            cur = req._cursor  # type: ignore[attr-defined]
            self.pos[s] += 1
            req._cursor = cur + 1  # type: ignore[attr-defined]
            if bad[s]:
                self.poisoned_rows += 1
                self._terminate(req, FAILED, slot=s,
                                error="poisoned logits row (no finite value)")
                continue
            if cur + 1 >= full_len[s]:  # this step produced a real token
                self._register_prefix(s, req)
                self._emit_token(s, req, int(next_tok[s]))
        self.tick_tokens.append(len(active))
        self.steps_run += 1
        return len(active)

    def _step_chunked(self, active: List[int]) -> int:
        """One token-budget tick: decode for generating slots + prompt
        chunks for prefilling slots (oldest admitted first) within the
        leftover budget."""
        gen = [s for s in active if self.slot_state[s] == "gen"]
        pending = []
        for s in active:
            if self.slot_state[s] != "prefill":
                continue
            req = self.slot_req[s]
            remaining = len(req.prompt) + len(req.output) - req._cursor  # type: ignore[attr-defined]
            pending.append((s, req._admit_seq, remaining))  # type: ignore[attr-defined]
        chunk_lens = plan_prefill_chunks(
            self.token_budget, len(gen), pending, self.prefill_chunk
        )

        if gen and self.tables is not None:
            gen, pairs = self._cow_or_preempt(
                [(s, int(self.pos[s])) for s in gen]
            )
            self._apply_cow(pairs)
            gen = [s for s, _ in self._guard_work([(s, 1) for s in gen])]
        if gen:
            feed = np.zeros((self.scfg.slots,), np.int32)
            live = np.zeros((self.scfg.slots,), bool)
            for s in gen:
                req = self.slot_req[s]
                feed[s] = req.output[-1]
                live[s] = True
            poison = self._poison_mask(gen)
            next_tok, bad, self.cache, self._key = self._step(
                self.params, self._fresh_cache(), jnp.asarray(feed),
                jnp.asarray(self.pos), self._key, jnp.asarray(live),
                jnp.asarray(poison),
            )
            next_tok = np.asarray(next_tok)
            bad = np.asarray(bad)
            for s in gen:
                req = self.slot_req[s]
                self.pos[s] += 1
                req._cursor += 1  # type: ignore[attr-defined]
                if bad[s]:
                    self.poisoned_rows += 1
                    self._terminate(
                        req, FAILED, slot=s,
                        error="poisoned logits row (no finite value)")
                    continue
                self._emit_token(s, req, int(next_tok[s]))

        # COW during the gen dispatch may have preempted a prefilling slot
        chunk_lens = {s: n for s, n in chunk_lens.items()
                      if self.slot_req[s] is not None}
        if chunk_lens and self.tables is not None:
            ok, pairs = self._cow_or_preempt(
                [(s, int(self.pos[s]) + n - 1) for s, n in chunk_lens.items()]
            )
            chunk_lens = {s: chunk_lens[s] for s in ok}
            self._apply_cow(pairs)
            chunk_lens = dict(self._guard_work(list(chunk_lens.items())))
        if chunk_lens:
            width = self.prefill_chunk
            toks = np.zeros((self.scfg.slots, width), np.int32)
            lens = np.zeros((self.scfg.slots,), np.int32)
            for s, n in chunk_lens.items():
                req = self.slot_req[s]
                cur = req._cursor  # type: ignore[attr-defined]
                replay = (req.prompt + req.output)[cur : cur + n]
                toks[s, :n] = replay
                lens[s] = n
            poison = self._poison_mask(sorted(chunk_lens))
            ptok, pbad, self.cache, self._key = self._prefill(
                self.params, self._fresh_cache(), jnp.asarray(toks),
                jnp.asarray(self.pos), jnp.asarray(lens), self._key,
                jnp.asarray(poison),
            )
            ptok = np.asarray(ptok)
            pbad = np.asarray(pbad)
            for s, n in chunk_lens.items():
                req = self.slot_req[s]
                self.pos[s] += n
                req._cursor += n  # type: ignore[attr-defined]
                if pbad[s]:
                    self.poisoned_rows += 1
                    self._terminate(
                        req, FAILED, slot=s,
                        error="poisoned logits row (no finite value)")
                    continue
                if req._cursor >= len(req.prompt) + len(req.output):  # type: ignore[attr-defined]
                    # the chunk reached the end of the replay stream: its
                    # last live logits produce the next real token
                    self.slot_state[s] = "gen"
                    self._register_prefix(s, req)
                    self._emit_token(s, req, int(ptok[s]))

        self.tick_tokens.append(len(gen) + sum(chunk_lens.values()))
        self.steps_run += 1
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain (or step budget)."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.completed

    # -- lifecycle: drain / shutdown ------------------------------------
    def drain(self, max_steps: int = 10_000) -> List[Request]:
        """Stop admission and finish every request already holding a slot.
        Queued requests stay queued — drain stops intake, it does not
        cancel.  Afterwards the pool holds only prefix-cache pages (and
        admission stays closed; reopen by setting ``admission_open``)."""
        self.admission_open = False
        for _ in range(max_steps):
            if self.step() == 0:
                break
        return self.completed

    def shutdown(self) -> List[Request]:
        """Drain in-flight work, cancel everything still queued, and flush
        the prefix index: afterwards the pool holds **zero** allocated
        blocks — the freed-page guarantee the chaos harness asserts."""
        self.drain()
        for s in range(self.scfg.slots):
            req = self.slot_req[s]
            if req is not None:  # drain ran out of its step budget
                self._terminate(req, CANCELLED, slot=s,
                                error="engine shutdown")
        while self.queue:
            self._terminate(self.queue.popleft(), CANCELLED,
                            error="engine shutdown")
        if self.prefix is not None:
            self.prefix.flush()
            self._tables_dirty = True
        if self.scfg.audit:
            self.audits_run += 1
            audit_engine(self)
        return self.completed

    # -- crash-safe persistence -----------------------------------------
    def snapshot(self, path: Optional[str] = None) -> dict:
        """Serialize the prefix-cache radix index *and* the KV contents of
        its pages — the warm state an engine restart would otherwise lose.
        In-flight slots are deliberately not captured: requests are
        re-submittable, the cached prefix KV is not.  Returns the snapshot
        dict; ``path`` additionally pickles it to disk."""
        if self.prefix is None:
            raise ValueError(
                "snapshot() needs the prefix cache enabled "
                "(paged cache + an attention family)"
            )
        entries = self.prefix.export()
        snap = {
            "format": SNAPSHOT_FORMAT,
            "model": self.cfg.name,
            "page_size": self.pool.page_size,
            "kv_dtype": self.cfg.kv_dtype,
            "nodes": [(parent, list(blk)) for parent, blk, _ in entries],
            "leaves": lm.gather_pages(
                self.cache, [page for _, _, page in entries]
            ),
        }
        if path is not None:
            import pickle

            with open(path, "wb") as f:
                pickle.dump(snap, f)
        return snap

    def load_snapshot(self, snap) -> int:
        """Graft a snapshot's cached page chains into this engine (normally
        a fresh one — see :meth:`restore`).  Config mismatches (model, page
        size, kv dtype, page-pool layout) are loud ``ValueError``s —
        silently serving stale KV would be wrong tokens, not an error
        message.  When the pool is smaller than the snapshot, the longest
        chain prefixes that fit are restored (children of a skipped node
        are skipped).  Returns pages restored."""
        if not isinstance(snap, dict):
            import pickle

            with open(snap, "rb") as f:
                snap = pickle.load(f)
        if self.prefix is None:
            raise ValueError("load_snapshot() needs the prefix cache enabled")
        if snap.get("format") != SNAPSHOT_FORMAT:
            raise ValueError(
                f"unknown snapshot format {snap.get('format')!r} "
                f"(this engine writes {SNAPSHOT_FORMAT})"
            )
        for field, mine in (
            ("model", self.cfg.name),
            ("page_size", self.pool.page_size),
            ("kv_dtype", self.cfg.kv_dtype),
        ):
            if snap[field] != mine:
                raise ValueError(
                    f"snapshot {field}={snap[field]!r} does not match "
                    f"engine {field}={mine!r}"
                )
        want = [(tuple(a.shape[1:]), str(a.dtype)) for a in snap["leaves"]]
        if want != lm.page_leaf_shapes(self.cache):
            raise ValueError(
                "snapshot page-pool layout does not match this engine's "
                "cache (different reduced config or leaf set)"
            )
        phys: Dict[int, int] = {}
        keep: List[int] = []
        for i, (parent, _blk) in enumerate(snap["nodes"]):
            if parent >= 0 and parent not in phys:
                continue  # ancestor skipped (pool ran short): skip the chain
            if not self.pool.free:
                continue  # partial restore: longest prefixes that fit
            phys[i] = self.pool.alloc(owner="prefix-snapshot")
            keep.append(i)
        if keep:
            dst = [phys[i] for i in keep]
            values = [np.asarray(a)[keep] for a in snap["leaves"]]
            self.cache = lm.scatter_pages(self.cache, dst, values)
            local = {i: j for j, i in enumerate(keep)}
            entries = []
            for i in keep:
                parent, blk = snap["nodes"][i]
                entries.append((
                    local[parent] if parent >= 0 else -1, tuple(blk), phys[i]
                ))
            self.prefix.import_nodes(entries)
        return len(keep)

    @classmethod
    def restore(cls, cfg: ModelConfig, params, serve_cfg: ServeConfig,
                snap, injector: Optional[FaultInjector] = None,
                ) -> "ServingEngine":
        """Crash-safe restart: a fresh engine pre-warmed with
        ``snapshot()``'s radix index and page contents, so a warm-prefix
        request hits the cache immediately — TTFT matches the pre-restart
        cached path instead of paying a cold prefill."""
        eng = cls(cfg, params, serve_cfg, injector=injector)
        eng.load_snapshot(snap)
        return eng

    # -- accounting -----------------------------------------------------
    def kv_cache_bytes(self) -> int:
        """Bytes held by attention KV state under the current layout."""
        return self.cache.kv_bytes()

    def peak_kv_blocks(self) -> Optional[int]:
        return None if self.pool is None else self.pool.peak_in_use
