"""Batched serving engine with continuous batching.

The engine owns a fixed number of decode *slots* (static shapes — the jit'd
step never retraces).  Requests are admitted into free slots, prefilled by
streaming their prompt through the decode step at their own positions
(per-slot ``pos`` vector — see layers.attention_decode), and generate until
EOS / max_tokens, at which point the slot is recycled for the next queued
request.  This is vLLM-style continuous batching with a contiguous
(per-slot) KV cache; ring buffers bound the cache for sliding-window layers
and SSM archs hold O(1) state.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig

from .sampling import sample

# One jit'd decode step per model configuration, shared by every engine
# instance (and so by every request): constructing a fresh ``jax.jit``
# wrapper per engine discards XLA's trace cache and recompiles the step for
# each new engine even when the config is identical.  Keyed on the config's
# dataclass repr (deterministic over field values); the closure captures a
# deep copy so later mutation of the caller's config object cannot change
# what a cached entry computes.  LRU-bounded so config sweeps don't pin an
# XLA executable per visited config for process lifetime.
_STEP_FNS: "collections.OrderedDict[str, object]" = collections.OrderedDict()
_STEP_FNS_MAX = 8


def _decode_step_fn(cfg: ModelConfig):
    key = repr(cfg)
    fn = _STEP_FNS.get(key)
    if fn is None:
        snap = copy.deepcopy(cfg)
        fn = jax.jit(lambda p, c, t, pos: lm.decode_step(p, snap, c, t, pos))
        _STEP_FNS[key] = fn
        while len(_STEP_FNS) > _STEP_FNS_MAX:
            _STEP_FNS.popitem(last=False)
    else:
        _STEP_FNS.move_to_end(key)
    return fn


@dataclasses.dataclass
class ServeConfig:
    slots: int = 8  # decode batch width
    max_len: int = 1024  # per-slot cache length
    max_new_tokens: int = 128
    eos_id: int = -1  # -1: never stops early
    temperature: float = 0.0
    seed: int = 0


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        b = serve_cfg.slots
        self.cache = lm.init_cache(cfg, b, serve_cfg.max_len)
        self.pos = np.zeros((b,), np.int32)  # next write position per slot
        self.slot_req: List[Optional[Request]] = [None] * b
        self.queue: collections.deque[Request] = collections.deque()
        self._uid = itertools.count()
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._token_buf = np.zeros((b,), np.int32)
        self._step = _decode_step_fn(cfg)
        self.completed: List[Request] = []
        self.steps_run = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens=None) -> Request:
        req = Request(next(self._uid), list(prompt), max_new_tokens)
        self.queue.append(req)
        return req

    def _admit(self):
        for s in range(self.scfg.slots):
            if self.slot_req[s] is None and self.queue:
                req = self.queue.popleft()
                self.slot_req[s] = req
                self.pos[s] = 0
                req._cursor = 0  # type: ignore[attr-defined]

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine tick = one batched decode step.  Slots still consuming
        their prompt feed the next prompt token (prefill-as-decode); slots in
        generation feed their last sampled token.  Returns #active slots."""
        self._admit()
        active = [s for s in range(self.scfg.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        feed = np.zeros((self.scfg.slots,), np.int32)
        for s in active:
            req = self.slot_req[s]
            cur = req._cursor  # type: ignore[attr-defined]
            if cur < len(req.prompt):
                feed[s] = req.prompt[cur]
            else:
                feed[s] = req.output[-1] if req.output else req.prompt[-1]
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(feed), jnp.asarray(self.pos)
        )
        self._key, sub = jax.random.split(self._key)
        next_tok = np.asarray(
            sample(logits, sub, temperature=self.scfg.temperature)
        )
        for s in active:
            req = self.slot_req[s]
            cur = req._cursor  # type: ignore[attr-defined]
            self.pos[s] += 1
            req._cursor = cur + 1  # type: ignore[attr-defined]
            if cur + 1 >= len(req.prompt):  # this step produced a real token
                tok = int(next_tok[s])
                req.output.append(tok)
                limit = req.max_new_tokens or self.scfg.max_new_tokens
                if (
                    tok == self.scfg.eos_id
                    or len(req.output) >= limit
                    or self.pos[s] >= self.scfg.max_len
                ):
                    req.done = True
                    self.completed.append(req)
                    self.slot_req[s] = None
        self.steps_run += 1
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain (or step budget)."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.completed
