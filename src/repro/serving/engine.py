"""Batched serving engine: continuous batching over a paged KV cache.

The engine owns a fixed number of decode *slots* (static shapes — the jit'd
step never retraces).  Requests are admitted into free slots, prefilled,
and generate until EOS / max_tokens, at which point the slot is recycled
for the next queued request.

Prefill comes in two modes (``ServeConfig.prefill``):

* ``"chunked"`` (default, Sarathi-style) — each engine tick spends a fixed
  **token budget**: every generating slot consumes one budget token for its
  decode step, and the leftover budget feeds prompt *chunks* (up to
  ``prefill_chunk`` tokens, oldest-admitted request first) through one
  chunk-wide forward pass (``lm.prefill_step`` — the prefill_attention
  kernel path).  A 1k-token prompt then costs ~``1k / prefill_chunk``
  ticks instead of 1k full decode steps, while decode latency stays
  bounded: no tick ever exceeds ``token_budget`` tokens.  Falls back to
  replay for architectures without chunk-parallel cache writes (SSM /
  hybrid state, MLA latent caches).
* ``"replay"`` — the legacy baseline: prompts stream one token per engine
  tick through the decode step.

KV memory comes in two layouts behind one ``decode_step`` interface
(``ServeConfig.cache``):

* ``"paged"`` (default) — vLLM-style block pool: KV lives in fixed-size
  pages; each slot owns a block table (serving/paged_cache.py).  The
  scheduler is real: **admission** requires enough free blocks for the
  request's resident tokens, **preemption** evicts the lowest-priority
  (then youngest) request back to the queue when the pool is exhausted
  (recompute-style resume: its prompt *and* generated tokens replay through
  prefill), and completion **recycles blocks immediately** at EOS.
* ``"contiguous"`` — the legacy per-slot ``max_len`` strip (ring buffers
  for sliding-window layers); preallocates ``slots × max_len`` regardless
  of real prompt lengths.  Kept as the comparison baseline and as the
  fallback for MLA archs (latent paging is future work).

Both layouts produce identical outputs for identical requests — asserted in
tests/test_serving.py.
"""
from __future__ import annotations

import collections
import copy
import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.config import ModelConfig

from .paged_cache import BlockPool, PoolExhausted, SlotTables, blocks_for
from .sampling import sample

# One jit'd decode step per model configuration, shared by every engine
# instance (and so by every request): constructing a fresh ``jax.jit``
# wrapper per engine discards XLA's trace cache and recompiles the step for
# each new engine even when the config is identical.  Keyed on the config's
# dataclass repr (deterministic over field values); the closure captures a
# deep copy so later mutation of the caller's config object cannot change
# what a cached entry computes.  LRU-bounded so config sweeps don't pin an
# XLA executable per visited config for process lifetime.  Both cache
# layouts share one entry: the layout lives in the cache pytree's treedef,
# so jax.jit keeps one trace per layout under the same wrapper.
_STEP_FNS: "collections.OrderedDict[tuple, object]" = collections.OrderedDict()
_STEP_FNS_MAX = 8


def _cached_fn(key, build):
    fn = _STEP_FNS.get(key)
    if fn is None:
        fn = build()
        _STEP_FNS[key] = fn
        while len(_STEP_FNS) > _STEP_FNS_MAX:
            _STEP_FNS.popitem(last=False)
    else:
        _STEP_FNS.move_to_end(key)
    return fn


def _decode_step_fn(cfg: ModelConfig):
    def build():
        snap = copy.deepcopy(cfg)
        return jax.jit(lambda p, c, t, pos: lm.decode_step(p, snap, c, t, pos))

    return _cached_fn(("decode", repr(cfg)), build)


def _prefill_step_fn(cfg: ModelConfig):
    """One jit'd chunk-wide prefill step per model config (the chunk width
    is a trace-time shape, so differing ``prefill_chunk`` values simply
    trace separate entries under the same wrapper)."""

    def build():
        snap = copy.deepcopy(cfg)
        return jax.jit(
            lambda p, c, t, pos, lens: lm.prefill_step(p, snap, c, t, pos, lens)
        )

    return _cached_fn(("prefill", repr(cfg)), build)


def plan_prefill_chunks(
    budget: int,
    n_gen: int,
    pending: Sequence[Tuple[int, int, int]],  # (slot, admit_seq, remaining)
    chunk: int,
) -> Dict[int, int]:
    """Sarathi-style budget split: decode tokens are spent first (one per
    generating slot), the leftover feeds prompt chunks oldest-admitted
    first.  Grants are all-or-nothing per request — always ``min(chunk,
    remaining)``, never a room-limited partial — so every chunk *starts* at
    a multiple of ``chunk``: the page-alignment contract of the prefill
    kernel's table-directed page writes (a room-limited partial would shift
    every later chunk of that prompt off page boundaries).  Invariants
    (property-tested): ``n_gen + sum(result.values()) <= max(budget,
    n_gen)``, every grant equals ``min(chunk, remaining)``, and grants form
    an age-ordered prefix of ``pending`` (no head-of-line skipping)."""
    room = budget - n_gen
    out: Dict[int, int] = {}
    for slot, _seq, remaining in sorted(pending, key=lambda t: t[1]):
        n = min(chunk, remaining)
        if n <= 0:
            continue
        if n > room:
            break
        out[slot] = n
        room -= n
    return out


@dataclasses.dataclass
class ServeConfig:
    slots: int = 8  # decode batch width
    max_len: int = 1024  # per-request logical cache length
    max_new_tokens: int = 128
    eos_id: int = -1  # -1: never stops early
    temperature: float = 0.0
    seed: int = 0
    cache: str = "paged"  # "paged" | "contiguous"
    page_size: int = 16  # tokens per KV block (paged mode)
    # pool size in blocks; None = slots * ceil(max_len / page_size), i.e.
    # parity with the contiguous footprint.  Size it below that to actually
    # oversubscribe memory (that's the point of paging).
    num_blocks: Optional[int] = None
    # -- prefill fast path ------------------------------------------------
    prefill: str = "chunked"  # "chunked" | "replay"
    # prompt tokens per chunk-wide forward pass; clamped at engine init to
    # token_budget - slots + 1 so a chunk always fits the leftover budget
    # (grants are all-or-nothing to keep chunk starts page-aligned)
    prefill_chunk: int = 16
    # per-tick token budget shared by the decode batch and prefill chunks;
    # None = slots + prefill_chunk (one full chunk rides along with a full
    # decode batch).  Effective budget is floored at `slots` so a full
    # generation batch always fits.
    token_budget: Optional[int] = None


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new_tokens: Optional[int] = None
    priority: int = 0  # higher survives preemption longer
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    preemptions: int = 0
    error: Optional[str] = None  # set when the request can never be served
    submit_step: int = 0  # engine tick at submission
    first_token_step: Optional[int] = None  # tick that produced output[0]

    @property
    def ttft_ticks(self) -> Optional[int]:
        """Engine ticks from submission to the first generated token."""
        if self.first_token_step is None:
            return None
        return self.first_token_step - self.submit_step + 1


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        b = serve_cfg.slots
        mode = serve_cfg.cache
        if mode not in ("paged", "contiguous"):
            raise ValueError(f"unknown cache mode {mode!r}")
        if mode == "paged" and cfg.attention == "mla":
            mode = "contiguous"  # MLA latent paging not implemented
        self.cache_mode = mode

        if mode == "paged":
            ps = serve_cfg.page_size
            self.max_pages = blocks_for(serve_cfg.max_len, ps)
            nb = serve_cfg.num_blocks or b * self.max_pages
            # physical page 0 is reserved (padding/garbage page), so the
            # device pool holds nb + 1 pages and the allocator hands out
            # ids 1..nb.
            self.pool = BlockPool(nb, ps, base=1)
            self.tables = SlotTables(self.pool, b, self.max_pages)
            self.cache = lm.init_cache(
                cfg, b, serve_cfg.max_len, layout="paged", page_size=ps,
                num_blocks=nb + 1,
            )
        else:
            self.pool = None
            self.tables = None
            self.cache = lm.init_cache(cfg, b, serve_cfg.max_len)

        self.pos = np.zeros((b,), np.int32)  # next write position per slot
        self.slot_req: List[Optional[Request]] = [None] * b
        # chunked mode: "prefill" until the replay cursor reaches the end of
        # prompt+output, then "gen" (replay mode leaves these unused)
        self.slot_state: List[Optional[str]] = [None] * b
        self.queue: collections.deque[Request] = collections.deque()
        self._uid = itertools.count()
        self._admit_seq = itertools.count()
        self._key = jax.random.PRNGKey(serve_cfg.seed)
        self._step = _decode_step_fn(cfg)
        if serve_cfg.prefill not in ("chunked", "replay"):
            raise ValueError(f"unknown prefill mode {serve_cfg.prefill!r}")
        self.prefill_mode = (
            "chunked"
            if serve_cfg.prefill == "chunked" and lm.supports_chunked_prefill(cfg)
            else "replay"
        )
        self._prefill = (
            _prefill_step_fn(cfg) if self.prefill_mode == "chunked" else None
        )
        # effective per-tick budget: a full generation batch always fits
        self.token_budget = max(
            serve_cfg.token_budget or (b + serve_cfg.prefill_chunk), b
        )
        # effective chunk: grants are all-or-nothing (chunk starts must stay
        # chunk-aligned — the kernel's page-write contract), so the chunk is
        # clamped to the worst-case leftover room (budget minus a full
        # generation batch less the prefilling slot itself).  Guarantees a
        # prefill slot always makes progress: room = budget - n_gen >=
        # budget - (slots-1) >= chunk.
        self.prefill_chunk = max(
            1, min(serve_cfg.prefill_chunk, self.token_budget - b + 1)
        )
        # per-tick spend, bounded like every other per-process accumulator
        # here (a heavy-traffic engine must not grow state per tick)
        self.tick_tokens: "collections.deque[int]" = collections.deque(
            maxlen=4096
        )
        self.completed: List[Request] = []
        self.steps_run = 0
        self.preemptions = 0

    # ------------------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens=None,
               priority: int = 0) -> Request:
        req = Request(next(self._uid), list(prompt), max_new_tokens,
                      priority=priority, submit_step=self.steps_run)
        self.queue.append(req)
        return req

    # -- scheduler ------------------------------------------------------
    def _resident_tokens(self, req: Request) -> int:
        """Tokens the request must hold to make forward progress: its full
        replay (prompt + already-generated) plus the next write."""
        return len(req.prompt) + len(req.output) + 1

    def _admit(self):
        """FIFO admission into free slots; paged mode additionally gates on
        free-block count, allocating the request's replay footprint up front
        (no head-of-line skipping — deterministic order)."""
        for s in range(self.scfg.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue[0]
            if self.pool is not None:
                need = blocks_for(self._resident_tokens(req), self.pool.page_size)
                if need > min(self.pool.num_blocks, self.max_pages):
                    # can never fit — pool too small, or prompt beyond the
                    # per-slot table (max_len): fail fast instead of wedging
                    # the queue head forever (or crashing ensure_capacity).
                    self.queue.popleft()
                    req.error = (
                        f"needs {need} KV blocks; pool holds "
                        f"{self.pool.num_blocks}, table holds {self.max_pages}"
                    )
                    req.done = True
                    self.completed.append(req)
                    continue
                if self.pool.free < need:
                    break
            self.queue.popleft()
            self.slot_req[s] = req
            self.slot_state[s] = "prefill"
            self.pos[s] = 0
            req._cursor = 0  # type: ignore[attr-defined]
            req._admit_seq = next(self._admit_seq)  # type: ignore[attr-defined]
            if self.tables is not None:
                self.tables.ensure_capacity(
                    s, self._resident_tokens(req), req.uid
                )

    def _pick_victim(self, exclude: int) -> Optional[int]:
        """Preemption victim: lowest priority, then youngest admission."""
        best = None
        for s in range(self.scfg.slots):
            if s == exclude or self.slot_req[s] is None:
                continue
            r = self.slot_req[s]
            key = (r.priority, -r._admit_seq)  # type: ignore[attr-defined]
            if best is None or key < best[0]:
                best = (key, s)
        return None if best is None else best[1]

    def _preempt(self, s: int):
        """Evict slot ``s``: blocks back to the pool, request to the front of
        the queue (recompute resume — prompt + generated tokens replay)."""
        req = self.slot_req[s]
        self.tables.release_slot(s)
        self.slot_req[s] = None
        self.slot_state[s] = None
        self.pos[s] = 0
        req._cursor = 0  # type: ignore[attr-defined]
        req.preemptions += 1
        self.preemptions += 1
        self.queue.appendleft(req)

    def _grow(self, s: int) -> bool:
        """Ensure slot ``s`` can write at ``pos[s]``; preempt on exhaustion.
        Returns False when ``s`` itself was evicted to make room."""
        req = self.slot_req[s]
        if blocks_for(int(self.pos[s]) + 1, self.pool.page_size) > self.pool.num_blocks:
            # outgrew the entire pool mid-generation; no preemption can help
            self.tables.release_slot(s)
            self.slot_req[s] = None
            self.slot_state[s] = None
            req.error = "request outgrew the KV block pool"
            req.done = True
            self.completed.append(req)
            return False
        while True:
            try:
                self.tables.ensure_capacity(s, int(self.pos[s]) + 1, req.uid)
                return True
            except PoolExhausted:
                victim = self._pick_victim(exclude=s)
                if victim is None:
                    self._preempt(s)
                    return False
                # don't evict someone strictly more important than s
                v = self.slot_req[victim]
                if (v.priority, -v._admit_seq) > (req.priority, -req._admit_seq):  # type: ignore[attr-defined]
                    self._preempt(s)
                    return False
                self._preempt(victim)

    def _finish(self, s: int, req: Request):
        req.done = True
        self.completed.append(req)
        self.slot_req[s] = None
        self.slot_state[s] = None
        if self.tables is not None:
            self.tables.release_slot(s)  # blocks recycle immediately at EOS

    def _emit_token(self, s: int, req: Request, tok: int):
        """Record a generated token and apply the stop conditions."""
        req.output.append(tok)
        if req.first_token_step is None:
            req.first_token_step = self.steps_run
        limit = req.max_new_tokens or self.scfg.max_new_tokens
        if (
            tok == self.scfg.eos_id
            or len(req.output) >= limit
            or self.pos[s] >= self.scfg.max_len
        ):
            self._finish(s, req)

    # ------------------------------------------------------------------
    def _fresh_cache(self):
        cache = self.cache
        if self.tables is not None:
            cache = cache.with_tables(jnp.asarray(self.tables.tables()))
        return cache

    def step(self) -> int:
        """One engine tick.  Replay mode: one batched decode step (slots
        still replaying their prompt feed the next replay token).  Chunked
        mode: one decode step for the generating slots plus prompt chunks
        for prefilling slots, together bounded by ``token_budget``.
        Returns #active slots."""
        self._admit()
        if self.tables is not None:
            for s in range(self.scfg.slots):
                if self.slot_req[s] is not None:
                    self._grow(s)
            self._admit()  # preemption may have freed blocks for the queue head
        active = [s for s in range(self.scfg.slots) if self.slot_req[s] is not None]
        if not active:
            return 0
        if self.prefill_mode == "chunked":
            return self._step_chunked(active)

        feed = np.zeros((self.scfg.slots,), np.int32)
        full_len: Dict[int, int] = {}
        for s in active:
            req = self.slot_req[s]
            cur = req._cursor  # type: ignore[attr-defined]
            np_ = len(req.prompt)
            full_len[s] = np_ + len(req.output)
            feed[s] = (
                req.prompt[cur] if cur < np_ else req.output[cur - np_]
            )
        logits, self.cache = self._step(
            self.params, self._fresh_cache(), jnp.asarray(feed),
            jnp.asarray(self.pos)
        )
        self._key, sub = jax.random.split(self._key)
        next_tok = np.asarray(
            sample(logits, sub, temperature=self.scfg.temperature)
        )
        for s in active:
            req = self.slot_req[s]
            cur = req._cursor  # type: ignore[attr-defined]
            self.pos[s] += 1
            req._cursor = cur + 1  # type: ignore[attr-defined]
            if cur + 1 >= full_len[s]:  # this step produced a real token
                self._emit_token(s, req, int(next_tok[s]))
        self.tick_tokens.append(len(active))
        self.steps_run += 1
        return len(active)

    def _step_chunked(self, active: List[int]) -> int:
        """One token-budget tick: decode for generating slots + prompt
        chunks for prefilling slots (oldest admitted first) within the
        leftover budget."""
        gen = [s for s in active if self.slot_state[s] == "gen"]
        pending = []
        for s in active:
            if self.slot_state[s] != "prefill":
                continue
            req = self.slot_req[s]
            remaining = len(req.prompt) + len(req.output) - req._cursor  # type: ignore[attr-defined]
            pending.append((s, req._admit_seq, remaining))  # type: ignore[attr-defined]
        chunk_lens = plan_prefill_chunks(
            self.token_budget, len(gen), pending, self.prefill_chunk
        )

        if gen:
            feed = np.zeros((self.scfg.slots,), np.int32)
            for s in gen:
                req = self.slot_req[s]
                feed[s] = req.output[-1]
            logits, self.cache = self._step(
                self.params, self._fresh_cache(), jnp.asarray(feed),
                jnp.asarray(self.pos)
            )
            self._key, sub = jax.random.split(self._key)
            next_tok = np.asarray(
                sample(logits, sub, temperature=self.scfg.temperature)
            )
            for s in gen:
                req = self.slot_req[s]
                self.pos[s] += 1
                req._cursor += 1  # type: ignore[attr-defined]
                self._emit_token(s, req, int(next_tok[s]))

        if chunk_lens:
            width = self.prefill_chunk
            toks = np.zeros((self.scfg.slots, width), np.int32)
            lens = np.zeros((self.scfg.slots,), np.int32)
            for s, n in chunk_lens.items():
                req = self.slot_req[s]
                cur = req._cursor  # type: ignore[attr-defined]
                replay = (req.prompt + req.output)[cur : cur + n]
                toks[s, :n] = replay
                lens[s] = n
            plogits, self.cache = self._prefill(
                self.params, self._fresh_cache(), jnp.asarray(toks),
                jnp.asarray(self.pos), jnp.asarray(lens)
            )
            self._key, sub = jax.random.split(self._key)
            ptok = np.asarray(
                sample(plogits, sub, temperature=self.scfg.temperature)
            )
            for s, n in chunk_lens.items():
                req = self.slot_req[s]
                self.pos[s] += n
                req._cursor += n  # type: ignore[attr-defined]
                if req._cursor >= len(req.prompt) + len(req.output):  # type: ignore[attr-defined]
                    # the chunk reached the end of the replay stream: its
                    # last live logits produce the next real token
                    self.slot_state[s] = "gen"
                    self._emit_token(s, req, int(ptok[s]))

        self.tick_tokens.append(len(gen) + sum(chunk_lens.values()))
        self.steps_run += 1
        return len(active)

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drive until queue + slots drain (or step budget)."""
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
        return self.completed

    # -- accounting -----------------------------------------------------
    def kv_cache_bytes(self) -> int:
        """Bytes held by attention KV state under the current layout."""
        return self.cache.kv_bytes()

    def peak_kv_blocks(self) -> Optional[int]:
        return None if self.pool is None else self.pool.peak_in_use
