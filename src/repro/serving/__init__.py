from .engine import (
    TERMINAL,
    Request,
    ServeConfig,
    ServingEngine,
    plan_prefill_chunks,
)
from .faults import AuditError, Fault, FaultInjector, audit_engine, random_schedule
from .sampling import sample, sample_step

__all__ = [
    "AuditError",
    "Fault",
    "FaultInjector",
    "Request",
    "ServeConfig",
    "ServingEngine",
    "TERMINAL",
    "audit_engine",
    "plan_prefill_chunks",
    "random_schedule",
    "sample",
    "sample_step",
]
