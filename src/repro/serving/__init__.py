from .engine import Request, ServeConfig, ServingEngine
from .sampling import sample

__all__ = ["Request", "ServeConfig", "ServingEngine", "sample"]
