from .engine import Request, ServeConfig, ServingEngine, plan_prefill_chunks
from .sampling import sample, sample_step

__all__ = [
    "Request",
    "ServeConfig",
    "ServingEngine",
    "plan_prefill_chunks",
    "sample",
    "sample_step",
]
