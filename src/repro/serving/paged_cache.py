"""Paged KV cache bookkeeping: refcounted block pool, per-slot block tables
with copy-on-write, and the prefix-cache radix index.

The vLLM insight applied to the tile model: the KV cache is a pool of
fixed-size **blocks** (pages) of ``page_size`` tokens, and each request owns
an ordered list of physical blocks — its *block table* — instead of a
contiguous ``max_len`` strip.  Memory then scales with the tokens actually
resident, not ``slots x max_len``; admission/preemption decisions reduce to
free-block counting.

Blocks are **refcounted** so N slot tables (and the prefix index) can share
one physical page: two block tables pointing at the same page *is* the
sharing mechanism — the table-directed gather in the paged kernels needs no
change at all.  ``release`` decrements; a block recycles when its count
hits zero.  A slot that must write into a shared page first goes through
:meth:`SlotTables.ensure_writable` — **copy-on-write**: it gets a fresh
page, the caller copies the shared contents device-side
(``models.lm.copy_pages``), and the table entry is repointed before the
step runs.

:class:`PrefixCache` is the SGLang-style radix index over token ids at page
granularity: full pages of prompt tokens map to chains of physical pages.
Chain keys are rolling hashes (``hash((parent_key, page_tokens))`` from a
per-model-config salted root) but child lookup is by the exact token block,
so a hash collision can never alias two different prefixes.  The index
holds one reference per cached page; eviction (LRU leaves first) only ever
reclaims pages with refcount 1 — pages no slot table references — so a hot
pool degrades gracefully to the uncached behavior instead of failing
admission.

Everything here is host-side (numpy/python) bookkeeping: allocation,
per-slot tables, the padded ``(slots, max_pages)`` int32 table tensor the
decode step consumes.  The device-side page pools live in the model cache
pytree (``models.lm.init_cache(layout="paged")``); the gather itself is the
``paged_attention`` kernel (or its XLA oracle) indexing pages through this
table.

Invariants (property-tested in tests/test_property.py):

* a block recycles exactly when its refcount reaches zero (alloc/retain/
  release conserve blocks — never leak, never free early);
* after a copy-on-write the written page is reachable from exactly one
  table;
* eviction never reclaims a page with refcount > 1;
* table entries beyond a slot's live length hold page 0 — a *valid* page id
  (the kernel DMAs padding pages and masks their contribution).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np


class PoolExhausted(Exception):
    """No free blocks; caller should evict cached pages, preempt or queue."""


def blocks_for(num_tokens: int, page_size: int) -> int:
    """Blocks needed to hold ``num_tokens`` tokens (ceil division)."""
    return -(-num_tokens // page_size)


def blocks_for_bytes(budget_bytes: int, page_bytes: int) -> int:
    """Blocks a byte budget affords at ``page_bytes`` per block (floor).

    This is how a quantized cache converts its smaller per-page footprint
    into *capacity*: at a fixed byte budget, fewer bytes per page means more
    pages in the pool, which means later preemption under pressure.  Pair
    with :attr:`BlockPool.page_bytes` for accounting."""
    if page_bytes <= 0:
        raise ValueError("page_bytes must be positive")
    return int(budget_bytes) // int(page_bytes)


class BlockPool:
    """Fixed pool of refcounted KV blocks with owner tracking and peak
    accounting.

    ``alloc`` hands out a block at refcount 1; ``retain`` adds a reference
    (a second table, the prefix index); ``release`` drops one — the block
    returns to the free list only at zero.  ``in_use``/``peak_in_use``
    count *physical* blocks, not references: that is what admission and
    memory accounting care about.

    ``base`` offsets the physical ids handed out: the serving engine uses
    ``base=1`` so physical page 0 is never allocatable — it is the padding
    page that zeroed table rows (inactive slots, table tails) read from and
    inactive slots harmlessly write to.
    """

    def __init__(self, num_blocks: int, page_size: int, base: int = 0,
                 page_bytes: Optional[int] = None, injector=None):
        if num_blocks <= 0 or page_size <= 0:
            raise ValueError("num_blocks and page_size must be positive")
        # optional serving.faults.FaultInjector: when its schedule says so,
        # alloc() raises PoolExhausted exactly as a genuinely empty pool
        # would — chaos testing exercises every caller's rollback path
        self.injector = injector
        self.num_blocks = int(num_blocks)
        self.page_size = int(page_size)
        self.base = int(base)
        # bytes one physical page occupies across every pool leaf (packed
        # data + scales for quantized caches); purely advisory accounting
        # used by byte-budget sizing (``blocks_for_bytes``) and benchmarks
        self.page_bytes = None if page_bytes is None else int(page_bytes)
        # stack of free ids; reversed so .pop() hands out ascending ids first
        self._free: List[int] = list(
            range(base + self.num_blocks - 1, base - 1, -1)
        )
        self._ref: Dict[int, int] = {}
        self._owner: Dict[int, object] = {}
        self.peak_in_use = 0
        self.total_allocs = 0  # cumulative alloc() calls (sharing avoids them)

    # ------------------------------------------------------------------
    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def can_fit(self, num_tokens: int) -> bool:
        return self.free >= blocks_for(num_tokens, self.page_size)

    # ------------------------------------------------------------------
    def alloc(self, owner: object = None) -> int:
        if self.injector is not None and self.injector.fire("pool_alloc"):
            raise PoolExhausted("injected fault: pool_alloc")
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_blocks} KV blocks in use"
            )
        blk = self._free.pop()
        self._ref[blk] = 1
        self._owner[blk] = owner
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        self.total_allocs += 1
        return blk

    def retain(self, block: int) -> None:
        """Add a reference to an allocated block (page sharing)."""
        if block not in self._ref:
            raise ValueError(f"retain of free KV block {block}")
        self._ref[block] += 1

    def release(self, blocks: Sequence[int]) -> None:
        """Drop one reference per listed block; recycle at zero."""
        for blk in blocks:
            if blk not in self._ref:
                raise ValueError(f"double free of KV block {blk}")
            self._ref[blk] -= 1
            if self._ref[blk] == 0:
                del self._ref[blk]
                del self._owner[blk]
                self._free.append(blk)

    def refcount(self, block: int) -> int:
        return self._ref.get(block, 0)

    def owner_of(self, block: int) -> object:
        return self._owner.get(block)


@dataclasses.dataclass
class SlotTables:
    """Per-slot block lists + the padded device table tensor.

    ``tables()`` returns the ``(slots, max_pages)`` int32 array the decode
    step consumes; unowned entries point at page 0 (valid but masked).

    Sharing-aware operations: :meth:`attach` installs already-filled pages
    (cache hits) into a slot's table, :meth:`repoint` swaps one entry for a
    deduplicated twin, and :meth:`ensure_writable` is the copy-on-write
    gate every write path runs before touching a page.
    """

    pool: BlockPool
    slots: int
    max_pages: int

    def __post_init__(self):
        self._blocks: List[List[int]] = [[] for _ in range(self.slots)]
        self._np = np.zeros((self.slots, self.max_pages), np.int32)

    # ------------------------------------------------------------------
    def blocks(self, slot: int) -> List[int]:
        return list(self._blocks[slot])

    def num_blocks(self, slot: int) -> int:
        return len(self._blocks[slot])

    def ensure_capacity(self, slot: int, num_tokens: int, owner=None) -> int:
        """Grow ``slot``'s table to hold ``num_tokens`` tokens.

        Returns the number of blocks newly allocated.  Raises
        :class:`PoolExhausted` (allocating nothing) when the pool cannot
        cover the growth — the scheduler's preemption trigger.
        """
        need = blocks_for(num_tokens, self.pool.page_size)
        if need > self.max_pages:
            raise ValueError(
                f"slot {slot}: {num_tokens} tokens need {need} blocks "
                f"> max_pages={self.max_pages}"
            )
        have = len(self._blocks[slot])
        grow = need - have
        if grow <= 0:
            return 0
        if self.pool.free < grow:
            raise PoolExhausted(
                f"slot {slot} needs {grow} blocks, pool has {self.pool.free}"
            )
        got: List[int] = []
        try:
            for _ in range(grow):
                blk = self.pool.alloc(owner)
                got.append(blk)
                self._blocks[slot].append(blk)
                self._np[slot, len(self._blocks[slot]) - 1] = blk
        except PoolExhausted:
            # an injected alloc fault can fire past the free-count
            # pre-check above: roll back so the allocate-nothing contract
            # holds however the failure arrived
            n = len(self._blocks[slot])
            del self._blocks[slot][n - len(got):]
            self._np[slot, n - len(got): n] = 0
            self.pool.release(got)
            raise
        return grow

    def attach(self, slot: int, pages: Sequence[int]) -> int:
        """Append already-filled ``pages`` (a prefix-cache hit) to ``slot``'s
        table, retaining each — the slot now co-owns them with whoever
        filled them.  Returns the number of pages attached."""
        blks = self._blocks[slot]
        if len(blks) + len(pages) > self.max_pages:
            raise ValueError(
                f"slot {slot}: attaching {len(pages)} pages onto "
                f"{len(blks)} exceeds max_pages={self.max_pages}"
            )
        for p in pages:
            self.pool.retain(p)
            blks.append(p)
            self._np[slot, len(blks) - 1] = p
        return len(pages)

    def repoint(self, slot: int, page_idx: int, page: int) -> None:
        """Swap the entry at ``page_idx`` for ``page`` (dedup: an identical
        page already cached elsewhere).  Retains the new page, drops the
        slot's reference on the old one."""
        old = self._blocks[slot][page_idx]
        if old == page:
            return
        self.pool.retain(page)
        self.pool.release([old])
        self._blocks[slot][page_idx] = page
        self._np[slot, page_idx] = page

    def ensure_writable(self, slot: int, page_idx: int,
                        owner=None) -> Optional[Tuple[int, int]]:
        """Copy-on-write gate: make the page at ``page_idx`` exclusively
        ``slot``'s before a write lands in it.

        A page referenced only by this table (refcount 1) is already
        writable — returns ``None``.  A shared page gets a fresh block, the
        table entry is repointed, and ``(src, dst)`` is returned: the
        caller must copy page ``src`` onto ``dst`` device-side
        (``models.lm.copy_pages``) *before* dispatching the step, then
        re-upload the table.  Raises :class:`PoolExhausted` when no fresh
        block is available (the caller may evict cached pages and retry)."""
        blk = self._blocks[slot][page_idx]
        if self.pool.refcount(blk) <= 1:
            return None
        fresh = self.pool.alloc(owner)
        self.pool.release([blk])
        self._blocks[slot][page_idx] = fresh
        self._np[slot, page_idx] = fresh
        return (blk, fresh)

    def trim(self, slot: int, num_tokens: int) -> int:
        """Release ``slot``'s blocks beyond those holding ``num_tokens``
        tokens (the multi-step engine's grow-ahead give-back: unused
        worst-case pages return to the pool at the sync boundary).  Returns
        the number of blocks dropped from the table (shared blocks survive
        under their remaining references)."""
        need = blocks_for(num_tokens, self.pool.page_size) if num_tokens > 0 else 0
        blks = self._blocks[slot]
        extra = blks[need:]
        if not extra:
            return 0
        self.pool.release(extra)
        del blks[need:]
        self._np[slot, need:] = 0
        return len(extra)

    def release_slot(self, slot: int) -> int:
        """Drop all of ``slot``'s references (EOS / preemption); unshared
        blocks return to the pool."""
        blks = self._blocks[slot]
        n = len(blks)
        self.pool.release(blks)
        self._blocks[slot] = []
        self._np[slot, :] = 0
        return n

    def tables(self) -> np.ndarray:
        return self._np.copy()

    def poke(self, slot: int, idx: int, value: int) -> int:
        """Chaos hook: overwrite one *device-table* entry without touching
        the block ledger (``_blocks`` stays truthful, so release paths and
        page conservation are unaffected).  Models a corrupted table upload
        — the dispatch guard is expected to catch the divergence before
        any kernel consumes it.  Returns the previous entry."""
        prev = int(self._np[slot, idx])
        self._np[slot, idx] = int(value)
        return prev

    def lookup(self, slot: int, pos: int) -> int:
        """Physical page holding token position ``pos`` of ``slot``."""
        page = pos // self.pool.page_size
        if page >= len(self._blocks[slot]):
            raise IndexError(
                f"slot {slot} pos {pos}: logical page {page} not allocated"
            )
        return self._blocks[slot][page]


# ---------------------------------------------------------------------------
# Prefix cache: radix index over token ids -> page chains
# ---------------------------------------------------------------------------


class _PrefixNode:
    """One full page of cached tokens: a radix-tree edge labelled by the
    page's token block, holding the physical page those tokens' KV lives
    in."""

    __slots__ = ("page", "key", "parent", "token_block", "children",
                 "last_use")

    def __init__(self, page, key, parent, token_block):
        self.page = page
        self.key = key
        self.parent = parent
        self.token_block = token_block
        self.children: Dict[tuple, "_PrefixNode"] = {}
        self.last_use = 0


class PrefixCache:
    """Radix index mapping token-id prefixes to chains of filled KV pages
    (SGLang's radix attention at page granularity).

    Nodes are whole pages: a prompt contributes ``len(prompt) //
    page_size`` nodes, each holding the physical page whose KV was computed
    from exactly that token prefix.  Node keys are rolling content hashes —
    ``hash((parent_key, page_tokens))`` seeded from a per-model-config salt
    — used as chain identity; child *lookup* is by the exact token block,
    so hash collisions can never alias two different prefixes.

    The index holds one pool reference per cached page (``retain`` on
    insert).  :meth:`match` returns the longest cached page chain for a
    prompt (LRU-touched), :meth:`insert` indexes freshly-filled pages and
    reports duplicates for the caller to absorb, and :meth:`evict` reclaims
    LRU leaf pages **only** when no slot table references them (pool
    refcount 1) — the graceful-degradation contract: a hot pool behaves
    like an uncached engine rather than refusing admission.
    """

    def __init__(self, pool: BlockPool, salt: tuple = ()):
        self.pool = pool
        self.page_size = pool.page_size
        root_key = hash(("prefix-root", tuple(salt)))
        self._root = _PrefixNode(None, root_key, None, None)
        self._clock = 0
        self.hits = 0
        self.lookups = 0
        self.insertions = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _blocks_of(self, tokens: Sequence[int]) -> List[tuple]:
        ps = self.page_size
        return [
            tuple(tokens[i * ps:(i + 1) * ps])
            for i in range(len(tokens) // ps)
        ]

    @property
    def pages(self) -> int:
        """Physical pages currently held by the index."""
        n, stack = 0, [self._root]
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            n += 1
        return n - 1  # root holds no page

    # ------------------------------------------------------------------
    def match(self, tokens: Sequence[int],
              max_pages: Optional[int] = None) -> List[int]:
        """Longest cached chain of full pages prefixing ``tokens`` (at most
        ``max_pages`` of them), LRU-touched.  Returns the physical page
        ids in logical order; the caller attaches them to a slot table
        (which takes the references) before any further allocation can
        evict them."""
        self.lookups += 1
        now = self._tick()
        node = self._root
        pages: List[int] = []
        blocks = self._blocks_of(tokens)
        if max_pages is not None:
            blocks = blocks[: max(0, max_pages)]
        for blk in blocks:
            child = node.children.get(blk)
            if child is None:
                break
            child.last_use = now
            pages.append(child.page)
            node = child
        if pages:
            self.hits += 1
        return pages

    def insert(self, tokens: Sequence[int],
               pages: Sequence[int]) -> List[Tuple[int, int]]:
        """Index ``pages`` — the physical pages now holding the full-page
        prefix of ``tokens`` — retaining each newly-indexed page.

        Content-hash dedup happens here: when a token block is already
        cached under a *different* physical page (two requests prefilled
        the same prompt concurrently), the existing page wins and ``(idx,
        cached_page)`` is reported so the caller can repoint its table and
        free its duplicate copy.  Idempotent for pages already indexed."""
        now = self._tick()
        node = self._root
        dups: List[Tuple[int, int]] = []
        for idx, blk in enumerate(self._blocks_of(tokens)[: len(pages)]):
            child = node.children.get(blk)
            if child is None:
                page = pages[idx]
                child = _PrefixNode(page, hash((node.key, blk)), node, blk)
                node.children[blk] = child
                self.pool.retain(page)
                self.insertions += 1
            elif child.page != pages[idx]:
                dups.append((idx, child.page))
            child.last_use = now
            node = child
        return dups

    def evict(self, want: int,
              protect: FrozenSet[int] = frozenset()) -> int:
        """Reclaim up to ``want`` cached pages, LRU leaves first, skipping
        ``protect`` (e.g. pages just matched but not yet attached) and any
        page a slot table still references (pool refcount > 1).  Returns
        pages freed.  Removing a leaf can expose its parent as the next
        candidate, so eviction walks chains tail-first — a prefix chain
        never loses an interior page while a descendant survives."""
        freed = 0
        while freed < want:
            leaves = []
            stack = [self._root]
            while stack:
                nd = stack.pop()
                stack.extend(nd.children.values())
                if nd is not self._root and not nd.children:
                    if nd.page not in protect and \
                            self.pool.refcount(nd.page) == 1:
                        leaves.append(nd)
            if not leaves:
                break
            leaves.sort(key=lambda n: n.last_use)
            for nd in leaves:
                if freed >= want:
                    break
                del nd.parent.children[nd.token_block]
                self.pool.release([nd.page])
                self.evictions += 1
                freed += 1
        return freed

    # -- persistence (engine.snapshot / restore) ------------------------
    def export(self) -> List[Tuple[int, tuple, int]]:
        """Flatten the index to ``(parent, token_block, page)`` triples
        with parents strictly before children (parent ``-1`` = root) — the
        serializable half of the engine's ``snapshot()`` (the other half
        is the page *contents*, gathered from the device pools)."""
        out: List[Tuple[int, tuple, int]] = []
        index = {id(self._root): -1}
        queue = collections.deque([self._root])
        while queue:
            nd = queue.popleft()
            for child in nd.children.values():
                out.append((index[id(nd)], child.token_block, child.page))
                index[id(child)] = len(out) - 1
                queue.append(child)
        return out

    def import_nodes(self, entries: Sequence[Tuple[int, tuple, int]]) -> int:
        """Rebuild exported chains: each entry ``(parent, token_block,
        page)`` references an earlier entry by position (``-1`` = root) and
        hands the index a freshly-allocated page whose single reference the
        index takes over — the steady state a published prefill page
        reaches.  A token block already cached keeps its existing page and
        the caller's duplicate allocation is released.  Returns nodes
        added."""
        now = self._tick()
        nodes: Dict[int, _PrefixNode] = {-1: self._root}
        added = 0
        for i, (parent, blk, page) in enumerate(entries):
            pnode = nodes[parent]
            blk = tuple(blk)
            child = pnode.children.get(blk)
            if child is None:
                child = _PrefixNode(page, hash((pnode.key, blk)), pnode, blk)
                child.last_use = now
                pnode.children[blk] = child
                self.insertions += 1
                added += 1
            else:
                self.pool.release([page])
            nodes[i] = child
        return added

    def flush(self) -> int:
        """Drop the index's reference on every cached page and reset the
        tree (engine shutdown).  Pages a slot table still shares survive
        under their remaining references; the rest recycle immediately.
        Returns pages the index let go."""
        freed = 0
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            self.pool.release([nd.page])
            freed += 1
        self._root.children = {}
        return freed
