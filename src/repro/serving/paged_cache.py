"""Paged KV cache bookkeeping: block pool + per-slot block tables.

The vLLM insight applied to the tile model: the KV cache is a pool of
fixed-size **blocks** (pages) of ``page_size`` tokens, and each request owns
an ordered list of physical blocks — its *block table* — instead of a
contiguous ``max_len`` strip.  Memory then scales with the tokens actually
resident, not ``slots x max_len``; admission/preemption decisions reduce to
free-block counting.

Everything here is host-side (numpy/python) bookkeeping: allocation,
per-slot tables, the padded ``(slots, max_pages)`` int32 table tensor the
decode step consumes.  The device-side page pools live in the model cache
pytree (``models.lm.init_cache(layout="paged")``); the gather itself is the
``paged_attention`` kernel (or its XLA oracle) indexing pages through this
table.

Invariants (property-tested in tests/test_property.py):

* a block is owned by at most one slot at a time (never double-assigned);
* alloc/free round-trips conserve blocks (never leak);
* table entries beyond a slot's live length hold page 0 — a *valid* page id
  (the kernel DMAs padding pages and masks their contribution).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


class PoolExhausted(Exception):
    """No free blocks; caller should preempt or queue."""


def blocks_for(num_tokens: int, page_size: int) -> int:
    """Blocks needed to hold ``num_tokens`` tokens (ceil division)."""
    return -(-num_tokens // page_size)


class BlockPool:
    """Fixed pool of KV blocks with owner tracking and peak accounting.

    ``base`` offsets the physical ids handed out: the serving engine uses
    ``base=1`` so physical page 0 is never allocatable — it is the padding
    page that zeroed table rows (inactive slots, table tails) read from and
    inactive slots harmlessly write to.
    """

    def __init__(self, num_blocks: int, page_size: int, base: int = 0):
        if num_blocks <= 0 or page_size <= 0:
            raise ValueError("num_blocks and page_size must be positive")
        self.num_blocks = int(num_blocks)
        self.page_size = int(page_size)
        self.base = int(base)
        # stack of free ids; reversed so .pop() hands out ascending ids first
        self._free: List[int] = list(
            range(base + self.num_blocks - 1, base - 1, -1)
        )
        self._owner: Dict[int, object] = {}
        self.peak_in_use = 0

    # ------------------------------------------------------------------
    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_blocks - len(self._free)

    def can_fit(self, num_tokens: int) -> bool:
        return self.free >= blocks_for(num_tokens, self.page_size)

    # ------------------------------------------------------------------
    def alloc(self, owner: object = None) -> int:
        if not self._free:
            raise PoolExhausted(
                f"all {self.num_blocks} KV blocks in use"
            )
        blk = self._free.pop()
        self._owner[blk] = owner
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return blk

    def release(self, blocks: List[int]) -> None:
        for blk in blocks:
            if blk not in self._owner:
                raise ValueError(f"double free of KV block {blk}")
            del self._owner[blk]
            self._free.append(blk)

    def owner_of(self, block: int) -> object:
        return self._owner.get(block)


@dataclasses.dataclass
class SlotTables:
    """Per-slot block lists + the padded device table tensor.

    ``tables()`` returns the ``(slots, max_pages)`` int32 array the decode
    step consumes; unowned entries point at page 0 (valid but masked).
    """

    pool: BlockPool
    slots: int
    max_pages: int

    def __post_init__(self):
        self._blocks: List[List[int]] = [[] for _ in range(self.slots)]
        self._np = np.zeros((self.slots, self.max_pages), np.int32)

    # ------------------------------------------------------------------
    def blocks(self, slot: int) -> List[int]:
        return list(self._blocks[slot])

    def num_blocks(self, slot: int) -> int:
        return len(self._blocks[slot])

    def ensure_capacity(self, slot: int, num_tokens: int, owner=None) -> int:
        """Grow ``slot``'s table to hold ``num_tokens`` tokens.

        Returns the number of blocks newly allocated.  Raises
        :class:`PoolExhausted` (allocating nothing) when the pool cannot
        cover the growth — the scheduler's preemption trigger.
        """
        need = blocks_for(num_tokens, self.pool.page_size)
        if need > self.max_pages:
            raise ValueError(
                f"slot {slot}: {num_tokens} tokens need {need} blocks "
                f"> max_pages={self.max_pages}"
            )
        have = len(self._blocks[slot])
        grow = need - have
        if grow <= 0:
            return 0
        if self.pool.free < grow:
            raise PoolExhausted(
                f"slot {slot} needs {grow} blocks, pool has {self.pool.free}"
            )
        for _ in range(grow):
            blk = self.pool.alloc(owner)
            self._blocks[slot].append(blk)
            self._np[slot, len(self._blocks[slot]) - 1] = blk
        return grow

    def trim(self, slot: int, num_tokens: int) -> int:
        """Release ``slot``'s blocks beyond those holding ``num_tokens``
        tokens (the multi-step engine's grow-ahead give-back: unused
        worst-case pages return to the pool at the sync boundary).  Returns
        the number of blocks released."""
        need = blocks_for(num_tokens, self.pool.page_size) if num_tokens > 0 else 0
        blks = self._blocks[slot]
        extra = blks[need:]
        if not extra:
            return 0
        self.pool.release(extra)
        del blks[need:]
        self._np[slot, need:] = 0
        return len(extra)

    def release_slot(self, slot: int) -> int:
        """Return all of ``slot``'s blocks to the pool (EOS / preemption)."""
        blks = self._blocks[slot]
        n = len(blks)
        self.pool.release(blks)
        self._blocks[slot] = []
        self._np[slot, :] = 0
        return n

    def tables(self) -> np.ndarray:
        return self._np.copy()

    def lookup(self, slot: int, pos: int) -> int:
        """Physical page holding token position ``pos`` of ``slot``."""
        page = pos // self.pool.page_size
        if page >= len(self._blocks[slot]):
            raise IndexError(
                f"slot {slot} pos {pos}: logical page {page} not allocated"
            )
        return self._blocks[slot][page]
