"""Token sampling: greedy / temperature / top-k / top-p, batched.

:func:`sample` is the pure logits->tokens transform; :func:`sample_step`
is the engine-facing fused form that also owns the PRNG-key carry so the
whole thing can live *inside* the jit'd decode step (the engine never
downloads logits — sampled token ids are the only thing that crosses the
device boundary).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def guarded_argmax(logits: jax.Array) -> jax.Array:
    """argmax that never returns garbage on poisoned rows: NaN compares
    false everywhere (plain argmax of an all-NaN row is implementation-
    defined), so NaNs count as ``-inf`` and an all-``-inf`` row
    deterministically yields id 0 — always a valid vocab index."""
    return jnp.argmax(
        jnp.where(jnp.isnan(logits), -jnp.inf, logits), axis=-1
    ).astype(jnp.int32)


def sample(
    logits: jax.Array,  # (B, V) f32
    key,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
    top_p: Optional[float] = None,
) -> jax.Array:
    if temperature <= 0.0:
        return guarded_argmax(logits)
    raw = logits
    logits = logits / temperature
    vocab = logits.shape[-1]
    if top_k is not None:
        # top_k > V would make the negative index wrap around to a high
        # logit and silently truncate the distribution; >= V keeps it all.
        k = min(int(top_k), vocab)
        kth = jnp.sort(logits, axis=-1)[:, -k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p is not None:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # top_p >= 1.0 makes every cum < top_p, pushing the index to V;
        # clamp instead of relying on gather's silent index clipping.
        cutoff_idx = jnp.minimum(jnp.sum(cum < top_p, axis=-1), vocab - 1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    tok = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    # a row with no finite mass left (fully masked, or NaN/Inf-poisoned
    # upstream) makes categorical sample garbage — softmax of all -inf is
    # NaN.  Fall back to argmax of the *raw* logits so the emitted id is
    # always a valid vocab index; the serving engine separately counts and
    # fails requests whose raw logits were poisoned (poisoned_rows).
    bad = ~jnp.any(jnp.isfinite(logits), axis=-1)
    return jnp.where(bad, guarded_argmax(raw), tok)


def sample_step(
    logits: jax.Array,  # (B, V) f32
    key,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
    top_p: Optional[float] = None,
    gate=None,  # optional () bool: when False the key is left unadvanced
) -> Tuple[jax.Array, jax.Array]:
    """Fused sampling step: ``(tokens, new_key)`` with the key split folded
    in, so the caller can jit the model step and the sampler as one program
    and thread the key as a device-resident carry.

    Greedy fast path: at ``temperature <= 0`` the key is dead weight — no
    ``jax.random.split`` is traced and the key passes through untouched
    (deterministic benches pay zero PRNG cost).

    ``gate`` serves the multi-step decode loop: a scan iteration where every
    slot has already stopped must not advance the key, or the surviving key
    stream would diverge from an engine that never ran those ticks.  (This
    keeps ``temperature > 0`` streams bit-equal to per-tick stepping when
    the window covers the same ticks per-tick would run; admission deferred
    to a sync boundary can still shift the stream — see
    ``lm.decode_loop``.)
    """
    if temperature <= 0.0:
        return guarded_argmax(logits), key
    new_key, sub = jax.random.split(key)
    if gate is not None:
        new_key = jnp.where(gate, new_key, key)
    tok = sample(logits, sub, temperature=temperature, top_k=top_k,
                 top_p=top_p)
    return tok, new_key
