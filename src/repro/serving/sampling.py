"""Token sampling: greedy / temperature / top-k / top-p, batched."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample(
    logits: jax.Array,  # (B, V) f32
    key,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
    top_p: Optional[float] = None,
) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k is not None:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p is not None:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
