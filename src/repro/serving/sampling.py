"""Token sampling: greedy / temperature / top-k / top-p, batched.

:func:`sample` is the pure logits->tokens transform; :func:`sample_step`
is the engine-facing fused form that also owns the PRNG-key carry so the
whole thing can live *inside* the jit'd decode step (the engine never
downloads logits — sampled token ids are the only thing that crosses the
device boundary).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def guarded_argmax(logits: jax.Array) -> jax.Array:
    """argmax that never returns garbage on poisoned rows: NaN compares
    false everywhere (plain argmax of an all-NaN row is implementation-
    defined), so NaNs count as ``-inf`` and an all-``-inf`` row
    deterministically yields id 0 — always a valid vocab index."""
    return jnp.argmax(
        jnp.where(jnp.isnan(logits), -jnp.inf, logits), axis=-1
    ).astype(jnp.int32)


def sample(
    logits: jax.Array,  # (B, V) f32
    key,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
    top_p: Optional[float] = None,
) -> jax.Array:
    if temperature <= 0.0:
        return guarded_argmax(logits)
    raw = logits
    logits = logits / temperature
    vocab = logits.shape[-1]
    if top_k is not None:
        # top_k > V would make the negative index wrap around to a high
        # logit and silently truncate the distribution; >= V keeps it all.
        k = min(int(top_k), vocab)
        kth = jnp.sort(logits, axis=-1)[:, -k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p is not None:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # top_p >= 1.0 makes every cum < top_p, pushing the index to V;
        # clamp instead of relying on gather's silent index clipping.
        cutoff_idx = jnp.minimum(jnp.sum(cum < top_p, axis=-1), vocab - 1)
        cutoff = jnp.take_along_axis(sorted_logits, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    tok = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    # a row with no finite mass left (fully masked, or NaN/Inf-poisoned
    # upstream) makes categorical sample garbage — softmax of all -inf is
    # NaN.  Fall back to argmax of the *raw* logits so the emitted id is
    # always a valid vocab index; the serving engine separately counts and
    # fails requests whose raw logits were poisoned (poisoned_rows).
    bad = ~jnp.any(jnp.isfinite(logits), axis=-1)
    return jnp.where(bad, guarded_argmax(raw), tok)


def sample_step(
    logits: jax.Array,  # (B, V) f32
    key,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
    top_p: Optional[float] = None,
    gate=None,  # optional () bool: when False the key is left unadvanced
) -> Tuple[jax.Array, jax.Array]:
    """Fused sampling step: ``(tokens, new_key)`` with the key split folded
    in, so the caller can jit the model step and the sampler as one program
    and thread the key as a device-resident carry.

    Greedy fast path: at ``temperature <= 0`` the key is dead weight — no
    ``jax.random.split`` is traced and the key passes through untouched
    (deterministic benches pay zero PRNG cost).

    ``gate`` serves the multi-step decode loop: a scan iteration where every
    slot has already stopped must not advance the key, or the surviving key
    stream would diverge from an engine that never ran those ticks.  (This
    keeps ``temperature > 0`` streams bit-equal to per-tick stepping when
    the window covers the same ticks per-tick would run; admission deferred
    to a sync boundary can still shift the stream — see
    ``lm.decode_loop``.)
    """
    if temperature <= 0.0:
        return guarded_argmax(logits), key
    new_key, sub = jax.random.split(key)
    if gate is not None:
        new_key = jnp.where(gate, new_key, key)
    tok = sample(logits, sub, temperature=temperature, top_k=top_k,
                 top_p=top_p)
    return tok, new_key


def spec_accept(drafts: jax.Array, targets: jax.Array) -> jax.Array:
    """Token-match accept rule for self-speculative decoding.

    ``drafts`` (B, K) proposed tokens, ``targets`` (B, K+1) the model's own
    next tokens after each chunk prefix (``targets[:, i]`` follows the
    prefix ending at draft ``i``).  Returns the (B, K+1) leading-accept
    mask: position 0 (the model's token after the committed feed) is always
    acceptable, and draft ``i`` extends the run iff it matched the target
    the model produced at the same position — the first mismatch rejects
    everything after it, because later targets were conditioned on a prefix
    the model just refused.

    Under greedy targets this is byte-identical to plain decode by
    construction: every accepted position's target is the argmax after an
    exactly-committed prefix.  Under temperature targets, token-match
    against a sample from the true conditional is unbiased for the same
    reason — the emitted token at each position is drawn from the model's
    distribution given the accepted prefix.
    """
    acc = (drafts == targets[:, :-1]).astype(jnp.int32)
    run = jnp.cumprod(acc, axis=1).astype(bool)
    return jnp.concatenate(
        [jnp.ones((drafts.shape[0], 1), bool), run], axis=1
    )


def spec_sample_step(
    logits: jax.Array,  # (B, C, V) f32 — one row per verify-chunk position
    key,
    temperature: float = 0.0,
    top_k: Optional[int] = None,
    top_p: Optional[float] = None,
    gate=None,  # optional () bool: when False the key is left unadvanced
) -> Tuple[jax.Array, jax.Array]:
    """Chunk-wide :func:`sample_step` for speculative verify: one target
    token per chunk position, ``(targets (B, C), new_key)``.

    The key-stream determinism rule lives here: a gated round always splits
    the key into exactly ``C + 1`` subkeys — one carry + one per position —
    *regardless of how many positions end up accepted*.  Acceptance length
    only selects which already-sampled targets are emitted; it never feeds
    back into the key schedule, so a slot's token stream is a pure function
    of (seed, round index), deterministic across acceptance histories and
    across other slots' fates.  Greedy keeps the decode-loop contract: no
    split is traced and the key passes through untouched.
    """
    if temperature <= 0.0:
        return guarded_argmax(logits), key
    c = logits.shape[1]
    keys = jax.random.split(key, c + 1)
    new_key = keys[0]
    if gate is not None:
        new_key = jnp.where(gate, new_key, key)
    cols = [
        sample(logits[:, i], keys[i + 1], temperature=temperature,
               top_k=top_k, top_p=top_p)
        for i in range(c)
    ]
    return jnp.stack(cols, axis=1), new_key
