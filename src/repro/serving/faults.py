"""Fault injection and live invariant auditing for the serving engine.

The engine's failure handling is only trustworthy if it can be *exercised*:
:class:`FaultInjector` is a deterministic schedule of faults threaded
through the allocation and dispatch sites the engine already has —

* ``"pool_alloc"`` — :meth:`BlockPool.alloc` raises :class:`PoolExhausted`
  before allocating, exactly as a genuinely empty pool would.  Lands
  wherever the engine allocates: admission growth, per-tick growth,
  grow-ahead grants, copy-on-write copies.
* ``"grant"`` — the multi-step grow-ahead grant fails at the sync
  boundary, forcing the documented per-tick fallback path.
* ``"poison"`` — one dispatched logits row is overwritten with NaN before
  sampling (the engine routes this through the per-tick step so the row is
  detectable), modelling numerical corruption from a bad kernel or flaky
  device memory.
* ``"table_corrupt"`` — one device block-table entry of a dispatched slot
  is overwritten (out-of-range id / reserved page 0 / duplicate of another
  row's page, cycling), modelling a corrupted table upload.  The dispatch
  guard (``ServeConfig.guards``) must reject the row before any page is
  read or written; with guards off, :func:`audit_engine`'s ledger check is
  what notices.
* ``"spec_poison"`` — the speculative window's accept/rollback path: one
  slot's *verify* logits are overwritten with NaN on device
  (``lm.spec_decode_loop``'s ``poison`` mask), so every target of every
  round is garbage.  The loop's own non-finite check must emit nothing for
  that slot and report it ``bad``; the engine FAILs exactly that request,
  and the rejected draft tail plus the grow-ahead grant must still come
  back through ``trim`` — rollback never leaks pages.  (Grant denial
  mid-draft-window rides the existing ``"grant"`` site: the speculative
  grow-ahead runs through the same all-or-nothing grant.)

Pool and grant faults are *output-preserving* by the engine's own design
(preemption resumes by recompute, grant failure degrades to per-tick
stepping), so a chaos run can assert byte-identical outputs for every
request a fault didn't terminate.  Poison and table-corrupt faults fail
the affected request (``status="failed"``) and must leave everyone else
untouched.

:func:`audit_engine` is the live counterpart of the offline hypothesis
properties in tests/test_property.py: with ``ServeConfig.audit=True`` the
engine calls it after every tick, and it re-derives the refcount ledger
from scratch — slot tables + radix index — and checks it against the
pool's own books.  Any divergence raises :class:`AuditError` at the tick
that caused it, not at drain time.

Run ``python -m repro.serving.faults`` for the seeded chaos smoke CI uses:
a fixed workload x fault schedule, auditing every tick, asserting
byte-identity for unaffected requests and a fully drained pool.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .paged_cache import blocks_for

SITES = ("pool_alloc", "grant", "poison", "table_corrupt", "spec_poison")


@dataclasses.dataclass
class Fault:
    """One scheduled fault: fires at the first opportunity at or after
    engine tick ``tick``.  ``slot`` only matters for ``"poison"`` — it
    selects the dispatched row (mod the rows actually live that tick)."""

    site: str
    tick: int = 0
    slot: int = 0
    fired_at: Optional[int] = None  # engine tick it actually fired, once

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(expected one of {SITES})")


class FaultInjector:
    """Deterministic fault schedule consumed by the engine's hooks.

    Each :class:`Fault` fires exactly once, at the first call to
    :meth:`fire` for its site once the bound clock reaches its tick —
    so the same schedule against the same workload replays the same run.
    The clock is bound by the engine at construction
    (``lambda: engine.steps_run``).
    """

    def __init__(self, schedule: Sequence[Fault],
                 clock: Optional[Callable[[], int]] = None):
        self.schedule: List[Fault] = sorted(schedule, key=lambda f: f.tick)
        self._clock = clock or (lambda: 0)
        self.fired = {site: 0 for site in SITES}

    def bind_clock(self, clock: Callable[[], int]) -> None:
        self._clock = clock

    def fire(self, site: str) -> Optional[Fault]:
        """Consume and return the earliest due, unfired fault for ``site``
        (or None).  Called *by the fault sites themselves* — a returned
        fault means "fail now"."""
        now = self._clock()
        for f in self.schedule:
            if f.fired_at is None and f.site == site and f.tick <= now:
                f.fired_at = now
                self.fired[site] += 1
                return f
        return None

    def pending(self, site: str) -> bool:
        """Any unfired fault for ``site``, due or not.  The engine uses
        this to route around paths that cannot observe the fault (e.g. the
        multi-step window has no per-row poison detection)."""
        return any(f.fired_at is None and f.site == site
                   for f in self.schedule)

    @property
    def remaining(self) -> int:
        return sum(1 for f in self.schedule if f.fired_at is None)


def random_schedule(rng, n_faults: int = 6, max_tick: int = 40,
                    sites: Sequence[str] = SITES,
                    slots: int = 4) -> List[Fault]:
    """Seeded random fault schedule for chaos runs.  ``rng`` is a
    ``numpy.random.Generator`` or an int seed."""
    if isinstance(rng, (int, np.integer)):
        rng = np.random.default_rng(int(rng))
    return [
        Fault(site=str(rng.choice(list(sites))),
              tick=int(rng.integers(0, max_tick)),
              slot=int(rng.integers(0, max(1, slots))))
        for _ in range(n_faults)
    ]


# ---------------------------------------------------------------------------
# Invariant auditor
# ---------------------------------------------------------------------------


class AuditError(AssertionError):
    """A serving invariant does not hold.  Raised by :func:`audit_engine`
    at the tick the books diverged."""


def _fail(msg: str):
    raise AuditError(msg)


def audit_engine(engine) -> None:
    """Re-derive the engine's refcount ledger from scratch and check every
    serving invariant.  O(pool + tables + index) per call — test/debug
    machinery (``ServeConfig.audit=True``), not a production hot path.

    Invariants checked:

    1. **Page conservation** — the free list and the refcount ledger
       partition the pool exactly: disjoint, and together covering every
       physical id once.  The reserved page 0 is never allocatable.
    2. **Refcount consistency** — every block's pool refcount equals the
       number of slot-table entries referencing it plus one if the radix
       index holds it.  No allocated block is referenced by nobody.
    3. **Radix reachability** — every index node hangs off its parent under
       its own token block, carries a full page of tokens, and points at an
       allocated page.  No page is indexed twice.
    4. **No orphaned slots** — an empty slot holds no request state and no
       blocks (its table row is all page 0); an occupied slot's request is
       live (non-terminal) and its blocks cover every written position.
    """
    slots = engine.scfg.slots
    # -- slot/request pairing (both cache layouts) ----------------------
    for s in range(slots):
        req = engine.slot_req[s]
        if req is None:
            if engine.slot_state[s] is not None:
                _fail(f"slot {s}: empty but state={engine.slot_state[s]!r}")
            if engine.tables is not None and engine.tables.num_blocks(s):
                _fail(f"slot {s}: empty but holds "
                      f"{engine.tables.num_blocks(s)} blocks")
        else:
            if req.done:
                _fail(f"slot {s}: terminal request uid={req.uid} "
                      f"({req.status}) still holds the slot")
    for req in engine.queue:
        if req.done:
            _fail(f"queued request uid={req.uid} is terminal "
                  f"({req.status})")

    pool = engine.pool
    if pool is None:
        return  # contiguous layout: no pages to conserve

    # -- 1. page conservation -------------------------------------------
    free = set(pool._free)
    refd = set(pool._ref)
    if len(free) != len(pool._free):
        _fail("free list holds duplicate block ids")
    if free & refd:
        _fail(f"blocks both free and referenced: {sorted(free & refd)}")
    universe = set(range(pool.base, pool.base + pool.num_blocks))
    if free | refd != universe:
        _fail(f"pool books lost blocks: missing "
              f"{sorted(universe - free - refd)}, "
              f"foreign {sorted((free | refd) - universe)}")
    if any(c <= 0 for c in pool._ref.values()):
        _fail("allocated block with non-positive refcount")

    # -- rebuild the expected ledger from tables + index ----------------
    expected: collections.Counter = collections.Counter()
    tables = engine.tables
    for s in range(slots):
        blks = tables.blocks(s)
        for blk in blks:
            expected[blk] += 1
        row = tables._np[s]
        if list(row[: len(blks)]) != blks:
            _fail(f"slot {s}: device table row diverged from block list")
        if row[len(blks):].any():
            _fail(f"slot {s}: table tail past {len(blks)} blocks not page 0")
        req = engine.slot_req[s]
        if req is not None:
            written = int(engine.pos[s])
            if written > 0 and len(blks) < blocks_for(written, pool.page_size):
                _fail(f"slot {s}: {written} written tokens exceed its "
                      f"{len(blks)} blocks")

    # -- 3. radix reachability ------------------------------------------
    if engine.prefix is not None:
        seen_pages = set()
        stack = list(engine.prefix._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd.page in seen_pages:
                _fail(f"page {nd.page} indexed twice in the radix tree")
            seen_pages.add(nd.page)
            if nd.parent.children.get(nd.token_block) is not nd:
                _fail(f"radix node for page {nd.page} unreachable from its "
                      "parent under its own token block")
            if len(nd.token_block) != pool.page_size:
                _fail(f"radix node for page {nd.page} holds "
                      f"{len(nd.token_block)} tokens, not a full page")
            if pool.refcount(nd.page) < 1:
                _fail(f"radix index points at free page {nd.page}")
            expected[nd.page] += 1

    # -- 2. refcount consistency ----------------------------------------
    for blk, want in expected.items():
        have = pool.refcount(blk)
        if have != want:
            _fail(f"block {blk}: pool refcount {have}, but tables+index "
                  f"hold {want} references")
    orphans = refd - set(expected)
    if orphans:
        _fail(f"allocated blocks referenced by no table and no index: "
              f"{sorted(orphans)}")


# ---------------------------------------------------------------------------
# Seeded chaos smoke (python -m repro.serving.faults)
# ---------------------------------------------------------------------------


def chaos_smoke(seed: int = 0, verbose: bool = True) -> dict:
    """The fixed-schedule chaos run CI executes: a small shared-prefix
    workload driven twice — fault-free, then under an injected schedule
    with the auditor on every tick — asserting the fault-tolerance
    contract end to end.  Returns a summary dict; raises on any violation.
    """
    import jax

    from repro.configs import get_config
    from repro.models import lm
    from .engine import ServeConfig, ServingEngine

    cfg = get_config("qwen2_1_5b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=8).tolist()
    prompts = [shared + rng.integers(0, cfg.vocab_size, size=n).tolist()
               for n in (3, 5, 2, 6, 4, 3)]
    kw = dict(slots=2, max_len=48, max_new_tokens=6, page_size=4,
              num_blocks=14, temperature=0.0, sync_every=4)

    def drive(injector):
        eng = ServingEngine(
            cfg, params, ServeConfig(audit=True, **kw), injector=injector)
        reqs = [eng.submit(p) for p in prompts]
        eng.run(max_steps=500)
        return eng, reqs

    _, ref_reqs = drive(None)
    # poison early: windows stay closed while a poison fault is pending
    # (per-tick detection), so the grant fault must come due after it
    schedule = [
        Fault("pool_alloc", tick=2), Fault("poison", tick=4, slot=1),
        Fault("pool_alloc", tick=6), Fault("grant", tick=7),
        Fault("pool_alloc", tick=10), Fault("table_corrupt", tick=12),
    ]
    eng, reqs = drive(FaultInjector(schedule))
    eng.drain()
    eng.shutdown()

    ref_out = {r.uid: r.output for r in ref_reqs}
    mismatched = [r.uid for r in reqs
                  if r.status == "completed" and r.output != ref_out[r.uid]]
    affected = [r.uid for r in reqs if r.status != "completed"]
    summary = {
        "seed": seed,
        "completed": sum(r.status == "completed" for r in reqs),
        "affected": len(affected),
        "mismatched": len(mismatched),
        "faults_fired": dict(eng.injector.fired),
        "poisoned_rows": eng.poisoned_rows,
        "preemptions": eng.preemptions,
        "leaked_pages": eng.pool.in_use,
        "audits_run": eng.audits_run,
        "table_corruptions": eng.table_corruptions,
        "guard_failures": eng.guard_failures,
    }
    if mismatched:
        raise AuditError(f"unaffected requests diverged: uids {mismatched}")
    if eng.table_corruptions and not eng.guard_failures:
        raise AuditError(
            f"table corruption fired but the guard caught nothing: {summary}")
    if eng.pool.in_use != 0:
        raise AuditError(
            f"shutdown leaked {eng.pool.in_use} pages: {summary}")
    if eng.injector.remaining and verbose:
        print(f"note: {eng.injector.remaining} scheduled faults never came "
              "due (run too short)")
    if verbose:
        print("chaos smoke OK:", summary)
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=0)
    chaos_smoke(seed=ap.parse_args().seed)
