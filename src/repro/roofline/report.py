"""Generate the §Dry-run and §Roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report [--mesh single_pod]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import ARCHS, get_config
from repro.launch.cells import SHAPES
from repro.roofline.analysis import (
    HW_V5E,
    RooflineTerms,
    analytic_hbm_bytes,
    chunked_attention_correction,
)

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def load(arch: str, shape: str, mesh: str) -> dict | None:
    p = DRYRUN_DIR / f"{arch}__{shape}__{mesh}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def terms_of(rec: dict, flash_attention: bool = False) -> RooflineTerms:
    coll = rec.get("collective_bytes", {})
    cfg = get_config(rec["arch"])
    cell = SHAPES[rec["shape"]]
    chips = rec.get("chips", 256)
    mesh_shape = (
        {"pod": 2, "data": 16, "model": 16}
        if rec["mesh"] == "multi_pod"
        else {"data": 16, "model": 16}
    )
    return RooflineTerms(
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        flops=rec.get("flops", 0.0),
        hbm_bytes=rec.get("bytes_accessed", 0.0),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=rec.get("model_flops", 0.0),
        chips=chips,
        flop_correction=chunked_attention_correction(cfg, cell, chips),
        analytic_bytes=analytic_hbm_bytes(cfg, cell, mesh_shape,
                                          flash_attention=flash_attention),
    )


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def dryrun_table(mesh: str) -> str:
    lines = [
        "| arch | shape | status | GiB/chip | compile | collectives (counts) |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load(arch, shape, mesh)
            if rec is None:
                lines.append(f"| {arch} | {shape} | *pending* | | | |")
                continue
            if rec["status"] == "skipped":
                lines.append(
                    f"| {arch} | {shape} | skip | — | — | {rec['reason'][:48]} |"
                )
                continue
            if rec["status"] == "error":
                lines.append(
                    f"| {arch} | {shape} | **FAIL** | — | — | {rec.get('error','')[:60]} |"
                )
                continue
            gib = rec.get("per_chip_bytes", 0) / 2**30
            counts = rec.get("collective_counts", {})
            cstr = " ".join(
                f"{k.split('-')[-1][:4]}:{v}" for k, v in counts.items() if v
            )
            fits = "" if rec.get("fits_16gib") else " ⚠"
            lines.append(
                f"| {arch} | {shape} | ok | {gib:.2f}{fits} | "
                f"{rec.get('compile_s', 0):.0f}s | {cstr} |"
            )
    return "\n".join(lines)


def roofline_table(mesh: str) -> str:
    lines = [
        "| arch | shape | compute | memory (fused est / unfused UB) | "
        "collective | dominant | useful frac | MFU@roofline |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load(arch, shape, mesh)
            if rec is None or rec["status"] != "ok":
                continue
            t = terms_of(rec)
            lines.append(
                f"| {arch} | {shape} | {fmt_s(t.compute_s)} | "
                f"{fmt_s(t.memory_s)} / {fmt_s(t.memory_ub_s)} | "
                f"{fmt_s(t.collective_s)} | **{t.dominant}** | "
                f"{t.useful_fraction:.0%} | {t.mfu:.1%} |"
            )
    return "\n".join(lines)


def pick_hillclimb(mesh: str = "single_pod"):
    """The three §Perf cells: worst MFU, most collective-bound, and the one
    most representative of the paper (deepseek-v2 MLA decode)."""
    rows = []
    for arch in ARCHS:
        for shape in SHAPES:
            rec = load(arch, shape, mesh)
            if rec and rec["status"] == "ok":
                rows.append(terms_of(rec))
    if not rows:
        return []
    worst_mfu = min((r for r in rows if r.shape == "train_4k"), key=lambda r: r.mfu,
                    default=min(rows, key=lambda r: r.mfu))
    coll = max(rows, key=lambda r: r.collective_s / max(r.step_s, 1e-12))
    mla = next((r for r in rows if r.arch == "deepseek_v2_lite_16b"), rows[0])
    return [worst_mfu, coll, mla]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod"])
    args = ap.parse_args()
    print(f"## Dry-run ({args.mesh})\n")
    print(dryrun_table(args.mesh))
    print(f"\n## Roofline ({args.mesh})\n")
    print(roofline_table(args.mesh))
    picks = pick_hillclimb(args.mesh)
    if picks:
        print("\nhillclimb picks:",
              ", ".join(f"{t.arch}×{t.shape} ({t.dominant}, mfu {t.mfu:.1%})" for t in picks))


if __name__ == "__main__":
    main()
