"""Three-term roofline analysis from compiled dry-run artifacts.

    compute term    = HLO_FLOPs    / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes    / HBM_bw               (per chip)
    collective term = collective_bytes / ICI link bw      (per chip)

``compiled.cost_analysis()`` supplies FLOPs and bytes of the *partitioned*
(per-device) program.  Collective bytes are not in cost_analysis — we parse
the post-SPMD optimized HLO (``compiled.as_text()``) and sum the result
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI, 16 GiB HBM per chip.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

HW_V5E = {
    "peak_flops_bf16": 197e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,  # per link, one direction
    "hbm_bytes": 16 * 1024**3,
    "vmem_bytes": 128 * 1024**2,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.3 = bf16[8,128,3072]{2,1,0} all-gather(...)
_RE_INSTR = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
# tuple-result collectives:  = (f32[...], f32[...]) all-reduce(...)
_RE_TUPLE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    if not dims:
        return nb
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nb


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result sizes of collective instructions, keyed by op kind.

    ``-start`` instructions are counted; their matching ``-done`` is skipped
    (same tensor).  Result size is the natural "traffic unit": for
    all-gather it is the gathered (full) tensor, for reduce-scatter the
    scattered shard, for all-reduce the reduced tensor.
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # counted at -start
        m = _RE_INSTR.search(line)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            continue
        m = _RE_TUPLE.search(line)
        if m:
            shapes, kind = m.groups()
            for sm in _RE_SHAPE.finditer(shapes):
                out[kind] += _shape_bytes(*sm.groups())
            counts[kind] += 1
    out["_instruction_counts"] = counts  # type: ignore[assignment]
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device HLO bytes accessed (UNFUSED upper bound)
    coll_bytes: float  # per-device collective traffic
    coll_breakdown: Dict[str, int]
    model_flops: float  # 6*N*D useful flops (global)
    chips: int
    flop_correction: float = 0.0  # chunked-attention loop-body undercount
    analytic_bytes: float = 0.0  # fusion-aware HBM estimate (0 = unavailable)
    peak_flops: float = HW_V5E["peak_flops_bf16"]
    hbm_bw: float = HW_V5E["hbm_bw"]
    ici_bw: float = HW_V5E["ici_bw"]

    @property
    def compute_s(self) -> float:
        return (self.flops + self.flop_correction) / self.peak_flops

    @property
    def memory_ub_s(self) -> float:
        """Unfused upper bound (raw HLO bytes accessed)."""
        return self.hbm_bytes / self.hbm_bw

    @property
    def memory_s(self) -> float:
        """Memory term: the fusion-aware analytic estimate when available
        (the TPU backend fuses elementwise chains the CPU-side cost
        analysis counts), else the unfused bound."""
        if self.analytic_bytes > 0:
            return self.analytic_bytes / self.hbm_bw
        return self.memory_ub_s

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time: dominant term (others assumed overlapped)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): how much compiled compute is
        'useful' (catches remat / dispatch / padding waste)."""
        total = (self.flops + self.flop_correction) * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        denom = self.step_s * self.peak_flops * self.chips
        return self.model_flops / denom if denom else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_per_dev": self.flops,
            "useful_fraction": self.useful_fraction,
            "mfu_at_roofline": self.mfu,
        }


def attention_flops(cfg, cell, passes: int) -> float:
    """O(S^2) attention FLOPs (qk + pv), causal halved, windows clipped."""
    if not cfg.attends:
        return 0.0
    h, hd, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    s = cell.seq
    if cfg.sliding_window:
        # all-but-3 layers see only `window` keys (hybrid global layers full)
        w = cfg.sliding_window
        per_tok = min(w, s)
        full_layers = 3 if cfg.family == "hybrid" else 0
        win_layers = L - full_layers
        att = cell.batch * h * hd * 2 * 2 * (
            win_layers * s * per_tok + full_layers * (s * s // 2)
        )
    else:
        att = cell.batch * L * h * (s * s // 2) * hd * 2 * 2
    return float(att * passes)


def model_flops(cfg, cell) -> float:
    """Useful model FLOPs for the cell: 6*N*D train, 2*N*D per forward token
    (N = active params for MoE), plus attention score/value FLOPs."""
    n_active = cfg.param_count(active_only=True)
    tokens = cell.batch * cell.seq if cell.kind in ("train", "prefill") else cell.batch
    mult = 6 if cell.kind == "train" else 2
    base = mult * n_active * tokens
    if cell.kind in ("train", "prefill"):
        base += attention_flops(cfg, cell, 3 if cell.kind == "train" else 1)
    return float(base)


def chunked_attention_correction(cfg, cell, chips: int) -> float:
    """Per-device FLOPs that HLO cost analysis misses when the XLA attention
    path streams query chunks through a lax.map (while-loop bodies are
    counted once): (nq-1)/nq of the attention FLOPs."""
    from repro.kernels.ref import CHUNKED_THRESHOLD, Q_CHUNK

    if cell.kind not in ("train", "prefill") or not cfg.attends:
        return 0.0
    s = cell.seq
    if s < CHUNKED_THRESHOLD or s % Q_CHUNK:
        return 0.0
    nq = s // Q_CHUNK
    passes = 3 if cell.kind == "train" else 1
    missing = attention_flops(cfg, cell, passes) * (nq - 1) / nq
    return missing / chips


# ---------------------------------------------------------------------------
# Analytic (fusion-aware) HBM traffic model.
#
# XLA's cost_analysis "bytes accessed" counts every instruction's operands
# and outputs — an UNFUSED upper bound (the TPU backend fuses elementwise
# chains into their producers).  For the roofline's memory term we also
# compute an analytic estimate of the fused traffic:
#
#   params     : read in fwd + read in bwd (+ grad write)          [train]
#   optimizer  : ZeRO-1 masters/moments, 3 reads + 3 writes f32    [train]
#   activations: ~6 residual-width + 2 ffn-width values moved per
#                token-layer in fwd; x4 for fwd+remat-recompute+bwd [train]
#   attention  : the S^2 score tensor spills to HBM on the XLA path
#                (~4 passes); the Pallas flash kernel keeps it in VMEM
#                — `flash_attention=True` removes this term.
#   kv/state   : decode reads the entire cache once per token.
# ---------------------------------------------------------------------------


def analytic_hbm_bytes(cfg, cell, mesh_shape: Dict[str, int],
                       flash_attention: bool = False) -> float:
    tp = mesh_shape.get("model", 1)
    dp = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    chips = tp * dp
    p_total = cfg.param_count()
    p_active = cfg.param_count(active_only=True)
    bytes_param = 2  # bf16
    tokens_local = cell.batch * cell.seq / dp if cell.kind in ("train", "prefill") else cell.batch / min(dp, cell.batch)

    total = 0.0
    if cell.kind == "train":
        total += 2 * p_total / tp * bytes_param * 2  # fwd + bwd weight reads
        total += p_total / tp * bytes_param  # grad write (bf16 wire)
        total += 6 * 4 * p_total / chips  # ZeRO-1: r/w master+m+v f32
    else:
        # inference touches only active params (MoE skips unrouted experts)
        total += p_active / tp * bytes_param

    d, f = cfg.d_model, cfg.d_ff or (cfg.moe.d_ff_expert * cfg.moe.experts_per_token if cfg.moe else 0)
    L = cfg.num_layers
    passes = 4 if cell.kind == "train" else 1
    # ~6 residual-width + 2 ffn-width values per token-layer, tp-sharded
    total += passes * L * tokens_local * (6 * d + 2 * f) / max(tp, 1) * bytes_param

    if cfg.attends and not flash_attention and cell.kind in ("train", "prefill"):
        s = cell.seq
        h = cfg.num_heads
        b_loc = max(cell.batch / dp, 1)
        keys = min(cfg.sliding_window or s, s)
        att_passes = 4 if cell.kind == "train" else 2
        if h % tp == 0:  # heads shard over `model`
            h_loc, s_loc = h / tp, s
        else:  # seq-shard fallback (make_hints)
            h_loc, s_loc = h, s / tp
        total += att_passes * L * b_loc * h_loc * s_loc * keys * 4  # f32 scores

    if cell.kind == "decode":
        # read the full KV/state cache once per token
        if cfg.attention == "gqa":
            per_layer = cfg.num_kv_heads * cfg.head_dim * 2 * bytes_param
            sizes = []
            for i in range(L):
                wdw = cfg.window_for_layer(i)
                if cfg.family == "hybrid" and i in (0, L // 2, L - 1):
                    wdw = None
                sizes.append(min(wdw or cell.seq, cell.seq))
            total += cell.batch * per_layer * sum(sizes) / chips * dp  # sharded over chips
        elif cfg.attention == "mla":
            m = cfg.mla
            total += cell.batch * L * cell.seq * (m.kv_lora_rank + m.qk_rope_head_dim) * bytes_param / tp
        if cfg.ssm is not None:
            nh = cfg.ssm.num_heads(d)
            total += cell.batch * L * nh * cfg.ssm.state_dim * cfg.ssm.head_dim * 4 / tp
    return float(total)


def roofline_from_compiled(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    cfg,
    cell,
) -> RooflineTerms:
    coll = collective_bytes(hlo_text)
    counts = coll.pop("_instruction_counts", {})
    total_coll = float(sum(v for v in coll.values()))
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_bytes=total_coll,
        coll_breakdown={**coll, "counts": counts},
        model_flops=model_flops(cfg, cell),
        chips=chips,
    )
