from .analysis import (
    HW_V5E,
    RooflineTerms,
    collective_bytes,
    model_flops,
    roofline_from_compiled,
)

__all__ = [
    "HW_V5E",
    "RooflineTerms",
    "collective_bytes",
    "model_flops",
    "roofline_from_compiled",
]
