"""Sharding-aware checkpointing: atomic, versioned, async-capable.

Layout:
    <dir>/step_00000042/
        manifest.json      {step, keys, shapes, dtypes, complete: true}
        000000.npy ...     one file per pytree leaf (path-keyed order)

Atomicity: leaves are written into ``step_X.tmp`` and the directory is
renamed only after the manifest (with ``complete=true``) is flushed — a
crashed writer leaves a ``.tmp`` that restore ignores.  Restart picks the
newest complete manifest (``latest_step``).  On restore, leaves are
``device_put`` against the *current* mesh's shardings, which is what makes
elastic re-meshing (distributed.fault.elastic_remesh) a pure restore-time
decision.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def save(state, step: int, directory: str | Path, keep: Optional[int] = None):
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f"step_{step:08d}.tmp"
    final = directory / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves = _flatten_with_paths(state)
    manifest: Dict[str, Any] = {"step": step, "keys": [], "complete": False}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"{i:06d}.npy", arr)
        manifest["keys"].append(
            {"key": key, "file": f"{i:06d}.npy", "shape": list(arr.shape),
             "dtype": str(arr.dtype)}
        )
    manifest["complete"] = True
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    if keep:
        steps = sorted(p for p in directory.glob("step_????????") if p.is_dir())
        for p in steps[:-keep]:
            shutil.rmtree(p)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    best = None
    for p in sorted(directory.glob("step_????????")):
        man = p / "manifest.json"
        if man.exists():
            try:
                m = json.loads(man.read_text())
                if m.get("complete"):
                    best = m["step"]
            except (json.JSONDecodeError, KeyError):
                continue
    return best


def restore(state_like, step: int, directory: str | Path, shardings=None):
    """Load step into the structure of ``state_like`` (shapes validated).

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    placed directly onto the (possibly different) current mesh.
    """
    directory = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((directory / "manifest.json").read_text())
    if not manifest.get("complete"):
        raise ValueError(f"checkpoint at {directory} is incomplete")
    paths = _flatten_with_paths(state_like)
    by_key = {e["key"]: e for e in manifest["keys"]}
    flat_shardings = (
        [s for _, s in _flatten_with_paths(shardings)] if shardings is not None
        else [None] * len(paths)
    )
    leaves_out = []
    for (key, like), shard in zip(paths, flat_shardings):
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(directory / entry["file"])
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {like.shape}"
            )
        arr = arr.astype(like.dtype)
        leaves_out.append(
            jax.device_put(arr, shard) if shard is not None else arr
        )
    treedef = jax.tree_util.tree_structure(state_like)
    return jax.tree_util.tree_unflatten(treedef, leaves_out)


class CheckpointManager:
    """Periodic async checkpointing + restart bookkeeping."""

    def __init__(self, directory: str | Path, interval: int = 100,
                 keep: int = 3, async_save: bool = True):
        self.directory = Path(directory)
        self.interval = interval
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    def maybe_save(self, state, step: int, force: bool = False):
        if not force and (self.interval <= 0 or step % self.interval != 0):
            return False
        self.wait()  # one in-flight save at a time
        # snapshot to host NOW so training can mutate freely afterwards
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        if self.async_save:
            self._thread = threading.Thread(
                target=save, args=(host_state, step, self.directory, self.keep),
                daemon=True,
            )
            self._thread.start()
        else:
            save(host_state, step, self.directory, self.keep)
        return True

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def latest(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore(self, state_like, shardings=None, step: Optional[int] = None):
        step = step if step is not None else self.latest()
        if step is None:
            return None
        return restore(state_like, step, self.directory, shardings)
