"""Pure-jnp oracles for every tile-DSL kernel (paper §5 workloads).

These are the ground truth the Pallas lowerings are validated against
(``interpret=True`` on CPU), and double as the XLA execution path used by
the model layer when ``kernel_backend="xla"`` (the dry-run path).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# GEMM
# ---------------------------------------------------------------------------


def matmul(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ).astype(out_dtype)


# ---------------------------------------------------------------------------
# Weight dequantization (paper Fig. 15/17): packed sub-byte -> compute dtype.
# Weights are packed along the last axis: int4 -> 2 values/byte,
# int2 -> 4 values/byte.  NF4 uses the bitsandbytes codebook.
# ---------------------------------------------------------------------------

NF4_CODEBOOK = np.array(
    [
        -1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
        -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
        0.07958029955625534, 0.16093020141124725, 0.24611230194568634, 0.33791524171829224,
        0.44070982933044434, 0.5626170039176941, 0.7229568362236023, 1.0,
    ],
    dtype=np.float32,
)


def unpack_int4(packed: jax.Array, signed: bool = True) -> jax.Array:
    """(..., K//2) int8 -> (..., K) int8 values in [-8, 7] (or [0, 15])."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    vals = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    if signed:
        vals = jnp.where(vals >= 8, vals - 16, vals)
    return vals.astype(jnp.int8)


def unpack_int2(packed: jax.Array, signed: bool = True) -> jax.Array:
    """(..., K//4) int8 -> (..., K) int8 values in [-2, 1] (or [0, 3])."""
    parts = [(packed >> (2 * i)) & 0x3 for i in range(4)]
    vals = jnp.stack(parts, axis=-1).reshape(*packed.shape[:-1], -1)
    if signed:
        vals = jnp.where(vals >= 2, vals - 4, vals)
    return vals.astype(jnp.int8)


def unpack_nf4(packed: jax.Array) -> jax.Array:
    """(..., K//2) uint8-packed NF4 -> (..., K) float32 codebook values."""
    lo = packed & 0xF
    hi = (packed >> 4) & 0xF
    idx = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)
    return jnp.asarray(NF4_CODEBOOK)[idx]


def dequant_matmul(
    a: jax.Array,
    b_packed: jax.Array,
    fmt: str = "int4",
    scales: Optional[jax.Array] = None,
    group_size: int = 128,
    out_dtype=jnp.float32,
) -> jax.Array:
    """A[M,K] @ dequant(B_packed)[N,K]^T -> [M,N].

    B is stored N-major with the K axis packed (weight-only quantization,
    the W_{INTx}A_{FP16} layout of the paper).  ``scales`` is (N, K//group)
    per-group scaling.
    """
    if fmt == "int4":
        w = unpack_int4(b_packed).astype(jnp.float32)
    elif fmt == "int2":
        w = unpack_int2(b_packed).astype(jnp.float32)
    elif fmt == "nf4":
        w = unpack_nf4(b_packed)
    elif fmt == "int8":
        w = b_packed.astype(jnp.float32)
    else:
        raise ValueError(f"unknown dequant format {fmt}")
    if scales is not None:
        n, k = w.shape
        w = w.reshape(n, k // group_size, group_size) * scales[..., None].astype(
            jnp.float32
        )
        w = w.reshape(n, k)
    acc = jax.lax.dot_general(
        a.astype(jnp.float32),
        w,
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# KV-cache quantization: symmetric per-row (per-token) scales, packed along
# the feature axis.  The storage layout of the quantized paged pools
# (DESIGN.md §5.6): packed int8 data + a (rows, 1) scale column per page.
# ---------------------------------------------------------------------------

KV_QMAX = {"int8": 127.0, "int4": 7.0}
KV_PACK = {"int8": 1, "int4": 2}


def pack_int4(vals: jax.Array) -> jax.Array:
    """(..., K) int8 in [-8, 7] -> (..., K//2) int8, low nibble first
    (the byte order unpack_int4 and the kernel unpack loop expect)."""
    lo = vals[..., 0::2].astype(jnp.int32) & 0xF
    hi = vals[..., 1::2].astype(jnp.int32) & 0xF
    return jax.lax.bitcast_convert_type((lo | (hi << 4)).astype(jnp.uint8), jnp.int8)


def quantize_rows(x: jax.Array, fmt: str = "int8"):
    """Symmetric per-row quantization over the last axis.

    Returns ``(packed, scales)``: packed int8 data (last axis divided by the
    pack factor) and (..., 1) scales in ``x``'s dtype.  All-zero rows get
    scale 1 so dequantization stays exact (0 * 1 = 0).
    """
    qmax = KV_QMAX[fmt]
    xf = jnp.asarray(x).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / qmax, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -qmax, qmax).astype(jnp.int8)
    if fmt == "int4":
        q = pack_int4(q)
    return q, scale.astype(jnp.asarray(x).dtype)


def dequantize_rows(packed: jax.Array, scales: jax.Array, fmt: str = "int8") -> jax.Array:
    """Inverse of :func:`quantize_rows` -> float32."""
    vals = unpack_int4(packed) if fmt == "int4" else packed
    return vals.astype(jnp.float32) * scales.astype(jnp.float32)


def paged_attention_quant(
    q: jax.Array,  # (B, Hq, D)
    k_pages: jax.Array,  # (Hkv, P, page_size, D//pack) packed int8
    v_pages: jax.Array,
    k_scales: jax.Array,  # (Hkv, P, page_size, 1)
    v_scales: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    fmt: str = "int8",
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    out_dtype=None,
) -> jax.Array:
    """Quantized paged-decode oracle: dequantize the pools, then the fp
    oracle — the composition the tile kernel performs page-at-a-time."""
    kf = dequantize_rows(k_pages, k_scales, fmt).astype(q.dtype)
    vf = dequantize_rows(v_pages, v_scales, fmt).astype(q.dtype)
    return paged_attention(q, kf, vf, block_tables, seq_lens, sm_scale=sm_scale,
                           window=window, logit_soft_cap=logit_soft_cap,
                           out_dtype=out_dtype)


def mla_paged_quant(
    q_lat: jax.Array,  # (B, H, R)
    q_pe: jax.Array,
    ckv_pages: jax.Array,  # (P, page_size, R//pack) packed int8
    kpe_pages: jax.Array,  # (P, page_size, Dpe//pack) packed int8
    ckv_scales: jax.Array,  # (P, page_size, 1)
    kpe_scales: jax.Array,
    block_tables: jax.Array,
    seq_lens: jax.Array,
    fmt: str = "int8",
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    out_dtype=None,
) -> jax.Array:
    """Quantized paged MLA decode oracle (latent + rope pools both packed)."""
    ckv = dequantize_rows(ckv_pages, ckv_scales, fmt).astype(q_lat.dtype)
    kpe = dequantize_rows(kpe_pages, kpe_scales, fmt).astype(q_lat.dtype)
    return mla_paged(q_lat, q_pe, ckv, kpe, block_tables, seq_lens,
                     sm_scale=sm_scale, window=window,
                     logit_soft_cap=logit_soft_cap, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# FlashAttention (MHA/GQA, optional causal) — paper Table 3
# ---------------------------------------------------------------------------


def _attn_block(q, k, v, q_offset, sk_total, causal, sm_scale, logit_soft_cap,
                kv_len, window):
    """Attention for a block of queries at absolute offset ``q_offset``."""
    sq = q.shape[2]
    sk = k.shape[2]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * sm_scale
    if logit_soft_cap is not None:
        s = logit_soft_cap * jnp.tanh(s / logit_soft_cap)
    mask = None
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    if causal:
        mask = qi >= ki
    if window is not None:
        wmask = (qi - ki) < window
        mask = wmask if mask is None else (mask & wmask)
    if kv_len is not None:
        lmask = (ki < kv_len[:, None])[:, None, None, :]
        s = jnp.where(lmask, s, -jnp.inf)
    if mask is not None:
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


# query-chunk size above which the S^2 logits tensor is streamed through a
# lax.map (bounds peak memory for long-context prefill)
CHUNKED_THRESHOLD = 8192
Q_CHUNK = 512


def attention(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Sk, D)
    v: jax.Array,  # (B, Hkv, Sk, D)
    causal: bool = False,
    sm_scale: Optional[float] = None,
    logit_soft_cap: Optional[float] = None,
    kv_len: Optional[jax.Array] = None,
    window: Optional[int] = None,
    out_dtype=None,
    q_chunk: Optional[int] = None,
) -> jax.Array:
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    sk = k.shape[2]
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(d)
    if hq != hkv:
        assert hq % hkv == 0
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    off = sk - sq  # query absolute offset (suffix convention)
    chunk = q_chunk or (Q_CHUNK if sq >= CHUNKED_THRESHOLD else None)
    if chunk is not None and sq % chunk == 0 and sq > chunk:
        nq = sq // chunk

        def chunk_fn(i):
            qs = jax.lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=2)
            return _attn_block(
                qs, k, v, i * chunk + off, sk, causal, sm_scale,
                logit_soft_cap, kv_len, window,
            )

        out = jax.lax.map(chunk_fn, jnp.arange(nq))  # (nq, b, h, chunk, dv)
        # note: dv (v head dim) can differ from d (q/k dim), e.g. MLA
        out = jnp.moveaxis(out, 0, 2).reshape(b, hq, sq, v.shape[-1])
    else:
        out = _attn_block(
            q, k, v, off, sk, causal, sm_scale, logit_soft_cap, kv_len, window
        )
    return out.astype(out_dtype or q.dtype)


# ---------------------------------------------------------------------------
# Paged attention (vLLM-style): single-token decode over a paged KV pool.
# The oracle for kernels/paged_attention.py and the XLA execution path the
# serving engine uses on CPU hosts.
# ---------------------------------------------------------------------------


def paged_attention(
    q: jax.Array,  # (B, Hq, D) one query token per slot
    k_pages: jax.Array,  # (Hkv, P, page_size, D) physical page pool
    v_pages: jax.Array,  # (Hkv, P, page_size, D)
    block_tables: jax.Array,  # (B, max_pages) int32 physical page ids
    seq_lens: jax.Array,  # (B,) int32 live length per slot (0 = empty)
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    out_dtype=None,
) -> jax.Array:
    b, hq, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(d)
    # gather each slot's pages: (Hkv, B, max_pages, page_size, D) -> (B, Hkv, S, D)
    def gathered(pages):
        g = pages[:, block_tables]
        g = jnp.moveaxis(g, 0, 1)
        return g.reshape(b, hkv, -1, d)

    k = gathered(k_pages).astype(jnp.float32)
    v = gathered(v_pages).astype(jnp.float32)
    s_total = k.shape[2]
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    # scale first, then cap — the same order as attention()'s _attn_block,
    # so paged and contiguous decode stay token-identical for capped models
    scores = jnp.einsum("bhgd,bhsd->bhgs", qg, k) * sm_scale
    if logit_soft_cap is not None:
        scores = logit_soft_cap * jnp.tanh(scores / logit_soft_cap)
    ki = jnp.arange(s_total, dtype=jnp.int32)
    lens = jnp.asarray(seq_lens, jnp.int32)
    mask = ki[None, :] < lens[:, None]  # (B, S)
    if window is not None:
        mask = mask & (ki[None, :] >= (lens[:, None] - window))
    mask4 = mask[:, None, None, :]
    # masked, empty-row-safe softmax (slots with len 0 emit zeros)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask4, scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask4
    den = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    p = e / den
    out = jnp.einsum("bhgs,bhsd->bhgd", p, v)
    return out.reshape(b, hq, d).astype(out_dtype or q.dtype)


# ---------------------------------------------------------------------------
# Chunked-prefill attention: a block of C new tokens per slot attends prior
# context (gathered pages or a contiguous/ring strip) plus itself causally.
# The oracle for kernels/prefill_attention.py and the XLA execution path the
# serving engine's chunked-prefill fast path uses on CPU hosts.
# ---------------------------------------------------------------------------


def prefill_attention(
    q: jax.Array,  # (B, Hq, C, D) chunk queries
    k_new: jax.Array,  # (B, Hkv, C, D) the chunk's own keys
    v_new: jax.Array,  # (B, Hkv, C, D)
    k_ctx: jax.Array,  # (B, Hkv, S, D) prior context keys
    v_ctx: jax.Array,  # (B, Hkv, S, D)
    ctx_pos: jax.Array,  # (B, S) int32 absolute position per ctx entry; -1 = dead
    q_pos: jax.Array,  # (B, C) int32 absolute position per query
    chunk_lens: jax.Array,  # (B,) live tokens in the chunk (0 = inactive slot)
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    out_dtype=None,
) -> jax.Array:
    """Masked two-part attention: ``softmax([scores_ctx ; scores_new])``.

    Context validity/causality/windowing all derive from ``ctx_pos`` so one
    oracle serves every prior-KV layout: gathered pages (position = linear
    gather index), contiguous strips (position = index) and ring buffers
    (position from the ring decode formula).  Query rows past
    ``chunk_lens`` are *not* zeroed — they still attend whatever keys their
    causal window allows (garbage the callers discard; the kernel behaves
    identically) — but a row with no valid key at all (an inactive slot
    with empty context) emits zeros, not nan.
    """
    b, hq, c, d = q.shape
    hkv = k_new.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, group, c, d).astype(jnp.float32)

    def scores_of(k):
        s = jnp.einsum("bhgcd,bhsd->bhgcs", qg, k.astype(jnp.float32)) * sm_scale
        if logit_soft_cap is not None:
            s = logit_soft_cap * jnp.tanh(s / logit_soft_cap)
        return s

    s_ctx = scores_of(k_ctx)  # (B, Hkv, G, C, S)
    s_new = scores_of(k_new)  # (B, Hkv, G, C, C)
    qp = jnp.asarray(q_pos, jnp.int32)
    cp = jnp.asarray(ctx_pos, jnp.int32)
    lens = jnp.asarray(chunk_lens, jnp.int32)
    m_ctx = (cp[:, None, :] >= 0) & (cp[:, None, :] <= qp[:, :, None])
    ci = jnp.arange(c, dtype=jnp.int32)
    m_new = (ci[None, None, :] <= ci[None, :, None]) & (
        ci[None, None, :] < lens[:, None, None]
    )
    if window is not None:
        m_ctx = m_ctx & ((qp[:, :, None] - cp[:, None, :]) < window)
        m_new = m_new & ((ci[None, :, None] - ci[None, None, :]) < window)
    mask = jnp.concatenate(
        [
            jnp.broadcast_to(m_ctx, (b, c, s_ctx.shape[-1])),
            jnp.broadcast_to(m_new, (b, c, c)),
        ],
        axis=-1,
    )[:, None, None]  # (B, 1, 1, C, S+C)
    scores = jnp.concatenate([s_ctx, s_new], axis=-1)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask, scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask
    den = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    p = e / den
    v_all = jnp.concatenate(
        [v_ctx.astype(jnp.float32), v_new.astype(jnp.float32)], axis=2
    )
    out = jnp.einsum("bhgcs,bhsd->bhgcd", p, v_all)
    return out.reshape(b, hq, c, d).astype(out_dtype or q.dtype)


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (paper Fig. 14/18): queries attend to a shared
# latent KV (dim) + rotary part (pe_dim); V is the latent itself.
# ---------------------------------------------------------------------------


def mla_masked(
    q_lat: jax.Array,  # (B, H, R) absorbed latent queries
    q_pe: jax.Array,  # (B, H, Dpe)
    c_kv: jax.Array,  # (B, S, R) latent cache
    k_pe: jax.Array,  # (B, S, Dpe)
    kv_len: jax.Array,  # (B,) or scalar live length per slot
    sm_scale: float,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
) -> jax.Array:
    """Latent-space MLA decode attention with a length mask — the single
    oracle both latent layouts share: the contiguous decode path feeds the
    per-slot strip, :func:`mla_paged` the page gather.  Returns the float32
    latent output (B, H, R) (callers expand through W_uv)."""
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32))
        + jnp.einsum("bhp,bsp->bhs", q_pe.astype(jnp.float32), k_pe.astype(jnp.float32))
    ) * sm_scale
    # scale first, then cap — the same order as attention()'s _attn_block,
    # so latent and standard attention stay token-identical for capped models
    if logit_soft_cap is not None:
        scores = logit_soft_cap * jnp.tanh(scores / logit_soft_cap)
    kv_len = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (scores.shape[0],))
    ki = jnp.arange(c_kv.shape[1], dtype=jnp.int32)
    mask = ki[None, None, :] < kv_len[:, None, None]
    if window is not None:
        mask = mask & (ki[None, None, :] >= (kv_len[:, None, None] - window))
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bsr->bhr", p, c_kv.astype(jnp.float32))


def mla_paged(
    q_lat: jax.Array,  # (B, H, R)
    q_pe: jax.Array,  # (B, H, Dpe)
    ckv_pages: jax.Array,  # (P, page_size, R) latent page pool
    kpe_pages: jax.Array,  # (P, page_size, Dpe)
    block_tables: jax.Array,  # (B, max_pages) int32 physical page ids
    seq_lens: jax.Array,  # (B,) int32 live length per slot
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    out_dtype=None,
) -> jax.Array:
    """Paged MLA decode oracle: gather each slot's latent/rope pages through
    its block table, then the shared masked latent attention.  Because the
    gather reconstructs logical token order, outputs are identical to the
    contiguous strip path — the property the serving equivalence tests pin."""
    b, h, r = q_lat.shape
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(r + q_pe.shape[-1])
    ckv = ckv_pages[block_tables].reshape(b, -1, r)
    kpe = kpe_pages[block_tables].reshape(b, -1, kpe_pages.shape[-1])
    out = mla_masked(q_lat, q_pe, ckv, kpe, seq_lens, sm_scale,
                     window=window, logit_soft_cap=logit_soft_cap)
    return out.astype(out_dtype or q_lat.dtype)


def mla_prefill(
    q_lat: jax.Array,  # (B, H, C, R) absorbed chunk queries
    q_pe: jax.Array,  # (B, H, C, Dpe)
    ckv_new: jax.Array,  # (B, C, R) the chunk's own latents
    kpe_new: jax.Array,  # (B, C, Dpe)
    ckv_ctx: jax.Array,  # (B, S, R) prior latent context
    kpe_ctx: jax.Array,  # (B, S, Dpe)
    ctx_pos: jax.Array,  # (B, S) int32 absolute position per ctx entry; -1 = dead
    q_pos: jax.Array,  # (B, C) int32 absolute position per query
    chunk_lens: jax.Array,  # (B,) live tokens in the chunk (0 = inactive slot)
    sm_scale: Optional[float] = None,
    window: Optional[int] = None,
    logit_soft_cap: Optional[float] = None,
    out_dtype=None,
) -> jax.Array:
    """MLA chunked-prefill oracle: masked two-part latent attention
    ``softmax([scores_ctx ; scores_new])`` — prefill_attention's structure
    with the latent+rope score split and the latent as V.  Same row
    semantics: rows past ``chunk_lens`` attend what causality allows
    (garbage the callers discard); rows with no valid key emit zeros."""
    b, h, c, r = q_lat.shape
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(r + q_pe.shape[-1])
    qf = q_lat.astype(jnp.float32)
    qpef = q_pe.astype(jnp.float32)

    def scores_of(kv, pe):
        s = (
            jnp.einsum("bhcr,bsr->bhcs", qf, kv.astype(jnp.float32))
            + jnp.einsum("bhcp,bsp->bhcs", qpef, pe.astype(jnp.float32))
        ) * sm_scale
        if logit_soft_cap is not None:
            s = logit_soft_cap * jnp.tanh(s / logit_soft_cap)
        return s

    s_ctx = scores_of(ckv_ctx, kpe_ctx)  # (B, H, C, S)
    s_new = scores_of(ckv_new, kpe_new)  # (B, H, C, C)
    qp = jnp.asarray(q_pos, jnp.int32)
    cp = jnp.asarray(ctx_pos, jnp.int32)
    lens = jnp.asarray(chunk_lens, jnp.int32)
    m_ctx = (cp[:, None, :] >= 0) & (cp[:, None, :] <= qp[:, :, None])
    ci = jnp.arange(c, dtype=jnp.int32)
    m_new = (ci[None, None, :] <= ci[None, :, None]) & (
        ci[None, None, :] < lens[:, None, None]
    )
    if window is not None:
        m_ctx = m_ctx & ((qp[:, :, None] - cp[:, None, :]) < window)
        m_new = m_new & ((ci[None, :, None] - ci[None, None, :]) < window)
    mask = jnp.concatenate(
        [
            jnp.broadcast_to(m_ctx, (b, c, s_ctx.shape[-1])),
            jnp.broadcast_to(m_new, (b, c, c)),
        ],
        axis=-1,
    )[:, None]  # (B, 1, C, S+C)
    scores = jnp.concatenate([s_ctx, s_new], axis=-1)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask, scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m) * mask
    den = jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    p = e / den
    v_all = jnp.concatenate(
        [ckv_ctx.astype(jnp.float32), ckv_new.astype(jnp.float32)], axis=1
    )
    out = jnp.einsum("bhcs,bsr->bhcr", p, v_all)
    return out.astype(out_dtype or q_lat.dtype)


def mla(
    q: jax.Array,  # (B, Hq, D)
    q_pe: jax.Array,  # (B, Hq, Dpe)
    kv: jax.Array,  # (B, S, Hkv, D)
    k_pe: jax.Array,  # (B, S, Hkv, Dpe)
    sm_scale: Optional[float] = None,
    out_dtype=None,
) -> jax.Array:
    b, hq, d = q.shape
    s_len = kv.shape[1]
    hkv = kv.shape[2]
    group = hq // hkv
    if sm_scale is None:
        sm_scale = 1.0 / np.sqrt(d + q_pe.shape[-1])
    qg = q.reshape(b, hkv, group, d).astype(jnp.float32)
    qpeg = q_pe.reshape(b, hkv, group, -1).astype(jnp.float32)
    kvf = kv.astype(jnp.float32)
    kpef = k_pe.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qg, kvf)
    scores += jnp.einsum("bhgp,bshp->bhgs", qpeg, kpef)
    p = jax.nn.softmax(scores * sm_scale, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, kvf)
    return out.reshape(b, hq, d).astype(out_dtype or q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD chunked linear attention (paper Table 4: chunk_state/chunk_scan)
# ---------------------------------------------------------------------------


def chunk_cumsum(dt: jax.Array, a_log: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """dt (B,H,L), a_log (H,) -> per-chunk cumulative decay dA_cum (B,H,L)."""
    da = dt * (-jnp.exp(a_log))[None, :, None]
    return jnp.cumsum(da, axis=-1), da


def chunk_state(
    b_mat: jax.Array,  # (B, C, L, N)   "B" projections per chunk
    x: jax.Array,  # (B, C, L, P)   values
    da_cum: jax.Array,  # (B, C, L)      cumulative decay within chunk
) -> jax.Array:
    """Per-chunk state: S = sum_l exp(dA_last - dA_l) * B_l^T x_l  -> (B,C,N,P)."""
    decay = jnp.exp(da_cum[..., -1:] - da_cum)  # (B,C,L)
    bw = b_mat.astype(jnp.float32) * decay[..., None]
    return jnp.einsum("bcln,bclp->bcnp", bw, x.astype(jnp.float32))


def chunk_scan(
    c_mat: jax.Array,  # (B, C, L, N)   "C" projections
    b_mat: jax.Array,  # (B, C, L, N)
    x: jax.Array,  # (B, C, L, P)
    da_cum: jax.Array,  # (B, C, L)
    prev_states: jax.Array,  # (B, C, N, P)  inter-chunk states (already recurred)
) -> jax.Array:
    """Within-chunk scan + contribution of the carried state -> (B,C,L,P)."""
    cf = c_mat.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    l = x.shape[2]
    # inter-chunk: y_inter[l] = exp(dA_l) * C_l . S_prev
    y_inter = jnp.einsum("bcln,bcnp->bclp", cf, prev_states) * jnp.exp(da_cum)[..., None]
    # intra-chunk: masked decay attention
    seg = da_cum[..., :, None] - da_cum[..., None, :]  # (B,C,L,L) dA_l - dA_m
    mask = jnp.tril(jnp.ones((l, l), bool))
    att = jnp.einsum("bcln,bcmn->bclm", cf, bf) * jnp.exp(jnp.where(mask, seg, 0.0))
    att = jnp.where(mask, att, 0.0)
    y_intra = jnp.einsum("bclm,bcmp->bclp", att, xf)
    return (y_inter + y_intra).astype(x.dtype)


def state_recurrence(states: jax.Array, da_chunk: jax.Array) -> jax.Array:
    """Carry states across chunks: S'_c = exp(dA_chunk_c) S'_{c-1} + S_c.

    ``states`` (B,C,N,P) are per-chunk local states; ``da_chunk`` (B,C) is the
    total decay of each chunk.  Returns the *incoming* state for each chunk.
    """

    def step(carry, inp):
        s_local, decay = inp
        new = carry * jnp.exp(decay)[..., None, None] + s_local
        return new, carry  # emit the incoming state

    b, c, n, p = states.shape
    xs = (jnp.moveaxis(states, 1, 0), jnp.moveaxis(da_chunk, 1, 0))
    init = jnp.zeros((b, n, p), jnp.float32)
    _, incoming = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(incoming, 0, 1)


def ssd(
    c_mat: jax.Array,  # (B, S, N) shared across heads here; callers vmap heads
    b_mat: jax.Array,
    x: jax.Array,  # (B, S, P)
    dt: jax.Array,  # (B, S)
    a_log: jax.Array,  # scalar per head
    chunk: int = 64,
) -> jax.Array:
    """Full SSD pass (reference composition of the two kernels)."""
    bsz, s, n = c_mat.shape
    p = x.shape[-1]
    nc = s // chunk
    rs = lambda t: t.reshape(bsz, nc, chunk, *t.shape[2:])
    da = dt * (-jnp.exp(a_log))
    da_cum = jnp.cumsum(da.reshape(bsz, nc, chunk), axis=-1)
    states = chunk_state(rs(b_mat), rs(x), da_cum)
    incoming = state_recurrence(states, da_cum[..., -1])
    y = chunk_scan(rs(c_mat), rs(b_mat), rs(x), da_cum, incoming)
    return y.reshape(bsz, s, p)


# ---------------------------------------------------------------------------
# Fused RMSNorm (bonus beyond-paper kernel used by the model layer)
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(x.dtype)
