"""Paged-attention decode in the tile DSL (vLLM-style KV paging).

Single-token decode attention over a **paged KV cache**: keys/values live in
a pool of fixed-size pages (``(kv_heads, num_pages, page_size, head_dim)``)
and each decode slot owns a *block table* mapping its logical KV blocks to
physical pages.  The kernel grid runs over (kv_head, slot) with the KV-block
axis pipelined; each step's K/V windows are gathered **through the block
table** — a ``T.ScalarTensor`` scalar-prefetch param whose elements appear
in the copy-region starts, so the Pallas lowering turns the gather into a
``PrefetchScalarGridSpec`` index map and the DMA pipeline double-buffers
non-contiguous pages exactly like contiguous ones (TileLoom's "plan
dataflow over non-contiguous tiles" as a one-line index change).

Softmax is the shared online-rescaling template (attention_core.py) with a
page-gather KV source and GQA group-major Q packing; ragged sequence
lengths (every slot at its own position) and sliding windows compose the
ragged mask against the ``Lens`` scalar tensor.  Entries of the block
table beyond a slot's live length must still hold *valid* page ids (the
pool DMAs them regardless; masking kills their contribution) — the
serving engine pads tables with page 0.
"""

import math
from typing import Optional

from repro.core import TileProgram
from repro.core import lang as T

from . import attention_core as AC


def paged_attention_program(
    slots: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    window: Optional[int] = None,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
) -> TileProgram:
    if heads % kv_heads:
        raise ValueError("GQA requires heads % kv_heads == 0")
    group = heads // kv_heads
    scale = (sm_scale if sm_scale is not None else 1.0 / math.sqrt(head_dim)) * 1.44269504  # log2(e)

    @T.prim_func
    def PagedAttn(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Lens: T.ScalarTensor((slots,), "int32"),
        Q: T.Tensor((slots, heads, head_dim), dtype),
        KPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        VPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        Output: T.Tensor((slots, heads, head_dim), dtype),
    ):
        with T.Kernel(kv_heads, slots) as (bh, bz):
            Q_shared = T.alloc_shared((group, head_dim), dtype)
            K_shared = T.alloc_shared((page_size, head_dim), dtype)
            V_shared = T.alloc_shared((page_size, head_dim), dtype)
            acc_s = T.alloc_fragment((group, page_size), accum_dtype)
            # safe_div: empty slots (len 0) divide by the floor -> zeros
            ons = AC.OnlineSoftmax(group, head_dim, scale, accum_dtype,
                                   safe_div=True)

            T.copy(Q[bz, bh * group, 0], Q_shared)

            def load_kv(k):
                # the paged gather: page index loaded from the block table
                T.copy(KPages[bh, Tables[bz, k], 0, 0], K_shared)
                T.copy(VPages[bh, Tables[bz, k], 0, 0], V_shared)
                return K_shared, V_shared

            # ragged mask: this slot's live KV positions are
            # [max(0, len-window), len) — everything else (tail of the
            # last page, table padding) contributes nothing.
            def mask(k):
                return AC.ragged(Lens[bz], lambda j: k * page_size + j, window)

            AC.attend(
                ons, acc_s, page_size, max_pages, load_kv,
                lambda s, ks, k: AC.scores(s, Q_shared, ks), mask,
                num_stages=num_stages,
            )
            ons.finalize(Output[bz, bh * group, 0])

    return PagedAttn


def paged_attention_quant_program(
    slots: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    fmt: str = "int8",
    window: Optional[int] = None,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
) -> TileProgram:
    """Quantized paged decode: the fp kernel with ``load_kv`` routed through
    the :class:`attention_core.DequantStage` composition point.  Pages hold
    packed int8 K/V (``head_dim // pack`` bytes per token) plus a per-token
    scale column; the unpack+scale runs on the VPU between the page DMA and
    the score GEMM.  Everything else — grid, masks, online softmax — is the
    fp kernel unchanged."""
    if heads % kv_heads:
        raise ValueError("GQA requires heads % kv_heads == 0")
    group = heads // kv_heads
    pack = AC.KV_PACK[fmt]
    scale = (sm_scale if sm_scale is not None else 1.0 / math.sqrt(head_dim)) * 1.44269504  # log2(e)

    @T.prim_func
    def PagedAttnQuant(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Lens: T.ScalarTensor((slots,), "int32"),
        Q: T.Tensor((slots, heads, head_dim), dtype),
        KPages: T.Tensor((kv_heads, num_pages, page_size, head_dim // pack), "int8"),
        VPages: T.Tensor((kv_heads, num_pages, page_size, head_dim // pack), "int8"),
        KScales: T.Tensor((kv_heads, num_pages, page_size, 1), dtype),
        VScales: T.Tensor((kv_heads, num_pages, page_size, 1), dtype),
        Output: T.Tensor((slots, heads, head_dim), dtype),
    ):
        with T.Kernel(kv_heads, slots) as (bh, bz):
            Q_shared = T.alloc_shared((group, head_dim), dtype)
            kq = AC.DequantStage(page_size, head_dim, fmt, dtype)
            vq = AC.DequantStage(page_size, head_dim, fmt, dtype)
            acc_s = T.alloc_fragment((group, page_size), accum_dtype)
            ons = AC.OnlineSoftmax(group, head_dim, scale, accum_dtype,
                                   safe_div=True)

            T.copy(Q[bz, bh * group, 0], Q_shared)

            def load_kv(k):
                # paged gather + inline dequant (page index from the table)
                ks = kq.load(KPages[bh, Tables[bz, k], 0, 0],
                             KScales[bh, Tables[bz, k], 0, 0])
                vs = vq.load(VPages[bh, Tables[bz, k], 0, 0],
                             VScales[bh, Tables[bz, k], 0, 0])
                return ks, vs

            def mask(k):
                return AC.ragged(Lens[bz], lambda j: k * page_size + j, window)

            AC.attend(
                ons, acc_s, page_size, max_pages, load_kv,
                lambda s, ks, k: AC.scores(s, Q_shared, ks), mask,
                num_stages=num_stages,
            )
            ons.finalize(Output[bz, bh * group, 0])

    return PagedAttnQuant


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py); covers GQA + MQA head groupings, a sliding
# window, and the ragged case (block tables of different live lengths per
# slot — exercised through the input override below).  The _quant cases run
# the same shapes through the DequantStage KV source (int8 and the packed
# int4 sub-byte unpack).
PARITY_CASES = [
    (
        "paged_attention_mqa",
        dict(slots=2, heads=2, kv_heads=1, head_dim=16, page_size=16,
             max_pages=2, num_pages=4),
    ),
    (
        "paged_attention_gqa_ragged",
        dict(slots=3, heads=4, kv_heads=2, head_dim=16, page_size=16,
             max_pages=2, num_pages=8),
    ),
    (
        "paged_attention_windowed",
        dict(slots=2, heads=2, kv_heads=2, head_dim=16, page_size=16,
             max_pages=2, num_pages=4, window=12),
    ),
    (
        "paged_attention_quant_int8",
        dict(slots=3, heads=4, kv_heads=2, head_dim=16, page_size=16,
             max_pages=2, num_pages=8, fmt="int8"),
    ),
    (
        "paged_attention_quant_int4",
        dict(slots=2, heads=2, kv_heads=1, head_dim=16, page_size=16,
             max_pages=2, num_pages=4, fmt="int4"),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        maker = paged_attention_quant_program if "quant" in name else paged_attention_program
        yield name, maker(**cfg)


def parity_inputs(name, program, rng):
    """Valid inputs for the parity suite: block tables must hold live page
    ids and lens must be in range — random bytes won't do.  Tables are drawn
    without replacement (each physical page owned by one slot) and lens are
    ragged: every slot at a different fill level, including a partial page.
    Quantized cases get full-range packed bytes and positive scales.
    """
    cfg = dict(PARITY_CASES)[name]
    slots, mp, np_ = cfg["slots"], cfg["max_pages"], cfg["num_pages"]
    pages = rng.permutation(np_)[: slots * mp].reshape(slots, mp).astype("int32")
    max_len = mp * cfg["page_size"]
    lens = (rng.integers(1, max_len + 1, size=slots)).astype("int32")
    args = [pages, lens]
    for p in program.input_params()[2:]:
        if str(p.dtype).startswith("int"):
            args.append(rng.integers(-128, 128, size=p.shape).astype(p.dtype))
        elif p.name.endswith("Scales"):
            args.append(rng.uniform(0.05, 0.2, size=p.shape).astype(p.dtype))
        else:
            args.append(rng.standard_normal(p.shape).astype(p.dtype))
    return args
