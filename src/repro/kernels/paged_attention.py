"""Paged-attention decode in the tile DSL (vLLM-style KV paging).

Single-token decode attention over a **paged KV cache**: keys/values live in
a pool of fixed-size pages (``(kv_heads, num_pages, page_size, head_dim)``)
and each decode slot owns a *block table* mapping its logical KV blocks to
physical pages.  The kernel grid runs over (kv_head, slot) with the KV-block
axis pipelined; each step's K/V windows are gathered **through the block
table** — a ``T.ScalarTensor`` scalar-prefetch param whose elements appear
in the copy-region starts, so the Pallas lowering turns the gather into a
``PrefetchScalarGridSpec`` index map and the DMA pipeline double-buffers
non-contiguous pages exactly like contiguous ones (TileLoom's "plan
dataflow over non-contiguous tiles" as a one-line index change).

Softmax is the shared online-rescaling template (attention_core.py) with a
page-gather KV source and GQA group-major Q packing; ragged sequence
lengths (every slot at its own position) and sliding windows compose the
ragged mask against the ``Lens`` scalar tensor.  Entries of the block
table beyond a slot's live length must still hold *valid* page ids (the
pool DMAs them regardless; masking kills their contribution) — the
serving engine pads tables with page 0.
"""

import math
from typing import Optional

from repro.core import TileProgram
from repro.core import lang as T

from . import attention_core as AC


def paged_attention_program(
    slots: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    window: Optional[int] = None,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
) -> TileProgram:
    if heads % kv_heads:
        raise ValueError("GQA requires heads % kv_heads == 0")
    group = heads // kv_heads
    scale = (sm_scale if sm_scale is not None else 1.0 / math.sqrt(head_dim)) * 1.44269504  # log2(e)

    @T.prim_func
    def PagedAttn(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Lens: T.ScalarTensor((slots,), "int32"),
        Q: T.Tensor((slots, heads, head_dim), dtype),
        KPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        VPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        Output: T.Tensor((slots, heads, head_dim), dtype),
    ):
        with T.Kernel(kv_heads, slots) as (bh, bz):
            Q_shared = T.alloc_shared((group, head_dim), dtype)
            K_shared = T.alloc_shared((page_size, head_dim), dtype)
            V_shared = T.alloc_shared((page_size, head_dim), dtype)
            acc_s = T.alloc_fragment((group, page_size), accum_dtype)
            # safe_div: empty slots (len 0) divide by the floor -> zeros
            ons = AC.OnlineSoftmax(group, head_dim, scale, accum_dtype,
                                   safe_div=True)

            T.copy(Q[bz, bh * group, 0], Q_shared)

            def load_kv(k):
                # the paged gather: page index loaded from the block table
                T.copy(KPages[bh, Tables[bz, k], 0, 0], K_shared)
                T.copy(VPages[bh, Tables[bz, k], 0, 0], V_shared)
                return K_shared, V_shared

            # ragged mask: this slot's live KV positions are
            # [max(0, len-window), len) — everything else (tail of the
            # last page, table padding) contributes nothing.
            def mask(k):
                return AC.ragged(Lens[bz], lambda j: k * page_size + j, window)

            AC.attend(
                ons, acc_s, page_size, max_pages, load_kv,
                lambda s, ks, k: AC.scores(s, Q_shared, ks), mask,
                num_stages=num_stages,
            )
            ons.finalize(Output[bz, bh * group, 0])

    return PagedAttn


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py); covers GQA + MQA head groupings, a sliding
# window, and the ragged case (block tables of different live lengths per
# slot — exercised through the input override below).
PARITY_CASES = [
    (
        "paged_attention_mqa",
        dict(slots=2, heads=2, kv_heads=1, head_dim=16, page_size=16,
             max_pages=2, num_pages=4),
    ),
    (
        "paged_attention_gqa_ragged",
        dict(slots=3, heads=4, kv_heads=2, head_dim=16, page_size=16,
             max_pages=2, num_pages=8),
    ),
    (
        "paged_attention_windowed",
        dict(slots=2, heads=2, kv_heads=2, head_dim=16, page_size=16,
             max_pages=2, num_pages=4, window=12),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        yield name, paged_attention_program(**cfg)


def parity_inputs(name, program, rng):
    """Valid inputs for the parity suite: block tables must hold live page
    ids and lens must be in range — random bytes won't do.  Tables are drawn
    without replacement (each physical page owned by one slot) and lens are
    ragged: every slot at a different fill level, including a partial page.
    """
    cfg = dict(PARITY_CASES)[name]
    slots, mp, np_ = cfg["slots"], cfg["max_pages"], cfg["num_pages"]
    pages = rng.permutation(np_)[: slots * mp].reshape(slots, mp).astype("int32")
    max_len = mp * cfg["page_size"]
    lens = (rng.integers(1, max_len + 1, size=slots)).astype("int32")
    args = [pages, lens]
    for p in program.input_params()[2:]:
        args.append(rng.standard_normal(p.shape).astype(p.dtype))
    return args
