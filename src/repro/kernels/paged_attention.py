"""Paged-attention decode in the tile DSL (vLLM-style KV paging).

Single-token decode attention over a **paged KV cache**: keys/values live in
a pool of fixed-size pages (``(kv_heads, num_pages, page_size, head_dim)``)
and each decode slot owns a *block table* mapping its logical KV blocks to
physical pages.  The kernel grid runs over (kv_head, slot) with the KV-block
axis pipelined; each step's K/V windows are gathered **through the block
table** — a ``T.ScalarTensor`` scalar-prefetch param whose elements appear
in the copy-region starts, so the Pallas lowering turns the gather into a
``PrefetchScalarGridSpec`` index map and the DMA pipeline double-buffers
non-contiguous pages exactly like contiguous ones (TileLoom's "plan
dataflow over non-contiguous tiles" as a one-line index change).

Softmax is the same online-rescaling loop as flash_attention.py; ragged
sequence lengths (every slot at its own position) and sliding windows are
masked per element against the ``Lens`` scalar tensor.  Entries of the
block table beyond a slot's live length must still hold *valid* page ids
(the pool DMAs them regardless; masking kills their contribution) — the
serving engine pads tables with page 0.
"""

import math
from typing import Optional

from repro.core import TileProgram
from repro.core import lang as T


def paged_attention_program(
    slots: int,
    heads: int,
    kv_heads: int,
    head_dim: int,
    page_size: int,
    max_pages: int,
    num_pages: int,
    window: Optional[int] = None,
    dtype: str = "float32",
    accum_dtype: str = "float32",
    num_stages: int = 2,
    sm_scale: Optional[float] = None,
) -> TileProgram:
    if heads % kv_heads:
        raise ValueError("GQA requires heads % kv_heads == 0")
    group = heads // kv_heads
    scale = (sm_scale if sm_scale is not None else 1.0 / math.sqrt(head_dim)) * 1.44269504  # log2(e)

    @T.prim_func
    def PagedAttn(
        Tables: T.ScalarTensor((slots, max_pages), "int32"),
        Lens: T.ScalarTensor((slots,), "int32"),
        Q: T.Tensor((slots, heads, head_dim), dtype),
        KPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        VPages: T.Tensor((kv_heads, num_pages, page_size, head_dim), dtype),
        Output: T.Tensor((slots, heads, head_dim), dtype),
    ):
        with T.Kernel(kv_heads, slots) as (bh, bz):
            Q_shared = T.alloc_shared((group, head_dim), dtype)
            K_shared = T.alloc_shared((page_size, head_dim), dtype)
            V_shared = T.alloc_shared((page_size, head_dim), dtype)
            acc_s = T.alloc_fragment((group, page_size), accum_dtype)
            acc_o = T.alloc_fragment((group, head_dim), accum_dtype)
            scores_max = T.alloc_fragment((group,), accum_dtype)
            scores_max_prev = T.alloc_fragment((group,), accum_dtype)
            scores_scale = T.alloc_fragment((group,), accum_dtype)
            scores_sum = T.alloc_fragment((group,), accum_dtype)
            logsum = T.alloc_fragment((group,), accum_dtype)

            T.copy(Q[bz, bh * group, 0], Q_shared)
            T.fill(acc_o, 0.0)
            T.fill(logsum, 0.0)
            T.fill(scores_max, -T.infinity(accum_dtype))

            for k in T.Pipelined(max_pages, num_stages=num_stages):
                # the paged gather: page index loaded from the block table
                T.copy(KPages[bh, Tables[bz, k], 0, 0], K_shared)
                T.copy(VPages[bh, Tables[bz, k], 0, 0], V_shared)
                T.clear(acc_s)
                T.gemm(Q_shared, K_shared, acc_s, transpose_B=True)
                # ragged mask: this slot's live KV positions are
                # [max(0, len-window), len) — everything else (tail of the
                # last page, table padding) contributes nothing.
                for i, j in T.Parallel(group, page_size):
                    valid = (k * page_size + j) < Lens[bz]
                    if window is not None:
                        valid = valid & (
                            (k * page_size + j) >= (Lens[bz] - window)
                        )
                    acc_s[i, j] = T.if_then_else(
                        valid, acc_s[i, j], -T.infinity(accum_dtype)
                    )
                T.copy(scores_max, scores_max_prev)
                T.reduce_max(acc_s, scores_max, dim=1, clear=False)
                # Clamp before differencing: fully-masked pages leave the
                # running max at -inf and (-inf) - (-inf) = nan.
                neg_clamp = -1048576.0  # -2^20; exp2 underflows long before
                for i in T.Parallel(group):
                    scores_scale[i] = T.exp2(
                        T.maximum(scores_max_prev[i], neg_clamp) * scale
                        - T.maximum(scores_max[i], neg_clamp) * scale
                    )
                for i, j in T.Parallel(group, page_size):
                    acc_s[i, j] = T.exp2(
                        acc_s[i, j] * scale
                        - T.maximum(scores_max[i], neg_clamp) * scale
                    )
                T.reduce_sum(acc_s, scores_sum, dim=1)
                for i in T.Parallel(group):
                    logsum[i] = logsum[i] * scores_scale[i] + scores_sum[i]
                for i, j in T.Parallel(group, head_dim):
                    acc_o[i, j] = acc_o[i, j] * scores_scale[i]
                T.gemm(acc_s, V_shared, acc_o)

            # empty slots (len 0) divide by the floor and emit zeros, not nan
            for i, j in T.Parallel(group, head_dim):
                acc_o[i, j] = acc_o[i, j] / T.maximum(logsum[i], 1e-30)
            T.copy(acc_o, Output[bz, bh * group, 0])

    return PagedAttn


# Tiny-shape configs for the pallas-vs-reference parity suite
# (tests/test_pipeline.py); covers GQA + MQA head groupings, a sliding
# window, and the ragged case (block tables of different live lengths per
# slot — exercised through the input override below).
PARITY_CASES = [
    (
        "paged_attention_mqa",
        dict(slots=2, heads=2, kv_heads=1, head_dim=16, page_size=16,
             max_pages=2, num_pages=4),
    ),
    (
        "paged_attention_gqa_ragged",
        dict(slots=3, heads=4, kv_heads=2, head_dim=16, page_size=16,
             max_pages=2, num_pages=8),
    ),
    (
        "paged_attention_windowed",
        dict(slots=2, heads=2, kv_heads=2, head_dim=16, page_size=16,
             max_pages=2, num_pages=4, window=12),
    ),
]


def parity_programs():
    for name, cfg in PARITY_CASES:
        yield name, paged_attention_program(**cfg)


def parity_inputs(name, program, rng):
    """Valid inputs for the parity suite: block tables must hold live page
    ids and lens must be in range — random bytes won't do.  Tables are drawn
    without replacement (each physical page owned by one slot) and lens are
    ragged: every slot at a different fill level, including a partial page.
    """
    cfg = dict(PARITY_CASES)[name]
    slots, mp, np_ = cfg["slots"], cfg["max_pages"], cfg["num_pages"]
    pages = rng.permutation(np_)[: slots * mp].reshape(slots, mp).astype("int32")
    max_len = mp * cfg["page_size"]
    lens = (rng.integers(1, max_len + 1, size=slots)).astype("int32")
    args = [pages, lens]
    for p in program.input_params()[2:]:
        args.append(rng.standard_normal(p.shape).astype(p.dtype))
    return args
